"""The adaptation experiment: DFRS scheduling a TPU-pod job mix.

Job types are derived from the dry-run roofline artifacts (a bandwidth-bound
decode job cannot use the MXU fraction a trainer can — the paper's
fractional-use phenomenon, measured rather than assumed).  DFRS is compared
against EASY on max bounded stretch and underutilization, closing the loop
between the paper's claim and this framework's own workloads."""
from __future__ import annotations

import numpy as np

from repro.api import SimParams, max_stretch_lower_bound, simulate
from repro.workloads.jobgen import tpu_job_types, tpu_trace

from .common import BEST_POLICIES, Bench, fmt_table, write_csv
from .roofline import jobgen_records


def run(bench: Bench, verbose: bool = True):
    recs = jobgen_records("single")
    if not recs:
        if verbose:
            print("== TPU cluster bench: no dry-run artifacts yet; run "
                  "`python -m repro.launch.dryrun --all` first ==")
        return [], {}
    types = tpu_job_types(recs, chips_per_task=16)
    rows = []
    pols = ["FCFS", "EASY"] + BEST_POLICIES
    stats = {p: [] for p in pols}
    for seed in range(bench.scale.n_traces):
        specs = tpu_trace(types, n_jobs=bench.scale.n_jobs // 2,
                          n_nodes=64, seed=seed, target_load=0.6)
        lb = max_stretch_lower_bound(specs, 64)
        for p in pols:
            r = simulate(specs, p, SimParams(n_nodes=64))
            stats[p].append((r.max_stretch / lb, r.underutilization))
    for p in pols:
        a = np.array(stats[p])
        rows.append([p, round(float(a[:, 0].mean()), 1),
                     round(float(a[:, 0].max()), 1),
                     round(float(a[:, 1].mean()), 3)])
    header = ["policy", "degr_avg", "degr_max", "underut_avg"]
    write_csv("tpu_cluster.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows,
                        f"TPU job mix ({len(types)} job types from dry-run)"))
    by = {r[0]: r for r in rows}
    best = min(BEST_POLICIES, key=lambda p: by[p][1])
    claims = {
        "DFRS >= 5x better stretch than EASY on the TPU mix":
            by[best][1] * 5 <= by["EASY"][1],
    }
    if verbose:
        for k, v in claims.items():
            print(f"  claim: {k}: {'PASS' if v else 'FAIL'}")
    return rows, claims
