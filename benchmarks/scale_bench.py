"""Million-job scale benchmark → machine-readable BENCH_scale.json.

Measures the three claims of the O(active)-engine work at trace scales the
materialized path cannot reach:

* **bounded RSS** — a synthetic Standard-Workload-Format log is *generated
  line by line* (never held in memory), streamed through the ``swf-stream``
  workload kind into a compacting :class:`SimSession`, and the process
  RSS ceiling (``ru_maxrss``) plus the engine's peak row capacity are
  recorded per scale;
* **throughput** — events/s at each scale, so per-event cost degrading
  with *total* jobs (rather than *active* jobs) shows up as a falling
  curve across 10^4 → 10^5 → 10^6;
* **parity** — at the overlap scale the streamed + compacted run must
  produce a ``SimResult`` *bit-identical* to the submit-everything-upfront,
  never-compacted oracle (the same discipline as ``alloc_reference``).

CLI (used by the CI scale-smoke job)::

    PYTHONPATH=src python -m benchmarks.scale_bench --scales 1e4,1e5 \
        --rss-cap-mb 1500

Exits non-zero on a parity mismatch or a blown RSS cap only — wall time is
recorded, never gated (throttled-box convention).  ``--full`` adds the
10^6-job scale.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import resource
import sys
import tempfile
import time
from typing import List, Optional

import numpy as np

from repro.api import open_session
from repro.sched.engine import SimParams
from repro.workloads.hpc2n import NODE_MEM_GB
from repro.workloads.registry import WorkloadSpec, make_trace_ir, stream_trace

BENCH_JSON = "BENCH_scale.json"
POLICY = "FCFS"
N_NODES = 64
COMPACT_INTERVAL = 4096
PARITY_JOBS = 20_000

# synthetic-log shape: mean work per job after §5.3.1 preprocessing is
# 0.5 * E[procs] * E[run] ~ 0.5 * 16.5 * 3030 ~ 25k cpu-s, so a mean gap of
# 800 s offers ~0.5 load to the 64-node cluster — stable, which is what
# keeps the *active* set (and therefore per-event cost) bounded
MEAN_GAP_S = 800.0
RUN_RANGE_S = (60.0, 6000.0)
WINDOW_S = 4 * 86_400.0   # ~430 jobs per streamed chunk


def generate_swf(path: str, n_jobs: int, seed: int = 0,
                 chunk: int = 50_000) -> None:
    """Write ``n_jobs`` synthetic swf rows to ``path``, ``chunk`` rows of
    state at a time — generation itself is memory-bounded."""
    rng = np.random.default_rng(seed)
    node_kb = NODE_MEM_GB * 1024 * 1024
    t = 0.0
    with open(path, "w") as fh:
        fh.write(f"; synthetic scale-bench log: {n_jobs} jobs, seed {seed}\n")
        jid = 0
        while jid < n_jobs:
            m = min(chunk, n_jobs - jid)
            gaps = rng.exponential(MEAN_GAP_S, size=m)
            runs = rng.uniform(*RUN_RANGE_S, size=m)
            procs = rng.integers(1, 33, size=m)
            mems = rng.uniform(0.05, 0.45, size=m) * node_kb
            for g, run, p, mem in zip(gaps, runs, procs, mems):
                t += float(g)
                f = ["-1"] * 18
                f[0] = str(jid + 1)
                f[1] = f"{t:.1f}"
                f[3] = f"{run:.1f}"
                f[4] = str(int(p))
                f[6] = f"{mem:.0f}"
                fh.write(" ".join(f) + "\n")
                jid += 1


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run_scale(path: str, n_jobs: int, window_s: float = WINDOW_S) -> dict:
    """Stream ``path`` through a compacting session; record RSS + events/s."""
    wspec = WorkloadSpec("swf-stream", n_jobs=n_jobs, n_nodes=N_NODES,
                         params={"path": path, "window": window_s})
    ses = open_session(SimParams(n_nodes=N_NODES,
                                 compact_interval=COMPACT_INTERVAL), POLICY)
    st = ses.engine.state
    peak = {"capacity": 0, "live": 0}

    def watched():
        for ch in stream_trace(wspec):
            peak["capacity"] = max(peak["capacity"], st.capacity)
            peak["live"] = max(peak["live"], len(st.specs))
            yield ch

    t0 = time.perf_counter()
    ses.stream(watched())
    wall = time.perf_counter() - t0
    peak["capacity"] = max(peak["capacity"], st.capacity)
    r = ses.result(light=True)
    return {
        "n_jobs": n_jobs,
        "events": r.events,
        "wall_s": round(wall, 2),
        "events_per_sec": round(r.events / max(wall, 1e-9), 1),
        "ru_maxrss_mb": round(_rss_mb(), 1),
        "peak_row_capacity": int(peak["capacity"]),
        "peak_live_rows": int(peak["live"]),
        "final_live_rows": len(st.specs),
        "retired_rows": len(st.retired),
        "grow_count": st.grow_count,
        "mean_stretch": r.mean_stretch,
        "makespan": r.makespan,
    }


def run_parity(path: str, n_jobs: int = PARITY_JOBS) -> dict:
    """Streamed + compacted vs upfront + uncompacted: exact SimResult
    equality at a scale the materialized path still handles comfortably."""
    import dataclasses

    w_mat = WorkloadSpec("swf", n_jobs=n_jobs, n_nodes=N_NODES,
                         params={"path": path})
    w_str = WorkloadSpec("swf-stream", n_jobs=n_jobs, n_nodes=N_NODES,
                         params={"path": path, "window": WINDOW_S})

    s_ref = open_session(SimParams(n_nodes=N_NODES), POLICY)
    s_ref.submit(make_trace_ir(w_mat))
    r_ref = s_ref.run()

    s_cmp = open_session(SimParams(n_nodes=N_NODES,
                                   compact_interval=1000), POLICY)
    s_cmp.stream(stream_trace(w_str))
    r_cmp = s_cmp.result()

    ok = r_ref == r_cmp
    diff: List[str] = []
    if not ok:
        a, b = dataclasses.asdict(r_ref), dataclasses.asdict(r_cmp)
        diff = [k for k in a if a[k] != b[k] and k != "sim_wall_s"]
    return {"n_jobs": n_jobs, "ok": bool(ok), "diverging_fields": diff}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scales", default="1e4,1e5",
                    help="comma-separated job counts (default 1e4,1e5)")
    ap.add_argument("--full", action="store_true",
                    help="append the 10^6-job scale")
    ap.add_argument("--rss-cap-mb", type=float, default=None,
                    help="fail if ru_maxrss exceeds this after any scale")
    ap.add_argument("--swf", default=None, metavar="PATH",
                    help="use this (submit-sorted) real swf log instead of "
                         "the synthetic generator")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=BENCH_JSON)
    args = ap.parse_args()

    scales = sorted({int(float(s)) for s in args.scales.split(",") if s})
    if args.full:
        scales.append(1_000_000)

    tmp: Optional[str] = None
    if args.swf:
        path = args.swf
    else:
        fd, tmp = tempfile.mkstemp(suffix=".swf", prefix="scale_bench_")
        os.close(fd)
        path = tmp
        generate_swf(path, max(scales + [PARITY_JOBS]), seed=args.seed)

    try:
        results = []
        for n in scales:
            row = run_scale(path, n)
            results.append(row)
            print(f"  {n:>9,} jobs: {row['events_per_sec']:>8,.0f} ev/s  "
                  f"rss {row['ru_maxrss_mb']:.0f} MB  "
                  f"peak capacity {row['peak_row_capacity']:,} rows",
                  flush=True)
        parity = run_parity(path)
        verdict = ("OK" if parity["ok"]
                   else f"MISMATCH {parity['diverging_fields']}")
        print(f"  parity @ {parity['n_jobs']:,} jobs: {verdict}")
    finally:
        if tmp:
            os.unlink(tmp)

    payload = {
        "bench": "scale",
        "config": {"policy": POLICY, "n_nodes": N_NODES,
                   "compact_interval": COMPACT_INTERVAL,
                   "swf": args.swf or "synthetic", "seed": args.seed},
        "scales": results,
        "parity": parity,
        "rss_cap_mb": args.rss_cap_mb,
        "platform": platform.platform(),
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"  -> {args.out}")

    if not parity["ok"]:
        print("PARITY MISMATCH: streamed+compacted diverges from the "
              f"upfront oracle in {parity['diverging_fields']}",
              file=sys.stderr)
        return 1
    if args.rss_cap_mb is not None:
        worst = max(r["ru_maxrss_mb"] for r in results)
        if worst > args.rss_cap_mb:
            print(f"RSS CAP BLOWN: {worst:.0f} MB > "
                  f"{args.rss_cap_mb:.0f} MB cap", file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
