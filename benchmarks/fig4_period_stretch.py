"""Figure 4: max-stretch degradation vs MCB8 period (robustness claim:
a 20x period increase costs < ~3x stretch while underutilization improves).

Cells come from the shared ``Bench.sweep`` cache; the scaled-trace period
sweep is the same cell set figure 3 uses, so it simulates nothing new when
run after table 4.
"""
from __future__ import annotations

import numpy as np

from .common import BEST_POLICIES, Bench, fmt_table, records_for, write_csv


def run(bench: Bench, verbose: bool = True):
    pol = BEST_POLICIES[1]
    workloads = (bench.workloads("real") + bench.workloads("unscaled")
                 + bench.workloads("scaled"))
    records = bench.sweep(workloads, [pol], periods=bench.scale.periods)
    rows = []
    for period in bench.scale.periods:
        row = [int(period)]
        for kind in ("real", "unscaled", "scaled"):
            d = np.array([r["degradation"]
                          for r in records_for(records, kind,
                                               period=period)])
            row.append(round(float(d.mean()), 1))
        rows.append(row)
    header = ["period_s", "real", "unscaled", "scaled"]
    write_csv("fig4_stretch_vs_period.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows, f"Figure 4: stretch vs period ({pol})"))
    growth = rows[-1][3] / max(rows[0][3], 1e-9)
    claims = {
        f"{bench.scale.periods[-1]/600:.0f}x period costs <=4x stretch (scaled)":
            growth <= 4.0,
    }
    if verbose:
        for k, v in claims.items():
            print(f"  claim: {k}: {'PASS' if v else 'FAIL'} (growth {growth:.2f}x)")
    return rows, claims
