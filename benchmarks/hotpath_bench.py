"""Allocation hot-path benchmark → machine-readable BENCH_hotpath.json.

Profiles the vectorized allocation/placement microkernels (§4.6 maxmin /
avg yields, §4.2 greedy placement, §4.3 MCB8 packing) against the
pre-vectorization reference implementations on a deterministic fixture, and
times end-to-end ``GreedyPM */per/OPT=MIN/MINVT=600`` simulation cells —
the migration-heavy cells that dominated ``BENCH_sweep.json``.  Extends the
perf trajectory started by the sweep bench with per-kernel numbers.

CLI (used by the CI perf-smoke job)::

    PYTHONPATH=src python -m benchmarks.hotpath_bench --jobs 120 \
        --check-baseline benchmarks/hotpath_baseline.json

``--check-baseline`` exits non-zero when any end-to-end GreedyPM cell is
more than ``--max-regression`` (default 2.0) times slower than the
checked-in baseline.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core import alloc_reference
from repro.core.alloc_kernels import (avg_yields_csr, build_csr,
                                      maxmin_yields_csr, reference_kernels)
from repro.core.greedy import greedy_place
from repro.core.job import JobState, NodePool
from repro.core.mcb8 import mcb8
from repro.core.yield_alloc import avg_yields, maxmin_yields
from repro.sched.engine import Engine, SimParams
from repro.sched.scenarios import apply_scenario
from repro.workloads.registry import WorkloadSpec, make_trace

from .common import Bench, fmt_table

BENCH_JSON = "BENCH_hotpath.json"
GREEDYPM = "GreedyPM */per/OPT=MIN/MINVT=600"


# --------------------------------------------------------------------------- #
# fixtures                                                                     #
# --------------------------------------------------------------------------- #
def _alloc_fixture(n_jobs: int, n_nodes: int, seed: int = 0):
    """A saturated running set: greedy-place a Lublin job mix until full."""
    trace = make_trace(WorkloadSpec("lublin", n_jobs=n_jobs,
                                    n_nodes=n_nodes, seed=seed))
    pool = NodePool(n_nodes)
    specs, maps = [], []
    for s in trace:
        m = greedy_place(pool, s)
        if m is not None:
            specs.append(s)
            maps.append(m)
    return specs, maps, n_nodes


def _mcb8_fixture(n_jobs: int, n_nodes: int, seed: int = 0):
    trace = make_trace(WorkloadSpec("lublin", n_jobs=n_jobs,
                                    n_nodes=n_nodes, seed=seed))
    rng = np.random.default_rng(seed)
    states = []
    for s in trace:
        js = JobState(spec=s)
        js.vt = float(rng.uniform(1.0, 1000.0))
        states.append(js)
    return states, n_nodes


def _time(fn: Callable[[], object], repeats: int) -> float:
    """Mean seconds per call over ``repeats`` calls (after one warm-up)."""
    fn()
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats


def _jax_kernels(specs, maps, n_nodes: int, repeats: int) -> Optional[dict]:
    """Warm jitted JAX timings for the allocation kernels vs numpy.

    A separate payload section (``jax_kernels``) so the regression-gated
    ``kernels`` / ``e2e_greedypm_wall_s`` keys are untouched; returns None
    (section omitted) when jax is not installed.  ``_time`` warms each
    callable once before measuring, so the jitted numbers exclude compile.
    """
    try:
        from repro.core import alloc_jax
    except Exception:  # noqa: BLE001 — optional accelerator dep
        return None
    if not alloc_jax.has_jax():
        return None

    inc = build_csr([s.cpu_need for s in specs], maps, n_nodes)
    active = np.ones(inc.width, dtype=bool)
    cols = np.nonzero(active)[0].astype(np.int64)
    out: Dict[str, Dict[str, float]] = {}

    def entry(name: str, jax_fn, np_fn, per: int = 1) -> None:
        t_jax = _time(jax_fn, repeats) / per
        t_np = _time(np_fn, repeats)
        out[name] = {
            "jax_mean_us": round(t_jax * 1e6, 1),
            "numpy_mean_us": round(t_np * 1e6, 1),
            "jax_over_numpy": round(t_jax / max(t_np, 1e-12), 2),
        }

    entry("maxmin_single",
          lambda: alloc_jax.maxmin_yields_jax(inc, active),
          lambda: maxmin_yields_csr(inc, active))
    B = 16  # batched water-filling, reported per cell vs one numpy solve
    present, weight, act = alloc_jax.pad_batch([inc] * B, [active] * B)
    entry("maxmin_batch16_per_cell",
          lambda: alloc_jax.maxmin_yields_batch(present, weight, act),
          lambda: maxmin_yields_csr(inc, active), per=B)
    backend = alloc_jax.JaxAllocBackend()
    entry("avg",
          lambda: backend.allocate(inc, cols, "AVG"),
          lambda: avg_yields_csr(inc, cols))
    out["maxmin_single"]["bit_equal"] = bool(np.array_equal(
        alloc_jax.maxmin_yields_jax(inc, active), maxmin_yields_csr(inc, active)))
    return out


# --------------------------------------------------------------------------- #
# bench                                                                        #
# --------------------------------------------------------------------------- #
def run(bench: Bench, verbose: bool = True,
        n_jobs: Optional[int] = None, repeats: int = 5) -> dict:
    n_jobs = n_jobs or bench.scale.n_jobs
    n_nodes = bench.scale.n_nodes

    specs, maps, nn = _alloc_fixture(n_jobs, n_nodes)
    states, mn = _mcb8_fixture(n_jobs, 2 * n_nodes)
    place_trace = make_trace(WorkloadSpec("lublin", n_jobs=n_jobs,
                                          n_nodes=n_nodes, seed=1))

    def place_all() -> None:
        pool = NodePool(n_nodes)
        for s in place_trace:
            greedy_place(pool, s)

    def place_all_ref() -> None:
        pool = NodePool(n_nodes)
        for s in place_trace:
            alloc_reference.greedy_place(pool, s)

    kernels: Dict[str, Dict[str, float]] = {}

    def kernel(name: str, fast: Callable[[], object],
               ref: Callable[[], object]) -> None:
        t_fast = _time(fast, repeats)
        t_ref = _time(ref, repeats)
        kernels[name] = {
            "mean_us": round(t_fast * 1e6, 1),
            "ref_mean_us": round(t_ref * 1e6, 1),
            "speedup": round(t_ref / max(t_fast, 1e-12), 2),
        }

    kernel("maxmin_yields",
           lambda: maxmin_yields(specs, maps, nn),
           lambda: alloc_reference.maxmin_yields(specs, maps, nn))
    kernel("avg_yields",
           lambda: avg_yields(specs, maps, nn),
           lambda: alloc_reference.avg_yields(specs, maps, nn))
    kernel("greedy_place_trace", place_all, place_all_ref)

    def mcb8_ref() -> None:
        with reference_kernels():
            mcb8(states, mn, now=2000.0)

    kernel("mcb8", lambda: mcb8(states, mn, now=2000.0), mcb8_ref)

    # ---- end-to-end GreedyPM cells -------------------------------------- #
    e2e: Dict[str, float] = {}
    cells = [
        (WorkloadSpec("lublin", n_jobs=n_jobs, n_nodes=n_nodes, seed=0),
         "baseline"),
        (WorkloadSpec("hpc2n", n_jobs=n_jobs, n_nodes=128, seed=0),
         "baseline"),
        (WorkloadSpec("hpc2n", n_jobs=n_jobs, n_nodes=128, seed=0),
         "rack_failure"),
    ]
    for w, scenario in cells:
        trace = make_trace(w)
        trace, events = apply_scenario(scenario, trace, w.n_nodes, seed=w.seed)
        t0 = time.perf_counter()
        Engine(trace, GREEDYPM, SimParams(n_nodes=w.n_nodes),
               cluster_events=events).run()
        e2e[f"{w.name}×{scenario}"] = round(time.perf_counter() - t0, 3)

    payload = {
        "bench": "hotpath",
        "config": {"n_jobs": n_jobs, "n_nodes": n_nodes, "repeats": repeats,
                   "policy": GREEDYPM},
        "kernels": kernels,
        "e2e_greedypm_wall_s": e2e,
        "platform": platform.platform(),
    }
    jax_kernels = _jax_kernels(specs, maps, nn, repeats)
    if jax_kernels is not None:
        payload["jax_kernels"] = jax_kernels
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)

    if verbose:
        rows = [[k, v["mean_us"], v["ref_mean_us"], f'{v["speedup"]}x']
                for k, v in kernels.items()]
        print(fmt_table(["kernel", "mean_us", "ref_mean_us", "speedup"],
                        rows, f"Hot-path microkernels ({n_jobs} jobs)"))
        for name, wall in e2e.items():
            print(f"  e2e {name}: {wall:.2f}s")
        if jax_kernels is not None:
            for name, v in jax_kernels.items():
                print(f"  jax {name}: {v['jax_mean_us']}us "
                      f"(numpy {v['numpy_mean_us']}us)")
        print(f"  -> {BENCH_JSON}")
    return payload


def check_baseline(payload: dict, baseline_path: str,
                   max_regression: float) -> List[str]:
    """Names of end-to-end cells slower than ``max_regression``× baseline.

    The gate refuses to pass vacuously: a config mismatch (different
    ``--jobs`` than the baseline was recorded with) or zero overlapping
    cell names is itself a failure — otherwise a fixture rename would
    silently disable the regression check.
    """
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    if payload["config"]["n_jobs"] != base.get("config", {}).get("n_jobs"):
        failures.append(
            f"config mismatch: bench ran with n_jobs="
            f"{payload['config']['n_jobs']} but baseline was recorded with "
            f"n_jobs={base.get('config', {}).get('n_jobs')} — rerun with the "
            f"baseline's --jobs or re-record the baseline")
    compared = 0
    for name, wall in payload["e2e_greedypm_wall_s"].items():
        ref = base.get("e2e_greedypm_wall_s", {}).get(name)
        if ref is None:
            continue
        compared += 1
        if wall > max_regression * ref:
            failures.append(f"{name}: {wall:.2f}s > "
                            f"{max_regression:g}x baseline {ref:.2f}s")
    if compared == 0:
        failures.append(
            f"no e2e cell names overlap with {baseline_path} — the gate "
            f"compared nothing; re-record the baseline")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--jobs", type=int, default=None,
                    help="trace size (default: quick-scale n_jobs)")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--check-baseline", default=None, metavar="JSON",
                    help="fail if an e2e GreedyPM cell regresses vs this file")
    ap.add_argument("--max-regression", type=float, default=2.0)
    args = ap.parse_args()

    from .common import QUICK

    payload = run(Bench(QUICK), n_jobs=args.jobs, repeats=args.repeats)
    if args.check_baseline:
        failures = check_baseline(payload, args.check_baseline,
                                  args.max_regression)
        if failures:
            print("PERF REGRESSION:", *failures, sep="\n  ", file=sys.stderr)
            return 1
        print(f"perf within {args.max_regression:g}x of "
              f"{args.check_baseline}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
