"""Table 3: preemption/migration costs (bandwidth, events/hour, events/job)
over scaled traces with load >= 0.7.

The cells are a subset of the table-2 grid; through the shared
``Bench.sweep`` cache this table costs zero extra simulations when run
after table 2.
"""
from __future__ import annotations

import numpy as np

from .common import Bench, TABLE2_POLICIES, fmt_table, write_csv


def run(bench: Bench, verbose: bool = True):
    scaled = bench.workloads("scaled")
    hi = [w for w in scaled if (w.load or 0) >= 0.7]
    if not hi:        # quick scale may not include >=0.7; use max load
        max_load = max(w.load or 0 for w in scaled)
        hi = [w for w in scaled if w.load == max_load]
    records = bench.sweep(hi, TABLE2_POLICIES)
    rows = []
    for policy in TABLE2_POLICIES:
        rs = [r for r in records if r["policy"] == policy]
        bw = [r["bandwidth_gbps"] for r in rs]
        rows.append([
            policy,
            round(float(np.mean(bw)), 3), round(float(np.max(bw)), 3),
            round(float(np.mean([r["pmtn_per_hour"] for r in rs])), 2),
            round(float(np.mean([r["mig_per_hour"] for r in rs])), 2),
            round(float(np.mean([r["pmtn_per_job"] for r in rs])), 2),
            round(float(np.mean([r["mig_per_job"] for r in rs])), 2),
        ])
    header = ["policy", "bw_gbps_avg", "bw_gbps_max",
              "pmtn_per_hour", "mig_per_hour", "pmtn_per_job", "mig_per_job"]
    write_csv("table3_costs.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows, "Table 3: preemption/migration costs (load>=0.7)"))
    by = {r[0]: r for r in rows}
    best = by["GreedyPM */per/OPT=MIN/MINVT=600"]
    claims = {
        "batch schedulers never preempt": by["FCFS"][3] == by["EASY"][3] == 0.0,
        "best-policy bandwidth < 2 GB/s max (paper SS6.3)": best[2] < 2.0,
        "MCB8-on-submit migrates most":
            by["MCB8 */OPT=MIN/MINVT=600"][4] >= best[4],
    }
    if verbose:
        for k, v in claims.items():
            print(f"  claim: {k}: {'PASS' if v else 'FAIL'}")
    return rows, claims
