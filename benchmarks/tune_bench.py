"""Online-autotuner benchmark → machine-readable BENCH_tune.json.

The headline demo of the fork-race-promote autotuner
(:mod:`repro.tune`): a chaos scenario — a rack failure mid-run with a
late rejoin — where **no fixed policy choice is right for the whole
run**.  ``GreedyP`` is the better calm-phase incumbent but strands the
killed jobs; ``GreedyPM */per`` digs the cluster out of the failure but
pays migration overhead from t=0 if run fixed.  The autotuned session
starts on ``GreedyP``, forks and races the portfolio when the failure
bites, hot-swaps to the migration policy — and ends with a lower max
stretch than *every* fixed-policy baseline, none of which saw the
future either (the tuner races snapshots of the same live state; it has
no oracle).

Two gates, both **correctness** (never absolute perf — CI runs on a
throttled 2-core box):

* the tuned session's max stretch must strictly beat the best fixed
  oracle-free baseline;
* the tuned run must be bit-deterministic: a second identical run (and
  its decision log) must match the first exactly — decision records are
  wall-clock-free by construction.

Wall times are reported for context only.
"""
from __future__ import annotations

import json
import platform
import time

from repro import api

from .common import Bench, fmt_table

BENCH_JSON = "BENCH_tune.json"

NODES = 32
JOBS = 150
SEED = 7
LOAD = 1.1
RACK = list(range(8))
FAIL_T = 2050.0
JOIN_T = 7000.0

#: the oracle-free portfolio: every member is also a fixed baseline
PORTFOLIO = ["GreedyP */OPT=MIN", "GreedyPM */per/OPT=MIN/MINVT=600"]
INCUMBENT = PORTFOLIO[0]
SPEC = ("every=1500;horizon=4000;rungs=2;margin=0.01;dwell=0;"
        "policies=" + "|".join(PORTFOLIO))
TUNER_SEED = 3


def _scenario_session(policy: str):
    """One rack-failure cell: everything but the policy/tuner is shared."""
    ses = api.open_session(NODES, policy)
    return ses


def _drive(ses) -> None:
    ses.submit(api.parse_workload("lublin", n_jobs=JOBS, n_nodes=NODES,
                                  seed=SEED, load=LOAD))
    ses.inject({"kind": "fail", "t": FAIL_T, "nodes": RACK})
    ses.inject({"kind": "join", "t": JOIN_T, "nodes": RACK})
    ses.run_to_exhaustion()


def _fixed(policy: str) -> float:
    ses = _scenario_session(policy)
    _drive(ses)
    return ses.result(light=True).max_stretch


def _tuned():
    ses = _scenario_session(INCUMBENT)
    tuner = api.autotune(ses, SPEC, seed=TUNER_SEED)
    _drive(ses)
    return ses, tuner


def run(bench: Bench, verbose: bool = True):
    t_all = time.perf_counter()

    baselines = {}
    for pol in PORTFOLIO:
        t0 = time.perf_counter()
        baselines[pol] = _fixed(pol)
        if verbose:
            print(f"  fixed {pol:40s} max stretch "
                  f"{baselines[pol]:8.2f}  ({time.perf_counter()-t0:.1f}s)")

    t0 = time.perf_counter()
    ses, tuner = _tuned()
    tuned_wall = time.perf_counter() - t0
    tuned = ses.result(light=True).max_stretch
    swaps = [d for d in tuner.decisions if d["swapped"]]

    # determinism gate: an identical second run must reproduce the max
    # stretch AND the decision log bit for bit (records carry no wall
    # clock, so == is exact)
    ses2, tuner2 = _tuned()
    tuned2 = ses2.result(light=True).max_stretch
    deterministic = (tuned2 == tuned and tuner2.decisions == tuner.decisions)

    best_fixed = min(baselines.values())
    beats_all = tuned < best_fixed
    wall = time.perf_counter() - t_all

    payload = {
        "bench": "tune",
        "scenario": {
            "workload": f"lublin-j{JOBS}-n{NODES}-s{SEED}@{LOAD}",
            "nodes": NODES,
            "rack": RACK,
            "fail_t": FAIL_T,
            "join_t": JOIN_T,
        },
        "spec": SPEC,
        "tuner_seed": TUNER_SEED,
        "incumbent": INCUMBENT,
        "baselines": {pol: round(v, 6) for pol, v in baselines.items()},
        "best_fixed": round(best_fixed, 6),
        "tuned": {
            "max_stretch": round(tuned, 6),
            "final_policy": ses.policy_name,
            "n_decisions": len(tuner.decisions),
            "n_swaps": len(swaps),
            "swap_times": [d["t"] for d in swaps],
        },
        "improvement_vs_best_fixed": round(1.0 - tuned / best_fixed, 4),
        "gates": {"beats_all_baselines": beats_all,
                  "deterministic": deterministic},
        "decisions": tuner.decisions,
        "wall_s": round(wall, 3),
        "tuned_wall_s": round(tuned_wall, 3),
        "platform": platform.platform(),
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)

    if verbose:
        rows = [[pol, f"{v:.2f}", ""] for pol, v in baselines.items()]
        rows.append(["autotuned (fork-race-promote)", f"{tuned:.2f}",
                     f"{len(swaps)} swap(s) -> {ses.policy_name}"])
        print(fmt_table(
            ["policy", "max stretch", "notes"], rows,
            f"Tune bench (rack failure at t={FAIL_T:.0f}, "
            f"rejoin t={JOIN_T:.0f})"))
        print(f"  tuned beats best fixed by "
              f"{100 * payload['improvement_vs_best_fixed']:.1f}% "
              f"-> {BENCH_JSON}")

    # the CI gates: a tuner that loses to a fixed baseline — or that
    # cannot reproduce its own decisions — is broken, whatever the speed
    if not deterministic:
        raise RuntimeError(
            f"tuned run is not deterministic: max stretch {tuned} vs "
            f"{tuned2}, decision logs "
            f"{'match' if tuner2.decisions == tuner.decisions else 'differ'}")
    if not beats_all:
        raise RuntimeError(
            f"autotuned max stretch {tuned:.2f} does not beat the best "
            f"fixed oracle-free baseline {best_fixed:.2f} — the "
            f"fork-race-promote loop is not paying for itself")
    return payload
