"""Table 4 + Figure 3: normalized underutilization — EASY vs the two best
DFRS policies, and its dependence on the MCB8 period.

All cells come from the shared ``Bench.sweep`` cache: the default-period
table reuses the table-2 grid outright, and the period sweep (Figure 3)
shares its cells with figure 4.
"""
from __future__ import annotations

import numpy as np

from .common import BEST_POLICIES, Bench, fmt_table, records_for, write_csv


def run(bench: Bench, verbose: bool = True):
    policies = ["EASY"] + BEST_POLICIES
    all_workloads = (bench.workloads("real") + bench.workloads("unscaled")
                     + bench.workloads("scaled"))
    records = bench.sweep(all_workloads, policies)
    rows = []
    for policy in policies:
        row = [policy]
        for kind in ("real", "unscaled", "scaled"):
            u = [r["underutilization"]
                 for r in records_for(records, kind, policy=policy)]
            row.append(round(float(np.mean(u)), 3))
        rows.append(row)
    header = ["policy", "real", "unscaled", "scaled"]
    write_csv("table4_underutilization.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows, "Table 4: normalized underutilization"))

    # Figure 3: underutilization vs period (scaled traces; best policy)
    pol = BEST_POLICIES[1]
    scaled = bench.workloads("scaled")
    per_records = bench.sweep(scaled, [pol], periods=bench.scale.periods)
    easy_u = float(np.mean([r["underutilization"]
                            for r in records_for(records, "scaled",
                                                 policy="EASY")]))
    fig_rows = []
    for period in bench.scale.periods:
        u = [r["underutilization"] for r in per_records
             if r["period"] == period]
        fig_rows.append([int(period), round(float(np.mean(u)), 3),
                         round(easy_u, 3)])
    fh = ["period_s", "dfrs_underut", "easy_underut"]
    write_csv("fig3_underut_vs_period.csv", fh, fig_rows)
    if verbose:
        print(fmt_table(fh, fig_rows, "Figure 3: underutilization vs period"))

    d600 = fig_rows[0][1]
    dbig = min(r[1] for r in fig_rows)
    easy_max = max(r[2] for r in fig_rows)
    claims = {
        "underutilization decreases as period grows": dbig < d600,
        # the paper crosses below EASY at period >= 1.5x penalty on synthetic
        # traces at full scale; at quick scale we check the gap closes to
        # within ~2.5x (the trend is the claim)
        f"period sweep closes DFRS/EASY underutilization gap "
        f"(best {dbig:.2f} vs EASY {easy_max:.2f})": dbig <= easy_max * 2.5,
    }
    if verbose:
        for k, v in claims.items():
            print(f"  claim: {k}: {'PASS' if v else 'FAIL'}")
    return rows, fig_rows, claims
