"""Table 4 + Figure 3: normalized underutilization — EASY vs the two best
DFRS policies, and its dependence on the MCB8 period."""
from __future__ import annotations

import numpy as np

from .common import BEST_POLICIES, Bench, fmt_table, write_csv


def run(bench: Bench, verbose: bool = True):
    policies = ["EASY"] + BEST_POLICIES
    rows = []
    for policy in policies:
        row = [policy]
        for kind in ("real", "unscaled", "scaled"):
            u = [bench.run(t, policy).underutilization
                 for t in bench.traces(kind)]
            row.append(round(float(np.mean(u)), 3))
        rows.append(row)
    header = ["policy", "real", "unscaled", "scaled"]
    write_csv("table4_underutilization.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows, "Table 4: normalized underutilization"))

    # Figure 3: underutilization vs period (scaled traces; best policy)
    pol = BEST_POLICIES[1]
    fig_rows = []
    for period in bench.scale.periods:
        u = [bench.run(t, pol, period=period).underutilization
             for t in bench.traces("scaled")]
        e = [bench.run(t, "EASY").underutilization
             for t in bench.traces("scaled")]
        fig_rows.append([int(period), round(float(np.mean(u)), 3),
                         round(float(np.mean(e)), 3)])
    fh = ["period_s", "dfrs_underut", "easy_underut"]
    write_csv("fig3_underut_vs_period.csv", fh, fig_rows)
    if verbose:
        print(fmt_table(fh, fig_rows, "Figure 3: underutilization vs period"))

    d600 = fig_rows[0][1]
    dbig = min(r[1] for r in fig_rows)
    easy_u = max(r[2] for r in fig_rows)
    claims = {
        "underutilization decreases as period grows": dbig < d600,
        # the paper crosses below EASY at period >= 1.5x penalty on synthetic
        # traces at full scale; at quick scale we check the gap closes to
        # within ~2.5x (the trend is the claim)
        f"period sweep closes DFRS/EASY underutilization gap "
        f"(best {dbig:.2f} vs EASY {easy_u:.2f})": dbig <= easy_u * 2.5,
    }
    if verbose:
        for k, v in claims.items():
            print(f"  claim: {k}: {'PASS' if v else 'FAIL'}")
    return rows, fig_rows, claims
