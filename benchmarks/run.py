"""Benchmark harness: one module per paper table/figure + the roofline and
TPU-cluster benches.

    PYTHONPATH=src python -m benchmarks.run            # quick scale
    PYTHONPATH=src python -m benchmarks.run --full     # paper scale
    PYTHONPATH=src python -m benchmarks.run --only table2,roofline
    PYTHONPATH=src python -m benchmarks.run --swf /data/HPC2N-2002-2.2-cln.swf

With ``--swf`` the "real" trace set is the actual Parallel Workloads
Archive log (through the §5.3.1 preprocessing) instead of the synthetic
HPC2N-like generator.
"""
from __future__ import annotations

import argparse
import time

from . import (batched_bench, fig1_load, fig4_period_stretch, hotpath_bench,
               mcb8_runtime, roofline, serve_bench, sweep_bench,
               table2_stretch, table3_costs, table4_underutilization,
               tpu_cluster, tune_bench)
from .common import FULL, QUICK, Bench

BENCHES = {
    "table2": table2_stretch.run,
    "table3": table3_costs.run,
    "table4": table4_underutilization.run,
    "fig1": fig1_load.run,
    "fig4": fig4_period_stretch.run,
    "mcb8_runtime": mcb8_runtime.run,
    "roofline": roofline.run,
    "sweep": sweep_bench.run,
    "serve": serve_bench.run,
    "hotpath": hotpath_bench.run,
    "batched": batched_bench.run,
    "tpu_cluster": tpu_cluster.run,
    "tune": tune_bench.run,
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale study")
    ap.add_argument("--only", default="", help="comma-separated bench names")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="persist the shared sweep-record cache to PATH "
                         "(resumable across interrupted runs)")
    ap.add_argument("--swf", default=None, metavar="PATH",
                    help="use this real Parallel Workloads Archive log as "
                         "the 'real' trace set (hpc2n synthetic otherwise)")
    args = ap.parse_args()

    names = [n.strip() for n in args.only.split(",") if n.strip()] or list(BENCHES)
    bench = Bench(FULL if args.full else QUICK, cache_path=args.cache,
                  swf_path=args.swf)
    failed = []
    t_all = time.time()
    for name in names:
        print(f"\n### bench: {name} " + "#" * 40)
        t0 = time.time()
        try:
            BENCHES[name](bench)
        except Exception as e:  # noqa: BLE001 — report and continue
            failed.append(name)
            print(f"  BENCH FAILED: {e!r}")
        print(f"  ({time.time()-t0:.1f}s)")
    print(f"\n[benchmarks] {len(names)-len(failed)}/{len(names)} benches ok "
          f"in {time.time()-t_all:.1f}s"
          + (f"; FAILED: {failed}" if failed else ""))
    return 1 if failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
