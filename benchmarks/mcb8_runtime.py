"""SS6.2: MCB8 execution time vs number of jobs (the 'can it run online'
check: the paper reports <=4.5 s at 102 jobs on 2008 hardware; typical job
inter-arrivals are orders of magnitude larger)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.job import JobSpec, JobState
from repro.core.mcb8 import mcb8

from .common import Bench, fmt_table, write_csv


def _jobs(n: int, seed: int):
    rng = np.random.default_rng(seed)
    out = []
    for j in range(n):
        spec = JobSpec(
            jid=j, release=0.0, proc_time=1000.0,
            n_tasks=int(rng.integers(1, 17)),
            cpu_need=float(rng.choice([0.25, 1.0])),
            mem_req=float(rng.choice([0.1] * 11 + [0.2, 0.4, 0.6, 0.8, 1.0])),
        )
        js = JobState(spec=spec)
        js.vt = float(rng.uniform(1.0, 1000.0))
        out.append(js)
    return out


def run(bench: Bench, verbose: bool = True, n_nodes: int = 128):
    rows = []
    for n in (10, 25, 50, 100, 200, 400):
        ts = []
        for seed in range(3):
            jobs = _jobs(n, seed)
            t0 = time.perf_counter()
            mcb8(jobs, n_nodes, now=2000.0)
            ts.append(time.perf_counter() - t0)
        rows.append([n, round(float(np.mean(ts)) * 1e3, 1),
                     round(float(np.max(ts)) * 1e3, 1)])
    header = ["n_jobs", "avg_ms", "max_ms"]
    write_csv("mcb8_runtime.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows, "SS6.2: MCB8 runtime vs #jobs"))
    claims = {"MCB8 <= 4.5s at ~100 jobs (paper SS6.2)":
              rows[3][2] <= 4500.0}
    if verbose:
        for k, v in claims.items():
            print(f"  claim: {k}: {'PASS' if v else 'FAIL'}")
    return rows, claims
