"""Figure 1: average degradation from bound vs offered load.

One sweep over the (load × seed × policy) grid; each record already carries
the Theorem-1 bound of its scaled trace, so a row of the figure is a mean
over the matching records.
"""
from __future__ import annotations

import numpy as np

from repro.sched.sweep import grid, run_grid
from repro.workloads.registry import WorkloadSpec

from .common import Bench, N_WORKERS, fmt_table, write_csv

POLICIES = [
    "EASY",
    "GreedyPM */OPT=MIN",
    "GreedyPM/per/OPT=MIN/MINVT=600",
    "GreedyPM */per/OPT=MIN/MINVT=600",
    "/per/OPT=MIN",
]


def run(bench: Bench, verbose: bool = True):
    s = bench.scale
    workloads = [
        WorkloadSpec("lublin", n_jobs=s.n_jobs, n_nodes=s.n_nodes,
                     seed=seed, load=load)
        for load in s.fig_loads for seed in range(s.n_traces)
    ]
    res = run_grid(grid(workloads, POLICIES),
                   n_workers=N_WORKERS, compute_bound=True)

    rows = []
    for load in s.fig_loads:
        row = [load]
        for policy in POLICIES:
            ds = res.values("degradation", policy=policy, load=load)
            row.append(round(float(np.mean(ds)), 1))
        rows.append(row)
    header = ["load"] + POLICIES
    write_csv("fig1_degradation_vs_load.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows, "Figure 1: degradation vs load"))
        print(f"  [{res.n_cells} cells in {res.wall_s:.1f}s, "
              f"{res.cells_per_sec:.2f} cells/s, {res.n_workers} workers]")
    hi = rows[-1]
    claims = {
        "best policy beats EASY >=10x at high load":
            hi[4] * 10 <= hi[1],
    }
    if verbose:
        for k, v in claims.items():
            print(f"  claim: {k}: {'PASS' if v else 'FAIL'}")
    return rows, claims
