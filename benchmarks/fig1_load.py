"""Figure 1: average degradation from bound vs offered load.

One sweep over the (load × seed × policy) grid through the shared
``Bench.sweep`` cache; each record already carries the Theorem-1 bound of
its scaled trace, so a row of the figure is a mean over matching records.
"""
from __future__ import annotations

import numpy as np

from repro.sched.sweep import record_matches
from repro.workloads.registry import WorkloadSpec

from .common import Bench, fmt_table, write_csv

POLICIES = [
    "EASY",
    "GreedyPM */OPT=MIN",
    "GreedyPM/per/OPT=MIN/MINVT=600",
    "GreedyPM */per/OPT=MIN/MINVT=600",
    "/per/OPT=MIN",
]


def run(bench: Bench, verbose: bool = True):
    s = bench.scale
    workloads = [
        WorkloadSpec("lublin", n_jobs=s.n_jobs, n_nodes=s.n_nodes,
                     seed=seed, load=load)
        for load in s.fig_loads for seed in range(s.n_traces)
    ]
    records = bench.sweep(workloads, POLICIES)

    rows = []
    for load in s.fig_loads:
        row = [load]
        for policy in POLICIES:
            ds = [r["degradation"] for r in records
                  if record_matches(r, dict(policy=policy, load=load))]
            row.append(round(float(np.mean(ds)), 1))
        rows.append(row)
    header = ["load"] + POLICIES
    write_csv("fig1_degradation_vs_load.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows, "Figure 1: degradation vs load"))
        print(f"  [{len(records)} cells]")
    hi = rows[-1]
    claims = {
        "best policy beats EASY >=10x at high load":
            hi[4] * 10 <= hi[1],
    }
    if verbose:
        for k, v in claims.items():
            print(f"  claim: {k}: {'PASS' if v else 'FAIL'}")
    return rows, claims
