"""Figure 1: average degradation from bound vs offered load."""
from __future__ import annotations

import numpy as np

from repro.core.bound import max_stretch_lower_bound
from repro.sched.simulator import SimParams, simulate
from repro.workloads.lublin import lublin_trace, scale_to_load

from .common import Bench, fmt_table, write_csv

POLICIES = [
    "EASY",
    "GreedyPM */OPT=MIN",
    "GreedyPM/per/OPT=MIN/MINVT=600",
    "GreedyPM */per/OPT=MIN/MINVT=600",
    "/per/OPT=MIN",
]


def run(bench: Bench, verbose: bool = True):
    s = bench.scale
    rows = []
    for load in s.fig_loads:
        row = [load]
        for policy in POLICIES:
            ds = []
            for seed in range(s.n_traces):
                base = lublin_trace(n_jobs=s.n_jobs, n_nodes=s.n_nodes, seed=seed)
                specs = scale_to_load(base, s.n_nodes, load)
                lb = max_stretch_lower_bound(specs, s.n_nodes)
                r = simulate(specs, policy, SimParams(n_nodes=s.n_nodes))
                ds.append(r.max_stretch / lb)
            row.append(round(float(np.mean(ds)), 1))
        rows.append(row)
    header = ["load"] + POLICIES
    write_csv("fig1_degradation_vs_load.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows, "Figure 1: degradation vs load"))
    hi = rows[-1]
    claims = {
        "best policy beats EASY >=10x at high load":
            hi[4] * 10 <= hi[1],
    }
    if verbose:
        for k, v in claims.items():
            print(f"  claim: {k}: {'PASS' if v else 'FAIL'}")
    return rows, claims
