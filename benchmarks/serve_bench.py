"""Service-layer capacity benchmark → machine-readable BENCH_serve.json.

One in-process server (``ServerThread``) with a small ``max_live`` cap is
loaded with 1000+ named sessions across four tenants — far more sessions
than live engine slots, so the snapshot-backed eviction/rehydration path
is exercised on nearly every touch.  The tracked numbers are capacity
(sessions held, peak RSS) and service rate (ops/s, p99 step latency).

Two gates, both **correctness** (never absolute perf — CI runs on a
throttled 2-core box):

* a sample of sessions is run to exhaustion *through the server* — after
  hundreds of evictions — and each result must be bit-identical to a
  serial single-process :class:`SimSession` run of the same cell;
* eviction must actually have happened (``evictions > 0``), otherwise the
  capacity number is meaningless.

Journal fsync is disabled for the bench (the durability guarantee is
covered by tests/test_serve.py's SIGKILL drill; here it would only add
per-op disk latency to a throughput measurement).
"""
from __future__ import annotations

import json
import platform
import resource
import tempfile
import time

from repro import api
from repro.serve import Client, CreditParams, ServerThread

from . import common
from .common import Bench, fmt_table

BENCH_JSON = "BENCH_serve.json"

POLICY = "EASY"
NODES = 8
JOBS = 6
MAX_LIVE = 64
TENANTS = ("acme", "globex", "initech", "umbrella")
PARITY_SAMPLE = 6


def _serial_result(seed: int):
    ses = api.open_session(NODES, POLICY)
    ses.submit(api.parse_workload("lublin", n_jobs=JOBS, n_nodes=NODES,
                                  seed=seed))
    ses.step(2)
    ses.run_to_exhaustion()
    import dataclasses
    d = dataclasses.asdict(ses.result())
    d.pop("sim_wall_s")
    return d


def _norm(resp):
    d = {k: v for k, v in resp.items()
         if k not in ("id", "ok", "partial", "sim_wall_s")}
    for k in ("completions", "stretches"):
        d[k] = {int(a): b for a, b in d[k].items()}
    return d


def _timed(lat, fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    lat.append(time.perf_counter() - t0)
    return out


def _p(lat, q):
    xs = sorted(lat)
    return xs[min(len(xs) - 1, int(q * len(xs)))] if xs else 0.0


def run(bench: Bench, verbose: bool = True):
    n_sessions = 2000 if bench.scale is common.FULL else 1000
    rss0_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    lat_open, lat_step = [], []
    t_all = time.perf_counter()

    with tempfile.TemporaryDirectory() as tmp:
        # capacity load, not an admission test: the budget throttle would
        # (correctly) refuse this firehose at the default 500 units/window
        with ServerThread(store=tmp, max_live=MAX_LIVE, fsync=False,
                          credit=CreditParams(budget=1e12)) as srv:
            clients = {t: Client("127.0.0.1", srv.port, tenant=t)
                       for t in TENANTS}
            names = [(TENANTS[i % len(TENANTS)], f"s{i}", i)
                     for i in range(n_sessions)]
            for tenant, name, seed in names:
                c = clients[tenant]
                _timed(lat_open, c.open, name, POLICY, nodes=NODES)
                c.submit(name, workload="lublin", jobs=JOBS, nodes=NODES,
                         seed=seed)
            # a second full pass: every session is cold by now (the live
            # cap is tiny), so each step pays one rehydration
            for tenant, name, seed in names:
                _timed(lat_step, clients[tenant].step, name, n=2)
            stats = clients[TENANTS[0]].stats()

            # correctness gate: finish a sample through the server and
            # diff bit-for-bit against serial SimSession runs
            mismatches = []
            stride = max(1, n_sessions // PARITY_SAMPLE)
            sample = names[::stride][:PARITY_SAMPLE]
            for tenant, name, seed in sample:
                c = clients[tenant]
                c.run(name)
                if _norm(c.result(name)) != _serial_result(seed):
                    mismatches.append(f"{tenant}/{name}")
            for c in clients.values():
                c.close()

    wall = time.perf_counter() - t_all
    rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
    reg = stats["registry"]
    n_ops = 3 * n_sessions
    payload = {
        "bench": "serve",
        "n_sessions": n_sessions,
        "n_tenants": len(TENANTS),
        "max_live": MAX_LIVE,
        "sessions_held": reg["sessions"],
        "live_at_peak": reg["live"],
        "evictions": reg["evictions"],
        "rehydrations": reg["rehydrations"],
        "wall_s": round(wall, 3),
        "ops": n_ops,
        "ops_per_sec": round(n_ops / max(wall, 1e-9), 1),
        "open_p50_ms": round(1e3 * _p(lat_open, 0.50), 3),
        "open_p99_ms": round(1e3 * _p(lat_open, 0.99), 3),
        "step_p50_ms": round(1e3 * _p(lat_step, 0.50), 3),
        "step_p99_ms": round(1e3 * _p(lat_step, 0.99), 3),
        "rss_peak_mb": round(rss_kb / 1024.0, 1),
        "rss_start_mb": round(rss0_kb / 1024.0, 1),
        "fsync": False,
        "parity": {"sampled": len(sample), "mismatches": mismatches},
        "cell": {"policy": POLICY, "nodes": NODES, "jobs": JOBS},
        "platform": platform.platform(),
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)

    if verbose:
        rows = [[n_sessions, reg["live"], reg["evictions"],
                 reg["rehydrations"], payload["ops_per_sec"],
                 payload["step_p99_ms"], payload["rss_peak_mb"]]]
        print(fmt_table(
            ["sessions", "live", "evict", "rehydrate", "ops/s",
             "step p99 ms", "rss MB"],
            rows, f"Serve bench ({len(TENANTS)} tenants, "
                  f"max_live={MAX_LIVE})"))
        print(f"  parity sample: {len(sample)} sessions, "
              f"{len(mismatches)} mismatches -> {BENCH_JSON}")

    # the CI gates: correctness and an actually-exercised eviction path
    if mismatches:
        raise RuntimeError(
            f"server results diverged from serial SimSession runs for "
            f"{mismatches} — the eviction/rehydration path is broken")
    if reg["evictions"] == 0 or reg["rehydrations"] == 0:
        raise RuntimeError(
            f"eviction path not exercised (evictions={reg['evictions']}, "
            f"rehydrations={reg['rehydrations']}) — capacity numbers "
            f"are meaningless without it")
    if reg["live"] > MAX_LIVE:
        raise RuntimeError(
            f"live sessions ({reg['live']}) exceed max_live ({MAX_LIVE}); "
            f"RSS is not bounded")
    return payload
