"""Roofline table from the dry-run artifacts (EXPERIMENTS.md SSRoofline).

Reads experiments/dryrun/*.json (produced by repro.launch.dryrun) and prints
per (arch x shape x mesh): the three roofline terms, the dominant bottleneck,
MODEL_FLOPS/HLO_FLOPs, and HBM bytes/chip."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.workloads.jobgen import HBM_BYTES

from .common import fmt_table, write_csv

DRYRUN_DIR = "experiments/dryrun"


def load_records(mesh: str = "single") -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def bytes_per_chip(rec: dict) -> float:
    m = rec.get("memory_analysis", {})
    return (m.get("argument_size_in_bytes", 0.0)
            + m.get("temp_size_in_bytes", 0.0)
            + m.get("output_size_in_bytes", 0.0)
            - m.get("alias_size_in_bytes", 0.0))


def jobgen_records(mesh: str = "single") -> List[dict]:
    """Adapter: dry-run artifacts -> repro.workloads.jobgen record format."""
    out = []
    for rec in load_records(mesh):
        if rec.get("status") != "ok":
            continue
        r = rec["roofline"]
        out.append({
            "arch": rec["arch"], "shape": rec["shape"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"],
            "bytes_per_device": bytes_per_chip(rec),
            "n_chips": rec["chips"],
        })
    return out


def run(bench=None, verbose: bool = True, mesh: str = "single"):
    rows = _run_mesh(verbose, mesh)
    # multi-pod pass: compile-only artifacts (no extrapolation; the roofline
    # table proper is single-pod) — emitted as a coverage/fit report
    _run_mesh(verbose, "multi")
    return rows


def _run_mesh(verbose: bool, mesh: str):
    rows = []
    n_ok = n_skip = 0
    for rec in load_records(mesh):
        if rec.get("status") == "skipped":
            n_skip += 1
            rows.append([rec["arch"], rec["shape"], "SKIP", "-", "-", "-", "-",
                         "-", rec.get("reason", "")[:38]])
            continue
        if rec.get("status") != "ok":
            rows.append([rec["arch"], rec["shape"], "FAIL", "-", "-", "-", "-",
                         "-", ""])
            continue
        n_ok += 1
        r = rec["roofline"]
        bpc = bytes_per_chip(rec)
        rows.append([
            rec["arch"], rec["shape"], r["bottleneck"],
            f"{r['compute_s']:.3g}", f"{r['memory_s']:.3g}",
            f"{r['collective_s']:.3g}",
            f"{rec.get('model_vs_hlo_flops', 0.0):.2f}",
            f"{bpc/2**30:.1f}", "fits" if bpc <= HBM_BYTES else "OVER",
        ])
    header = ["arch", "shape", "bottleneck", "compute_s", "memory_s",
              "collective_s", "model/hlo", "GiB/chip", "hbm"]
    write_csv(f"roofline_{mesh}.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows, f"Roofline ({mesh}-pod)"))
        print(f"  {n_ok} ok, {n_skip} skipped (documented), "
              f"{len(rows)-n_ok-n_skip} missing/failed of {len(rows)}")
    return rows
