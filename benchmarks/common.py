"""Shared infrastructure for the paper-table benchmarks.

Scaled-down but structurally faithful reproduction of §5: three trace sets
(HPC2N-like real-world, unscaled Lublin synthetic, load-scaled synthetic)
available two ways — declaratively as sweep workloads (``workload_specs``,
used by the run_grid-based table2/fig1 benches) and as memoized ``Bench``
traces with a per-process result cache (used by tables 3/4 and figure 4;
sweep records don't feed this cache, so mixing both paths in one run
re-simulates shared cells).

Scale knobs: the paper uses 100-182 traces x 1000 jobs x 128 nodes; the
default here is QUICK (fewer/smaller traces) so ``python -m benchmarks.run``
finishes on one CPU core.  Pass ``--full`` for the paper-scale study.
"""
from __future__ import annotations

import csv
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.bound import max_stretch_lower_bound
from repro.sched.simulator import SimParams, SimResult, simulate
from repro.workloads.hpc2n import hpc2n_like_trace
from repro.workloads.lublin import lublin_trace, scale_to_load
from repro.workloads.registry import WorkloadSpec

RESULTS_DIR = "experiments/results"

#: worker processes for sweep-based benchmarks
N_WORKERS = max(1, min(os.cpu_count() or 1, 8))

#: Table-2 policy subset (the paper's headline algorithms; all OPT=MIN)
TABLE2_POLICIES = [
    "FCFS",
    "EASY",
    "Greedy */OPT=MIN",
    "GreedyP */OPT=MIN",
    "GreedyPM */OPT=MIN",
    "Greedy/per/OPT=MIN",
    "GreedyP/per/OPT=MIN/MINVT=600",
    "GreedyPM/per/OPT=MIN/MINVT=600",
    "GreedyP */per/OPT=MIN/MINVT=600",
    "GreedyPM */per/OPT=MIN/MINVT=600",
    "MCB8 */OPT=MIN/MINVT=600",
    "MCB8/per/OPT=MIN/MINVT=600",
    "/per/OPT=MIN",
    "/stretch-per/OPT=MAX",
]

BEST_POLICIES = [
    "GreedyP */per/OPT=MIN/MINVT=600",
    "GreedyPM */per/OPT=MIN/MINVT=600",
]


@dataclass
class Scale:
    n_traces: int = 3
    n_jobs: int = 250
    n_nodes: int = 64
    loads: Tuple[float, ...] = (0.3, 0.7)
    fig_loads: Tuple[float, ...] = (0.2, 0.5, 0.8)
    periods: Tuple[float, ...] = (600.0, 1200.0, 3000.0, 6000.0, 12000.0)


QUICK = Scale()
FULL = Scale(n_traces=10, n_jobs=1000, n_nodes=128,
             loads=(0.1, 0.3, 0.5, 0.7, 0.9),
             fig_loads=(0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9))


def workload_specs(kind: str, scale: Scale) -> List[WorkloadSpec]:
    """The paper's three trace sets (§5.3) as declarative sweep workloads:
    ``real`` (HPC2N-like on 128 nodes), ``unscaled`` (Lublin), ``scaled``
    (Lublin rescaled to each target load)."""
    s = scale
    if kind == "real":
        return [WorkloadSpec("hpc2n", n_jobs=s.n_jobs, n_nodes=128, seed=seed)
                for seed in range(s.n_traces)]
    if kind == "unscaled":
        return [WorkloadSpec("lublin", n_jobs=s.n_jobs, n_nodes=s.n_nodes,
                             seed=seed)
                for seed in range(s.n_traces)]
    if kind == "scaled":
        return [WorkloadSpec("lublin", n_jobs=s.n_jobs, n_nodes=s.n_nodes,
                             seed=seed, load=load)
                for seed in range(s.n_traces) for load in s.loads]
    raise KeyError(kind)


def records_for(records: Sequence[dict], kind: str, **kv) -> List[dict]:
    """Filter sweep records down to one of the trace sets of §5.3."""
    from repro.sched.sweep import record_matches

    sel = {"real": lambda r: r["kind"] == "hpc2n",
           "unscaled": lambda r: r["kind"] == "lublin" and r["load"] is None,
           "scaled": lambda r: r["kind"] == "lublin" and r["load"] is not None}[kind]
    return [r for r in records if sel(r) and record_matches(r, kv)]


@dataclass
class Trace:
    name: str            # set name: real | unscaled | scaled
    seed: int
    load: Optional[float]
    specs: list
    n_nodes: int
    bound: float = 0.0


class Bench:
    """Trace registry + memoized simulation."""

    def __init__(self, scale: Scale):
        self.scale = scale
        self._traces: Dict[str, List[Trace]] = {}
        self._cache: Dict[Tuple[str, float, str], SimResult] = {}

    # ---- trace sets -----------------------------------------------------
    def traces(self, kind: str) -> List[Trace]:
        if kind in self._traces:
            return self._traces[kind]
        s = self.scale
        out: List[Trace] = []
        if kind == "real":
            for seed in range(s.n_traces):
                specs = hpc2n_like_trace(n_jobs=s.n_jobs, seed=seed)
                out.append(Trace("real", seed, None, specs, 128))
        elif kind == "unscaled":
            for seed in range(s.n_traces):
                specs = lublin_trace(n_jobs=s.n_jobs, n_nodes=s.n_nodes, seed=seed)
                out.append(Trace("unscaled", seed, None, specs, s.n_nodes))
        elif kind == "scaled":
            for seed in range(s.n_traces):
                base = lublin_trace(n_jobs=s.n_jobs, n_nodes=s.n_nodes, seed=seed)
                for load in s.loads:
                    specs = scale_to_load(base, s.n_nodes, load)
                    out.append(Trace("scaled", seed, load, specs, s.n_nodes))
        else:
            raise KeyError(kind)
        for tr in out:
            tr.bound = max_stretch_lower_bound(tr.specs, tr.n_nodes)
        self._traces[kind] = out
        return out

    # ---- simulation -----------------------------------------------------
    def run(self, tr: Trace, policy: str,
            period: float = 600.0) -> SimResult:
        key = (f"{tr.name}:{tr.seed}:{tr.load}", period, policy)
        if key not in self._cache:
            params = SimParams(n_nodes=tr.n_nodes, period=period)
            self._cache[key] = simulate(tr.specs, policy, params)
        return self._cache[key]

    def degradations(self, kind: str, policy: str,
                     period: float = 600.0) -> np.ndarray:
        return np.array([
            self.run(tr, policy, period).max_stretch / tr.bound
            for tr in self.traces(kind)
        ])


def write_csv(name: str, header: Sequence[str], rows: Sequence[Sequence]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def fmt_table(header: Sequence[str], rows: Sequence[Sequence], title: str) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows else len(str(h))
              for i, h in enumerate(header)]
    lines = [f"== {title} =="]
    lines.append("  ".join(str(h).ljust(w) for h, w in zip(header, widths)))
    for r in rows:
        lines.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(lines)
