"""Shared infrastructure for the paper-table benchmarks.

Scaled-down but structurally faithful reproduction of §5: three trace sets
(HPC2N-like real-world, unscaled Lublin synthetic, load-scaled synthetic)
expressed declaratively as sweep workloads (``workload_specs``).  All paper
benchmarks draw their simulation cells from one shared
:class:`Bench` record cache built on the ``run_grid`` sweep API: each
(workload × policy × period × scenario) cell is simulated at most once per
``benchmarks.run`` process no matter how many tables/figures consume it,
and every miss batch fans out across worker processes.

Scale knobs: the paper uses 100-182 traces x 1000 jobs x 128 nodes; the
default here is QUICK (fewer/smaller traces) so ``python -m benchmarks.run``
finishes on one CPU core.  Pass ``--full`` for the paper-scale study.
"""
from __future__ import annotations

import csv
import os
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.sched.sweep import RecordCache, record_matches
from repro.workloads.registry import WorkloadSpec, parse_workload

RESULTS_DIR = "experiments/results"

#: worker processes for sweep-based benchmarks
N_WORKERS = max(1, min(os.cpu_count() or 1, 8))

#: Table-2 policy subset (the paper's headline algorithms; all OPT=MIN)
TABLE2_POLICIES = [
    "FCFS",
    "EASY",
    "Greedy */OPT=MIN",
    "GreedyP */OPT=MIN",
    "GreedyPM */OPT=MIN",
    "Greedy/per/OPT=MIN",
    "GreedyP/per/OPT=MIN/MINVT=600",
    "GreedyPM/per/OPT=MIN/MINVT=600",
    "GreedyP */per/OPT=MIN/MINVT=600",
    "GreedyPM */per/OPT=MIN/MINVT=600",
    "MCB8 */OPT=MIN/MINVT=600",
    "MCB8/per/OPT=MIN/MINVT=600",
    "/per/OPT=MIN",
    "/stretch-per/OPT=MAX",
]

BEST_POLICIES = [
    "GreedyP */per/OPT=MIN/MINVT=600",
    "GreedyPM */per/OPT=MIN/MINVT=600",
]


@dataclass
class Scale:
    n_traces: int = 3
    n_jobs: int = 250
    n_nodes: int = 64
    loads: Tuple[float, ...] = (0.3, 0.7)
    fig_loads: Tuple[float, ...] = (0.2, 0.5, 0.8)
    periods: Tuple[float, ...] = (600.0, 1200.0, 3000.0, 6000.0, 12000.0)


QUICK = Scale()
FULL = Scale(n_traces=10, n_jobs=1000, n_nodes=128,
             loads=(0.1, 0.3, 0.5, 0.7, 0.9),
             fig_loads=(0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9))


def workload_specs(kind: str, scale: Scale,
                   swf_path: Optional[str] = None) -> List[WorkloadSpec]:
    """The paper's three trace sets (§5.3) as declarative sweep workloads:
    ``real`` (HPC2N-like on 128 nodes — or the actual log when an swf path
    is given, as in ``benchmarks.run --swf``), ``unscaled`` (Lublin),
    ``scaled`` (Lublin rescaled to each target load)."""
    s = scale
    if kind == "real":
        if swf_path:
            # one deterministic real trace replaces the synthetic seeds
            return [parse_workload(f"swf:{swf_path}", n_jobs=s.n_jobs,
                                   n_nodes=128)]
        return [WorkloadSpec("hpc2n", n_jobs=s.n_jobs, n_nodes=128, seed=seed)
                for seed in range(s.n_traces)]
    if kind == "unscaled":
        return [WorkloadSpec("lublin", n_jobs=s.n_jobs, n_nodes=s.n_nodes,
                             seed=seed)
                for seed in range(s.n_traces)]
    if kind == "scaled":
        return [WorkloadSpec("lublin", n_jobs=s.n_jobs, n_nodes=s.n_nodes,
                             seed=seed, load=load)
                for seed in range(s.n_traces) for load in s.loads]
    raise KeyError(kind)


def records_for(records: Sequence[dict], kind: str, **kv) -> List[dict]:
    """Filter sweep records down to one of the trace sets of §5.3.

    The "real" set is the synthetic hpc2n generator by default and the
    actual log (kind ``swf``) under ``benchmarks.run --swf`` — both count.
    """
    sel = {"real": lambda r: r["kind"] in ("hpc2n", "swf"),
           "unscaled": lambda r: r["kind"] == "lublin" and r["load"] is None,
           "scaled": lambda r: r["kind"] == "lublin" and r["load"] is not None}[kind]
    return [r for r in records if sel(r) and record_matches(r, kv)]


class Bench:
    """Shared sweep-record cache across all paper benchmarks.

    ``sweep`` returns one flat record per requested
    (workload × policy × period × scenario) cell; only cells not yet in the
    cache are simulated, in a single ``run_grid`` fan-out across worker
    processes.  Tables 2/3/4 and figures 1/3/4 overlap heavily on the
    default-period grid — with this cache a full ``benchmarks.run`` pays for
    each shared cell exactly once.  The caching itself is
    ``repro.sched.sweep.RecordCache``; pass ``cache_path`` (or
    ``benchmarks.run --cache``) to persist the records on disk, making
    interrupted benchmark runs resumable across processes.
    """

    def __init__(self, scale: Scale, cache_path: Optional[str] = None,
                 swf_path: Optional[str] = None):
        self.scale = scale
        self.swf_path = swf_path
        self._cache = RecordCache(cache_path)
        self._workloads: Dict[str, List[WorkloadSpec]] = {}

    def workloads(self, kind: str) -> List[WorkloadSpec]:
        if kind not in self._workloads:
            self._workloads[kind] = workload_specs(kind, self.scale,
                                                   swf_path=self.swf_path)
        return self._workloads[kind]

    def sweep(
        self,
        workloads: Iterable[WorkloadSpec],
        policies: Iterable[str],
        periods: Iterable[float] = (600.0,),
        scenarios: Iterable[str] = ("baseline",),
        n_workers: Optional[int] = None,
    ) -> List[Dict[str, Any]]:
        """Records for the full cross product, simulating only cache misses."""
        return self._cache.sweep(
            workloads, policies, periods, scenarios,
            n_workers=n_workers or N_WORKERS, compute_bound=True,
        )


def write_csv(name: str, header: Sequence[str], rows: Sequence[Sequence]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def fmt_table(header: Sequence[str], rows: Sequence[Sequence], title: str) -> str:
    widths = [max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows else len(str(h))
              for i, h in enumerate(header)]
    lines = [f"== {title} =="]
    lines.append("  ".join(str(h).ljust(w) for h, w in zip(header, widths)))
    for r in rows:
        lines.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(lines)
