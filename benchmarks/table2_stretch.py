"""Table 2: max-stretch degradation from the Theorem-1 bound, per policy,
over the three trace sets (real-world-like, unscaled synthetic, scaled
synthetic)."""
from __future__ import annotations

import numpy as np

from .common import Bench, TABLE2_POLICIES, fmt_table, write_csv


def run(bench: Bench, verbose: bool = True):
    rows = []
    for policy in TABLE2_POLICIES:
        row = [policy]
        for kind in ("real", "unscaled", "scaled"):
            d = bench.degradations(kind, policy)
            row += [round(float(d.mean()), 1), round(float(d.std()), 1),
                    round(float(d.max()), 1)]
        rows.append(row)
    header = ["policy",
              "real_avg", "real_std", "real_max",
              "unscaled_avg", "unscaled_std", "unscaled_max",
              "scaled_avg", "scaled_std", "scaled_max"]
    write_csv("table2_stretch.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows, "Table 2: degradation from bound"))

    # paper-claim checks (qualitative, quick-scale)
    by = {r[0]: r for r in rows}
    fcfs, easy = by["FCFS"], by["EASY"]
    best = min((r for r in rows if r[0] not in ("FCFS", "EASY")),
               key=lambda r: r[7])
    # the paper's across-the-board winner is evaluated at HIGH load
    # (Fig. 1: below ~0.3, non-periodic greedy matches it — same crossover
    # we see at quick scale)
    hi = [t for t in bench.traces("scaled")
          if t.load == max(x.load for x in bench.traces("scaled"))]
    win = "GreedyPM */per/OPT=MIN/MINVT=600"
    win_hi = np.mean([bench.run(t, win).max_stretch / t.bound for t in hi])
    others_hi = {
        p: float(np.mean([bench.run(t, p).max_stretch / t.bound for t in hi]))
        for p in TABLE2_POLICIES if p not in ("FCFS", "EASY")
    }
    claims = {
        "EASY <= FCFS (scaled avg)": easy[7] <= fcfs[7] * 1.05,
        "best DFRS >= 10x better than EASY (scaled avg)":
            best[7] * 10 <= easy[7],
        "GreedyPM */per/MINVT=600 within 2x of best at high load":
            win_hi <= 2.0 * min(others_hi.values()) + 0.5,
        "GreedyP beats Greedy (scaled avg)":
            by["GreedyP */OPT=MIN"][7] <= by["Greedy */OPT=MIN"][7],
        "/per alone worse than best greedy-per (scaled avg)":
            by["/per/OPT=MIN"][7] >= best[7],
        "/stretch-per ~ /per (scaled avg)":
            abs(by["/stretch-per/OPT=MAX"][7] - by["/per/OPT=MIN"][7])
            <= 0.5 * max(by["/per/OPT=MIN"][7], 1.0),
    }
    if verbose:
        for k, v in claims.items():
            print(f"  claim: {k}: {'PASS' if v else 'FAIL'}")
    return rows, claims
