"""Table 2: max-stretch degradation from the Theorem-1 bound, per policy,
over the three trace sets (real-world-like, unscaled synthetic, scaled
synthetic).

Runs on the shared ``Bench.sweep`` record cache: the whole
(trace-set × policy) grid is one ``run_grid`` fan-out across worker
processes on first touch, and later benchmarks (tables 3/4) reuse the very
same cells instead of re-simulating them.
"""
from __future__ import annotations

import numpy as np

from .common import Bench, TABLE2_POLICIES, fmt_table, records_for, write_csv


def run(bench: Bench, verbose: bool = True):
    s = bench.scale
    workloads = (bench.workloads("real") + bench.workloads("unscaled")
                 + bench.workloads("scaled"))
    records = bench.sweep(workloads, TABLE2_POLICIES)

    rows = []
    for policy in TABLE2_POLICIES:
        row = [policy]
        for kind in ("real", "unscaled", "scaled"):
            d = np.array([r["degradation"]
                          for r in records_for(records, kind, policy=policy)])
            row += [round(float(d.mean()), 1), round(float(d.std()), 1),
                    round(float(d.max()), 1)]
        rows.append(row)
    header = ["policy",
              "real_avg", "real_std", "real_max",
              "unscaled_avg", "unscaled_std", "unscaled_max",
              "scaled_avg", "scaled_std", "scaled_max"]
    write_csv("table2_stretch.csv", header, rows)
    if verbose:
        print(fmt_table(header, rows, "Table 2: degradation from bound"))
        print(f"  [{len(records)} cells]")

    # paper-claim checks (qualitative, quick-scale)
    by = {r[0]: r for r in rows}
    fcfs, easy = by["FCFS"], by["EASY"]
    best = min((r for r in rows if r[0] not in ("FCFS", "EASY")),
               key=lambda r: r[7])

    # the paper's across-the-board winner is evaluated at HIGH load
    # (Fig. 1: below ~0.3, non-periodic greedy matches it — same crossover
    # we see at quick scale)
    hi_load = max(s.loads)

    def mean_deg_at_hi(policy):
        recs = records_for(records, "scaled", policy=policy, load=hi_load)
        return float(np.mean([r["degradation"] for r in recs]))

    win = "GreedyPM */per/OPT=MIN/MINVT=600"
    win_hi = mean_deg_at_hi(win)
    others_hi = {p: mean_deg_at_hi(p)
                 for p in TABLE2_POLICIES if p not in ("FCFS", "EASY")}
    claims = {
        "EASY <= FCFS (scaled avg)": easy[7] <= fcfs[7] * 1.05,
        "best DFRS >= 10x better than EASY (scaled avg)":
            best[7] * 10 <= easy[7],
        "GreedyPM */per/MINVT=600 within 2x of best at high load":
            win_hi <= 2.0 * min(others_hi.values()) + 0.5,
        "GreedyP beats Greedy (scaled avg)":
            by["GreedyP */OPT=MIN"][7] <= by["Greedy */OPT=MIN"][7],
        "/per alone worse than best greedy-per (scaled avg)":
            by["/per/OPT=MIN"][7] >= best[7],
        "/stretch-per ~ /per (scaled avg)":
            abs(by["/stretch-per/OPT=MAX"][7] - by["/per/OPT=MIN"][7])
            <= 0.5 * max(by["/per/OPT=MIN"][7], 1.0),
    }
    if verbose:
        for k, v in claims.items():
            print(f"  claim: {k}: {'PASS' if v else 'FAIL'}")
    return rows, claims
