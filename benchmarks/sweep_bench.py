"""Sweep-subsystem throughput benchmark → machine-readable BENCH_sweep.json.

Runs the canonical 16-cell grid (2 workloads × 4 policies × 2 scenarios)
through ``run_grid`` with 4 workers and records the perf trajectory numbers
(cells/sec, wall time) plus per-policy stretch aggregates.  The JSON lands
in the working directory as ``BENCH_sweep.json`` so successive PRs can
track scheduler throughput.
"""
from __future__ import annotations

import json
import platform
import time

from repro.sched.sweep import grid, run_batched, run_grid
from repro.workloads import registry
from repro.workloads.registry import WorkloadSpec

from . import common
from .common import Bench, fmt_table

BENCH_JSON = "BENCH_sweep.json"

NARRATOR_SPEC = "breakdown(mtbf=2e4,repair=2e3)+cancel(rate=2e-5)+noise"


def _narrator_session(scale, spec=None):
    from repro.sched.narrator import parse_narrator
    from repro.sched.session import open_session

    ses = open_session(scale.n_nodes, "GreedyP */OPT=MIN")
    if spec:
        ses.attach_narrator(parse_narrator(spec, seed=0))
    ses.submit(WorkloadSpec("lublin", n_jobs=scale.n_jobs,
                            n_nodes=scale.n_nodes, seed=0))
    return ses


def _narrator_overhead(scale):
    out = {"spec": NARRATOR_SPEC}
    for key, spec in (("clean", None), ("chaos", NARRATOR_SPEC)):
        t0 = time.perf_counter()
        ses = _narrator_session(scale, spec)
        r = ses.run()
        wall = time.perf_counter() - t0
        out[key] = {
            "wall_s": round(wall, 4),
            "events": r.events,
            "events_per_sec": round(r.events / max(wall, 1e-9), 1),
            "n_cancelled": r.n_cancelled,
            "n_pmtn": r.n_pmtn,
        }
    out["overhead_x"] = round(
        out["chaos"]["wall_s"] / max(out["clean"]["wall_s"], 1e-9), 3)
    return out

POLICIES = [
    "FCFS",
    "EASY",
    "GreedyP */OPT=MIN",
    "GreedyPM */per/OPT=MIN/MINVT=600",
]
SCENARIOS = ["baseline", "rack_failure"]
# canonical 4-worker shape, but never oversubscribe a smaller machine
# (cells/sec is the tracked trajectory number; n_workers lands in the JSON)
N_WORKERS = min(4, common.N_WORKERS)


def run(bench: Bench, verbose: bool = True):
    s = bench.scale
    workloads = [
        WorkloadSpec("lublin", n_jobs=s.n_jobs, n_nodes=s.n_nodes, seed=0),
        WorkloadSpec("hpc2n", n_jobs=s.n_jobs, n_nodes=128, seed=0),
    ]
    # trace materialization is now a separate, tracked cost: time a cold
    # columnar build of the grid's workloads (what each worker process pays
    # once before simulating its first cell of a workload)
    registry.trace_cache_clear()
    t0 = time.perf_counter()
    for w in workloads:
        registry.make_trace_ir(w)
    trace_s = time.perf_counter() - t0

    cells = grid(workloads, POLICIES, SCENARIOS)
    res = run_grid(cells, n_workers=N_WORKERS)

    per_policy = res.summary(
        by="policy",
        keys=("mean_stretch", "max_stretch", "wall_s", "sim_wall_s",
              "n_events"))
    # cells/s variance on a throttled box is mostly event-count variance:
    # record the grid's total engine events and events/s so trajectory
    # comparisons can normalize for it
    total_events = sum(r["n_events"] for r in res.records)
    sim_wall = sum(r["sim_wall_s"] for r in res.records)
    payload = {
        "bench": "sweep",
        "n_cells": res.n_cells,
        "n_workers": res.n_workers,
        "wall_s": round(res.wall_s, 3),
        "trace_materialization_s": round(trace_s, 3),
        "cells_per_sec": round(res.cells_per_sec, 4),
        "total_events": total_events,
        "events_per_sec": round(total_events / max(res.wall_s, 1e-9), 1),
        "sim_wall_s_total": round(sim_wall, 3),
        "grid": {"workloads": [w.name for w in workloads],
                 "policies": POLICIES, "scenarios": SCENARIOS},
        "per_policy": per_policy,
        "platform": platform.platform(),
    }

    # batched-backend trajectory: the same kind of grid (8 lublin seeds ×
    # one allocating policy) through the lockstep JAX backend vs numpy on
    # one worker, so batched_cells_per_sec sits next to cells_per_sec in
    # the tracked JSON.  Wall time includes jit compile — that is the real
    # cost a cold sweep pays, so it is the honest trajectory number.
    try:
        from repro.core.alloc_jax import has_jax
        if has_jax():
            b_cells = grid(
                [WorkloadSpec("lublin", n_jobs=s.n_jobs, n_nodes=s.n_nodes,
                              seed=i) for i in range(8)],
                ["GreedyP */OPT=MIN"], ["baseline"])
            b_np = run_grid(b_cells, compute_bound=False, n_workers=1)
            b_jax = run_batched(b_cells, compute_bound=False)
            parity = all(
                g["mean_stretch"] == r["mean_stretch"]
                and g["max_stretch"] == r["max_stretch"]
                for g, r in zip(b_jax.records, b_np.records))
            payload["batched_cells_per_sec"] = round(b_jax.cells_per_sec, 4)
            payload["batched"] = {
                "n_cells": b_jax.n_cells,
                "wall_s": round(b_jax.wall_s, 3),
                "numpy_1worker_cells_per_sec": round(b_np.cells_per_sec, 4),
                "policy": "GreedyP */OPT=MIN",
                "stretch_parity": parity,
            }
    except Exception as e:  # noqa: BLE001 — optional accelerator dep
        payload["batched"] = {"error": repr(e)}

    # narrator overhead: the same streaming session with and without chaos
    # streams (breakdown/cancel/noise), tracked as events/s — what the lazy
    # peek/fire loop and the truth-noise rewrite cost on top of a clean run
    payload["narrator"] = _narrator_overhead(s)

    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)

    if verbose:
        rows = [[p, round(v["mean_mean_stretch"], 1),
                 round(v["max_max_stretch"], 1), round(v["mean_wall_s"], 2)]
                for p, v in per_policy.items()]
        print(fmt_table(["policy", "mean_stretch", "max_stretch", "cell_s"],
                        rows, "Sweep bench (16 cells, 4 workers)"))
        print(f"  {res.n_cells} cells in {res.wall_s:.1f}s = "
              f"{res.cells_per_sec:.2f} cells/s, {total_events} engine "
              f"events ({payload['events_per_sec']:.0f} ev/s) "
              f"(+{trace_s:.2f}s cold trace materialization) -> {BENCH_JSON}")
        if "batched_cells_per_sec" in payload:
            b = payload["batched"]
            print(f"  batched backend: {b['n_cells']} cells in "
                  f"{b['wall_s']:.1f}s = {payload['batched_cells_per_sec']:.2f}"
                  f" cells/s (numpy 1-worker "
                  f"{b['numpy_1worker_cells_per_sec']:.2f}), "
                  f"stretch parity={b['stretch_parity']}")
        nar = payload["narrator"]
        print(f"  narrator overhead: clean "
              f"{nar['clean']['events_per_sec']:.0f} ev/s vs chaos "
              f"{nar['chaos']['events_per_sec']:.0f} ev/s "
              f"({nar['overhead_x']:.2f}x wall, "
              f"{nar['chaos']['n_cancelled']} cancels, "
              f"{nar['chaos']['n_pmtn']} pmtn)")
    return payload
