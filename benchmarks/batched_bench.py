"""Batched-sweep benchmark → machine-readable BENCH_batched.json.

Runs an N-seed grid (one workload family × one allocating policy × N
seeds) through serial numpy ``run_grid`` and the lockstep JAX backend
``run_batched`` — the latter twice, splitting *cold* (jit trace + XLA
compile) from *warm* (cached executable) cells/s — and records the
throughputs plus a per-cell parity check: every cell's mean/max stretch
must be *exactly* equal across the two paths (the backend's contract is
bit-identity under x64, stronger than the 1e-9 relative tolerance the
acceptance criterion asks for).  ``--compile-cache DIR`` additionally
enables JAX's persistent compilation cache there, so re-invocations skip
XLA compilation across processes.

CLI (used by the CI jax-smoke job)::

    PYTHONPATH=src python -m benchmarks.batched_bench --cells 8 \
        --jobs 40 --nodes 16 --matvec pallas

Exits non-zero on a parity mismatch only — throughput is recorded, never
gated (the batched path is compile-dominated at smoke scale; its win is
amortizing one jitted program over many lanes on an accelerator).
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
from typing import Optional

from repro.sched.sweep import grid, run_batched, run_grid
from repro.workloads.registry import WorkloadSpec

from .common import Bench

BENCH_JSON = "BENCH_batched.json"
POLICY = "GreedyP */OPT=MIN"


def _enable_compilation_cache(cache_dir: Optional[str]) -> Optional[str]:
    """Point JAX's persistent compilation cache at ``cache_dir`` so repeat
    invocations (CI re-runs, sweep restarts) skip XLA compilation entirely.
    Returns the directory actually configured, or None if unavailable."""
    if cache_dir is None:
        return None
    try:
        import jax
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # cache every program, however small/fast to compile: the lockstep
        # sweep kernel is one program, and it is exactly what we re-run
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)
    except Exception:
        return None
    return cache_dir


def run(bench: Bench, verbose: bool = True, n_cells: int = 100,
        n_jobs: int = 25, n_nodes: int = 8, matvec: str = "auto",
        cache_dir: Optional[str] = None) -> dict:
    """One seeded grid through both sweep paths; parity + throughput.

    The batched pass runs *twice*: the first (cold) pays jit tracing +
    XLA compilation — or a persistent-cache read when ``cache_dir`` is
    warm from an earlier process — while the second (warm) hits the
    in-process executable cache and measures pure lockstep throughput.
    Both are recorded; compile amortization is the whole point of the
    batched backend, so conflating the two in one number hides it.
    """
    cache_dir = _enable_compilation_cache(cache_dir)
    workloads = [WorkloadSpec("lublin", n_jobs=n_jobs, n_nodes=n_nodes,
                              seed=s) for s in range(n_cells)]
    cells = grid(workloads, [POLICY], ["baseline"])

    res_np = run_grid(cells, compute_bound=False, n_workers=1)
    res_jax = run_batched(cells, compute_bound=False, matvec=matvec)
    res_warm = run_batched(cells, compute_bound=False, matvec=matvec)

    mismatches = [
        {"workload": g["workload"], "seed": g["seed"],
         "jax": [g["mean_stretch"], g["max_stretch"]],
         "numpy": [r["mean_stretch"], r["max_stretch"]]}
        for g, r in zip(res_jax.records, res_np.records)
        if g["mean_stretch"] != r["mean_stretch"]
        or g["max_stretch"] != r["max_stretch"]
    ]
    payload = {
        "bench": "batched",
        "config": {"n_cells": n_cells, "n_jobs": n_jobs, "n_nodes": n_nodes,
                   "policy": POLICY, "matvec": matvec,
                   "compilation_cache_dir": cache_dir},
        "batched_cells_per_sec": round(res_jax.cells_per_sec, 4),
        "batched_wall_s": round(res_jax.wall_s, 3),
        "batched_warm_cells_per_sec": round(res_warm.cells_per_sec, 4),
        "batched_warm_wall_s": round(res_warm.wall_s, 3),
        "numpy_cells_per_sec": round(res_np.cells_per_sec, 4),
        "numpy_wall_s": round(res_np.wall_s, 3),
        "stretch_parity": not mismatches,
        "n_mismatches": len(mismatches),
        "mismatches": mismatches[:10],
        "platform": platform.platform(),
    }
    with open(BENCH_JSON, "w") as f:
        json.dump(payload, f, indent=1)

    if verbose:
        print(f"== Batched sweep ({n_cells} cells, {POLICY}, "
              f"matvec={matvec}) ==")
        print(f"  numpy 1-worker: {res_np.wall_s:.2f}s = "
              f"{res_np.cells_per_sec:.2f} cells/s")
        print(f"  jax cold:       {res_jax.wall_s:.2f}s = "
              f"{res_jax.cells_per_sec:.2f} cells/s (incl. jit compile)")
        print(f"  jax warm:       {res_warm.wall_s:.2f}s = "
              f"{res_warm.cells_per_sec:.2f} cells/s (executable cached)")
        print(f"  stretch parity: {payload['stretch_parity']} "
              f"({len(mismatches)} mismatches) -> {BENCH_JSON}")
    return payload


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cells", type=int, default=100,
                    help="number of seeds in the grid (default 100)")
    ap.add_argument("--jobs", type=int, default=25)
    ap.add_argument("--nodes", type=int, default=8)
    ap.add_argument("--matvec", default="auto",
                    choices=["auto", "jnp", "pallas"])
    ap.add_argument("--compile-cache", default=None, metavar="DIR",
                    help="persistent JAX compilation cache directory; a "
                         "warm cache makes even the cold pass skip XLA "
                         "compilation across processes/CI runs")
    ap.add_argument("--no-check-parity", dest="check_parity",
                    action="store_false", default=True,
                    help="record parity but never fail on it")
    args = ap.parse_args()

    from repro.core.alloc_jax import has_jax
    if not has_jax():
        print("jax not installed — batched bench skipped", file=sys.stderr)
        return 0

    from .common import QUICK

    payload = run(Bench(QUICK), n_cells=args.cells, n_jobs=args.jobs,
                  n_nodes=args.nodes, matvec=args.matvec,
                  cache_dir=args.compile_cache)
    if args.check_parity and not payload["stretch_parity"]:
        print(f"PARITY MISMATCH: {payload['n_mismatches']} cells diverge "
              f"from the numpy sweep (first: {payload['mismatches'][:1]})",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
