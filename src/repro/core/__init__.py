"""repro.core — the paper's contribution: DFRS scheduling algorithms.

Dynamic Fractional Resource Scheduling (Casanova, Stillwell, Vivien, 2011):
yield-driven fractional allocation of node resources with preemption and
migration, plus the offline max-stretch lower bound used for evaluation.
"""
from .job import JobSpec, JobState, NodePool, PENDING, RUNNING, PAUSED, COMPLETED
from .state import EngineState, JobView
from .yield_alloc import allocate, maxmin_yields, avg_yields, min_yield
from .greedy import greedy_place, greedy_p, greedy_pm, GreedyAdmission
from .mcb8 import mcb8, mcb8_pack, MCB8Result
from .stretch_opt import mcb8_stretch, improve_max_stretch, improve_avg_stretch, StretchResult
from .equipartition import equipartition_schedule, max_stretch, thm4_instance
from .bound import max_stretch_lower_bound, stretch_feasible
from .policies import (PolicySpec, parse_policy, render_policy,
                       TABLE1_POLICIES, all_paper_policies)

__all__ = [
    "JobSpec", "JobState", "NodePool", "EngineState", "JobView",
    "PENDING", "RUNNING", "PAUSED", "COMPLETED",
    "allocate", "maxmin_yields", "avg_yields", "min_yield",
    "greedy_place", "greedy_p", "greedy_pm", "GreedyAdmission",
    "mcb8", "mcb8_pack", "MCB8Result",
    "mcb8_stretch", "improve_max_stretch", "improve_avg_stretch", "StretchResult",
    "equipartition_schedule", "max_stretch", "thm4_instance",
    "max_stretch_lower_bound", "stretch_feasible",
    "PolicySpec", "parse_policy", "render_policy", "TABLE1_POLICIES",
    "all_paper_policies",
]
