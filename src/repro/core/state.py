"""Structure-of-arrays job state for the unified scheduling engine.

``EngineState`` keeps every per-job quantity the hot loop touches —
release / proc_time / vt / yield / status / penalty_until — in flat NumPy
arrays indexed by a dense job index (arrival order), so the fluid-progress
advance and the next-event computation are single vectorized expressions
instead of Python-object traversals.  Task→node mappings stay as per-job
lists (ragged, policy-produced) in ``mappings``.

Policy modules (``core.greedy``, ``core.mcb8``, ``core.stretch_opt``) are
written against the ``JobState`` object interface; ``JobView`` is a
zero-copy proxy with the same attribute surface whose reads/writes go
straight to the arrays, so policies run unchanged on top of the SoA core.

``EngineState.from_trace`` is the array-native constructor: a columnar
:class:`repro.workloads.trace.Trace` shares its layout with this state, so
the hot-loop arrays (proc_time / cpu_need / demand) ingest whole columns —
sorting is one ``lexsort``, demand one vectorized product — with no
per-spec Python loop.  The ``JobSpec`` object graph survives only at the
policy boundary (``JobView.spec``) and is rebuilt once per *trace* (not per
engine): traces are frozen and content-hashed, so the spec lists memoize
safely across the policy cells of a sweep.

Scale model (million-job traces):

* Arrays live in geometrically doubled capacity buffers; the public
  attributes are length-``n`` views, so online ``extend`` is amortized
  O(1) per job instead of a full reallocation per batch.
* The running / in-system index sets are maintained incrementally by
  ``set_status`` (sorted lists mirroring ``np.nonzero`` output exactly),
  so every hot-loop scan is O(active), not O(jobs ever submitted).
* ``compact()`` evicts COMPLETED/CANCELLED rows from the SoA arrays, the
  view list, and the node-incidence CSR, folding the per-job quantities
  ``Engine._result`` needs into the append-only :class:`RetiredLog`.
  Merged back in global-arrival order, the retired log reproduces the
  uncompacted metric accumulation **bit for bit** (same float op order) —
  the same oracle discipline ``alloc_reference`` applies to the kernels.
"""
from __future__ import annotations

from bisect import bisect_left, insort
from functools import lru_cache
from typing import Dict, List, Optional, Sequence

import numpy as np

from .alloc_kernels import NodeIncidence
from .job import (
    CANCELLED,
    COMPLETED,
    PAUSED,
    PENDING,
    RUNNING,
    JobSpec,
    NodePool,
)

__all__ = [
    "EngineState",
    "JobView",
    "RetiredLog",
    "S_NOT_ARRIVED",
    "S_PENDING",
    "S_RUNNING",
    "S_PAUSED",
    "S_COMPLETED",
    "S_CANCELLED",
]

_EPS = 1e-9

# integer status codes (array-friendly); "in system" == 0 < status < COMPLETED
# (CANCELLED > COMPLETED, so cancelled jobs fall out of every in-system mask)
S_NOT_ARRIVED = 0
S_PENDING = 1
S_RUNNING = 2
S_PAUSED = 3
S_COMPLETED = 4
S_CANCELLED = 5

_STATUS_STR = {
    S_PENDING: PENDING,
    S_RUNNING: RUNNING,
    S_PAUSED: PAUSED,
    S_COMPLETED: COMPLETED,
    S_CANCELLED: CANCELLED,
}
_STATUS_CODE = {v: k for k, v in _STATUS_STR.items()}

# per-job SoA columns managed by the capacity buffers (order is the
# copy/compact order; values never depend on it)
_COLS = (
    ("proc_time", np.float64),
    ("proc_truth", np.float64),
    ("cpu_need", np.float64),
    ("demand", np.float64),
    ("vt", np.float64),
    ("yld", np.float64),
    ("penalty_until", np.float64),
    ("completed_at", np.float64),
    ("status", np.int8),
    ("n_pmtn", np.int64),
    ("n_mig", np.int64),
    ("gidx", np.int64),
)


class JobView:
    """JobState-compatible view over one row of an ``EngineState``.

    Provides exactly the attributes/methods the policy modules read
    (``spec``, ``vt``, ``yld``, ``status``, ``mapping``, ``penalty_until``,
    ``priority_key`` …); assignments write through to the arrays.

    ``i`` is the *dense* row index and is rewritten in place by
    ``EngineState.compact`` — holders keep their object reference (batch
    queues, snapshots-in-progress) and never see a stale row.
    """

    __slots__ = ("_st", "i", "spec")

    def __init__(self, st: "EngineState", i: int):
        self._st = st
        self.i = i
        self.spec = st.specs[i]

    # ---- array-backed fields -------------------------------------------
    @property
    def vt(self) -> float:
        return float(self._st.vt[self.i])

    @vt.setter
    def vt(self, v: float) -> None:
        self._st.vt[self.i] = v

    @property
    def yld(self) -> float:
        return float(self._st.yld[self.i])

    @yld.setter
    def yld(self, v: float) -> None:
        self._st.yld[self.i] = v

    @property
    def penalty_until(self) -> float:
        return float(self._st.penalty_until[self.i])

    @penalty_until.setter
    def penalty_until(self, v: float) -> None:
        self._st.penalty_until[self.i] = v

    @property
    def status(self) -> str:
        return _STATUS_STR[int(self._st.status[self.i])]

    @status.setter
    def status(self, v: str) -> None:
        self._st.set_status(self.i, _STATUS_CODE[v])

    @property
    def mapping(self) -> Optional[List[int]]:
        return self._st.mappings[self.i]

    @mapping.setter
    def mapping(self, v: Optional[List[int]]) -> None:
        self._st.mappings[self.i] = v

    @property
    def completed_at(self) -> Optional[float]:
        c = self._st.completed_at[self.i]
        return None if np.isnan(c) else float(c)

    @completed_at.setter
    def completed_at(self, v: float) -> None:
        self._st.completed_at[self.i] = v

    @property
    def n_pmtn(self) -> int:
        return int(self._st.n_pmtn[self.i])

    @n_pmtn.setter
    def n_pmtn(self, v: int) -> None:
        self._st.n_pmtn[self.i] = v

    @property
    def n_mig(self) -> int:
        return int(self._st.n_mig[self.i])

    @n_mig.setter
    def n_mig(self, v: int) -> None:
        self._st.n_mig[self.i] = v

    # ---- scheduler-visible quantities (same formulas as JobState) -------
    def flow_time(self, now: float) -> float:
        return now - self.spec.release

    def priority(self, now: float) -> float:
        vt = self.vt
        if vt <= 0.0:
            return np.inf
        return self.flow_time(now) / (vt * vt)

    def priority_key(self, now: float):
        return (self.priority(now), -self.spec.jid)

    # ---- simulator-side quantities --------------------------------------
    def remaining_vt(self) -> float:
        # estimate-based (policies never see the truth column); under noisy
        # truth the job may run past its estimate, so clamp at zero
        return max(0.0, self.spec.proc_time - self.vt)

    @property
    def proc_truth(self) -> float:
        """Executed processing time — engine-side only; policies must keep
        reading ``spec.proc_time`` (the non-clairvoyant estimate)."""
        return float(self._st.proc_truth[self.i])

    @property
    def is_running(self) -> bool:
        return int(self._st.status[self.i]) == S_RUNNING


class RetiredLog:
    """Streaming per-job accumulators for rows evicted by ``compact()``.

    Stores, per retired job, exactly the raw inputs ``Engine._result``
    needs — global arrival index, jid, release, completion time (NaN marks
    cancelled), executed processing time, and the precomputed work term
    ``n_tasks * proc_truth * cpu_need`` (that exact multiply order) — so
    the final metrics can be re-accumulated in the original global order
    with bit-identical float arithmetic.
    """

    _RCOLS = (
        ("gidx", np.int64),
        ("jid", np.int64),
        ("release", np.float64),
        ("completed_at", np.float64),
        ("proc_truth", np.float64),
        ("work", np.float64),
    )

    __slots__ = ("_n", "_cap", "_bufs", "n_cancelled", "n_noisy",
                 "_jid_sorted", "_jid_dirty")

    def __init__(self) -> None:
        self._n = 0
        self._cap = 0
        self._bufs: Dict[str, np.ndarray] = {
            name: np.empty(0, dtype=dt) for name, dt in self._RCOLS}
        self.n_cancelled = 0
        self.n_noisy = 0
        self._jid_sorted = np.empty(0, dtype=np.int64)
        self._jid_dirty = False

    def __len__(self) -> int:
        return self._n

    @property
    def n_completed(self) -> int:
        return self._n - self.n_cancelled

    def col(self, name: str) -> np.ndarray:
        return self._bufs[name][: self._n]

    def _ensure(self, need: int) -> None:
        if need <= self._cap:
            return
        cap = max(need, 2 * self._cap, 1024)
        for name, dt in self._RCOLS:
            buf = np.empty(cap, dtype=dt)
            buf[: self._n] = self._bufs[name][: self._n]
            self._bufs[name] = buf
        self._cap = cap

    def append(self, st: "EngineState", idx: np.ndarray) -> None:
        """Fold the (about-to-be-evicted) rows ``idx`` of ``st`` in."""
        k = int(idx.shape[0])
        if k == 0:
            return
        self._ensure(self._n + k)
        n0, n1 = self._n, self._n + k
        b = self._bufs
        b["gidx"][n0:n1] = st.gidx[idx]
        b["completed_at"][n0:n1] = st.completed_at[idx]
        b["proc_truth"][n0:n1] = st.proc_truth[idx]
        jid = b["jid"]
        rel = b["release"]
        wrk = b["work"]
        pt = st.proc_truth
        est = st.proc_time
        status = st.status
        nc = nz = 0
        for j, i in enumerate(idx.tolist()):
            s = st.specs[i]
            jid[n0 + j] = s.jid
            rel[n0 + j] = s.release
            if int(status[i]) == S_CANCELLED:
                wrk[n0 + j] = 0.0
                nc += 1
            else:
                # exact op order of Engine._result's total_work term
                wrk[n0 + j] = s.n_tasks * float(pt[i]) * s.cpu_need
            if pt[i] != est[i]:
                nz += 1
        self.n_cancelled += nc
        self.n_noisy += nz
        self._n = n1
        self._jid_dirty = True

    def contains(self, jids: Sequence[int]) -> List[int]:
        """Subset of ``jids`` already retired (for submit dup-checks)."""
        if self._n == 0:
            return []
        if self._jid_dirty:
            # stable sort exploits the sorted-runs structure of merged logs
            self._jid_sorted = np.sort(self.col("jid"), kind="stable")
            self._jid_dirty = False
        q = np.asarray(list(jids), dtype=np.int64)
        if q.size == 0:
            return []
        srt = self._jid_sorted
        pos = np.minimum(np.searchsorted(srt, q), srt.size - 1)
        return [int(x) for x in q[srt[pos] == q]]

    # ---- snapshot plumbing ----------------------------------------------
    def payload(self) -> dict:
        out = {name: self.col(name).tolist() for name, _ in self._RCOLS}
        out["n_cancelled"] = int(self.n_cancelled)
        out["n_noisy"] = int(self.n_noisy)
        return out

    @classmethod
    def from_payload(cls, payload: dict) -> "RetiredLog":
        log = cls()
        n = len(payload["gidx"])
        log._ensure(n)
        for name, dt in cls._RCOLS:
            log._bufs[name][:n] = np.asarray(payload[name], dtype=dt)
        log._n = n
        log.n_cancelled = int(payload["n_cancelled"])
        log.n_noisy = int(payload.get("n_noisy", 0))
        log._jid_dirty = True
        return log


def _sorted_add(lst: List[int], i: int) -> None:
    """Duplicate-safe insort (tolerates out-of-band status-array writes:
    the sets then stay merely incomplete, never corrupted)."""
    p = bisect_left(lst, i)
    if p >= len(lst) or lst[p] != i:
        lst.insert(p, i)


def _sorted_drop(lst: List[int], i: int) -> None:
    p = bisect_left(lst, i)
    if p < len(lst) and lst[p] == i:
        del lst[p]


@lru_cache(maxsize=64)
def _specs_of(trace) -> tuple:
    """Policy-boundary ``JobSpec`` objects for a (sorted) trace, memoized by
    the trace's content fingerprint — the cells of a policy sweep share one
    spec list per trace instead of rebuilding the object graph per engine."""
    return tuple(trace.to_specs())


class EngineState:
    """All dynamic job state of one simulation, as flat arrays.

    The job index is arrival order (specs sorted by ``(release, jid)``);
    every policy-facing iteration below yields views in index order, which
    matches the insertion order of the pre-refactor per-job dict exactly.
    Under compaction the *global* arrival index lives in ``gidx`` (strictly
    increasing over the live rows) while the dense index stays contiguous.
    """

    def __init__(self, specs: Sequence[JobSpec], n_nodes: int):
        self.specs = list(specs)
        self.proc_time = np.array([s.proc_time for s in self.specs], dtype=np.float64)
        # truth column: what the engine executes.  Defaults to the estimate
        # (clairvoyant); narrator noise or a trace truth column diverge it.
        self.proc_truth = self.proc_time.copy()
        self.cpu_need = np.array([s.cpu_need for s in self.specs], dtype=np.float64)
        # per-job demand, n_tasks * cpu_need — reused every advance
        self.demand = np.array(
            [s.n_tasks * s.cpu_need for s in self.specs], dtype=np.float64)
        self._init_dynamic(n_nodes)

    @classmethod
    def from_trace(cls, trace, n_nodes: int) -> "EngineState":
        """Array-native construction from a columnar Trace: the hot-loop
        arrays are whole-column copies (ordering by one lexsort), and the
        policy-facing ``JobSpec`` list is memoized per trace fingerprint."""
        trace = trace.sorted_by_release()
        st = cls.__new__(cls)
        st.specs = list(_specs_of(trace))
        st.proc_time = trace.proc_time.astype(np.float64)     # writable copy
        truth = getattr(trace, "proc_truth", None)
        st.proc_truth = (truth.astype(np.float64) if truth is not None
                         else st.proc_time.copy())
        st.cpu_need = trace.cpu_need.astype(np.float64)
        st.demand = trace.n_tasks * trace.cpu_need
        st._init_dynamic(n_nodes)
        return st

    def _init_dynamic(self, n_nodes: int) -> None:
        n = len(self.specs)
        self.vt = np.zeros(n)
        self.yld = np.zeros(n)
        self.penalty_until = np.full(n, -np.inf)
        self.completed_at = np.full(n, np.nan)
        self.status = np.full(n, S_NOT_ARRIVED, dtype=np.int8)
        self.n_pmtn = np.zeros(n, dtype=np.int64)
        self.n_mig = np.zeros(n, dtype=np.int64)
        self.gidx = np.arange(n, dtype=np.int64)
        self.mappings: List[Optional[List[int]]] = [None] * n
        self.views = [JobView(self, i) for i in range(n)]

        # lifetime accounting that survives compaction
        self.n_total = n                       # jobs ever submitted
        self.first_release = min(
            (s.release for s in self.specs), default=np.inf)
        self.retired = RetiredLog()

        # adopt the freshly built arrays as capacity buffers (no copy);
        # extend() grows them geometrically from here
        self._cap = n
        self._bufs = {name: getattr(self, name) for name, _ in _COLS}
        self.grow_count = 0                    # buffer reallocations (tests)

        self.pool = NodePool(n_nodes)
        # job×node CSR incidence of the running tasks, kept consistent by
        # the engine on every start/pause/migrate/complete transition — the
        # §4.6 allocation kernels read it instead of rescanning mappings
        self.inc = NodeIncidence(n_nodes, self.cpu_need)
        self.alive = np.ones(n_nodes, dtype=bool)
        self.now = 0.0
        self.util_integral = 0.0       # ∫ useful allocation dt
        self.demand_integral = 0.0     # ∫ min(|P|, demand) dt

        # incremental index sets + demand-sum cache (O(active) hot loop)
        self._dvers = 0
        self._dsum: Optional[float] = None
        self._dsum_vers = -1
        self.rebuild_index_sets()

    # ------------------------------------------------------------------ #
    # capacity management                                                 #
    # ------------------------------------------------------------------ #
    def _ensure_capacity(self, need: int) -> None:
        if need <= self._cap:
            return
        n = len(self.specs)
        cap = max(need, 2 * self._cap, 16)
        for name, dt in _COLS:
            buf = np.empty(cap, dtype=dt)
            buf[:n] = self._bufs[name][:n]
            self._bufs[name] = buf
        self._cap = cap
        self.grow_count += 1

    def _reslice(self, n: int) -> None:
        for name, _ in _COLS:
            setattr(self, name, self._bufs[name][:n])

    @property
    def capacity(self) -> int:
        return self._cap

    # ------------------------------------------------------------------ #
    # online ingest (streaming sessions)                                  #
    # ------------------------------------------------------------------ #
    def extend(self, specs: Sequence[JobSpec]) -> List[int]:
        """Append jobs to the SoA state mid-simulation (true online
        arrivals for :class:`repro.sched.session.SimSession`).

        New rows start as ``S_NOT_ARRIVED``; the per-spec column values are
        computed by the exact expressions ``__init__`` uses, so a state
        grown in batches is bit-identical to one built in a single shot.
        Appends land in geometrically doubled buffers (amortized O(1) per
        job — no per-batch reallocation).  Returns the dense indices
        assigned to the new jobs.
        """
        specs = list(specs)
        if not specs:
            return []
        base = len(self.specs)
        k = len(specs)
        self._ensure_capacity(base + k)
        tail_proc = np.array([s.proc_time for s in specs], dtype=np.float64)
        tail_cpu = np.array([s.cpu_need for s in specs], dtype=np.float64)
        tail_dem = np.array(
            [s.n_tasks * s.cpu_need for s in specs], dtype=np.float64)
        b = self._bufs
        sl = slice(base, base + k)
        b["proc_time"][sl] = tail_proc
        # new rows start clairvoyant; a narrator noise stream perturbs the
        # truth right after submit (before the jobs can arrive)
        b["proc_truth"][sl] = tail_proc
        b["cpu_need"][sl] = tail_cpu
        b["demand"][sl] = tail_dem
        b["vt"][sl] = 0.0
        b["yld"][sl] = 0.0
        b["penalty_until"][sl] = -np.inf
        b["completed_at"][sl] = np.nan
        b["status"][sl] = S_NOT_ARRIVED
        b["n_pmtn"][sl] = 0
        b["n_mig"][sl] = 0
        b["gidx"][sl] = np.arange(
            self.n_total, self.n_total + k, dtype=np.int64)
        self._reslice(base + k)
        self.n_total += k
        self.first_release = min(
            self.first_release, min(s.release for s in specs))
        self.specs.extend(specs)
        self.mappings.extend([None] * k)
        self.views.extend(JobView(self, base + j) for j in range(k))
        self.inc.extend(tail_cpu)
        return list(range(base, base + k))

    # ------------------------------------------------------------------ #
    # incremental index sets                                              #
    # ------------------------------------------------------------------ #
    def set_status(self, i: int, code: int) -> None:
        """The single write path for status transitions: keeps the sorted
        running / in-system index lists (and retired count) in sync so the
        hot-loop scans stay O(active)."""
        i = int(i)
        old = int(self.status[i])
        if old == code:
            return
        self.status[i] = code
        was_in = S_NOT_ARRIVED < old < S_COMPLETED
        now_in = S_NOT_ARRIVED < code < S_COMPLETED
        if was_in != now_in:
            if now_in:
                _sorted_add(self._ins, i)
            else:
                _sorted_drop(self._ins, i)
            self._ins_arr = None
            self._dvers += 1
        was_run = old == S_RUNNING
        now_run = code == S_RUNNING
        if was_run != now_run:
            if now_run:
                _sorted_add(self._run, i)
            else:
                _sorted_drop(self._run, i)
            self._run_arr = None
        if code >= S_COMPLETED and old < S_COMPLETED:
            self._n_retired += 1

    def set_demand(self, i: int, value: float) -> None:
        """Demand writes (job resize) invalidate the cached in-system sum."""
        self.demand[int(i)] = value
        self._dvers += 1

    def rebuild_index_sets(self) -> None:
        """Recompute the incremental sets from the status array — for
        wholesale writes (snapshot restore) and after compaction."""
        st = self.status
        self._run: List[int] = np.nonzero(st == S_RUNNING)[0].tolist()
        self._ins: List[int] = np.nonzero(
            (st > S_NOT_ARRIVED) & (st < S_COMPLETED))[0].tolist()
        self._run_arr: Optional[np.ndarray] = None
        self._ins_arr: Optional[np.ndarray] = None
        self._n_retired = int((st >= S_COMPLETED).sum())
        self._dvers += 1

    @property
    def n_retired_rows(self) -> int:
        """Live COMPLETED/CANCELLED rows currently evictable by compact()."""
        return self._n_retired

    def in_system_demand(self) -> float:
        """Cached ``demand[in_system].sum()`` — recomputed (by the exact
        same expression) only when the set or a demand entry changed."""
        if self._dsum is None or self._dsum_vers != self._dvers:
            ins = self.in_system_indices()
            self._dsum = float(self.demand[ins].sum())
            self._dsum_vers = self._dvers
        return self._dsum

    # ------------------------------------------------------------------ #
    # index helpers                                                       #
    # ------------------------------------------------------------------ #
    def running_indices(self) -> np.ndarray:
        arr = self._run_arr
        if arr is None:
            arr = self._run_arr = np.asarray(self._run, dtype=np.intp)
        return arr

    def in_system_indices(self) -> np.ndarray:
        arr = self._ins_arr
        if arr is None:
            arr = self._ins_arr = np.asarray(self._ins, dtype=np.intp)
        return arr

    def running(self) -> List[JobView]:
        return [self.views[i] for i in self.running_indices()]

    def uncompleted(self) -> List[JobView]:
        return [self.views[i] for i in self.in_system_indices()]

    def any_in_system(self) -> bool:
        return bool(self._ins)

    # ------------------------------------------------------------------ #
    # compaction                                                          #
    # ------------------------------------------------------------------ #
    def compact(self, protect: Optional[Sequence[int]] = None
                ) -> Optional[np.ndarray]:
        """Evict COMPLETED/CANCELLED rows from the SoA arrays.

        Their result-bearing quantities are folded into ``self.retired``
        (see :class:`RetiredLog`); surviving rows slide down in order, so
        both the dense index and ``gidx`` stay strictly increasing.  Every
        ``JobView`` of a surviving row has its ``.i`` rewritten *in place*
        (object identity preserved for policy queues), and the node
        incidence is remapped.  ``protect`` lists dense indices to keep
        regardless of status (e.g. rows with a pending arrival-heap entry,
        whose pop must still happen).

        Returns the old→new dense index map (``-1`` for evicted rows), or
        ``None`` if nothing was evictable.
        """
        status = self.status
        n = status.shape[0]
        keep_mask = status < S_COMPLETED
        if protect is not None and len(protect):
            keep_mask[np.asarray(protect, dtype=np.intp)] = True
        if bool(keep_mask.all()):
            return None
        keep = np.nonzero(keep_mask)[0]
        evict = np.nonzero(~keep_mask)[0]
        self.retired.append(self, evict)
        m = int(keep.shape[0])
        new_of_old = np.full(n, -1, dtype=np.int64)
        new_of_old[keep] = np.arange(m, dtype=np.int64)
        for name, _ in _COLS:
            buf = self._bufs[name]
            buf[:m] = buf[:n][keep]
        self._reslice(m)
        keep_list = keep.tolist()
        self.specs = [self.specs[i] for i in keep_list]
        self.mappings = [self.mappings[i] for i in keep_list]
        old_views = self.views
        views = []
        for newi, oldi in enumerate(keep_list):
            v = old_views[oldi]
            v.i = newi
            views.append(v)
        self.views = views
        self.inc.compact(keep, new_of_old)
        self.rebuild_index_sets()
        return new_of_old

    # ------------------------------------------------------------------ #
    # vectorized hot-loop kernels                                         #
    # ------------------------------------------------------------------ #
    def next_completion_time(self) -> float:
        """Earliest time any running job's virtual time reaches p_j."""
        run = self.running_indices()
        if run.size == 0:
            return np.inf
        yld = self.yld[run]
        ok = yld > _EPS
        if not ok.any():
            return np.inf
        run = run[ok]
        yld = yld[ok]
        t0 = np.maximum(self.now, self.penalty_until[run])
        t = t0 + (self.proc_truth[run] - self.vt[run]) / yld
        return float(t.min())

    def finished_running_indices(self) -> np.ndarray:
        """Running jobs whose remaining virtual time is exhausted.

        Besides the absolute ``rem <= _EPS`` cut, a job whose *projected
        completion time* rounds to ``<= now`` is finished too: at large
        simulation times (multi-month traces, ``eps(now) > 1e-9``) the
        event loop cannot represent a later timestamp for it, so leaving
        it running would spin the loop at constant ``now`` forever.  For
        ``now`` below ~4e6 s the extra cut is unreachable (the projection
        adds at least ``rem > _EPS`` to ``now``), so small-trace runs are
        bit-identical with or without it.
        """
        run = self.running_indices()
        if run.size == 0:
            return run
        yld = self.yld[run]
        rem = self.proc_truth[run] - self.vt[run]
        active = yld > _EPS
        done = (rem <= _EPS) & active
        if active.any():
            t0 = np.maximum(self.now, self.penalty_until[run])
            with np.errstate(divide="ignore", invalid="ignore"):
                proj = t0 + rem / yld
            done |= active & (proj <= self.now)
        return run[done]

    def advance(self, t_next: float) -> None:
        """Advance virtual times + utilization integrals to ``t_next``.

        u(t) is piecewise-constant except at penalty expiries inside the
        window; integrate exactly by splitting at those points.
        """
        if t_next <= self.now:
            return
        demand = self.in_system_demand()
        cap = float(self.alive.sum())
        run = self.running_indices()
        pen = self.penalty_until[run]
        inner_mask = (pen > self.now) & (pen < t_next)
        contrib = self.yld[run] * self.demand[run]
        if not inner_mask.any():
            # fast path (the common case): no penalty expiry strictly inside
            # the window, so u(t) is constant on [now, t_next) — exactly the
            # single segment the cut machinery below would produce.
            u = float(contrib[pen <= self.now + _EPS].sum())
            dt = t_next - self.now
            self.util_integral += u * dt
            self.demand_integral += min(cap, demand) * dt
        else:
            cuts = np.unique(np.concatenate(
                [[self.now, t_next], pen[inner_mask]]))
            for a, b in zip(cuts[:-1], cuts[1:]):
                u = float(contrib[pen <= a + _EPS].sum())
                self.util_integral += u * (b - a)
                self.demand_integral += min(cap, demand) * (b - a)
        eff = np.maximum(0.0, t_next - np.maximum(self.now, pen))
        self.vt[run] = np.minimum(
            self.proc_truth[run], self.vt[run] + self.yld[run] * eff
        )
        self.now = t_next
