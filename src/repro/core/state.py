"""Structure-of-arrays job state for the unified scheduling engine.

``EngineState`` keeps every per-job quantity the hot loop touches —
release / proc_time / vt / yield / status / penalty_until — in flat NumPy
arrays indexed by a dense job index (arrival order), so the fluid-progress
advance and the next-event computation are single vectorized expressions
instead of Python-object traversals.  Task→node mappings stay as per-job
lists (ragged, policy-produced) in ``mappings``.

Policy modules (``core.greedy``, ``core.mcb8``, ``core.stretch_opt``) are
written against the ``JobState`` object interface; ``JobView`` is a
zero-copy proxy with the same attribute surface whose reads/writes go
straight to the arrays, so policies run unchanged on top of the SoA core.

``EngineState.from_trace`` is the array-native constructor: a columnar
:class:`repro.workloads.trace.Trace` shares its layout with this state, so
the hot-loop arrays (proc_time / cpu_need / demand) ingest whole columns —
sorting is one ``lexsort``, demand one vectorized product — with no
per-spec Python loop.  The ``JobSpec`` object graph survives only at the
policy boundary (``JobView.spec``) and is rebuilt once per *trace* (not per
engine): traces are frozen and content-hashed, so the spec lists memoize
safely across the policy cells of a sweep.
"""
from __future__ import annotations

from functools import lru_cache
from typing import List, Optional, Sequence

import numpy as np

from .alloc_kernels import NodeIncidence
from .job import (
    CANCELLED,
    COMPLETED,
    PAUSED,
    PENDING,
    RUNNING,
    JobSpec,
    NodePool,
)

__all__ = [
    "EngineState",
    "JobView",
    "S_NOT_ARRIVED",
    "S_PENDING",
    "S_RUNNING",
    "S_PAUSED",
    "S_COMPLETED",
    "S_CANCELLED",
]

_EPS = 1e-9

# integer status codes (array-friendly); "in system" == 0 < status < COMPLETED
# (CANCELLED > COMPLETED, so cancelled jobs fall out of every in-system mask)
S_NOT_ARRIVED = 0
S_PENDING = 1
S_RUNNING = 2
S_PAUSED = 3
S_COMPLETED = 4
S_CANCELLED = 5

_STATUS_STR = {
    S_PENDING: PENDING,
    S_RUNNING: RUNNING,
    S_PAUSED: PAUSED,
    S_COMPLETED: COMPLETED,
    S_CANCELLED: CANCELLED,
}
_STATUS_CODE = {v: k for k, v in _STATUS_STR.items()}


class JobView:
    """JobState-compatible view over one row of an ``EngineState``.

    Provides exactly the attributes/methods the policy modules read
    (``spec``, ``vt``, ``yld``, ``status``, ``mapping``, ``penalty_until``,
    ``priority_key`` …); assignments write through to the arrays.
    """

    __slots__ = ("_st", "i", "spec")

    def __init__(self, st: "EngineState", i: int):
        self._st = st
        self.i = i
        self.spec = st.specs[i]

    # ---- array-backed fields -------------------------------------------
    @property
    def vt(self) -> float:
        return float(self._st.vt[self.i])

    @vt.setter
    def vt(self, v: float) -> None:
        self._st.vt[self.i] = v

    @property
    def yld(self) -> float:
        return float(self._st.yld[self.i])

    @yld.setter
    def yld(self, v: float) -> None:
        self._st.yld[self.i] = v

    @property
    def penalty_until(self) -> float:
        return float(self._st.penalty_until[self.i])

    @penalty_until.setter
    def penalty_until(self, v: float) -> None:
        self._st.penalty_until[self.i] = v

    @property
    def status(self) -> str:
        return _STATUS_STR[int(self._st.status[self.i])]

    @status.setter
    def status(self, v: str) -> None:
        self._st.status[self.i] = _STATUS_CODE[v]

    @property
    def mapping(self) -> Optional[List[int]]:
        return self._st.mappings[self.i]

    @mapping.setter
    def mapping(self, v: Optional[List[int]]) -> None:
        self._st.mappings[self.i] = v

    @property
    def completed_at(self) -> Optional[float]:
        c = self._st.completed_at[self.i]
        return None if np.isnan(c) else float(c)

    @completed_at.setter
    def completed_at(self, v: float) -> None:
        self._st.completed_at[self.i] = v

    @property
    def n_pmtn(self) -> int:
        return int(self._st.n_pmtn[self.i])

    @n_pmtn.setter
    def n_pmtn(self, v: int) -> None:
        self._st.n_pmtn[self.i] = v

    @property
    def n_mig(self) -> int:
        return int(self._st.n_mig[self.i])

    @n_mig.setter
    def n_mig(self, v: int) -> None:
        self._st.n_mig[self.i] = v

    # ---- scheduler-visible quantities (same formulas as JobState) -------
    def flow_time(self, now: float) -> float:
        return now - self.spec.release

    def priority(self, now: float) -> float:
        vt = self.vt
        if vt <= 0.0:
            return np.inf
        return self.flow_time(now) / (vt * vt)

    def priority_key(self, now: float):
        return (self.priority(now), -self.spec.jid)

    # ---- simulator-side quantities --------------------------------------
    def remaining_vt(self) -> float:
        # estimate-based (policies never see the truth column); under noisy
        # truth the job may run past its estimate, so clamp at zero
        return max(0.0, self.spec.proc_time - self.vt)

    @property
    def proc_truth(self) -> float:
        """Executed processing time — engine-side only; policies must keep
        reading ``spec.proc_time`` (the non-clairvoyant estimate)."""
        return float(self._st.proc_truth[self.i])

    @property
    def is_running(self) -> bool:
        return int(self._st.status[self.i]) == S_RUNNING


@lru_cache(maxsize=64)
def _specs_of(trace) -> tuple:
    """Policy-boundary ``JobSpec`` objects for a (sorted) trace, memoized by
    the trace's content fingerprint — the cells of a policy sweep share one
    spec list per trace instead of rebuilding the object graph per engine."""
    return tuple(trace.to_specs())


class EngineState:
    """All dynamic job state of one simulation, as flat arrays.

    The job index is arrival order (specs sorted by ``(release, jid)``);
    every policy-facing iteration below yields views in index order, which
    matches the insertion order of the pre-refactor per-job dict exactly.
    """

    def __init__(self, specs: Sequence[JobSpec], n_nodes: int):
        self.specs = list(specs)
        self.proc_time = np.array([s.proc_time for s in self.specs], dtype=np.float64)
        # truth column: what the engine executes.  Defaults to the estimate
        # (clairvoyant); narrator noise or a trace truth column diverge it.
        self.proc_truth = self.proc_time.copy()
        self.cpu_need = np.array([s.cpu_need for s in self.specs], dtype=np.float64)
        # per-job demand, n_tasks * cpu_need — reused every advance
        self.demand = np.array(
            [s.n_tasks * s.cpu_need for s in self.specs], dtype=np.float64)
        self._init_dynamic(n_nodes)

    @classmethod
    def from_trace(cls, trace, n_nodes: int) -> "EngineState":
        """Array-native construction from a columnar Trace: the hot-loop
        arrays are whole-column copies (ordering by one lexsort), and the
        policy-facing ``JobSpec`` list is memoized per trace fingerprint."""
        trace = trace.sorted_by_release()
        st = cls.__new__(cls)
        st.specs = list(_specs_of(trace))
        st.proc_time = trace.proc_time.astype(np.float64)     # writable copy
        truth = getattr(trace, "proc_truth", None)
        st.proc_truth = (truth.astype(np.float64) if truth is not None
                         else st.proc_time.copy())
        st.cpu_need = trace.cpu_need.astype(np.float64)
        st.demand = trace.n_tasks * trace.cpu_need
        st._init_dynamic(n_nodes)
        return st

    def _init_dynamic(self, n_nodes: int) -> None:
        n = len(self.specs)
        self.vt = np.zeros(n)
        self.yld = np.zeros(n)
        self.penalty_until = np.full(n, -np.inf)
        self.completed_at = np.full(n, np.nan)
        self.status = np.full(n, S_NOT_ARRIVED, dtype=np.int8)
        self.n_pmtn = np.zeros(n, dtype=np.int64)
        self.n_mig = np.zeros(n, dtype=np.int64)
        self.mappings: List[Optional[List[int]]] = [None] * n
        self.views = [JobView(self, i) for i in range(n)]

        self.pool = NodePool(n_nodes)
        # job×node CSR incidence of the running tasks, kept consistent by
        # the engine on every start/pause/migrate/complete transition — the
        # §4.6 allocation kernels read it instead of rescanning mappings
        self.inc = NodeIncidence(n_nodes, self.cpu_need)
        self.alive = np.ones(n_nodes, dtype=bool)
        self.now = 0.0
        self.util_integral = 0.0       # ∫ useful allocation dt
        self.demand_integral = 0.0     # ∫ min(|P|, demand) dt

    # ------------------------------------------------------------------ #
    # online ingest (streaming sessions)                                  #
    # ------------------------------------------------------------------ #
    def extend(self, specs: Sequence[JobSpec]) -> List[int]:
        """Append jobs to the SoA state mid-simulation (true online
        arrivals for :class:`repro.sched.session.SimSession`).

        New rows start as ``S_NOT_ARRIVED``; the per-spec column values are
        computed by the exact expressions ``__init__`` uses, so a state
        grown in batches is bit-identical to one built in a single shot.
        Returns the dense indices assigned to the new jobs.
        """
        specs = list(specs)
        if not specs:
            return []
        base = len(self.specs)
        k = len(specs)
        self.specs.extend(specs)
        tail_proc = np.array([s.proc_time for s in specs], dtype=np.float64)
        tail_cpu = np.array([s.cpu_need for s in specs], dtype=np.float64)
        tail_dem = np.array(
            [s.n_tasks * s.cpu_need for s in specs], dtype=np.float64)
        self.proc_time = np.concatenate([self.proc_time, tail_proc])
        # new rows start clairvoyant; a narrator noise stream perturbs the
        # truth right after submit (before the jobs can arrive)
        self.proc_truth = np.concatenate([self.proc_truth, tail_proc.copy()])
        self.cpu_need = np.concatenate([self.cpu_need, tail_cpu])
        self.demand = np.concatenate([self.demand, tail_dem])
        self.vt = np.concatenate([self.vt, np.zeros(k)])
        self.yld = np.concatenate([self.yld, np.zeros(k)])
        self.penalty_until = np.concatenate(
            [self.penalty_until, np.full(k, -np.inf)])
        self.completed_at = np.concatenate(
            [self.completed_at, np.full(k, np.nan)])
        self.status = np.concatenate(
            [self.status, np.full(k, S_NOT_ARRIVED, dtype=np.int8)])
        self.n_pmtn = np.concatenate(
            [self.n_pmtn, np.zeros(k, dtype=np.int64)])
        self.n_mig = np.concatenate([self.n_mig, np.zeros(k, dtype=np.int64)])
        self.mappings.extend([None] * k)
        self.views.extend(JobView(self, base + j) for j in range(k))
        self.inc.extend(tail_cpu)
        return list(range(base, base + k))

    # ------------------------------------------------------------------ #
    # index helpers                                                       #
    # ------------------------------------------------------------------ #
    def running_indices(self) -> np.ndarray:
        return np.nonzero(self.status == S_RUNNING)[0]

    def in_system_indices(self) -> np.ndarray:
        return np.nonzero((self.status > S_NOT_ARRIVED) & (self.status < S_COMPLETED))[0]

    def running(self) -> List[JobView]:
        return [self.views[i] for i in self.running_indices()]

    def uncompleted(self) -> List[JobView]:
        return [self.views[i] for i in self.in_system_indices()]

    def any_in_system(self) -> bool:
        return bool(((self.status > S_NOT_ARRIVED) & (self.status < S_COMPLETED)).any())

    # ------------------------------------------------------------------ #
    # vectorized hot-loop kernels                                         #
    # ------------------------------------------------------------------ #
    def next_completion_time(self) -> float:
        """Earliest time any running job's virtual time reaches p_j."""
        run = self.running_indices()
        if run.size == 0:
            return np.inf
        yld = self.yld[run]
        ok = yld > _EPS
        if not ok.any():
            return np.inf
        run = run[ok]
        yld = yld[ok]
        t0 = np.maximum(self.now, self.penalty_until[run])
        t = t0 + (self.proc_truth[run] - self.vt[run]) / yld
        return float(t.min())

    def finished_running_indices(self) -> np.ndarray:
        """Running jobs whose remaining virtual time is exhausted."""
        run = self.running_indices()
        if run.size == 0:
            return run
        done = (self.proc_truth[run] - self.vt[run] <= _EPS) & (self.yld[run] > _EPS)
        return run[done]

    def advance(self, t_next: float) -> None:
        """Advance virtual times + utilization integrals to ``t_next``.

        u(t) is piecewise-constant except at penalty expiries inside the
        window; integrate exactly by splitting at those points.
        """
        if t_next <= self.now:
            return
        ins = self.in_system_indices()
        demand = float(self.demand[ins].sum())
        cap = float(self.alive.sum())
        run = self.running_indices()
        pen = self.penalty_until[run]
        inner = pen[(pen > self.now) & (pen < t_next)]
        cuts = np.unique(np.concatenate([[self.now, t_next], inner]))
        contrib = self.yld[run] * self.demand[run]
        for a, b in zip(cuts[:-1], cuts[1:]):
            u = float(contrib[pen <= a + _EPS].sum())
            self.util_integral += u * (b - a)
            self.demand_integral += min(cap, demand) * (b - a)
        eff = np.maximum(0.0, t_next - np.maximum(self.now, pen))
        self.vt[run] = np.minimum(
            self.proc_truth[run], self.vt[run] + self.yld[run] * eff
        )
        self.now = t_next
