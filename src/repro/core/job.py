"""Job / task / cluster-node model for DFRS (paper §2.2).

A job j consists of ``n_tasks`` identical tasks.  Each task has a *CPU need*
``cpu_need`` in (0, 1] (fraction of a node's CPU it can use when dedicated)
and a *memory requirement* ``mem_req`` in (0, 1] (hard, non-oversubscribable
fraction of node memory).  All tasks of a job receive the same instantaneous
CPU fraction, hence the same *yield* = allocated fraction / cpu_need.

The scheduler is non-clairvoyant: ``proc_time`` is carried on the spec for
simulation/bound purposes but MUST NOT be read by scheduling policies.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

__all__ = [
    "JobSpec",
    "JobState",
    "NodePool",
    "RUNNING",
    "PAUSED",
    "PENDING",
    "COMPLETED",
    "CANCELLED",
]

PENDING = "pending"      # submitted, never-yet-placed or removed before start
RUNNING = "running"
PAUSED = "paused"        # was running, preempted to storage
COMPLETED = "completed"
CANCELLED = "cancelled"  # withdrawn by its owner; never counted in metrics


@dataclass
class JobSpec:
    """Static description of a job (the simulator input record)."""

    jid: int
    release: float           # r_j, submission time (s)
    proc_time: float         # p_j, dedicated execution time (s); non-clairvoyant!
    n_tasks: int
    cpu_need: float          # c_j in (0, 1]
    mem_req: float           # m_j in (0, 1]

    def __post_init__(self) -> None:
        if not (0.0 < self.cpu_need <= 1.0):
            raise ValueError(f"cpu_need must be in (0,1], got {self.cpu_need}")
        if not (0.0 < self.mem_req <= 1.0):
            raise ValueError(f"mem_req must be in (0,1], got {self.mem_req}")
        if self.n_tasks < 1:
            raise ValueError("n_tasks must be >= 1")
        if self.proc_time <= 0:
            raise ValueError("proc_time must be > 0")

    @property
    def total_work(self) -> float:
        """Total CPU-seconds of work: n_tasks * p_j * c_j."""
        return self.n_tasks * self.proc_time * self.cpu_need


@dataclass
class JobState:
    """Dynamic, scheduler-visible state of a submitted job."""

    spec: JobSpec
    status: str = PENDING
    vt: float = 0.0                      # virtual time (integral of yield)
    yld: float = 0.0                     # current yield in [0, 1]
    mapping: Optional[List[int]] = None  # node id per task, len n_tasks
    penalty_until: float = -np.inf       # zero progress until then
    completed_at: Optional[float] = None
    n_pmtn: int = 0
    n_mig: int = 0
    started_once: bool = False

    # ---- scheduler-visible quantities (no proc_time!) -------------------
    def flow_time(self, now: float) -> float:
        return now - self.spec.release

    def priority(self, now: float) -> float:
        """flow_time / virtual_time**2 (paper §4.1); +inf when vt == 0."""
        if self.vt <= 0.0:
            return np.inf
        return self.flow_time(now) / (self.vt * self.vt)

    def priority_key(self, now: float):
        """Sort key: larger = higher priority; ties by submission order
        (earlier submission wins, §4.1)."""
        return (self.priority(now), -self.spec.jid)

    # ---- simulator-side quantities --------------------------------------
    def remaining_vt(self) -> float:
        return self.spec.proc_time - self.vt

    @property
    def is_running(self) -> bool:
        return self.status == RUNNING


class NodePool:
    """Tracks per-node CPU load (sum of needs of resident tasks) and free
    memory.  CPU may be oversubscribed (load > 1); memory never."""

    def __init__(self, n_nodes: int):
        self.n = int(n_nodes)
        self.load = np.zeros(self.n)       # sum of cpu_need of tasks
        self.mem_free = np.ones(self.n)

    def copy(self) -> "NodePool":
        c = NodePool(self.n)
        c.load = self.load.copy()
        c.mem_free = self.mem_free.copy()
        return c

    def place(self, spec: JobSpec, mapping: List[int]) -> None:
        for node in mapping:
            self.load[node] += spec.cpu_need
            self.mem_free[node] -= spec.mem_req
        if (self.mem_free < -1e-9).any():
            raise RuntimeError("node memory oversubscribed")

    def remove(self, spec: JobSpec, mapping: List[int]) -> None:
        for node in mapping:
            self.load[node] -= spec.cpu_need
            self.mem_free[node] += spec.mem_req

    def max_load(self) -> float:
        return float(self.load.max()) if self.n else 0.0

    def fits(self, spec: JobSpec, node: int) -> bool:
        return self.mem_free[node] >= spec.mem_req - 1e-12

    def masked_loads(self, mem_req: float) -> np.ndarray:
        """Fresh candidate array for greedy placement: per-node load with
        memory-infeasible nodes masked to +inf.  The caller owns the array
        and keeps it current with O(1) writes per placement instead of
        rebuilding the mask per task."""
        return np.where(self.mem_free >= mem_req - 1e-12, self.load, np.inf)


def rebuild_pool(n_nodes: int, jobs: Dict[int, JobState]) -> NodePool:
    """Construct a NodePool from the mappings of all running jobs."""
    pool = NodePool(n_nodes)
    for js in jobs.values():
        if js.status == RUNNING and js.mapping is not None:
            pool.place(js.spec, js.mapping)
    return pool
