"""Greedy task-mapping heuristics (paper §4.2).

* ``greedy_place``     — map an incoming job without disturbing running jobs.
* ``greedy_p``         — GreedyP: additionally pause lower-priority running
                         jobs (by increasing priority) to force admission.
* ``greedy_pm``        — GreedyPM: like GreedyP, but paused victims get a
                         chance to be *moved* (re-placed via Greedy) instead.

All functions are pure with respect to the passed-in ``NodePool`` copies;
they return placement decisions, the caller (simulator) applies them and
does penalty/bandwidth accounting.

``greedy_place`` keeps one masked candidate-load array per call and updates
only the chosen node after each task placement (the reference rebuilt the
feasibility mask and masked array per task); results are bit-identical —
the per-node load arithmetic and the argmin tie-breaking are unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import numpy as np

from . import alloc_kernels, alloc_reference
from .job import JobSpec, JobState, NodePool

__all__ = ["greedy_place", "GreedyAdmission", "greedy_p", "greedy_pm"]


def greedy_place(pool: NodePool, spec: JobSpec) -> Optional[List[int]]:
    """Map each task of ``spec`` to the feasible node with the lowest CPU
    load (§4.2), updating ``pool`` in place.  Returns the mapping or None if
    some task cannot fit in memory (pool is then left unmodified)."""
    if alloc_kernels.reference_kernels_active():
        return alloc_reference.greedy_place(pool, spec)
    # one masked-load array per call; only the chosen node changes per task
    masked = pool.masked_loads(spec.mem_req)
    if masked.size == 0:
        return None
    load, mem_free = pool.load, pool.mem_free
    cpu_need, mem_req = spec.cpu_need, spec.mem_req
    thr = mem_req - 1e-12
    mapping: List[int] = []
    for _ in range(spec.n_tasks):
        node = int(masked.argmin())
        if masked[node] == np.inf:          # no feasible node
            if mapping:
                pool.remove(spec, mapping)
            return None
        mapping.append(node)
        load[node] += cpu_need
        mem_free[node] -= mem_req
        masked[node] = load[node] if mem_free[node] >= thr else np.inf
    return mapping


@dataclass
class GreedyAdmission:
    """Outcome of GreedyP / GreedyPM admission of one incoming job."""

    mapping: Optional[List[int]]                 # for the incoming job
    paused: List[int] = field(default_factory=list)     # jids paused
    moved: Dict[int, List[int]] = field(default_factory=dict)  # jid -> new map


def _can_place(pool: NodePool, spec: JobSpec) -> bool:
    probe = greedy_place(pool, spec)
    if probe is None:
        return False
    pool.remove(spec, probe)
    return True


def greedy_p(
    pool: NodePool,
    spec: JobSpec,
    running: Sequence[JobState],
    now: float,
) -> GreedyAdmission:
    """GreedyP admission (§4.2): force-admit ``spec`` by pausing running jobs.

    ``running`` — running jobs, candidates for pausing.  ``pool`` is updated
    to the post-admission state when admission succeeds.
    """
    direct = greedy_place(pool, spec)
    if direct is not None:
        return GreedyAdmission(mapping=direct)

    by_prio = sorted(running, key=lambda js: js.priority_key(now))  # increasing
    # Phase 1: mark by increasing priority until the incoming job fits.
    marked: List[JobState] = []
    fits = False
    for js in by_prio:
        pool.remove(js.spec, js.mapping)
        marked.append(js)
        if _can_place(pool, spec):
            fits = True
            break
    if not fits:
        for js in marked:            # roll back
            pool.place(js.spec, js.mapping)
        return GreedyAdmission(mapping=None)
    # Phase 2: unmark in decreasing priority order when memory allows.
    unmarked: set = set()
    for js in sorted(marked, key=lambda j: j.priority_key(now), reverse=True):
        pool.place(js.spec, js.mapping)      # tentatively keep it running
        if _can_place(pool, spec):
            unmarked.add(js.spec.jid)
        else:
            pool.remove(js.spec, js.mapping)  # must stay paused
    mapping = greedy_place(pool, spec)
    assert mapping is not None
    return GreedyAdmission(
        mapping=mapping,
        paused=[js.spec.jid for js in marked if js.spec.jid not in unmarked],
    )


def greedy_pm(
    pool: NodePool,
    spec: JobSpec,
    running: Sequence[JobState],
    now: float,
) -> GreedyAdmission:
    """GreedyPM (§4.2): as GreedyP, but victims are re-placed with Greedy
    (migrated) when possible instead of paused."""
    adm = greedy_p(pool, spec, running, now)
    if adm.mapping is None or not adm.paused:
        return adm
    by_jid = {js.spec.jid: js for js in running}
    still_paused: List[int] = []
    moved: Dict[int, List[int]] = {}
    # Re-place victims in decreasing priority order (§4.2: "in order of
    # their priority").
    victims = sorted(
        (by_jid[jid] for jid in adm.paused),
        key=lambda js: js.priority_key(now),
        reverse=True,
    )
    for js in victims:
        new_map = greedy_place(pool, js.spec)
        if new_map is None:
            still_paused.append(js.spec.jid)
        else:
            moved[js.spec.jid] = new_map
    adm.paused = still_paused
    adm.moved = moved
    return adm
