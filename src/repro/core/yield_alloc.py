"""Resource (CPU fraction) allocation given a fixed task→node mapping (§4.6).

Step 1 (always): every job gets yield 1/max(1, Λ) where Λ is the maximum node
CPU load — this maximizes the minimum yield for the given mapping.

Step 2 (OPT=MIN): iterated max-min improvement (water-filling): freeze the
jobs on the bottleneck node at the bottleneck level and keep raising the
rest, until every job is frozen or capped at yield 1.

Step 2' (OPT=AVG): maximize the *average* yield subject to no job dropping
below the step-1 minimum — a rational LP (paper Linear Program (2)), solved
with scipy's HiGHS.

Both passes run on the vectorized CSR kernels of
:mod:`repro.core.alloc_kernels` (the engine feeds them its incrementally
maintained incidence matrix directly; this module's (specs, mappings) API
builds the same CSR from scratch).  The original loop implementations live
on as the oracle in :mod:`repro.core.alloc_reference`.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from . import alloc_kernels, alloc_reference
from .alloc_kernels import CSRIncidence, build_csr
from .job import JobSpec

__all__ = ["min_yield", "maxmin_yields", "avg_yields", "allocate",
           "allocate_incidence"]


def min_yield(max_load: float) -> float:
    """Equal yield maximizing the minimum for a given max node load Λ."""
    return 1.0 / max(1.0, max_load)


def maxmin_yields(
    specs: Sequence[JobSpec],
    mappings: Sequence[Sequence[int]],
    n_nodes: int,
) -> np.ndarray:
    """OPT=MIN: lexicographic max-min yields for the given mapping."""
    if alloc_kernels.reference_kernels_active():
        return alloc_reference.maxmin_yields(specs, mappings, n_nodes)
    m = len(specs)
    if m == 0:
        return np.zeros(0)
    inc = build_csr([s.cpu_need for s in specs], mappings, n_nodes)
    return alloc_kernels.maxmin_yields_csr(inc, np.ones(m, dtype=bool))


def avg_yields(
    specs: Sequence[JobSpec],
    mappings: Sequence[Sequence[int]],
    n_nodes: int,
) -> np.ndarray:
    """OPT=AVG: maximize sum of yields s.t. y_j >= 1/max(1,Λ) (LP (2))."""
    if alloc_kernels.reference_kernels_active():
        return alloc_reference.avg_yields(specs, mappings, n_nodes)
    m = len(specs)
    if m == 0:
        return np.zeros(0)
    inc = build_csr([s.cpu_need for s in specs], mappings, n_nodes)
    return alloc_kernels.avg_yields_csr(inc, np.arange(m, dtype=np.int64))


def allocate(
    specs: Sequence[JobSpec],
    mappings: Sequence[Sequence[int]],
    n_nodes: int,
    opt: str = "MIN",
) -> np.ndarray:
    """Full §4.6 allocation: equal min-yield floor + OPT=MIN / OPT=AVG pass."""
    if opt == "MIN":
        return maxmin_yields(specs, mappings, n_nodes)
    if opt == "AVG":
        return avg_yields(specs, mappings, n_nodes)
    raise ValueError(f"unknown OPT {opt!r}")


def allocate_incidence(
    inc: "CSRIncidence",
    cols: np.ndarray,
    opt: str = "MIN",
) -> np.ndarray:
    """§4.6 allocation straight off an engine incidence snapshot.

    ``cols`` — sorted job columns of the running set.  Returns yields aligned
    with ``cols``.  This is the engine's hot path: no per-event table rebuild,
    no (specs, mappings) list materialization.
    """
    if opt == "MIN":
        active = np.zeros(inc.width, dtype=bool)
        active[cols] = True
        return alloc_kernels.maxmin_yields_csr(inc, active)[cols]
    if opt == "AVG":
        return alloc_kernels.avg_yields_csr(inc, cols)
    raise ValueError(f"unknown OPT {opt!r}")
