"""Resource (CPU fraction) allocation given a fixed task→node mapping (§4.6).

Step 1 (always): every job gets yield 1/max(1, Λ) where Λ is the maximum node
CPU load — this maximizes the minimum yield for the given mapping.

Step 2 (OPT=MIN): iterated max-min improvement (water-filling): freeze the
jobs on the bottleneck node at the bottleneck level and keep raising the
rest, until every job is frozen or capped at yield 1.

Step 2' (OPT=AVG): maximize the *average* yield subject to no job dropping
below the step-1 minimum — a rational LP (paper Linear Program (2)), solved
with scipy's HiGHS.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from .job import JobSpec

__all__ = ["min_yield", "maxmin_yields", "avg_yields", "allocate"]

_EPS = 1e-12


def min_yield(max_load: float) -> float:
    """Equal yield maximizing the minimum for a given max node load Λ."""
    return 1.0 / max(1.0, max_load)


def _node_tables(
    specs: Sequence[JobSpec], mappings: Sequence[Sequence[int]], n_nodes: int
) -> Tuple[np.ndarray, List[List[Tuple[int, int]]]]:
    """Return (per-node list of (job_idx, multiplicity)) and per-node total
    CPU need, for the jobs' task placements."""
    per_node: List[Dict[int, int]] = [dict() for _ in range(n_nodes)]
    for ji, mapping in enumerate(mappings):
        for node in mapping:
            per_node[node][ji] = per_node[node].get(ji, 0) + 1
    node_lists = [sorted(d.items()) for d in per_node]
    need = np.zeros(n_nodes)
    for node, items in enumerate(node_lists):
        need[node] = sum(specs[ji].cpu_need * mult for ji, mult in items)
    return need, node_lists


def maxmin_yields(
    specs: Sequence[JobSpec],
    mappings: Sequence[Sequence[int]],
    n_nodes: int,
) -> np.ndarray:
    """OPT=MIN: lexicographic max-min yields for the given mapping.

    Classic water-filling: raise all unfrozen jobs' yields uniformly until a
    node saturates (or a job hits yield 1); freeze the binding jobs; repeat.
    """
    m = len(specs)
    y = np.zeros(m)
    if m == 0:
        return y
    frozen = np.zeros(m, dtype=bool)
    load_need, node_lists = _node_tables(specs, mappings, n_nodes)

    # residual capacity per node once frozen jobs are accounted for
    for _ in range(m + 1):
        if frozen.all():
            break
        # For each node, level = (1 - frozen usage) / unfrozen need
        best_level = 1.0  # cap at yield 1
        binding_nodes: List[int] = []
        for node, items in enumerate(node_lists):
            f_use = 0.0
            u_need = 0.0
            for ji, mult in items:
                c = specs[ji].cpu_need * mult
                if frozen[ji]:
                    f_use += y[ji] * c
                else:
                    u_need += c
            if u_need <= _EPS:
                continue
            level = max(0.0, (1.0 - f_use)) / u_need
            if level < best_level - 1e-15:
                best_level = level
                binding_nodes = [node]
            elif abs(level - best_level) <= 1e-15:
                binding_nodes.append(node)
        # raise every unfrozen job to best_level
        newly = np.zeros(m, dtype=bool)
        if best_level >= 1.0 - 1e-12:
            best_level = 1.0
            newly |= ~frozen  # everyone capped
        else:
            for node in binding_nodes:
                for ji, _ in node_lists[node]:
                    if not frozen[ji]:
                        newly[ji] = True
        y[~frozen] = best_level
        if not newly.any():          # numerical safety
            newly |= ~frozen
        frozen |= newly
    return np.clip(y, 0.0, 1.0)


def avg_yields(
    specs: Sequence[JobSpec],
    mappings: Sequence[Sequence[int]],
    n_nodes: int,
) -> np.ndarray:
    """OPT=AVG: maximize sum of yields s.t. y_j >= 1/max(1,Λ) (LP (2))."""
    from scipy.optimize import linprog
    from scipy.sparse import lil_matrix

    m = len(specs)
    if m == 0:
        return np.zeros(0)
    load_need, node_lists = _node_tables(specs, mappings, n_nodes)
    lam = float(load_need.max()) if n_nodes else 0.0
    y_min = min_yield(lam)
    a = lil_matrix((n_nodes, m))
    for node, items in enumerate(node_lists):
        for ji, mult in items:
            a[node, ji] = specs[ji].cpu_need * mult
    res = linprog(
        c=-np.ones(m),
        A_ub=a.tocsr(),
        b_ub=np.ones(n_nodes),
        bounds=[(y_min, 1.0)] * m,
        method="highs",
    )
    if not res.success:  # numerically degenerate: fall back to the safe floor
        return np.full(m, y_min)
    return np.clip(res.x, 0.0, 1.0)


def allocate(
    specs: Sequence[JobSpec],
    mappings: Sequence[Sequence[int]],
    n_nodes: int,
    opt: str = "MIN",
) -> np.ndarray:
    """Full §4.6 allocation: equal min-yield floor + OPT=MIN / OPT=AVG pass."""
    if opt == "MIN":
        return maxmin_yields(specs, mappings, n_nodes)
    if opt == "AVG":
        return avg_yields(specs, mappings, n_nodes)
    raise ValueError(f"unknown OPT {opt!r}")
