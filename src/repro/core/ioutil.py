"""Concurrency-safe atomic file writes.

Every on-disk artifact in the repo (sweep record caches, session
snapshots, the serve layer's shared snapshot store) is written through
:func:`atomic_write_json`: the payload lands in a uniquely-named temp
file in the destination directory (``tempfile.mkstemp`` opens it with
``O_EXCL``, so two writers can never share a temp path — a plain
``f"{path}.tmp.{os.getpid()}"`` collides between threads of one
process), is fsynced, and is moved over the destination with the atomic
``os.replace``.  Concurrent writers race to *whole* files: readers see
either the old or one writer's complete new content, never a torn mix,
and no writer ever unlinks another writer's temp file.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional

__all__ = ["atomic_write_json", "atomic_write_text"]


def atomic_write_text(path: str, text: str) -> str:
    """Atomically replace ``path`` with ``text`` (parents created).

    Safe under concurrent writers to the same ``path``: unique ``O_EXCL``
    temp names + atomic rename; last completed writer wins wholesale.
    """
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".tmp.")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    finally:
        try:
            os.unlink(tmp)          # only reached when replace() didn't run
        except FileNotFoundError:
            pass
    return path


def atomic_write_json(path: str, payload: Any,
                      indent: Optional[int] = 1) -> str:
    """Serialize ``payload`` as JSON and atomically replace ``path``."""
    return atomic_write_text(path, json.dumps(payload, indent=indent))
