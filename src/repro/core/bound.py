"""Offline lower bound on the optimal maximum (bounded) stretch (paper §3.1).

Theorem 1: a max-stretch target S is achievable (infinite memory, free
instantaneous migration) iff a feasibility LP over the release/deadline
intervals has a solution.  That LP is a transportation problem, so we check
feasibility with a max-flow instead of a general LP:

    source -> job j           capacity  n_j * p_j * c_j      (total work)
    job j  -> interval t      capacity  n_j * l(t)           (Constraint 1d)
    interval t -> sink        capacity  |P| * l(t)           (Constraint 1e)

(job->interval edges only for intervals inside [r_j, d_j), Constraints 1b/1c;
Constraint 1a == full flow value.)  A binary search on S yields the optimal
target within ``rtol``.  With the *bounded* stretch (threshold tau, §2.2)
job j additionally requires S >= tau / p_j, so the search starts at
S_lo = max(1, tau / min_j p_j).

Capacities are scaled to integers with demands rounded *down* and capacities
rounded *up*, so "feasible" is never falsely rejected and the returned value
remains a true lower bound.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np
from scipy.sparse import csr_matrix
from scipy.sparse.csgraph import maximum_flow

from .job import JobSpec

__all__ = ["stretch_feasible", "max_stretch_lower_bound"]

_SCALE_TARGET = 10**8   # keep total integer flow comfortably inside int64


def _intervals(bounds: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    pts = np.unique(bounds)
    return pts[:-1], pts[1:]


def stretch_feasible(
    specs: Sequence[JobSpec], n_nodes: int, s: float, tau: float = 10.0
) -> bool:
    """Max-flow feasibility of max-stretch target ``s`` (Theorem 1)."""
    r = np.array([sp.release for sp in specs])
    d = r + s * np.array([sp.proc_time for sp in specs])
    lo, hi = _intervals(np.concatenate([r, d]))
    ell = hi - lo
    n_j, n_t = len(specs), len(ell)
    work = np.array([sp.total_work for sp in specs])
    total = work.sum()
    if total <= 0:
        return True
    scale = _SCALE_TARGET / max(total, n_nodes * ell.sum(), 1e-9)

    # node ids: 0 = source, 1..n_j = jobs, n_j+1..n_j+n_t = intervals, last = sink
    src, snk = 0, n_j + n_t + 1
    rows: List[int] = []
    cols: List[int] = []
    caps: List[int] = []
    demand = np.floor(work * scale).astype(np.int64)
    for j in range(n_j):
        rows.append(src); cols.append(1 + j); caps.append(int(demand[j]))
    t_cap = np.ceil(n_nodes * ell * scale).astype(np.int64)
    for t in range(n_t):
        rows.append(1 + n_j + t); cols.append(snk); caps.append(int(t_cap[t]))
    for j, sp in enumerate(specs):
        t0 = int(np.searchsorted(lo, r[j], side="left"))
        t1 = int(np.searchsorted(lo, d[j] - 1e-12, side="right"))
        for t in range(t0, t1):
            cap = int(np.ceil(sp.n_tasks * ell[t] * scale))
            if cap > 0:
                rows.append(1 + j); cols.append(1 + n_j + t); caps.append(cap)
    g = csr_matrix(
        (np.asarray(caps, dtype=np.int64), (rows, cols)),
        shape=(snk + 1, snk + 1),
    )
    flow = maximum_flow(g, src, snk).flow_value
    return flow >= int(demand.sum())


def max_stretch_lower_bound(
    specs: Sequence[JobSpec],
    n_nodes: int,
    tau: float = 10.0,
    rtol: float = 1e-3,
) -> float:
    """Binary-search lower bound on the optimal max bounded stretch."""
    specs = list(specs)
    if not specs:
        return 1.0
    p_min = min(sp.proc_time for sp in specs)
    s_lo = max(1.0, tau / p_min)
    if stretch_feasible(specs, n_nodes, s_lo, tau):
        return s_lo
    s_hi = s_lo * 2.0
    while not stretch_feasible(specs, n_nodes, s_hi, tau):
        s_hi *= 2.0
        if s_hi > 1e9:
            return s_hi  # degenerate instance
    while (s_hi - s_lo) / s_hi > rtol:
        mid = 0.5 * (s_lo + s_hi)
        if stretch_feasible(specs, n_nodes, mid, tau):
            s_hi = mid
        else:
            s_lo = mid
    return s_hi
