"""DFRS policy naming scheme (paper §4.5, Table 1).

``"<submit>[ *]/per/OPT=<MIN|AVG|MAX>[/MINVT=<s>|/MINFT=<s>]"``

* first part: action on job submission — ``Greedy``, ``GreedyP``,
  ``GreedyPM``, ``MCB8`` or empty (no action);
* a trailing ``*`` on the first part: opportunistic scheduling on job
  completion (MCB8 if MCB8 was used on submission, Greedy for the greedy
  family — and for the bare ``Greedy`` policy itself);
* ``per``: apply MCB8 periodically; ``stretch-per``: apply MCB8-stretch
  periodically;
* ``OPT``: resource-allocation post-pass (§4.6/§4.7);
* ``MINVT``/``MINFT``: grace bound (seconds of virtual/flow time) under
  which MCB8 may pause a running job but must not *move* it.

The grammar is *sugar* over the declarative :class:`PolicySpec`:
:func:`parse_policy` canonicalizes every accepted spelling (case,
whitespace, component order, implicit ``/OPT=MIN``) so that equivalent
strings produce *equal* specs carrying one canonical ``name`` —
``parse_policy(render_policy(spec)) == spec`` round-trips by construction.

The 116-combination space of the paper is
``{none, Greedy, GreedyP, GreedyPM} x {*, } x {per, } x {OPT} x {MIN*}``
plus the ``/stretch-per`` family; `all_paper_policies()` enumerates it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

__all__ = [
    "PolicySpec",
    "parse_policy",
    "render_policy",
    "all_paper_policies",
    "TABLE1_POLICIES",
]

_SUBMIT = {"": None, "greedy": "greedy", "greedyp": "greedyP", "greedypm": "greedyPM", "mcb8": "mcb8"}

#: canonical spelling of each submit component (inverse of ``_SUBMIT``)
_SUBMIT_CANON = {None: "", "greedy": "Greedy", "greedyP": "GreedyP",
                 "greedyPM": "GreedyPM", "mcb8": "MCB8"}


@dataclass(frozen=True)
class PolicySpec:
    name: str
    on_submit: Optional[str]       # None | greedy | greedyP | greedyPM | mcb8
    opportunistic: bool            # on-completion action enabled?
    periodic: Optional[str]        # None | mcb8 | mcb8-stretch
    opt: str = "MIN"               # MIN | AVG | MAX (MAX only for stretch-per)
    minvt: Optional[float] = None
    minft: Optional[float] = None

    @property
    def on_complete(self) -> Optional[str]:
        if not self.opportunistic:
            return None
        return "mcb8" if self.on_submit == "mcb8" else "greedy"

    @property
    def is_batch(self) -> bool:
        return self.name.upper() in ("FCFS", "EASY")

    @classmethod
    def make(
        cls,
        on_submit: Optional[str] = None,
        opportunistic: bool = False,
        periodic: Optional[str] = None,
        opt: str = "MIN",
        minvt: Optional[float] = None,
        minft: Optional[float] = None,
    ) -> "PolicySpec":
        """Construct a spec with its canonical ``name`` computed for you."""
        spec = cls("", on_submit, opportunistic, periodic, opt, minvt, minft)
        return cls(render_policy(spec), on_submit, opportunistic, periodic,
                   opt, minvt, minft)


def render_policy(spec: PolicySpec) -> str:
    """The canonical string spelling of ``spec`` (grammar sugar inverse).

    Canonical form: ``Submit[ *][/per|/stretch-per]/OPT=X[/MINVT=s|/MINFT=s]``
    with the submit part in its reference capitalization and ``OPT`` always
    explicit.  ``parse_policy(render_policy(spec)) == spec`` for every spec
    produced by :func:`parse_policy` or :meth:`PolicySpec.make`.
    """
    if spec.is_batch:
        return spec.name.upper()
    head = _SUBMIT_CANON[spec.on_submit]
    if spec.opportunistic:
        head = f"{head} *" if head else "*"
    parts = [head]
    if spec.periodic == "mcb8":
        parts.append("per")
    elif spec.periodic == "mcb8-stretch":
        parts.append("stretch-per")
    parts.append(f"OPT={spec.opt}")
    if spec.minvt is not None:
        parts.append(f"MINVT={spec.minvt:g}")
    if spec.minft is not None:
        parts.append(f"MINFT={spec.minft:g}")
    return "/".join(parts)


def parse_policy(name: str) -> PolicySpec:
    if name.strip().upper() in ("FCFS", "EASY"):
        return PolicySpec(name.strip().upper(), None, False, None)
    parts = name.split("/")
    head = parts[0].strip()
    opportunistic = head.endswith("*")
    head = head[:-1].strip() if opportunistic else head
    if head.lower() not in _SUBMIT:
        raise ValueError(f"unknown submit policy {head!r} in {name!r}")
    on_submit = _SUBMIT[head.lower()]
    periodic = None
    opt = "MIN"
    minvt = minft = None
    for part in parts[1:]:
        p = part.strip()
        if not p:
            continue
        low = p.lower()
        if low == "per":
            periodic = "mcb8"
        elif low == "stretch-per":
            periodic = "mcb8-stretch"
        elif low.startswith("opt="):
            opt = p.split("=", 1)[1].strip().upper()
        elif low.startswith("minvt="):
            minvt = float(p.split("=", 1)[1])
        elif low.startswith("minft="):
            minft = float(p.split("=", 1)[1])
        else:
            raise ValueError(f"unknown policy component {p!r} in {name!r}")
    if opt not in ("MIN", "AVG", "MAX"):
        raise ValueError(f"unknown OPT {opt!r}")
    if opt == "MAX" and periodic != "mcb8-stretch":
        raise ValueError("OPT=MAX is only defined for /stretch-per")
    return PolicySpec.make(on_submit, opportunistic, periodic, opt,
                           minvt, minft)


#: the 14 Table-1 combinations (with the paper's recommended parameters)
TABLE1_POLICIES: List[str] = [
    "Greedy *",
    "GreedyP *",
    "GreedyPM *",
    "Greedy/per",
    "GreedyP/per",
    "GreedyPM/per",
    "Greedy */per",
    "GreedyP */per",
    "GreedyPM */per",
    "MCB8 *",
    "MCB8/per",
    "MCB8 */per",
    "/per",
    "/stretch-per",
]


def all_paper_policies() -> List[str]:
    """The full 116-combination space of §6.1."""
    out = []
    for base in ["Greedy *", "GreedyP *", "GreedyPM *"]:
        for opt in ("MIN", "AVG"):
            out.append(f"{base}/OPT={opt}")
    mcb_bases = TABLE1_POLICIES[3:]  # every combination that invokes MCB8
    limits = ["", "/MINFT=300", "/MINFT=600", "/MINVT=300", "/MINVT=600"]
    for base in mcb_bases:
        opts = ("MAX", "AVG") if base == "/stretch-per" else ("MIN", "AVG")
        for opt in opts:
            for lim in limits:
                out.append(f"{base}/OPT={opt}{lim}")
    return out
