"""MCB8-stretch — direct (estimated-)stretch minimization (paper §4.7).

Runs only periodically (needs the scheduling period T).  At a scheduling
event, the best non-clairvoyant estimate of job j's stretch one period ahead
is  Ŝ_j = (ft_j + T) / (vt_j + y_j·T).  Given a target Ŝ, the required yield
is  y_j = ((ft_j + T)/Ŝ - vt_j) / T  (clamped to [0, 1]; > 1 ⇒ infeasible).
A binary search over 1/Ŝ ∈ (0, 1] finds the smallest feasible target, with
MCB8 packing checking feasibility; if no target is feasible the lowest
priority job is removed (as in §4.3).

Post-passes: OPT=MAX iteratively lowers the maximum estimated stretch using
left-over node capacity (water-filling in stretch space); OPT=AVG maximizes
the total projected progress Σ y_j·T/(ft_j+T) (linear proxy for average
stretch minimization) with HiGHS.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .job import JobState
from .mcb8 import _try_pack

__all__ = ["StretchResult", "mcb8_stretch", "improve_max_stretch", "improve_avg_stretch"]

_EPS = 1e-9


@dataclass
class StretchResult:
    mappings: Dict[int, List[int]]
    yields: Dict[int, float]       # initial per-job yields for the target
    target: float                  # achieved estimated max stretch
    removed: List[int]


def _required_yield(js: JobState, now: float, period: float, target: float) -> float:
    ft = js.flow_time(now)
    return ((ft + period) / target - js.vt) / period


def mcb8_stretch(
    candidates: Sequence[JobState],
    n_nodes: int,
    now: float,
    period: float,
    pinned: Optional[Dict[int, List[int]]] = None,
    accuracy: float = 0.01,
    alive: Optional[np.ndarray] = None,
) -> StretchResult:
    pinned = dict(pinned or {})
    active = sorted(candidates, key=lambda js: js.priority_key(now))  # incr prio
    removed: List[int] = []

    def feasible(inv_s: float, jobs: Sequence[JobState]):
        target = 1.0 / inv_s
        items = []
        pins: Dict[int, Tuple[float, float, List[int]]] = {}
        ylds: Dict[int, float] = {}
        for js in jobs:
            y = _required_yield(js, now, period, target)
            if y > 1.0 + _EPS:
                return None
            y = float(np.clip(y, 0.0, 1.0))
            ylds[js.spec.jid] = y
            cpu_req = y * js.spec.cpu_need
            if js.spec.jid in pinned:
                pins[js.spec.jid] = (cpu_req, js.spec.mem_req, pinned[js.spec.jid])
            else:
                items.append((js.spec.jid, cpu_req, js.spec.mem_req, js.spec.n_tasks))
        pack = _try_pack(n_nodes, items, pins, alive)
        if pack is None:
            return None
        return pack, ylds

    while True:
        jobs = [js for js in active if js.spec.jid not in removed]
        if not jobs:
            return StretchResult({}, {}, np.inf, removed)
        base = feasible(accuracy, jobs)  # very lax target (stretch 100)
        if base is None:
            removed.append(jobs[0].spec.jid)
            continue
        best, best_inv = base, accuracy
        top = feasible(1.0, jobs)        # stretch-1 target
        if top is not None:
            return StretchResult(top[0], top[1], 1.0, removed)
        lo, hi = accuracy, 1.0
        while hi - lo > accuracy:
            mid = 0.5 * (lo + hi)
            r = feasible(mid, jobs)
            if r is not None:
                best, best_inv, lo = r, mid, mid
            else:
                hi = mid
        return StretchResult(best[0], best[1], 1.0 / best_inv, removed)


def _node_usage(jobs, mappings, yields, n_nodes):
    use = np.zeros(n_nodes)
    for js in jobs:
        for node in mappings[js.spec.jid]:
            use[node] += yields[js.spec.jid] * js.spec.cpu_need
    return use


def improve_max_stretch(
    jobs: Sequence[JobState],
    mappings: Dict[int, List[int]],
    yields: Dict[int, float],
    n_nodes: int,
    now: float,
    period: float,
    max_rounds: int = 200,
) -> Dict[int, float]:
    """OPT=MAX (§4.7): iteratively reduce the max estimated stretch using
    slack — raise the worst job's yield until slack, cap, or the next-worst
    stretch level is reached."""
    jobs = [js for js in jobs if js.spec.jid in mappings]
    if not jobs:
        return yields
    yields = dict(yields)
    frozen: set = set()

    def est(js):
        return (js.flow_time(now) + period) / max(_EPS, js.vt + yields[js.spec.jid] * period)

    for _ in range(max_rounds):
        live = [js for js in jobs if js.spec.jid not in frozen and yields[js.spec.jid] < 1.0 - _EPS]
        if not live:
            break
        worst = max(live, key=est)
        s_worst = est(worst)
        others = [est(js) for js in jobs if js is not worst]
        s_next = max([s for s in others if s < s_worst - 1e-12], default=1.0)
        target = max(s_next, 1.0)
        y_target = _required_yield(worst, now, period, target)
        use = _node_usage(jobs, mappings, yields, n_nodes)
        jid = worst.spec.jid
        mult: Dict[int, int] = {}
        for node in mappings[jid]:
            mult[node] = mult.get(node, 0) + 1
        dy_slack = min(
            (1.0 - use[node]) / (worst.spec.cpu_need * k) for node, k in mult.items()
        )
        dy = min(max(0.0, y_target - yields[jid]), max(0.0, dy_slack), 1.0 - yields[jid])
        if dy <= 1e-6:
            frozen.add(jid)
            continue
        yields[jid] += dy
    return yields


def improve_avg_stretch(
    jobs: Sequence[JobState],
    mappings: Dict[int, List[int]],
    yields: Dict[int, float],
    n_nodes: int,
    now: float,
    period: float,
) -> Dict[int, float]:
    """OPT=AVG (§4.7): maximize Σ projected progress (linear proxy) with the
    achieved target as per-job floor."""
    from scipy.optimize import linprog
    from scipy.sparse import lil_matrix

    jobs = [js for js in jobs if js.spec.jid in mappings]
    if not jobs:
        return yields
    m = len(jobs)
    a = lil_matrix((n_nodes, m))
    lo = np.zeros(m)
    w = np.zeros(m)
    for i, js in enumerate(jobs):
        for node in mappings[js.spec.jid]:
            a[node, i] += js.spec.cpu_need
        lo[i] = yields[js.spec.jid]
        w[i] = period / (js.flow_time(now) + period)
    res = linprog(
        c=-w,
        A_ub=a.tocsr(),
        b_ub=np.ones(n_nodes),
        bounds=list(zip(lo, np.ones(m))),
        method="highs",
    )
    out = dict(yields)
    if res.success:
        for i, js in enumerate(jobs):
            out[js.spec.jid] = float(np.clip(res.x[i], 0.0, 1.0))
    return out
