"""MCB8-stretch — direct (estimated-)stretch minimization (paper §4.7).

Runs only periodically (needs the scheduling period T).  At a scheduling
event, the best non-clairvoyant estimate of job j's stretch one period ahead
is  Ŝ_j = (ft_j + T) / (vt_j + y_j·T).  Given a target Ŝ, the required yield
is  y_j = ((ft_j + T)/Ŝ - vt_j) / T  (clamped to [0, 1]; > 1 ⇒ infeasible).
A binary search over 1/Ŝ ∈ (0, 1] finds the smallest feasible target, with
MCB8 packing checking feasibility; if no target is feasible the lowest
priority job is removed (as in §4.3).

Post-passes: OPT=MAX iteratively lowers the maximum estimated stretch using
left-over node capacity (water-filling in stretch space); OPT=AVG maximizes
the total projected progress Σ y_j·T/(ft_j+T) (linear proxy for average
stretch minimization) with HiGHS.

The probe loop and both post-passes run on flat arrays (candidate columns
precomputed once per call, per-node usage via an in-order ``np.add.at``
scatter); all float accumulation orders match the reference implementations
in :mod:`repro.core.alloc_reference` bit for bit.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from . import alloc_kernels, alloc_reference
from .job import JobState
from .mcb8 import _Candidates

__all__ = ["StretchResult", "mcb8_stretch", "improve_max_stretch", "improve_avg_stretch"]

_EPS = 1e-9


@dataclass
class StretchResult:
    mappings: Dict[int, List[int]]
    yields: Dict[int, float]       # initial per-job yields for the target
    target: float                  # achieved estimated max stretch
    removed: List[int]


def mcb8_stretch(
    candidates: Sequence[JobState],
    n_nodes: int,
    now: float,
    period: float,
    pinned: Optional[Dict[int, List[int]]] = None,
    accuracy: float = 0.01,
    alive: Optional[np.ndarray] = None,
) -> StretchResult:
    pinned = dict(pinned or {})
    active = sorted(candidates, key=lambda js: js.priority_key(now))  # incr prio
    removed: List[int] = []

    # flat candidate columns, priority order (suffixes drop removed heads)
    cand = _Candidates(active, pinned)
    ft_a = np.array([js.flow_time(now) for js in active])
    vt_a = np.array([js.vt for js in active])

    def feasible(inv_s: float, k: int):
        target = 1.0 / inv_s
        y = ((ft_a[k:] + period) / target - vt_a[k:]) / period
        if (y > 1.0 + _EPS).any():
            return None
        y = np.clip(y, 0.0, 1.0)
        ylds = {int(j): float(v) for j, v in zip(cand.jid[k:], y)}
        pack = cand.pack_probe(y * cand.cpu[k:], k, n_nodes, alive)
        if pack is None:
            return None
        return pack, ylds

    k0 = 0
    while True:
        if k0 >= len(active):
            return StretchResult({}, {}, np.inf, removed)
        base = feasible(accuracy, k0)  # very lax target (stretch 100)
        if base is None:
            removed.append(active[k0].spec.jid)
            k0 += 1
            continue
        best, best_inv = base, accuracy
        top = feasible(1.0, k0)        # stretch-1 target
        if top is not None:
            return StretchResult(top[0], top[1], 1.0, removed)
        lo, hi = accuracy, 1.0
        while hi - lo > accuracy:
            mid = 0.5 * (lo + hi)
            r = feasible(mid, k0)
            if r is not None:
                best, best_inv, lo = r, mid, mid
            else:
                hi = mid
        return StretchResult(best[0], best[1], 1.0 / best_inv, removed)


def _required_yield(js: JobState, now: float, period: float, target: float) -> float:
    ft = js.flow_time(now)
    return ((ft + period) / target - js.vt) / period


def improve_max_stretch(
    jobs: Sequence[JobState],
    mappings: Dict[int, List[int]],
    yields: Dict[int, float],
    n_nodes: int,
    now: float,
    period: float,
    max_rounds: int = 200,
) -> Dict[int, float]:
    """OPT=MAX (§4.7): iteratively reduce the max estimated stretch using
    slack — raise the worst job's yield until slack, cap, or the next-worst
    stretch level is reached."""
    if alloc_kernels.reference_kernels_active():
        return alloc_reference.improve_max_stretch(
            jobs, mappings, yields, n_nodes, now, period, max_rounds)
    jobs = [js for js in jobs if js.spec.jid in mappings]
    if not jobs:
        return yields
    m = len(jobs)
    yields = dict(yields)
    jid_a = [js.spec.jid for js in jobs]
    cpu_a = np.array([js.spec.cpu_need for js in jobs])
    ftp = np.array([js.flow_time(now) for js in jobs]) + period
    vt_a = np.array([js.vt for js in jobs])
    y_a = np.array([yields[j] for j in jid_a])
    # flat (job-position, node) scatter columns in job-then-task order: the
    # in-order np.add.at accumulation equals the reference per-task loop
    pos_flat = np.repeat(np.arange(m),
                         [len(mappings[j]) for j in jid_a])
    node_flat = np.concatenate(
        [np.asarray(mappings[j], dtype=np.int64) for j in jid_a])
    # per-job (node, multiplicity) in first-occurrence order, as the
    # reference's dict accumulation produces
    mult_of: List[Dict[int, int]] = []
    for j in jid_a:
        mult: Dict[int, int] = {}
        for node in mappings[j]:
            mult[node] = mult.get(node, 0) + 1
        mult_of.append(mult)

    frozen = np.zeros(m, dtype=bool)
    use = np.empty(n_nodes)
    for _ in range(max_rounds):
        live = ~frozen & (y_a < 1.0 - _EPS)
        if not live.any():
            break
        est = ftp / np.maximum(_EPS, vt_a + y_a * period)
        # first-maximal among live, in job order (== reference max(live, key))
        live_idx = np.nonzero(live)[0]
        w = int(live_idx[int(est[live_idx].argmax())])
        s_worst = float(est[w])
        others = np.delete(est, w)
        below = others[others < s_worst - 1e-12]
        s_next = float(below.max()) if below.size else 1.0
        target = max(s_next, 1.0)
        y_target = (ftp[w] / target - vt_a[w]) / period
        use[:] = 0.0
        np.add.at(use, node_flat, (y_a * cpu_a)[pos_flat])
        c = cpu_a[w]
        dy_slack = min(
            (1.0 - use[node]) / (c * k) for node, k in mult_of[w].items()
        )
        y_w = float(y_a[w])
        dy = min(max(0.0, float(y_target) - y_w), max(0.0, dy_slack),
                 1.0 - y_w)
        if dy <= 1e-6:
            frozen[w] = True
            continue
        y_a[w] = y_w + dy
    for i, j in enumerate(jid_a):
        yields[j] = float(y_a[i])
    return yields


def improve_avg_stretch(
    jobs: Sequence[JobState],
    mappings: Dict[int, List[int]],
    yields: Dict[int, float],
    n_nodes: int,
    now: float,
    period: float,
) -> Dict[int, float]:
    """OPT=AVG (§4.7): maximize Σ projected progress (linear proxy) with the
    achieved target as per-job floor."""
    if alloc_kernels.reference_kernels_active():
        return alloc_reference.improve_avg_stretch(
            jobs, mappings, yields, n_nodes, now, period)
    from scipy.optimize import linprog
    from scipy.sparse import csr_matrix

    jobs = [js for js in jobs if js.spec.jid in mappings]
    if not jobs:
        return yields
    m = len(jobs)
    dense = np.zeros((n_nodes, m))
    lo = np.zeros(m)
    w = np.zeros(m)
    for i, js in enumerate(jobs):
        nodes = np.asarray(mappings[js.spec.jid], dtype=np.int64)
        np.add.at(dense[:, i], nodes, js.spec.cpu_need)
        lo[i] = yields[js.spec.jid]
        w[i] = period / (js.flow_time(now) + period)
    res = linprog(
        c=-w,
        A_ub=csr_matrix(dense),
        b_ub=np.ones(n_nodes),
        bounds=list(zip(lo, np.ones(m))),
        method="highs",
    )
    out = dict(yields)
    if res.success:
        for i, js in enumerate(jobs):
            out[js.spec.jid] = float(np.clip(res.x[i], 0.0, 1.0))
    return out
