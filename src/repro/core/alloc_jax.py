"""Batched JAX backend for the §4.6 allocation kernels.

``alloc_kernels`` made the per-event allocation a handful of sparse numpy
matvecs; this module makes *many cells at once* a single device dispatch.
The CSR incidence is padded to a dense ``(batch, n_nodes, width)`` SoA
layout (boolean ``present`` mask + float64 ``weight = cpu_need ×
multiplicity``), and the OPT=MIN water-filling runs as **one jitted
``lax.while_loop`` stepping every lane in lockstep** — two batched
sequential matvecs per freeze round (frozen use, unfrozen need), a vmapped
bottleneck scan, masked freeze updates.  Finished lanes are masked out and
idle until the slowest lane converges, so one compiled program serves the
whole batch.

Bit-identity contract (the same one ``alloc_kernels`` holds against
``alloc_reference``): with ``jax_enable_x64``, every per-lane result is
**bit-equal** to ``maxmin_yields_csr`` / ``avg_yields_csr`` on that lane's
CSR alone.  Three properties make this work:

* padding is exact — a padded column/row/lane contributes an exact
  ``+0.0`` to every accumulation, which never changes a finite partial sum,
  and padded lanes start all-frozen so the lockstep loop never writes them;
* the inner matvec materializes all products with one vectorized multiply
  and then accumulates with an adds-only ``fori_loop`` (ascending column
  order).  XLA CPU would contract a mul feeding an add in the same loop
  body into a single-rounding FMA — 1 ulp off numpy's two-rounding sequence
  — so the multiply must live outside the accumulation loop (see
  ``kernels/alloc_matvec.py``);
* x64 is enabled through the *scoped* ``jax.experimental.enable_x64``
  context, not the global flag, so the repo's float32 model/kernel stack is
  untouched in the same process.

OPT=AVG is a HiGHS LP — a host simplex solver, not jittable — so the
batched path computes the LP's yield floor (``1/max(1, Λ)``, Λ = max
sequential node load) on device for all lanes at once and solves the small
per-lane LPs on host from bit-identical inputs; the results equal
``avg_yields_csr`` exactly.

The matvec dispatches per the ``kernels/ops.py`` backend convention:
``"jnp"`` (the pure-jnp formulation, default on CPU), ``"pallas"`` (the
Pallas kernel, ``interpret=True`` off-TPU), or ``"auto"`` (Pallas only when
the process-wide kernel backend is ``"pallas"`` and the batch is large
enough to justify a kernel launch).

On top sits the lockstep machinery ``sweep.run_batched`` drives: a
:class:`BatchedAllocator` turning N allocation requests into one padded
dispatch (shapes bucketed to powers of two to bound recompiles), and a
:class:`LockstepDispatcher` that parks engine threads at their allocation
points until every live lane has a request in the batch.

Everything imports lazily: environments without jax can import this module,
and ``has_jax()`` gates the callers (``pytest.importorskip`` in tests).
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .alloc_kernels import CSRIncidence

__all__ = [
    "has_jax",
    "densify_csr",
    "pad_batch",
    "maxmin_yields_batch",
    "maxmin_yields_jax",
    "node_usage",
    "node_usage_batch",
    "JaxAllocBackend",
    "BatchedAllocator",
    "LockstepDispatcher",
]

_EPS = 1e-12


# --------------------------------------------------------------------------- #
# lazy jax                                                                     #
# --------------------------------------------------------------------------- #
_STATE: Dict[str, object] = {}


def has_jax() -> bool:
    """True when a working jax import is available (the backend is usable)."""
    try:
        _jax()
        return True
    except Exception:
        return False


def _jax():
    jax = _STATE.get("jax")
    if jax is None:
        import jax  # noqa: PLC0415 — lazy: tier-1 must pass without jax

        _STATE["jax"] = jax
    return _STATE["jax"]


def _x64():
    """The scoped x64 context (thread-local — never the global flag)."""
    from jax.experimental import enable_x64

    return enable_x64()


def _bucket(n: int, floor: int = 1) -> int:
    """Smallest power of two >= max(n, floor) — bounds distinct jit shapes."""
    n = max(int(n), floor)
    return 1 << (n - 1).bit_length()


# --------------------------------------------------------------------------- #
# padding: CSR -> dense (batch, n_nodes, width) SoA                            #
# --------------------------------------------------------------------------- #
def densify_csr(
    inc: CSRIncidence,
    n_nodes: Optional[int] = None,
    cols: Optional[np.ndarray] = None,
    width: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Dense ``(present, weight)`` of one incidence snapshot.

    ``cols`` compacts the job axis to those (sorted) columns — ascending
    column order is preserved, so sequential accumulation over the compact
    axis performs the identical operation sequence (every entry must lie in
    ``cols``, which holds for engine snapshots: the incidence contains only
    running tasks).  ``n_nodes``/``width`` pad with exact zeros.
    """
    N = inc.n_nodes if n_nodes is None else n_nodes
    if cols is None:
        W = inc.width if width is None else width
        col_idx = inc.indices
    else:
        W = cols.shape[0] if width is None else width
        col_idx = np.searchsorted(cols, inc.indices)
    present = np.zeros((N, W), dtype=bool)
    weight = np.zeros((N, W), dtype=np.float64)
    rows = np.repeat(np.arange(inc.n_nodes), np.diff(inc.indptr))
    present[rows, col_idx] = True
    weight[rows, col_idx] = inc.data
    return present, weight


def pad_batch(
    incs: Sequence[CSRIncidence],
    actives: Sequence[np.ndarray],
    n_nodes: Optional[int] = None,
    width: Optional[int] = None,
    n_lanes: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pad a list of (incidence, active-mask) cells into one dense batch.

    Returns ``(present, weight, active)`` with shapes ``(B, N, W)``,
    ``(B, N, W)``, ``(B, W)``.  Extra lanes (``n_lanes > len(incs)``) are
    all-inactive: the lockstep loop treats them as already converged.
    """
    B = len(incs) if n_lanes is None else n_lanes
    N = n_nodes if n_nodes is not None else max(
        (i.n_nodes for i in incs), default=1)
    W = width if width is not None else max(
        (i.width for i in incs), default=1)
    present = np.zeros((B, N, W), dtype=bool)
    weight = np.zeros((B, N, W), dtype=np.float64)
    active = np.zeros((B, W), dtype=bool)
    for b, (inc, act) in enumerate(zip(incs, actives)):
        p, w = densify_csr(inc, n_nodes=N, width=W)
        present[b], weight[b] = p, w
        active[b, : act.shape[0]] = act
    return present, weight, active


# --------------------------------------------------------------------------- #
# the lockstep water-filling program                                           #
# --------------------------------------------------------------------------- #
def _matvec_fn(matvec: str):
    """Resolve a matvec kind to a traced ``(weight, x) -> use`` callable."""
    if matvec == "pallas":
        from ..kernels.alloc_matvec import alloc_matvec

        interpret = _jax().default_backend() != "tpu"
        return lambda w, x: alloc_matvec(w, x, interpret=interpret)
    from ..kernels.alloc_matvec import alloc_matvec_ref

    return alloc_matvec_ref


def _resolve_matvec(matvec: str, n_nodes: int, width: int) -> str:
    if matvec != "auto":
        return matvec
    # "auto": the Pallas kernel only pays off when the process opted into
    # the pallas kernel backend (TPU runs) and the block is kernel-sized;
    # interpret-mode Pallas on CPU is a correctness path, not a fast path.
    try:
        from ..kernels import ops

        if ops.get_backend() == "pallas" and n_nodes * width >= 4096:
            return "pallas"
    except Exception:
        pass
    return "jnp"


def _build_maxmin(matvec: str):
    """The jitted lockstep program for one matvec kind (shape-polymorphic:
    jax caches one executable per padded shape)."""
    jax = _jax()
    import jax.numpy as jnp
    from jax import lax

    mv = _matvec_fn(matvec)

    def maxmin_batch(present, weight, active):
        B, N, W = weight.shape
        n_active = jnp.sum(active, axis=1)                       # (B,)
        arange_n = jnp.arange(N)

        def lane_done(i, frozen):
            # mirrors the numpy loop: stop on full freeze or after the
            # n_active+1 safety cap (i counts completed rounds)
            return jnp.all(frozen, axis=1) | (i >= n_active + 1)

        def cond(carry):
            i, _, frozen = carry
            return ~jnp.all(lane_done(i, frozen))

        def scan_single(levels, valid):
            # the reference's tolerance-updated running minimum — order-
            # dependent when two levels sit within 1e-15, so it must scan
            # nodes in ascending order exactly like the numpy loop
            def scan_body(n, best_binding):
                best, binding = best_binding
                lvl, v = levels[n], valid[n]
                lower = v & (lvl < best - 1e-15)
                tie = v & ~lower & (jnp.abs(lvl - best) <= 1e-15)
                onehot = arange_n == n
                binding = jnp.where(
                    lower, onehot,
                    jnp.where(tie, binding | onehot, binding))
                best = jnp.where(lower, lvl, best)
                return best, binding

            return lax.fori_loop(
                0, N, scan_body,
                (jnp.asarray(1.0, levels.dtype), jnp.zeros(N, bool)))

        def body(carry):
            i, y, frozen = carry
            live = ~lane_done(i, frozen)                         # (B,)
            f_use = mv(weight, jnp.where(frozen, y, 0.0))        # (B, N)
            u_need = mv(weight, (~frozen).astype(weight.dtype))  # (B, N)
            valid = u_need > _EPS
            levels = jnp.maximum(0.0, 1.0 - f_use) / jnp.where(
                valid, u_need, 1.0)
            best, binding = jax.vmap(scan_single)(levels, valid)
            cap = best >= 1.0 - 1e-12
            best = jnp.where(cap, 1.0, best)
            on_binding = jnp.any(present & binding[:, :, None], axis=1)
            newly = jnp.where(cap[:, None], ~frozen, on_binding & ~frozen)
            # numerical safety (reference semantics): a round that froze
            # nothing freezes everything still open
            newly = jnp.where(
                jnp.any(newly, axis=1)[:, None], newly, ~frozen)
            upd = live[:, None] & ~frozen
            y = jnp.where(upd, best[:, None], y)
            frozen = frozen | (newly & live[:, None])
            return i + 1, y, frozen

        _, y, _ = lax.while_loop(
            cond, body,
            (jnp.asarray(0, jnp.int64),
             jnp.zeros((B, W), weight.dtype), ~active))
        return jnp.clip(y, 0.0, 1.0)

    return jax.jit(maxmin_batch)


def _maxmin_jit(matvec: str):
    key = ("maxmin", matvec)
    fn = _STATE.get(key)
    if fn is None:
        fn = _build_maxmin(matvec)
        _STATE[key] = fn
    return fn


def maxmin_yields_batch(
    present: np.ndarray,
    weight: np.ndarray,
    active: np.ndarray,
    matvec: str = "jnp",
) -> np.ndarray:
    """OPT=MIN water-filling over a padded dense batch — one jitted lockstep
    dispatch.  Per lane bit-equal to ``maxmin_yields_csr`` under x64."""
    matvec = _resolve_matvec(matvec, present.shape[1], present.shape[2])
    with _x64():
        y = _maxmin_jit(matvec)(present, weight, active)
        return np.asarray(y)


def maxmin_yields_jax(
    inc: CSRIncidence, active: np.ndarray, matvec: str = "jnp",
) -> np.ndarray:
    """Single-cell convenience (a 1-lane batch): full-width yield vector,
    bit-equal to ``maxmin_yields_csr(inc, active)``."""
    present, weight = densify_csr(inc)
    y = maxmin_yields_batch(present[None], weight[None], active[None],
                            matvec=matvec)
    return y[0]


# --------------------------------------------------------------------------- #
# batched stretch scatter (§4.7 node-usage pass)                               #
# --------------------------------------------------------------------------- #
def _usage_jit(n_nodes: int, batched: bool):
    key = ("usage", n_nodes, batched)
    fn = _STATE.get(key)
    if fn is None:
        jax = _jax()

        def usage(nodes, vals):
            # one extra segment swallows the padding (sentinel id n_nodes)
            out = jax.ops.segment_sum(vals, nodes,
                                      num_segments=n_nodes + 1)
            return out[..., :n_nodes]

        fn = jax.jit(jax.vmap(usage) if batched else usage)
        _STATE[key] = fn
    return fn


def node_usage(nodes: np.ndarray, vals: np.ndarray, n_nodes: int) -> np.ndarray:
    """Per-node usage scatter — bit-equal to the in-order ``np.add.at``
    accumulation of the §4.7 stretch passes.  ``nodes`` entries equal to
    ``n_nodes`` are padding and are dropped."""
    with _x64():
        return np.asarray(_usage_jit(int(n_nodes), False)(nodes, vals))


def node_usage_batch(
    nodes: np.ndarray, vals: np.ndarray, n_nodes: int,
) -> np.ndarray:
    """Batched :func:`node_usage` over ``(B, K)`` scatter lists (padded with
    the ``n_nodes`` sentinel), one fused device dispatch."""
    with _x64():
        return np.asarray(_usage_jit(int(n_nodes), True)(nodes, vals))


# --------------------------------------------------------------------------- #
# OPT=AVG: device floor + host HiGHS                                           #
# --------------------------------------------------------------------------- #
def _lam_jit(matvec: str):
    key = ("lam", matvec)
    fn = _STATE.get(key)
    if fn is None:
        jax = _jax()
        import jax.numpy as jnp

        mv = _matvec_fn(matvec)

        def lam(weight):
            # Λ per lane: max over nodes of the sequential row load sums
            B, N, W = weight.shape
            load = mv(weight, jnp.ones((B, W), weight.dtype))
            return jnp.max(load, axis=1)

        fn = jax.jit(lam)
        _STATE[key] = fn
    return fn


def _avg_lp(inc: CSRIncidence, cols: np.ndarray, y_min: float) -> np.ndarray:
    """The LP (2) solve of ``avg_yields_csr`` with the floor injected (the
    floor is the only device-computed input; from bit-identical ``y_min``
    the host solve is the identical scipy call)."""
    from scipy.optimize import linprog

    m = int(cols.shape[0])
    res = linprog(
        c=-np.ones(m),
        A_ub=inc.scipy_csr(cols),
        b_ub=np.ones(inc.n_nodes),
        bounds=[(y_min, 1.0)] * m,
        method="highs",
    )
    if not res.success:  # numerically degenerate: the safe floor
        return np.full(m, y_min)
    return np.clip(res.x, 0.0, 1.0)


# --------------------------------------------------------------------------- #
# engine-pluggable backends                                                    #
# --------------------------------------------------------------------------- #
class BatchedAllocator:
    """Serve many cells' allocation requests as single padded dispatches.

    ``allocate_many([(inc, cols, opt), ...])`` answers every request with
    the bit-exact yields for its cell: OPT=MIN requests are compacted to
    their running columns, padded into one ``(B, N, W)`` batch (shapes
    bucketed to powers of two so a sweep compiles a handful of programs,
    not one per event) and solved in one lockstep dispatch; OPT=AVG
    requests get their floors from one device reduction and their LPs from
    the host solver.
    """

    def __init__(self, matvec: str = "auto"):
        if matvec not in ("auto", "jnp", "pallas"):
            raise ValueError(f"unknown matvec backend {matvec!r}")
        self.matvec = matvec

    # -- single request (the Engine alloc_backend protocol) ---------------- #
    def allocate(self, inc: CSRIncidence, cols: np.ndarray,
                 opt: str = "MIN") -> np.ndarray:
        return self.allocate_many([(inc, cols, opt)])[0]

    # -- batched ----------------------------------------------------------- #
    def allocate_many(
        self, requests: Sequence[Tuple[CSRIncidence, np.ndarray, str]],
    ) -> List[np.ndarray]:
        out: List[Optional[np.ndarray]] = [None] * len(requests)
        min_idx = [i for i, (_, c, opt) in enumerate(requests)
                   if opt == "MIN" and c.shape[0]]
        avg_idx = [i for i, (_, c, opt) in enumerate(requests)
                   if opt == "AVG" and c.shape[0]]
        for i, (_, c, opt) in enumerate(requests):
            if opt not in ("MIN", "AVG"):
                raise ValueError(f"unknown OPT {opt!r}")
            if not c.shape[0]:
                out[i] = np.zeros(0)
        if min_idx:
            self._serve_min(requests, min_idx, out)
        if avg_idx:
            self._serve_avg(requests, avg_idx, out)
        return out  # fully populated

    def _pad_compact(self, requests, idx):
        """Compact each request to its running columns and pad the set into
        one bucketed batch (per-lane exactness makes the co-batching safe:
        no lane's answer depends on what else is in the batch)."""
        N = _bucket(max(requests[i][0].n_nodes for i in idx))
        W = _bucket(max(requests[i][1].shape[0] for i in idx), 8)
        B = _bucket(len(idx))
        present = np.zeros((B, N, W), dtype=bool)
        weight = np.zeros((B, N, W), dtype=np.float64)
        active = np.zeros((B, W), dtype=bool)
        for b, i in enumerate(idx):
            inc, cols, _ = requests[i]
            p, w = densify_csr(inc, n_nodes=N, cols=cols, width=W)
            present[b], weight[b] = p, w
            active[b, : cols.shape[0]] = True
        return present, weight, active

    def _serve_min(self, requests, idx, out):
        present, weight, active = self._pad_compact(requests, idx)
        y = maxmin_yields_batch(present, weight, active, matvec=self.matvec)
        for b, i in enumerate(idx):
            m = requests[i][1].shape[0]
            out[i] = y[b, :m].copy()

    def _serve_avg(self, requests, idx, out):
        _, weight, _ = self._pad_compact(requests, idx)
        matvec = _resolve_matvec(self.matvec, weight.shape[1], weight.shape[2])
        with _x64():
            lams = np.asarray(_lam_jit(matvec)(weight))
        for b, i in enumerate(idx):
            inc, cols, _ = requests[i]
            lam = float(lams[b]) if inc.n_nodes else 0.0
            out[i] = _avg_lp(inc, cols, 1.0 / max(1.0, lam))


class JaxAllocBackend(BatchedAllocator):
    """One-cell engine backend: ``Engine(..., alloc_backend=JaxAllocBackend())``
    answers every §4.6 reallocation from the device, bit-identically to the
    numpy hot path (``allocate_incidence``)."""


# --------------------------------------------------------------------------- #
# lockstep dispatch: many engine threads, one device                           #
# --------------------------------------------------------------------------- #
class LockstepDispatcher:
    """Coordinate N engine threads so their allocation requests land on the
    device as one batch per scheduling round.

    Each engine runs in its own thread with a :meth:`lane` backend plugged
    in; a lane's ``allocate`` parks the thread until the driver thread
    (:meth:`serve`) has collected a request from *every* lane that is still
    running — engines that never allocate (batch baselines) simply run to
    completion and drop out of the barrier via :meth:`finish_lane`.  The
    driver answers each round with one ``BatchedAllocator.allocate_many``
    and wakes the lanes.  Per-lane results are bit-independent of batch
    composition, so the lockstep schedule cannot change any cell's outcome.
    """

    def __init__(self, n_lanes: int, allocator: BatchedAllocator):
        self.n_lanes = int(n_lanes)
        self.allocator = allocator
        self._cond = threading.Condition()
        self._pending: Dict[int, Tuple[CSRIncidence, np.ndarray, str]] = {}
        self._results: Dict[int, object] = {}
        self._finished: set = set()
        self._broken: Optional[BaseException] = None

    def lane(self, i: int) -> "_Lane":
        return _Lane(self, i)

    def finish_lane(self, i: int) -> None:
        """A lane's engine is done (or died) — it leaves the barrier."""
        with self._cond:
            self._finished.add(i)
            self._cond.notify_all()

    def _request(self, i, inc, cols, opt) -> np.ndarray:
        with self._cond:
            if self._broken is not None:
                raise self._broken
            self._pending[i] = (inc, cols, opt)
            self._cond.notify_all()
            self._cond.wait_for(
                lambda: i in self._results or self._broken is not None)
            res = self._results.pop(i, self._broken)
        if isinstance(res, BaseException):
            raise res
        return res

    def serve(self) -> None:
        """Drive rounds until every lane finished.  Call from the thread
        that owns the device (the sweep driver)."""
        while True:
            with self._cond:
                self._cond.wait_for(
                    lambda: len(self._pending) + len(self._finished)
                    >= self.n_lanes)
                if not self._pending:
                    return              # every lane finished
                batch = sorted(self._pending.items())
                self._pending.clear()
            lanes = [i for i, _ in batch]
            try:
                answers = self.allocator.allocate_many([r for _, r in batch])
            except BaseException as exc:
                with self._cond:        # poison: wake every parked/future lane
                    self._broken = exc
                    self._cond.notify_all()
                raise
            with self._cond:
                for i, y in zip(lanes, answers):
                    self._results[i] = y
                self._cond.notify_all()


class _Lane:
    """The per-engine view of a :class:`LockstepDispatcher` (the object an
    ``Engine`` receives as ``alloc_backend``)."""

    __slots__ = ("_dispatcher", "index")

    def __init__(self, dispatcher: LockstepDispatcher, index: int):
        self._dispatcher = dispatcher
        self.index = index

    def allocate(self, inc: CSRIncidence, cols: np.ndarray,
                 opt: str = "MIN") -> np.ndarray:
        if not cols.shape[0]:
            return np.zeros(0)          # nothing running: no round trip
        return self._dispatcher._request(self.index, inc, cols, opt)
