"""EQUIPARTITION on a single unit-capacity resource (paper §3.2, Theorem 4).

Used for the theoretical analysis: every not-yet-completed job receives an
equal share 1/m(t) of the resource.  Jobs here are perfectly parallel /
single-task with need 1 (the Theorem-2/3/4 setting).  Returns completion
times; the simulation is exact (piecewise-constant shares between events).
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

__all__ = ["equipartition_schedule", "max_stretch", "thm4_instance"]


def equipartition_schedule(
    releases: Sequence[float], proc_times: Sequence[float]
) -> List[float]:
    """Exact completion times under EQUIPARTITION on one unit resource."""
    n = len(releases)
    rem = np.asarray(proc_times, dtype=float).copy()
    rel = np.asarray(releases, dtype=float)
    done = np.full(n, np.inf)
    active = np.zeros(n, dtype=bool)
    order = np.argsort(rel, kind="stable")
    idx = 0
    t = float(rel[order[0]]) if n else 0.0
    while True:
        while idx < n and rel[order[idx]] <= t + 1e-15:
            active[order[idx]] = True
            idx += 1
        m = int(active.sum())
        if m == 0:
            if idx >= n:
                break
            t = float(rel[order[idx]])
            continue
        rate = 1.0 / m
        t_fin = t + rem[active].min() / rate          # next completion
        t_arr = float(rel[order[idx]]) if idx < n else np.inf
        t_next = min(t_fin, t_arr)
        rem[active] -= rate * (t_next - t)
        finished = active & (rem <= 1e-12)
        done[finished] = t_next
        active &= ~finished
        t = t_next
        if idx >= n and not active.any():
            break
    return list(done)


def max_stretch(
    releases: Sequence[float], proc_times: Sequence[float], completions: Sequence[float]
) -> float:
    s = [
        (c - r) / p
        for r, p, c in zip(releases, proc_times, completions)
    ]
    return max(s) if s else 0.0


def thm4_instance(n: int) -> Tuple[List[float], List[float]]:
    """The adversarial instance from Theorem 4's proof: p_1 = p_2 = n-1,
    p_i = (n-1)/(i-1) for i >= 3, releases r_1 = r_2 = 0,
    r_i = r_{i-1} + p_{i-1}.  Under EQUIPARTITION every job completes at
    r_n + n and the max stretch is n, while an optimal schedule achieves
    2 + sum_{i=2}^{n-1} 1/i."""
    assert n >= 3
    p = [0.0] * (n + 1)
    p[1] = p[2] = float(n - 1)
    for i in range(3, n + 1):
        p[i] = (n - 1) / (i - 1)
    r = [0.0] * (n + 1)
    r[1] = r[2] = 0.0
    for i in range(3, n + 1):
        r[i] = r[i - 1] + p[i - 1]
    return r[1:], p[1:]
