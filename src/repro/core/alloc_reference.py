"""Pre-vectorization reference implementations of the allocation hot path.

These are the original (PR-1 era) pure-Python/dict-loop implementations of
the §4.6 yield allocation, the §4.2 greedy placement and the §4.3 MCB8
packing core, kept verbatim as the *oracle* for the vectorized kernels in
:mod:`repro.core.alloc_kernels`:

* property tests drive randomized specs/mappings through both paths and
  require bit-identical outputs;
* :func:`repro.core.alloc_kernels.reference_kernels` switches the whole
  engine onto these implementations so golden end-to-end equivalence tests
  can compare full ``SimResult``s against the vectorized hot path.

Do not "improve" this module — its value is that it does not change.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .job import JobSpec, NodePool

__all__ = [
    "node_tables",
    "maxmin_yields",
    "avg_yields",
    "greedy_place",
    "pack_core",
    "node_usage",
    "improve_max_stretch",
    "improve_avg_stretch",
]

_EPS = 1e-12


# --------------------------------------------------------------------------- #
# §4.6 yield allocation (original yield_alloc.py)                              #
# --------------------------------------------------------------------------- #
def node_tables(
    specs: Sequence[JobSpec], mappings: Sequence[Sequence[int]], n_nodes: int
) -> Tuple[np.ndarray, List[List[Tuple[int, int]]]]:
    """Return (per-node total CPU need, per-node list of (job_idx, mult))."""
    per_node: List[Dict[int, int]] = [dict() for _ in range(n_nodes)]
    for ji, mapping in enumerate(mappings):
        for node in mapping:
            per_node[node][ji] = per_node[node].get(ji, 0) + 1
    node_lists = [sorted(d.items()) for d in per_node]
    need = np.zeros(n_nodes)
    for node, items in enumerate(node_lists):
        need[node] = sum(specs[ji].cpu_need * mult for ji, mult in items)
    return need, node_lists


def maxmin_yields(
    specs: Sequence[JobSpec],
    mappings: Sequence[Sequence[int]],
    n_nodes: int,
) -> np.ndarray:
    """OPT=MIN reference: nested-loop water-filling."""
    m = len(specs)
    y = np.zeros(m)
    if m == 0:
        return y
    frozen = np.zeros(m, dtype=bool)
    load_need, node_lists = node_tables(specs, mappings, n_nodes)

    for _ in range(m + 1):
        if frozen.all():
            break
        best_level = 1.0  # cap at yield 1
        binding_nodes: List[int] = []
        for node, items in enumerate(node_lists):
            f_use = 0.0
            u_need = 0.0
            for ji, mult in items:
                c = specs[ji].cpu_need * mult
                if frozen[ji]:
                    f_use += y[ji] * c
                else:
                    u_need += c
            if u_need <= _EPS:
                continue
            level = max(0.0, (1.0 - f_use)) / u_need
            if level < best_level - 1e-15:
                best_level = level
                binding_nodes = [node]
            elif abs(level - best_level) <= 1e-15:
                binding_nodes.append(node)
        newly = np.zeros(m, dtype=bool)
        if best_level >= 1.0 - 1e-12:
            best_level = 1.0
            newly |= ~frozen  # everyone capped
        else:
            for node in binding_nodes:
                for ji, _ in node_lists[node]:
                    if not frozen[ji]:
                        newly[ji] = True
        y[~frozen] = best_level
        if not newly.any():          # numerical safety
            newly |= ~frozen
        frozen |= newly
    return np.clip(y, 0.0, 1.0)


def avg_yields(
    specs: Sequence[JobSpec],
    mappings: Sequence[Sequence[int]],
    n_nodes: int,
) -> np.ndarray:
    """OPT=AVG reference: LP (2) with a lil_matrix-built constraint matrix."""
    from scipy.optimize import linprog
    from scipy.sparse import lil_matrix

    m = len(specs)
    if m == 0:
        return np.zeros(0)
    load_need, node_lists = node_tables(specs, mappings, n_nodes)
    lam = float(load_need.max()) if n_nodes else 0.0
    y_min = 1.0 / max(1.0, lam)
    a = lil_matrix((n_nodes, m))
    for node, items in enumerate(node_lists):
        for ji, mult in items:
            a[node, ji] = specs[ji].cpu_need * mult
    res = linprog(
        c=-np.ones(m),
        A_ub=a.tocsr(),
        b_ub=np.ones(n_nodes),
        bounds=[(y_min, 1.0)] * m,
        method="highs",
    )
    if not res.success:  # numerically degenerate: fall back to the safe floor
        return np.full(m, y_min)
    return np.clip(res.x, 0.0, 1.0)


# --------------------------------------------------------------------------- #
# §4.2 greedy placement (original greedy.py)                                   #
# --------------------------------------------------------------------------- #
def greedy_place(pool: NodePool, spec: JobSpec) -> Optional[List[int]]:
    """Per-task argmin over a freshly rebuilt masked-load array."""
    mapping: List[int] = []
    for _ in range(spec.n_tasks):
        feasible = pool.mem_free >= spec.mem_req - 1e-12
        if not feasible.any():
            if mapping:
                pool.remove(spec, mapping)
            return None
        loads = np.where(feasible, pool.load, np.inf)
        node = int(np.argmin(loads))
        mapping.append(node)
        pool.load[node] += spec.cpu_need
        pool.mem_free[node] -= spec.mem_req
    return mapping


# --------------------------------------------------------------------------- #
# §4.3 MCB8 packing core (original mcb8.py)                                    #
# --------------------------------------------------------------------------- #
_PACK_EPS = 1e-9


def _sorted_arrays(entries):
    entries = sorted(entries, key=lambda e: (-max(e[1], e[2]), e[0]))
    jid = np.array([e[0] for e in entries], dtype=np.int64)
    cpu = np.array([e[1] for e in entries])
    mem = np.array([e[2] for e in entries])
    left = np.array([e[3] for e in entries], dtype=np.int64)
    return jid, cpu, mem, left


def pack_core(n_nodes, jobs, pre_placed, cpu_free, mem_free, out):
    """One MCB8 pack over ``jobs`` = [(jid, cpu_req, mem_req, n_tasks)]."""
    lists = [
        _sorted_arrays([e for e in jobs if e[1] > e[2]]),    # CPU-intensive
        _sorted_arrays([e for e in jobs if e[1] <= e[2]]),   # memory-intensive
    ]
    for e in jobs:
        out.setdefault(int(e[0]), [])

    def take_from(li: int, node: int, prefer_mem: bool) -> int:
        jid, cpu, mem, left = lists[li]
        if jid.size == 0:
            return 0
        cf, mf = cpu_free[node], mem_free[node]
        ok = (left > 0) & (cpu <= cf + _PACK_EPS) & (mem <= mf + _PACK_EPS)
        i = int(np.argmax(ok))
        if not ok[i]:
            return 0
        k = int(left[i])
        if cpu[i] > _PACK_EPS:
            k = min(k, int((cf + _PACK_EPS) / cpu[i]))
        if mem[i] > _PACK_EPS:
            k = min(k, int((mf + _PACK_EPS) / mem[i]))
        d0 = mf - cf
        delta = mem[i] - cpu[i]
        if prefer_mem and delta > _PACK_EPS:          # d must stay > 0
            k = min(k, max(1, int(np.ceil((d0 - _PACK_EPS) / delta))))
        elif not prefer_mem and delta < -_PACK_EPS:   # d must stay <= 0
            k = min(k, max(1, int(np.ceil((d0 + _PACK_EPS) / delta))))
        k = max(k, 1)
        left[i] -= k
        cpu_free[node] -= k * cpu[i]
        mem_free[node] -= k * mem[i]
        out[int(jid[i])].extend([node] * k)
        return k

    remaining = int(lists[0][3].sum() + lists[1][3].sum())
    for node in range(n_nodes):
        while remaining > 0:
            prefer_mem = bool(mem_free[node] > cpu_free[node])
            first, second = (1, 0) if prefer_mem else (0, 1)
            placed = take_from(first, node, prefer_mem) or take_from(second, node, prefer_mem)
            if placed:
                remaining -= placed
            else:
                break
        if remaining == 0:
            break
    if remaining > 0:
        return None
    out.update(pre_placed)
    return out


# --------------------------------------------------------------------------- #
# §4.7 stretch post-passes (original stretch_opt.py internals)                 #
# --------------------------------------------------------------------------- #
def node_usage(jobs, mappings, yields, n_nodes):
    use = np.zeros(n_nodes)
    for js in jobs:
        for node in mappings[js.spec.jid]:
            use[node] += yields[js.spec.jid] * js.spec.cpu_need
    return use


def _required_yield(js, now: float, period: float, target: float) -> float:
    ft = js.flow_time(now)
    return ((ft + period) / target - js.vt) / period


def improve_max_stretch(
    jobs,
    mappings: Dict[int, List[int]],
    yields: Dict[int, float],
    n_nodes: int,
    now: float,
    period: float,
    max_rounds: int = 200,
) -> Dict[int, float]:
    """OPT=MAX reference: per-round Python loops over jobs and node usage."""
    jobs = [js for js in jobs if js.spec.jid in mappings]
    if not jobs:
        return yields
    yields = dict(yields)
    frozen: set = set()

    def est(js):
        return (js.flow_time(now) + period) / max(
            _PACK_EPS, js.vt + yields[js.spec.jid] * period)

    for _ in range(max_rounds):
        live = [js for js in jobs
                if js.spec.jid not in frozen
                and yields[js.spec.jid] < 1.0 - _PACK_EPS]
        if not live:
            break
        worst = max(live, key=est)
        s_worst = est(worst)
        others = [est(js) for js in jobs if js is not worst]
        s_next = max([s for s in others if s < s_worst - 1e-12], default=1.0)
        target = max(s_next, 1.0)
        y_target = _required_yield(worst, now, period, target)
        use = node_usage(jobs, mappings, yields, n_nodes)
        jid = worst.spec.jid
        mult: Dict[int, int] = {}
        for node in mappings[jid]:
            mult[node] = mult.get(node, 0) + 1
        dy_slack = min(
            (1.0 - use[node]) / (worst.spec.cpu_need * k) for node, k in mult.items()
        )
        dy = min(max(0.0, y_target - yields[jid]), max(0.0, dy_slack),
                 1.0 - yields[jid])
        if dy <= 1e-6:
            frozen.add(jid)
            continue
        yields[jid] += dy
    return yields


def improve_avg_stretch(
    jobs,
    mappings: Dict[int, List[int]],
    yields: Dict[int, float],
    n_nodes: int,
    now: float,
    period: float,
) -> Dict[int, float]:
    """OPT=AVG reference: lil_matrix-built LP."""
    from scipy.optimize import linprog
    from scipy.sparse import lil_matrix

    jobs = [js for js in jobs if js.spec.jid in mappings]
    if not jobs:
        return yields
    m = len(jobs)
    a = lil_matrix((n_nodes, m))
    lo = np.zeros(m)
    w = np.zeros(m)
    for i, js in enumerate(jobs):
        for node in mappings[js.spec.jid]:
            a[node, i] += js.spec.cpu_need
        lo[i] = yields[js.spec.jid]
        w[i] = period / (js.flow_time(now) + period)
    res = linprog(
        c=-w,
        A_ub=a.tocsr(),
        b_ub=np.ones(n_nodes),
        bounds=list(zip(lo, np.ones(m))),
        method="highs",
    )
    out = dict(yields)
    if res.success:
        for i, js in enumerate(jobs):
            out[js.spec.jid] = float(np.clip(res.x[i], 0.0, 1.0))
    return out
