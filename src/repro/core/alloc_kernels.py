"""Vectorized allocation kernels over a job×node CSR incidence matrix.

The §4.6 yield allocation and §4.7 stretch passes all reduce to the same
question: *per node, how much CPU do the resident tasks of each job use?*
The pre-vectorization code answered it by rebuilding per-node dict tables
from every job's task mapping on every scheduling event and then running
nested Python loops over them — the profile-dominant cost of a simulation
cell.  This module replaces that with:

* :class:`CSRIncidence` — an immutable node-major CSR snapshot
  (``indptr``/``indices``/``data``) where row = node, column = job index and
  ``data = cpu_need * multiplicity``;
* :class:`NodeIncidence` — the engine-owned *incremental* structure: per-node
  ``{job: multiplicity}`` counts updated on start/pause/migrate/complete,
  with dirty-row tracking so a CSR snapshot costs only the changed rows;
* :func:`maxmin_yields_csr` — §4.6 water-filling as whole-array sparse
  matvecs (per-node frozen use and unfrozen need) with one freeze round per
  pass instead of nested per-item Python loops.

Bit-identity contract: every kernel here reproduces the reference
implementations in :mod:`repro.core.alloc_reference` *bit for bit*.  The
row sums use a sequential (left-to-right, column-ascending) CSR matvec —
NOT ``np.sum``/``np.dot``, whose pairwise summation rounds differently —
so each per-node accumulation performs the identical IEEE operation
sequence as the original dict-loop code.  Masked-out terms contribute an
exact ``+ 0.0``, which never changes a finite non-negative partial sum.

:func:`reference_kernels` flips the whole engine (yield_alloc, greedy,
mcb8, stretch_opt) onto the reference implementations; the golden
equivalence tests run every cell both ways and require identical
``SimResult``s.
"""
from __future__ import annotations

import contextlib
from typing import Iterator, List, Optional, Sequence

import numpy as np

__all__ = [
    "CSRIncidence",
    "NodeIncidence",
    "build_csr",
    "maxmin_yields_csr",
    "avg_yields_csr",
    "reference_kernels",
    "reference_kernels_active",
]

_EPS = 1e-12

_EMPTY_I = np.zeros(0, dtype=np.int64)
_EMPTY_F = np.zeros(0, dtype=np.float64)

# --------------------------------------------------------------------------- #
# reference-mode switch                                                        #
# --------------------------------------------------------------------------- #
_REFERENCE = False


def reference_kernels_active() -> bool:
    """True while the engine is forced onto the pre-vectorization oracle."""
    return _REFERENCE


@contextlib.contextmanager
def reference_kernels() -> Iterator[None]:
    """Run everything under the :mod:`repro.core.alloc_reference` oracle.

    Used by the golden equivalence tests: a simulation executed inside this
    context takes the original dict/loop allocation paths end to end, so its
    ``SimResult`` is the ground truth the vectorized hot path must match
    bit for bit.
    """
    global _REFERENCE
    prev = _REFERENCE
    _REFERENCE = True
    try:
        yield
    finally:
        _REFERENCE = prev


# --------------------------------------------------------------------------- #
# sequential CSR matvec (bitwise-equal to the reference Python accumulation)   #
# --------------------------------------------------------------------------- #
try:  # scipy's C kernel accumulates strictly left to right — exactly what
    # the dict-loop reference does.  Private but stable; guarded fallback.
    from scipy.sparse import _sparsetools as _sptools

    def _seq_matvec(indptr, indices, data, x, out):
        out[:] = 0.0
        _sptools.csr_matvec(indptr.shape[0] - 1, x.shape[0],
                            indptr, indices, data, x, out)
        return out
except Exception:  # pragma: no cover - depends on scipy version
    def _seq_matvec(indptr, indices, data, x, out):
        out[:] = 0.0
        np.add.at(out, np.repeat(np.arange(indptr.shape[0] - 1),
                                 np.diff(indptr)), data * x[indices])
        return out


class CSRIncidence:
    """Immutable node-major CSR snapshot of the job×node incidence.

    ``data[k]`` is ``cpu_need[j] * multiplicity`` for job ``j = indices[k]``
    on the row's node; columns are ascending within each row, which fixes
    the accumulation order of every kernel to the reference order.
    """

    __slots__ = ("n_nodes", "width", "indptr", "indices", "data")

    def __init__(self, n_nodes: int, width: int,
                 indptr: np.ndarray, indices: np.ndarray, data: np.ndarray):
        self.n_nodes = n_nodes
        self.width = width          # number of job columns (dense job space)
        self.indptr = indptr
        self.indices = indices
        self.data = data

    def matvec(self, x: np.ndarray, out: Optional[np.ndarray] = None) -> np.ndarray:
        """Per-node sequential row sums of ``data * x[indices]``."""
        if out is None:
            out = np.empty(self.n_nodes)
        return _seq_matvec(self.indptr, self.indices, self.data, x, out)

    def row_jobs(self, node: int) -> np.ndarray:
        """Job columns resident on ``node`` (ascending)."""
        return self.indices[self.indptr[node]:self.indptr[node + 1]]

    def scipy_csr(self, cols: np.ndarray):
        """Scipy CSR restricted to ``cols`` (sorted job columns) for the LP
        passes; equals the reference lil-built constraint matrix."""
        from scipy.sparse import csr_matrix

        pos = np.searchsorted(cols, self.indices)
        return csr_matrix((self.data, pos, self.indptr),
                          shape=(self.n_nodes, cols.shape[0]))


def build_csr(cpu_need: Sequence[float],
              mappings: Sequence[Sequence[int]],
              n_nodes: int) -> CSRIncidence:
    """From-scratch CSR for the public (specs, mappings) API: column ``j`` is
    position ``j`` in ``mappings``; rows hold ascending columns, mirroring the
    sorted per-node tables of the reference implementation."""
    per_node: List[dict] = [dict() for _ in range(n_nodes)]
    for ji, mapping in enumerate(mappings):
        for node in mapping:
            per_node[node][ji] = per_node[node].get(ji, 0) + 1
    indptr = np.zeros(n_nodes + 1, dtype=np.int64)
    idx_rows: List[np.ndarray] = []
    dat_rows: List[np.ndarray] = []
    cpu = np.asarray(cpu_need, dtype=np.float64)
    for node, d in enumerate(per_node):
        if d:
            items = sorted(d.items())
            ji = np.array([i for i, _ in items], dtype=np.int64)
            mult = np.array([m for _, m in items], dtype=np.float64)
            idx_rows.append(ji)
            dat_rows.append(cpu[ji] * mult)
        else:
            idx_rows.append(_EMPTY_I)
            dat_rows.append(_EMPTY_F)
        indptr[node + 1] = indptr[node] + idx_rows[-1].shape[0]
    indices = np.concatenate(idx_rows) if idx_rows else _EMPTY_I
    data = np.concatenate(dat_rows) if dat_rows else _EMPTY_F
    return CSRIncidence(n_nodes, len(mappings), indptr, indices, data)


class NodeIncidence:
    """Incrementally maintained job×node incidence.

    The engine calls :meth:`place` / :meth:`remove` on every
    start/pause/migrate/complete transition (mirroring its ``NodePool``
    bookkeeping), so at any scheduling event the CSR snapshot of the
    *currently running* tasks is available without rescanning any mapping.
    Rows are rebuilt lazily and only when dirty; the concatenated snapshot
    is cached until the next structural change.
    """

    def __init__(self, n_nodes: int, cpu_need: np.ndarray):
        self.n_nodes = int(n_nodes)
        # owned geometric buffer; cpu_need is the width-sized head view
        self._cpu_buf = np.array(cpu_need, dtype=np.float64)
        self._width = int(self._cpu_buf.shape[0])
        self.cpu_need = self._cpu_buf[: self._width]
        self.rows: List[dict] = [dict() for _ in range(self.n_nodes)]
        self._row_idx: List[np.ndarray] = [_EMPTY_I] * self.n_nodes
        self._row_dat: List[np.ndarray] = [_EMPTY_F] * self.n_nodes
        self._dirty: set = set()
        self._snap: Optional[CSRIncidence] = None

    def place(self, job: int, mapping: Sequence[int]) -> None:
        rows = self.rows
        for node in mapping:
            r = rows[node]
            r[job] = r.get(job, 0) + 1
        self._dirty.update(mapping)
        self._snap = None

    def remove(self, job: int, mapping: Sequence[int]) -> None:
        rows = self.rows
        for node in mapping:
            r = rows[node]
            m = r[job] - 1
            if m:
                r[job] = m
            else:
                del r[job]
        self._dirty.update(mapping)
        self._snap = None

    def extend(self, cpu_need_tail: np.ndarray) -> None:
        """Grow the job-column space (streaming sessions append jobs).

        Existing rows keep their cached arrays — old column data is
        untouched — but the cached CSR snapshot is invalidated because the
        matrix ``width`` (dense job count) changes.  Appends land in a
        geometrically doubled buffer (amortized O(1) per job).
        """
        tail = np.asarray(cpu_need_tail, dtype=np.float64)
        need = self._width + int(tail.shape[0])
        if need > self._cpu_buf.shape[0]:
            buf = np.empty(max(need, 2 * self._cpu_buf.shape[0], 16))
            buf[: self._width] = self._cpu_buf[: self._width]
            self._cpu_buf = buf
        self._cpu_buf[self._width:need] = tail
        self._width = need
        self.cpu_need = self._cpu_buf[:need]
        self._snap = None

    def compact(self, keep: np.ndarray, new_of_old: np.ndarray) -> None:
        """Drop evicted job columns (``EngineState.compact``).

        ``keep`` — ascending surviving dense indices; ``new_of_old`` — the
        old→new column map.  Every resident task belongs to a RUNNING job,
        so all occupied columns survive; the remap is monotone, which keeps
        each row's ``sorted(d.items())`` order — and therefore the CSR data
        order every kernel accumulates in — exactly what a from-scratch
        build over the compacted state would produce.
        """
        m = int(keep.shape[0])
        self._cpu_buf[:m] = self._cpu_buf[: self._width][keep]
        self._width = m
        self.cpu_need = self._cpu_buf[:m]
        for node, d in enumerate(self.rows):
            if d:
                self.rows[node] = {
                    int(new_of_old[j]): mult for j, mult in d.items()}
                self._dirty.add(node)
        self._snap = None

    def csr(self) -> CSRIncidence:
        if self._snap is not None:
            return self._snap
        cpu = self.cpu_need
        for node in self._dirty:
            d = self.rows[node]
            if d:
                items = sorted(d.items())
                ji = np.array([i for i, _ in items], dtype=np.int64)
                mult = np.array([m for _, m in items], dtype=np.float64)
                self._row_idx[node] = ji
                self._row_dat[node] = cpu[ji] * mult
            else:
                self._row_idx[node] = _EMPTY_I
                self._row_dat[node] = _EMPTY_F
        self._dirty.clear()
        indptr = np.zeros(self.n_nodes + 1, dtype=np.int64)
        np.cumsum([r.shape[0] for r in self._row_idx], out=indptr[1:])
        indices = np.concatenate(self._row_idx) if self.n_nodes else _EMPTY_I
        data = np.concatenate(self._row_dat) if self.n_nodes else _EMPTY_F
        self._snap = CSRIncidence(self.n_nodes, self.cpu_need.shape[0],
                                  indptr, indices, data)
        return self._snap


# --------------------------------------------------------------------------- #
# §4.6 kernels                                                                 #
# --------------------------------------------------------------------------- #
def maxmin_yields_csr(inc: CSRIncidence, active: np.ndarray) -> np.ndarray:
    """OPT=MIN water-filling over the incidence matrix.

    ``active`` flags the job columns that participate (the running set);
    inactive columns must have no incidence entries.  Returns the full-width
    yield vector (zeros at inactive columns).  Each freeze round is two
    sequential matvecs (frozen use, unfrozen need) plus an O(n_nodes) scan —
    the per-item Python loops of the reference are gone, the float operation
    sequence per node is unchanged.
    """
    w = inc.width
    y = np.zeros(w)
    n_active = int(active.sum())
    if n_active == 0:
        return y
    frozen = ~active
    indptr, indices = inc.indptr, inc.indices
    f_use = np.empty(inc.n_nodes)
    u_need = np.empty(inc.n_nodes)
    for _ in range(n_active + 1):
        if frozen.all():
            break
        inc.matvec(np.where(frozen, y, 0.0), out=f_use)
        inc.matvec((~frozen).astype(np.float64), out=u_need)
        valid = np.nonzero(u_need > _EPS)[0]
        levels = np.maximum(0.0, 1.0 - f_use[valid]) / u_need[valid]
        # Sequential bottleneck scan in node order: replicates the reference's
        # tolerance-updated running minimum (order-dependent when two levels
        # sit within 1e-15 of each other, so it cannot be a plain argmin).
        best_level = 1.0
        binding: List[int] = []
        for node, level in zip(valid.tolist(), levels.tolist()):
            if level < best_level - 1e-15:
                best_level = level
                binding = [node]
            elif abs(level - best_level) <= 1e-15:
                binding.append(node)
        newly = np.zeros(w, dtype=bool)
        if best_level >= 1.0 - 1e-12:
            best_level = 1.0
            newly |= ~frozen  # everyone capped
        else:
            for node in binding:
                sl = indices[indptr[node]:indptr[node + 1]]
                newly[sl[~frozen[sl]]] = True
        y[~frozen] = best_level
        if not newly.any():          # numerical safety
            newly |= ~frozen
        frozen |= newly
    return np.clip(y, 0.0, 1.0)


def avg_yields_csr(inc: CSRIncidence, cols: np.ndarray) -> np.ndarray:
    """OPT=AVG over the incidence matrix: LP (2) with the constraint matrix
    sliced straight out of the CSR snapshot (no lil_matrix rebuild).

    ``cols`` — sorted job columns participating (the running set).  Returns
    yields aligned with ``cols``.
    """
    from scipy.optimize import linprog

    m = int(cols.shape[0])
    if m == 0:
        return np.zeros(0)
    load_need = inc.matvec(np.ones(inc.width))
    lam = float(load_need.max()) if inc.n_nodes else 0.0
    y_min = 1.0 / max(1.0, lam)
    res = linprog(
        c=-np.ones(m),
        A_ub=inc.scipy_csr(cols),
        b_ub=np.ones(inc.n_nodes),
        bounds=[(y_min, 1.0)] * m,
        method="highs",
    )
    if not res.success:  # numerically degenerate: fall back to the safe floor
        return np.full(m, y_min)
    return np.clip(res.x, 0.0, 1.0)
