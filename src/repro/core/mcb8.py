"""MCB8 — two-dimensional vector-packing resource allocation (paper §4.3).

Fixing a target yield Y turns fluid CPU needs into CPU *requirements*
(c_j * Y); the mapping problem then becomes 2-D vector packing (CPU, memory)
which we solve with the Leinberger-style multi-capacity heuristic the paper
calls MCB8: two lists (CPU-intensive / memory-intensive), each sorted by
non-increasing largest requirement, packing always drawing from the list
that goes against the current node imbalance.

A binary search (accuracy 0.01) finds the largest feasible Y.  If no Y is
feasible (memory-infeasible), the lowest-priority job is removed from
consideration and the search restarts (§4.3).

``pinned`` mappings support the MINVT/MINFT grace parameters: a pinned job,
if it keeps running, must keep its current node mapping — it is pre-placed
before the two-list packing fills the remainder.

Hot-path implementation notes (bit-identical to
:func:`repro.core.alloc_reference.pack_core`, which is the tested oracle):

* Each list is sorted by non-increasing *dominant* requirement, so the
  dominant-axis feasibility test is a contiguous suffix found by bisection
  instead of a whole-array boolean scan per placement.
* Within that suffix, exhausted items are skipped through a path-compressed
  "next alive" union-find, and the *fallback* list never needs its secondary
  requirement checked at all: when memory is the node's scarcer axis the
  CPU-intensive item that fits on CPU automatically fits in memory (its
  memory need is below its CPU need, which is below the CPU slack, which is
  below the memory slack), and symmetrically for the other direction.  Only
  the *preferred* list pays a secondary scan, and that scan vectorizes after
  a few misses.
* A conservative aggregate capacity pre-check (total requirement vs. total
  free capacity plus the maximum possible epsilon over-consumption) rejects
  hopeless probes before packing a single task — the binary search probes
  infeasibly-high yields about half the time.
"""
from __future__ import annotations

import math
from bisect import bisect_left
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import alloc_kernels, alloc_reference
from .job import JobState

__all__ = ["MCB8Result", "mcb8_pack", "mcb8"]

_EPS = 1e-9
Y_FLOOR = 0.01  # smallest yield probed; also the binary-search accuracy


@dataclass
class MCB8Result:
    mappings: Dict[int, List[int]]   # jid -> node per task (scheduled jobs)
    yld: float                       # achieved uniform target yield
    removed: List[int]               # jids dropped from consideration


# --------------------------------------------------------------------------- #
# packing core                                                                 #
# --------------------------------------------------------------------------- #
class _PackList:
    """One MCB8 list: items sorted by non-increasing dominant requirement.

    ``prim`` is the dominant axis (CPU for the CPU-intensive list, memory
    for the memory-intensive list), ``sec`` the other one.  Plain Python
    lists carry the per-placement scalar reads; ``sec_np``/``left_np``
    mirror the columns the vectorized fallback scan needs.  ``nxt`` is the
    union-find "first alive index >= i" structure (items only ever die).
    """

    __slots__ = ("n", "jid", "prim", "sec", "cpu", "mem", "left",
                 "neg_prim", "sec_np", "left_np", "nxt")

    def __init__(self, jid, cpu, mem, left, primary_is_cpu: bool):
        maxv = np.maximum(cpu, mem)
        order = np.lexsort((jid, -maxv))   # == sorted by (-max req, jid)
        jid, cpu, mem = jid[order], cpu[order], mem[order]
        left = left[order]
        prim, sec = (cpu, mem) if primary_is_cpu else (mem, cpu)
        self.n = int(jid.shape[0])
        self.jid = jid.tolist()
        self.cpu = cpu.tolist()
        self.mem = mem.tolist()
        self.prim = prim.tolist()
        self.sec = sec.tolist()
        self.left = left.tolist()
        self.neg_prim = (-prim).tolist()   # ascending, for bisect
        self.sec_np = sec
        self.left_np = left.copy()
        self.nxt = list(range(self.n + 1))

    def first_alive(self, i: int) -> int:
        """Smallest alive index >= i (== n when none), path-compressed."""
        nxt = self.nxt
        j = i
        while nxt[j] != j:
            j = nxt[j]
        while nxt[i] != i:
            nxt[i], i = j, nxt[i]
        return j


def _pack_core(n_nodes, jid, cpu, mem, ntask, pre_placed, cpu_free, mem_free):
    """Fast MCB8 pack; items given as parallel arrays in candidate order."""
    cpu_mask = cpu > mem
    lists = [
        _PackList(jid[cpu_mask], cpu[cpu_mask], mem[cpu_mask],
                  ntask[cpu_mask], primary_is_cpu=True),
        _PackList(jid[~cpu_mask], cpu[~cpu_mask], mem[~cpu_mask],
                  ntask[~cpu_mask], primary_is_cpu=False),
    ]
    out: Dict[int, List[int]] = {int(j): [] for j in jid}

    remaining = int(ntask.sum())
    # Aggregate capacity bound: the heuristic can never consume more than
    # the positive free capacity plus one _EPS of tolerated overdraw per
    # placed batch and per node, so a total requirement beyond that bound is
    # a guaranteed (bit-identical) pack failure.  Checked up front and again
    # at every node boundary against the *suffix* capacity — nodes are
    # filled strictly in order and never revisited, so once the untouched
    # nodes cannot possibly host what is left, the pack is doomed and the
    # remaining per-node crawl (the bulk of an infeasible probe) is skipped.
    slack = (remaining + n_nodes) * 4e-9 + 1e-7
    req_cpu = float((cpu * ntask).sum())
    req_mem = float((mem * ntask).sum())
    # suffix[i] = free capacity of nodes i.. (clipped at 0 per node)
    cpu_suffix = np.append(
        np.cumsum(np.maximum(0.0, cpu_free)[::-1])[::-1], 0.0).tolist()
    mem_suffix = np.append(
        np.cumsum(np.maximum(0.0, mem_free)[::-1])[::-1], 0.0).tolist()
    if req_cpu > cpu_suffix[0] + slack or req_mem > mem_suffix[0] + slack:
        return None

    cf_l = cpu_free.tolist()
    mf_l = mem_free.tolist()

    def take_from(li: int, node: int, prefer_mem: bool, easy: bool) -> int:
        L = lists[li]
        n = L.n
        if n == 0:
            return 0
        cf = cf_l[node]
        mf = mf_l[node]
        if li == 0:
            p_lim, s_lim = cf + _EPS, mf + _EPS
        else:
            p_lim, s_lim = mf + _EPS, cf + _EPS
        s = bisect_left(L.neg_prim, -p_lim)   # first prim[i] <= p_lim
        i = L.first_alive(s)
        if not easy:
            sec = L.sec
            hops = 0
            while i < n and sec[i] > s_lim:
                i = L.first_alive(i + 1)
                hops += 1
                if hops >= 16 and i < n:      # vectorize the long tail
                    ok = (L.sec_np[i:] <= s_lim) & (L.left_np[i:] > 0)
                    j = int(ok.argmax())
                    i = i + j if ok[j] else n
                    break
        if i >= n:
            return 0
        cpu_i = L.cpu[i]
        mem_i = L.mem[i]
        k = L.left[i]
        if cpu_i > _EPS:
            k = min(k, int((cf + _EPS) / cpu_i))
        if mem_i > _EPS:
            k = min(k, int((mf + _EPS) / mem_i))
        # preference-flip cap: preference is evaluated before each placement;
        # d_s = (mf - cf) - s*(mem_i - cpu_i) must keep its sign for s<k.
        d0 = mf - cf
        delta = mem_i - cpu_i
        if prefer_mem and delta > _EPS:          # d must stay > 0
            k = min(k, max(1, math.ceil((d0 - _EPS) / delta)))
        elif not prefer_mem and delta < -_EPS:   # d must stay <= 0
            k = min(k, max(1, math.ceil((d0 + _EPS) / delta)))
        k = max(k, 1)
        left = L.left[i] - k
        L.left[i] = left
        L.left_np[i] = left
        if left == 0:
            L.nxt[i] = i + 1
        cf_l[node] = cf - k * cpu_i
        mf_l[node] = mf - k * mem_i
        nonlocal req_cpu, req_mem
        req_cpu -= k * cpu_i
        req_mem -= k * mem_i
        out[L.jid[i]].extend([node] * k)
        return k

    for node in range(n_nodes):
        while remaining > 0:
            # Go against the imbalance: if available memory exceeds available
            # CPU, consume memory first (pick a memory-intensive job).
            prefer_mem = mf_l[node] > cf_l[node]
            first, second = (1, 0) if prefer_mem else (0, 1)
            placed = (take_from(first, node, prefer_mem, easy=False)
                      or take_from(second, node, prefer_mem, easy=True))
            if placed:
                remaining -= placed
            else:
                break
        if remaining == 0:
            break
        # nodes 0..node are final now; if what is left cannot possibly fit
        # in the untouched suffix, the pack is already a guaranteed failure
        if (req_cpu > cpu_suffix[node + 1] + slack
                or req_mem > mem_suffix[node + 1] + slack):
            return None
    if remaining > 0:
        return None
    out.update(pre_placed)
    return out


def _try_pack(
    n_nodes: int,
    jid: np.ndarray,
    cpu: np.ndarray,
    mem: np.ndarray,
    ntask: np.ndarray,
    pinned_full: Dict[int, Tuple[float, float, List[int]]],
    alive: Optional[np.ndarray] = None,
) -> Optional[Dict[int, List[int]]]:
    """Pack with pinned jobs pre-placed.  pinned_full: jid -> (cpu_req,
    mem_req, mapping).  Items are parallel arrays in candidate order."""
    cpu_free = np.ones(n_nodes)
    mem_free = np.ones(n_nodes)
    if alive is not None:
        cpu_free[~alive] = -1.0
        mem_free[~alive] = -1.0
    pre: Dict[int, List[int]] = {}
    for pj, (cpu_req, mem_req, mapping) in pinned_full.items():
        for node in mapping:
            cpu_free[node] -= cpu_req
            mem_free[node] -= mem_req
        pre[pj] = list(mapping)
    if (cpu_free < -_EPS).any() or (mem_free < -_EPS).any():
        return None
    if alloc_kernels.reference_kernels_active():
        jobs = list(zip(jid.tolist(), cpu.tolist(), mem.tolist(),
                        ntask.tolist()))
        return alloc_reference.pack_core(n_nodes, jobs, pre,
                                         cpu_free, mem_free, {})
    return _pack_core(n_nodes, jid, cpu, mem, ntask, pre, cpu_free, mem_free)


def mcb8_pack(
    n_nodes: int,
    jobs: Sequence[Tuple[int, float, float, int]],  # (jid, cpu_req, mem_req, n_tasks)
) -> Optional[Dict[int, List[int]]]:
    """One shot of the MCB8 packing heuristic.  Returns jid->mapping or None."""
    jid = np.array([e[0] for e in jobs], dtype=np.int64)
    cpu = np.array([e[1] for e in jobs], dtype=np.float64)
    mem = np.array([e[2] for e in jobs], dtype=np.float64)
    ntask = np.array([e[3] for e in jobs], dtype=np.int64)
    return _try_pack(n_nodes, jid, cpu, mem, ntask, {})


# --------------------------------------------------------------------------- #
# full MCB8 allocation                                                         #
# --------------------------------------------------------------------------- #
class _Candidates:
    """Per-call arrays over the priority-sorted candidate set; a probe with
    per-candidate CPU requirements and suffix start k materializes items
    without touching the ``JobState`` objects again.  Shared by plain MCB8
    (requirements = yield-scaled needs) and MCB8-stretch (requirements
    derived from the stretch target)."""

    __slots__ = ("states", "jid", "cpu", "mem", "ntask", "pin_mask", "pinned")

    def __init__(self, active: Sequence[JobState], pinned: Dict[int, List[int]]):
        self.states = active
        self.jid = np.array([js.spec.jid for js in active], dtype=np.int64)
        self.cpu = np.array([js.spec.cpu_need for js in active])
        self.mem = np.array([js.spec.mem_req for js in active])
        self.ntask = np.array([js.spec.n_tasks for js in active], dtype=np.int64)
        self.pin_mask = np.array([js.spec.jid in pinned for js in active],
                                 dtype=bool)
        self.pinned = pinned

    def pack_probe(self, cpu_req: np.ndarray, k: int, n_nodes: int,
                   alive: Optional[np.ndarray]):
        """Pack candidates[k:] with ``cpu_req`` aligned to that suffix."""
        pin = self.pin_mask[k:]
        pins: Dict[int, Tuple[float, float, List[int]]] = {}
        for i in np.nonzero(pin)[0].tolist():
            j = int(self.jid[k + i])
            pins[j] = (float(cpu_req[i]), float(self.mem[k + i]), self.pinned[j])
        free = ~pin
        return _try_pack(
            n_nodes,
            self.jid[k:][free], cpu_req[free],
            self.mem[k:][free], self.ntask[k:][free],
            pins, alive,
        )

    def probe(self, y: float, k: int, n_nodes: int,
              alive: Optional[np.ndarray]):
        """Feasibility of uniform yield ``y`` for candidates[k:]."""
        return self.pack_probe(np.minimum(1.0, self.cpu[k:] * y),
                               k, n_nodes, alive)


def mcb8(
    candidates: Sequence[JobState],
    n_nodes: int,
    now: float,
    pinned: Optional[Dict[int, List[int]]] = None,
    accuracy: float = Y_FLOOR,
    alive: Optional[np.ndarray] = None,
) -> MCB8Result:
    """Full MCB8 allocation: binary search on yield + low-priority removal."""
    pinned = dict(pinned or {})
    active = sorted(candidates, key=lambda js: js.priority_key(now))  # incr prio
    removed: List[int] = []
    cand = _Candidates(active, pinned)

    def feasible(y: float, k: int):
        return cand.probe(y, k, n_nodes, alive)

    # Removal loop (§4.3): drop the lowest-priority job and retry until the
    # remainder fits at the smallest probed yield.  Feasibility is monotone
    # in the number of removals, so the smallest feasible removal count is
    # found by bisection — identical outcome to one-at-a-time removal.
    k0 = 0
    base = feasible(accuracy, k0)
    if base is None:
        lo_r, hi_r = 0, len(active)          # lo_r infeasible; hi_r feasible
        if feasible(accuracy, len(active)) is None:  # not even the pinned fit
            return MCB8Result({}, 0.0, [js.spec.jid for js in active])
        while hi_r - lo_r > 1:
            mid = (lo_r + hi_r) // 2
            if feasible(accuracy, mid) is None:
                lo_r = mid
            else:
                hi_r = mid
        removed = [js.spec.jid for js in active[:hi_r]]
        k0 = hi_r
        base = feasible(accuracy, k0)
        assert base is not None

    if k0 >= len(active):
        return MCB8Result({}, 0.0, removed)
    best_map, best_y = base, accuracy
    full = feasible(1.0, k0)
    if full is not None:
        return MCB8Result(full, 1.0, removed)
    lo, hi = accuracy, 1.0
    while hi - lo > accuracy:
        mid = 0.5 * (lo + hi)
        pack = feasible(mid, k0)
        if pack is not None:
            best_map, best_y, lo = pack, mid, mid
        else:
            hi = mid
    return MCB8Result(best_map, best_y, removed)
