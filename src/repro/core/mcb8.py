"""MCB8 — two-dimensional vector-packing resource allocation (paper §4.3).

Fixing a target yield Y turns fluid CPU needs into CPU *requirements*
(c_j * Y); the mapping problem then becomes 2-D vector packing (CPU, memory)
which we solve with the Leinberger-style multi-capacity heuristic the paper
calls MCB8: two lists (CPU-intensive / memory-intensive), each sorted by
non-increasing largest requirement, packing always drawing from the list
that goes against the current node imbalance.

A binary search (accuracy 0.01) finds the largest feasible Y.  If no Y is
feasible (memory-infeasible), the lowest-priority job is removed from
consideration and the search restarts (§4.3).

``pinned`` mappings support the MINVT/MINFT grace parameters: a pinned job,
if it keeps running, must keep its current node mapping — it is pre-placed
before the two-list packing fills the remainder.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .job import JobSpec, JobState

__all__ = ["MCB8Result", "mcb8_pack", "mcb8"]

_EPS = 1e-9
Y_FLOOR = 0.01  # smallest yield probed; also the binary-search accuracy


@dataclass
class MCB8Result:
    mappings: Dict[int, List[int]]   # jid -> node per task (scheduled jobs)
    yld: float                       # achieved uniform target yield
    removed: List[int]               # jids dropped from consideration


@dataclass
class _Item:
    jid: int
    cpu: float
    mem: float
    left: int                        # unassigned task count


def mcb8_pack(
    n_nodes: int,
    jobs: Sequence[Tuple[int, float, float, int]],  # (jid, cpu_req, mem_req, n_tasks)
) -> Optional[Dict[int, List[int]]]:
    """One shot of the MCB8 packing heuristic.  Returns jid->mapping or None."""
    cpu_free = np.ones(n_nodes)
    mem_free = np.ones(n_nodes)
    return _pack_core(n_nodes, jobs, {}, cpu_free, mem_free, {})


def _sorted_arrays(entries):
    """entries: list of (jid, cpu, mem, n_tasks) -> numpy columns sorted by
    (-max requirement, jid).  Deterministic tie-break on jid: the paper's
    MCB8 "always considers the tasks and the nodes in the same order" (§4.4
    footnote), which is what keeps successive mappings stable and avoids
    remapping churn; sorting only by the max requirement would break ties by
    the caller's (time-varying, priority-sorted) order."""
    entries = sorted(entries, key=lambda e: (-max(e[1], e[2]), e[0]))
    jid = np.array([e[0] for e in entries], dtype=np.int64)
    cpu = np.array([e[1] for e in entries])
    mem = np.array([e[2] for e in entries])
    left = np.array([e[3] for e in entries], dtype=np.int64)
    return jid, cpu, mem, left


def _pack_core(n_nodes, jobs, pre_placed, cpu_free, mem_free, out):
    # Split + sort (§4.3): list 1 = CPU-intensive, list 2 = memory-intensive,
    # each by non-increasing max requirement.
    lists = [
        _sorted_arrays([e for e in jobs if e[1] > e[2]]),    # CPU-intensive
        _sorted_arrays([e for e in jobs if e[1] <= e[2]]),   # memory-intensive
    ]
    for e in jobs:
        out.setdefault(int(e[0]), [])

    def take_from(li: int, node: int, prefer_mem: bool) -> int:
        """Place as many tasks of the first feasible item of list ``li`` as
        the per-task heuristic would have placed consecutively — i.e. until
        the node's (memory>CPU) imbalance preference flips, capacity runs
        out, or the item's tasks are exhausted.  Exactly equivalent to the
        one-task-at-a-time reference loop (capacity only shrinks, so the
        first-feasible item cannot change while the preference holds)."""
        jid, cpu, mem, left = lists[li]
        if jid.size == 0:
            return 0
        cf, mf = cpu_free[node], mem_free[node]
        ok = (left > 0) & (cpu <= cf + _EPS) & (mem <= mf + _EPS)
        i = int(np.argmax(ok))
        if not ok[i]:
            return 0
        # capacity caps (per-task feasibility after t prior placements)
        k = int(left[i])
        if cpu[i] > _EPS:
            k = min(k, int((cf + _EPS) / cpu[i]))
        if mem[i] > _EPS:
            k = min(k, int((mf + _EPS) / mem[i]))
        # preference-flip cap: preference is evaluated before each placement;
        # d_s = (mf - cf) - s*(mem_i - cpu_i) must keep its sign for s<k.
        d0 = mf - cf
        delta = mem[i] - cpu[i]
        if prefer_mem and delta > _EPS:          # d must stay > 0
            k = min(k, max(1, int(np.ceil((d0 - _EPS) / delta))))
        elif not prefer_mem and delta < -_EPS:   # d must stay <= 0
            k = min(k, max(1, int(np.ceil((d0 + _EPS) / delta))))
        k = max(k, 1)
        left[i] -= k
        cpu_free[node] -= k * cpu[i]
        mem_free[node] -= k * mem[i]
        out[int(jid[i])].extend([node] * k)
        return k

    remaining = int(lists[0][3].sum() + lists[1][3].sum())
    for node in range(n_nodes):
        while remaining > 0:
            # Go against the imbalance: if available memory exceeds available
            # CPU, consume memory first (pick a memory-intensive job).
            prefer_mem = bool(mem_free[node] > cpu_free[node])
            first, second = (1, 0) if prefer_mem else (0, 1)
            placed = take_from(first, node, prefer_mem) or take_from(second, node, prefer_mem)
            if placed:
                remaining -= placed
            else:
                break
        if remaining == 0:
            break
    if remaining > 0:
        return None
    out.update(pre_placed)
    return out


def _try_pack(
    n_nodes: int,
    items: Sequence[Tuple[int, float, float, int]],
    pinned_full: Dict[int, Tuple[float, float, List[int]]],
    alive: Optional[np.ndarray] = None,
) -> Optional[Dict[int, List[int]]]:
    """Pack with pinned jobs pre-placed.  pinned_full: jid -> (cpu_req,
    mem_req, mapping)."""
    cpu_free = np.ones(n_nodes)
    mem_free = np.ones(n_nodes)
    if alive is not None:
        cpu_free[~alive] = -1.0
        mem_free[~alive] = -1.0
    pre: Dict[int, List[int]] = {}
    for jid, (cpu_req, mem_req, mapping) in pinned_full.items():
        for node in mapping:
            cpu_free[node] -= cpu_req
            mem_free[node] -= mem_req
        pre[jid] = list(mapping)
    if (cpu_free < -_EPS).any() or (mem_free < -_EPS).any():
        return None
    return _pack_core(n_nodes, items, pre, cpu_free, mem_free, {})


def mcb8(
    candidates: Sequence[JobState],
    n_nodes: int,
    now: float,
    pinned: Optional[Dict[int, List[int]]] = None,
    accuracy: float = Y_FLOOR,
    alive: Optional[np.ndarray] = None,
) -> MCB8Result:
    """Full MCB8 allocation: binary search on yield + low-priority removal."""
    pinned = dict(pinned or {})
    active = sorted(candidates, key=lambda js: js.priority_key(now))  # incr prio
    removed: List[int] = []

    def feasible(y: float, jobs: Sequence[JobState]):
        items = []
        pins: Dict[int, Tuple[float, float, List[int]]] = {}
        for js in jobs:
            s = js.spec
            if s.jid in pinned:
                pins[s.jid] = (min(1.0, s.cpu_need * y), s.mem_req, pinned[s.jid])
            else:
                items.append((s.jid, min(1.0, s.cpu_need * y), s.mem_req, s.n_tasks))
        return _try_pack(n_nodes, items, pins, alive)

    # Removal loop (§4.3): drop the lowest-priority job and retry until the
    # remainder fits at the smallest probed yield.  Feasibility is monotone
    # in the number of removals, so the smallest feasible removal count is
    # found by bisection — identical outcome to one-at-a-time removal.
    base = feasible(accuracy, active)
    if base is None:
        lo_r, hi_r = 0, len(active)          # lo_r infeasible; hi_r feasible
        if feasible(accuracy, []) is None:   # not even the pinned jobs fit
            return MCB8Result({}, 0.0, [js.spec.jid for js in active])
        while hi_r - lo_r > 1:
            mid = (lo_r + hi_r) // 2
            if feasible(accuracy, active[mid:]) is None:
                lo_r = mid
            else:
                hi_r = mid
        removed = [js.spec.jid for js in active[:hi_r]]
        active = active[hi_r:]
        base = feasible(accuracy, active)
        assert base is not None

    while True:
        jobs = list(active)
        if not jobs:
            return MCB8Result({}, 0.0, removed)
        best_map, best_y = base, accuracy
        full = feasible(1.0, jobs)
        if full is not None:
            return MCB8Result(full, 1.0, removed)
        lo, hi = accuracy, 1.0
        while hi - lo > accuracy:
            mid = 0.5 * (lo + hi)
            pack = feasible(mid, jobs)
            if pack is not None:
                best_map, best_y, lo = pack, mid, mid
            else:
                hi = mid
        return MCB8Result(best_map, best_y, removed)
