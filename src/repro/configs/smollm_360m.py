"""SmolLM-360M  [hf:HuggingFaceTB/SmolLM-360M] (llama-arch small).

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152, tied embeddings.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab=49152,
    head_dim=64,
    tie_embeddings=True,
    rope_theta=1e4,
    mlp_act="swiglu",
)
