"""Qwen1.5-MoE-A2.7B  [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (kv=16, i.e. MHA) moe_intermediate=1408 vocab=151936;
60 routed experts top-4 + 4 shared experts (shared intermediate 4x1408=5632)
with a sigmoid shared-expert gate.  All layers are MoE (first_dense=0).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                  # routed-expert hidden size
    vocab=151936,
    head_dim=128,
    rope_theta=1e6,
    n_experts=60,
    top_k=4,
    d_expert=1408,
    n_shared_experts=4,
    d_shared=5632,              # 4 x 1408
    shared_gate=True,
    mlp_act="swiglu",
)
