"""DeepSeek-V3 671B  [arXiv:2412.19437; hf:deepseek-ai/DeepSeek-V3].

61L d_model=7168 128H, MLA (q_lora 1536, kv_lora 512, nope 128 + rope 64,
v 128), MoE: 1 shared + 256 routed top-8 with moe_intermediate=2048; first
3 layers dense (intermediate 18432); MTP (1 extra depth); vocab 129280.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,             # MLA: per-head latent KV (spec kv=128)
    d_ff=18432,                 # dense (first_dense) layers' hidden size
    vocab=129280,
    rope_theta=1e4,
    head_dim=128,
    mla=True,
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    n_experts=256,
    top_k=8,
    d_expert=2048,
    n_shared_experts=1,
    d_shared=2048,
    first_dense=3,
    mtp=True,
    mlp_act="swiglu",
)
