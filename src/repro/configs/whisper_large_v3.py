"""Whisper-large-v3 backbone  [arXiv:2212.04356].

Encoder-decoder: 32 encoder + 32 decoder layers, d_model=1280 20H (MHA,
kv=20) d_ff=5120 vocab=51866, GELU MLP, LayerNorm, learned positions
(approximated with RoPE-free sinusoidal here).  The conv audio frontend is a
STUB: ``input_specs()`` provides precomputed frame embeddings (B, S, 1280).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,                # decoder layers
    encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    mlp_act="gelu",
    norm_kind="layernorm",
    frontend="audio",
)
