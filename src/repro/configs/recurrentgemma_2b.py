"""RecurrentGemma-2B (Griffin)  [arXiv:2402.19427].

26L d_model=2560, pattern (RG-LRU, RG-LRU, local-attn) repeating (1 attn per
2 recurrent), 10H MQA (kv=1), local window 2048, d_ff=7680 (gated GeLU),
lru_width=2560, vocab=256000.  Sub-quadratic (local attn + recurrence):
runs the long_500k shape.
"""
from ..models.config import ModelConfig, RGLRU, LOCAL_ATTN

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    window=2048,
    attn_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    lru_width=2560,
    mlp_act="gelu_gated",
)
