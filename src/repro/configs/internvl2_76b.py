"""InternVL2-Llama3-76B backbone  [arXiv:2404.16821].

Language backbone (Llama3-70B-like): 80L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256.  The InternViT-6B vision frontend is a STUB:
``input_specs()`` provides precomputed patch embeddings (B, 256, 8192)
prepended to the token sequence.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    rope_theta=5e5,
    mlp_act="swiglu",
    frontend="vision",
    n_frontend_tokens=256,
)
