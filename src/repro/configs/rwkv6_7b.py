"""RWKV-6 "Finch" 7B  [arXiv:2404.05892].

32L d_model=4096 (attention-free, data-dependent decay), channel-mix
d_ff=14336, vocab=65536, head size 64 (=> 64 WKV heads).  Sub-quadratic:
decode state is O(heads x 64 x 64) per layer -> runs the long_500k shape.
"""
from ..models.config import ModelConfig, RWKV6

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                # d_model / rwkv_head_dim
    n_kv_heads=64,
    d_ff=14336,
    vocab=65536,
    head_dim=64,
    rwkv_head_dim=64,
    attn_pattern=(RWKV6,),
    mlp_act="swiglu",
)
