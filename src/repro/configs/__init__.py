"""Assigned architecture configs (one module per arch) + shape registry.

Every config mirrors the published architecture exactly (``[source]`` noted
per module).  ``get_config(name)`` returns the full config, ``get_reduced``
the smoke-test reduction, and ``SHAPES`` the assigned input-shape set.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Tuple

from ..models.config import ModelConfig, reduce_config

ARCHS: Tuple[str, ...] = (
    "qwen2_moe_a2_7b",
    "deepseek_v3_671b",
    "qwen3_8b",
    "granite_3_2b",
    "smollm_360m",
    "llama3_8b",
    "rwkv6_7b",
    "whisper_large_v3",
    "recurrentgemma_2b",
    "internvl2_76b",
)

# canonical dashed ids (CLI) -> module names
ALIASES: Dict[str, str] = {a.replace("_", "-"): a for a in ARCHS}
ALIASES.update({
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "granite-3-2b": "granite_3_2b",
    "whisper-large-v3": "whisper_large_v3",
    "internvl2-76b": "internvl2_76b",
})


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def get_config(name: str) -> ModelConfig:
    mod_name = ALIASES.get(name, name).replace("-", "_")
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.CONFIG


def get_reduced(name: str) -> ModelConfig:
    return reduce_config(get_config(name))


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention (full-attn arch)"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    """All 40 assigned (arch, shape) cells, including inapplicable ones."""
    return [(a, s) for a in ARCHS for s in SHAPES]
