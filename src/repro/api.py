"""repro.api — the one-import facade over the whole scheduling stack.

Everything the paper's evaluation needs — policy specs (grammar *and*
component compositions), workloads, scenarios, single-cell simulation,
parallel sweeps with resumable on-disk caching — through one module:

    from repro import api

    # one cell: policy grammar, a registered composition, or a Policy object
    r = api.simulate(api.WorkloadSpec("lublin", n_jobs=300, n_nodes=64),
                     "GreedyPM */per/OPT=MIN/MINVT=600")
    print(r.max_stretch, r.pmtn_per_job)

    # a grid, fanned over processes, cached on disk (resumable); workloads
    # come from the open registry (swf:<path> = a real PWA log), scenarios
    # compose with the "+" chain grammar
    res = api.sweep(
        [api.WorkloadSpec("lublin", n_jobs=250, n_nodes=64, seed=s)
         for s in range(3)]
        + [api.parse_workload("swf:/data/HPC2N-2002.swf", n_nodes=128)],
        ["FCFS", "EASY", "GreedyP */OPT=MIN", "EASY+OPT=MIN"],
        scenarios=["baseline", "rack_failure+arrival_burst"],
        n_workers=8, cache_path="experiments/results/cache.json")
    print(res.summary(by="policy"))

    # extend the policy space through the component registry
    api.register_policy("my-hybrid", lambda: api.compose(
        "my-hybrid", MySubmit(), api.get_component("opt", "MIN")()))

    # streaming: an open session with online arrivals, live injection,
    # snapshot/restore and mid-run what-if forks
    ses = api.open_session(64, "GreedyPM */OPT=MIN")
    ses.submit(api.WorkloadSpec("lublin", n_jobs=200, n_nodes=64))
    ses.step_until(3600.0)
    if ses.observe()["queue_depth"] > 8:
        ses.inject({"kind": "fail", "t": 4000.0, "nodes": [0, 1, 2, 3]})
    snap = ses.snapshot()                    # fingerprinted, JSON-serializable
    alt = api.SimSession.restore(snap, policy="EASY")   # what-if branch
    print(ses.run().mean_stretch, alt.run().mean_stretch)

The same surface is scriptable as ``python -m repro`` (``simulate``,
``sweep``, ``session``, ``policies``, ``scenarios`` subcommands).
"""
from __future__ import annotations

import time as _time
from typing import Any, Dict, Iterable, Optional, Sequence, Union

from .core.bound import max_stretch_lower_bound
from .core.job import JobSpec
from .core.policies import (PolicySpec, TABLE1_POLICIES, all_paper_policies,
                            parse_policy, render_policy)
from .sched.cluster import ClusterEvent
from .sched.components import (ComposedPolicy, Component, compose,
                               compose_from_spec, get_component,
                               list_components, register_component,
                               register_policy, registered_policies,
                               resolve_policy)
from .sched.engine import Engine, Policy, SimParams, SimResult
from .sched.scenarios import (apply_scenario, apply_scenario_trace,
                              list_reactive, list_scenarios,
                              parse_scenario_chain, reactive_docs,
                              register_reactive, register_scenario,
                              run_reactive, scenario_docs)
from .sched.narrator import (Narrator, list_streams, narrator_docs,
                             parse_narrator, register_stream)
from .sched.session import SessionState, SimSession, open_session
from .serve import (Client, CreditParams, ServeConfig, ServeError,
                    ServerThread, connect)
from .serve import run_server as _run_server
from .sched.sweep import (Cell, RecordCache, SweepResult, grid, run_batched,
                          run_branches, run_grid)
from .tune import (AutoTuner, Objective, RaceResult, TuneConfig, Variant,
                   list_objectives, parse_objective, parse_tune, race)
from .workloads.registry import (WorkloadSpec, list_workloads, make_trace,
                                 make_trace_ir, parse_workload,
                                 register_workload, stream_trace,
                                 workload_kind)
from .workloads.trace import Trace, as_trace


def __getattr__(name):
    # live view over the open registry: kinds registered after this module
    # imported still appear (a static re-export would freeze a snapshot)
    if name == "WORKLOAD_KINDS":
        from .workloads.registry import WORKLOAD_KINDS
        return WORKLOAD_KINDS
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    # one-call entry points
    "simulate", "sweep", "list_policies",
    # streaming sessions
    "open_session", "SimSession", "SessionState",
    # scheduler-as-a-service (multi-tenant session server + client)
    "serve", "connect", "Client", "ServeError", "ServeConfig",
    "CreditParams", "ServerThread",
    # policy surface
    "PolicySpec", "parse_policy", "render_policy", "TABLE1_POLICIES",
    "all_paper_policies", "Policy", "ComposedPolicy", "Component",
    "compose", "compose_from_spec", "get_component", "list_components",
    "register_component", "register_policy", "registered_policies",
    "resolve_policy",
    # engine + metrics
    "Engine", "SimParams", "SimResult", "max_stretch_lower_bound",
    # workloads (columnar Trace IR + open registry) + scenarios
    "JobSpec", "Trace", "as_trace", "WorkloadSpec", "WORKLOAD_KINDS",
    "make_trace", "make_trace_ir", "parse_workload", "register_workload",
    "workload_kind", "list_workloads", "stream_trace",
    "ClusterEvent", "apply_scenario", "apply_scenario_trace",
    "parse_scenario_chain", "list_scenarios", "scenario_docs",
    "register_scenario",
    # reactive scenarios (callbacks over live session state)
    "run_reactive", "register_reactive", "list_reactive", "reactive_docs",
    # chaos narrator (seeded stochastic failure/cancel/noise streams)
    "Narrator", "parse_narrator", "register_stream", "list_streams",
    "narrator_docs",
    # sweep subsystem
    "Cell", "SweepResult", "RecordCache", "grid", "run_grid", "run_batched",
    "run_branches",
    # online what-if autotuning (fork-race-promote over live sessions)
    "autotune", "AutoTuner", "TuneConfig", "parse_tune", "race",
    "RaceResult", "Variant", "Objective", "parse_objective",
    "list_objectives",
]

TraceLike = Union[WorkloadSpec, Trace, Sequence[JobSpec]]
PolicyLike = Union[str, PolicySpec, Policy]


def simulate(
    trace: TraceLike,
    policy: PolicyLike,
    params: Optional[SimParams] = None,
    *,
    scenario: Optional[str] = None,
    cluster_events: Sequence[ClusterEvent] = (),
    seed: Optional[int] = None,
    **param_overrides: Any,
) -> SimResult:
    """Run one simulation cell through the unified engine.

    ``trace`` is a declarative :class:`WorkloadSpec` (materialized and
    memoized, cluster size taken from the spec — as in sweep cells), a
    columnar :class:`Trace`, or an explicit ``JobSpec`` sequence (for the
    latter two pass ``params`` or ``n_nodes=``).  ``policy`` is a grammar
    string (canonicalized), a registered composition name, a
    :class:`PolicySpec`, or any :class:`Policy` instance.  A named
    ``scenario`` — possibly a ``"a+b"`` chain — perturbs the cell
    deterministically via vectorized Trace transforms, seeded by ``seed``,
    which defaults to the workload's own seed (sweep cell semantics) or 0
    for a raw trace.  Extra keyword arguments override :class:`SimParams`
    fields (e.g. ``period=1200``).
    """
    if scenario is not None and cluster_events:
        raise ValueError("pass either scenario= or cluster_events=, not both")
    explicit_n = param_overrides.pop("n_nodes", None)
    if isinstance(trace, WorkloadSpec):
        tr = make_trace_ir(trace)
        n_nodes = explicit_n or trace.n_nodes
        if seed is None:
            seed = trace.seed
    else:
        tr = as_trace(trace)
        n_nodes = explicit_n or (params.n_nodes if params is not None else None)
        if n_nodes is None:
            raise ValueError("pass SimParams (or n_nodes=) when simulating "
                             "a raw trace")
        if seed is None:
            seed = 0
    events: Sequence[ClusterEvent] = tuple(cluster_events)
    if scenario is not None:
        tr, events = apply_scenario_trace(scenario, tr, n_nodes, seed=seed)
    if params is None:
        params = SimParams(n_nodes=n_nodes, **param_overrides)
    else:
        from dataclasses import replace
        params = replace(params, n_nodes=n_nodes, **param_overrides)
    return Engine(tr, policy, params, cluster_events=events).run()


def sweep(
    workloads: Iterable[WorkloadSpec],
    policies: Iterable[str],
    scenarios: Iterable[str] = ("baseline",),
    *,
    periods: Iterable[float] = (600.0,),
    params: Optional[SimParams] = None,
    n_workers: int = 1,
    compute_bound: bool = True,
    cache_path: Optional[str] = None,
    json_path: Optional[str] = None,
    timeout_s: Optional[float] = None,
    retries: int = 0,
) -> SweepResult:
    """Evaluate a (workload × policy × period × scenario) grid in parallel.

    Records are memoized in a :class:`~repro.sched.sweep.RecordCache`
    (equivalent policy spellings share one simulated cell).  With
    ``cache_path`` the cache lives in a JSON file rewritten atomically
    after every miss batch, so interrupted sweeps resume where they
    stopped and repeated sweeps over overlapping grids are incremental.
    ``json_path`` additionally writes the plain ``repro.sweep/v1``
    artifact.

    ``timeout_s``/``retries`` supervise the misses: each cell gets a
    wall-clock budget and bounded retries on fresh workers; cells that
    exhaust them come back as quarantine records (``quarantined=True``,
    never cached) and the sweep still completes — see
    :meth:`~repro.sched.sweep.RecordCache.sweep`.
    """
    workloads, policies = list(workloads), list(policies)
    scenarios, periods = list(scenarios), [float(p) for p in periods]
    t0 = _time.perf_counter()
    cache = RecordCache(cache_path)
    records = cache.sweep(workloads, policies, periods, scenarios,
                          params=params, n_workers=n_workers,
                          compute_bound=compute_bound,
                          timeout_s=timeout_s, retries=retries)
    res = SweepResult(records=list(records),
                      wall_s=_time.perf_counter() - t0,
                      n_workers=n_workers)
    if json_path is not None:
        res.save_json(json_path)
    return res


def serve(
    host: str = "127.0.0.1",
    port: int = 0,
    *,
    store: Optional[str] = None,
    max_live: int = 256,
    idle_evict_s: Optional[float] = None,
    checkpoint_every: int = 0,
    credit: Optional[CreditParams] = None,
    announce=None,
    **credit_overrides: Any,
) -> None:
    """Run the multi-tenant session server (blocking).

    JSONL-over-TCP, stdlib only.  ``store`` enables the durable layer:
    write-ahead op journals, snapshot-backed eviction of idle sessions
    past ``max_live`` (and ``idle_evict_s``), and crash recovery — a
    restarted server replays persisted snapshots + journals and client
    retries dedupe on per-session seq, so a ``kill -9`` mid-workload
    resumes bit-identically.  Tenant fairness comes from the credit score
    ``clamp(1 − α·budget_used − β·violations − γ·tail_latency)`` weighting
    a DRF fair queue; tune via ``credit=CreditParams(...)`` or keyword
    overrides (``alpha=``, ``budget=``, ``max_pending=``, …).

    Use :class:`ServerThread` for an in-process background server, and
    :func:`connect` for a client.  ``announce(server)`` fires once the
    socket is bound (``server.port`` is then known).
    """
    if credit is None:
        credit = CreditParams(**credit_overrides)
    elif credit_overrides:
        raise ValueError("pass either credit= or keyword overrides, "
                         "not both")
    _run_server(ServeConfig(host=host, port=port, store=store,
                            max_live=max_live, idle_evict_s=idle_evict_s,
                            checkpoint_every=checkpoint_every,
                            credit=credit),
                announce=announce)


def autotune(
    session: SimSession,
    config: Union[str, TuneConfig, None] = None,
    *,
    seed: int = 0,
    log_path: Optional[str] = None,
) -> AutoTuner:
    """Put a live session under online what-if autotuning.

    Builds an :class:`AutoTuner` (``config`` is a :class:`TuneConfig`, a
    ``parse_tune`` spec string like
    ``"every=5000;policies=GreedyP */OPT=MIN|GreedyPM */per/OPT=MIN/MINVT=600"``,
    or ``None`` for defaults), attaches it, and returns it.  From then on
    the stepping loop periodically forks the session, races the portfolio
    over a bounded horizon with successive halving, and hot-swaps a
    decisively better variant in (hysteresis + min-dwell).  Decisions
    accumulate on ``tuner.decisions`` (and ``log_path`` as JSONL); tuner
    state rides ``session.snapshot()`` bit-exactly.
    """
    tuner = AutoTuner(config, seed=seed, log_path=log_path)
    session.attach_autotuner(tuner)
    return tuner


def list_policies(include_paper_space: bool = False) -> Dict[str, Any]:
    """The policy surface: Table-1 strings (canonicalized), registered
    component compositions, the component registry, and the size of the
    full §6.1 space (expanded with ``include_paper_space``)."""
    out: Dict[str, Any] = {
        "table1": [parse_policy(p).name for p in TABLE1_POLICIES],
        "registered": registered_policies(),
        "components": list_components(),
        "n_paper_space": len(all_paper_policies()),
    }
    if include_paper_space:
        out["paper_space"] = [parse_policy(p).name for p in all_paper_policies()]
    return out
