"""``python -m repro`` — the scheduling stack from the command line.

Subcommands (all built on :mod:`repro.api`):

* ``policies``    — the policy surface: Table-1 grammar strings, registered
  component compositions, the component registry, the §6.1 space size.
* ``workloads``   — the registered workload kinds (the Trace-IR registry)
  with their knob contracts.
* ``scenarios``   — the named cluster-scenario transforms (composable with
  the ``+`` chain grammar).
* ``simulate``    — one (workload × policy × scenario) cell; prints the
  headline metrics (optionally against the Theorem-1 bound).
* ``sweep``       — a (workload × policy × period × scenario) grid across
  worker processes, with optional resumable on-disk record caching.
* ``session``     — a *streaming* simulation: drive an open
  :class:`repro.sched.session.SimSession` from a JSONL event script
  (online submits, ``step_until``/``step``, live fail/join/period
  injection, snapshots) and stream per-step JSONL metrics out.  With
  ``--restore`` the session resumes from a saved snapshot bit-identically.
* ``tune``        — an *autotuned* session end to end: attach the
  fork-race-promote :class:`repro.tune.AutoTuner` (periodically fork the
  live session, race a policy/period portfolio over a bounded sim-time
  horizon with successive halving, hot-swap the winner), optionally with
  a chaos narrator and a scripted rack failure; prints every decision.
  The ``session`` subcommand grows the same tuner via ``--autotune`` and
  a manual ``{"op": "tune"}`` trigger.
* ``trace-smoke`` — materialize every registered workload kind × every
  scenario at a small size and emit the content fingerprints (CI runs it
  in two processes and diffs the output).
* ``serve``       — the scheduler-as-a-service server: a long-lived
  multi-tenant :class:`SimSession` host (JSONL over TCP, stdlib only)
  with credit-based admission, weighted-DRF tenant fairness,
  snapshot-backed eviction and ``kill -9`` crash recovery (``--store``).
* ``client``      — drive named sessions on a running server from a JSONL
  script (the remote sibling of ``session``): ops carry a ``session``
  name, mutating ops are seq-stamped so re-driving a script after a
  server crash dedupes instead of double-applying.

The ``--workload`` argument accepts any registered kind, including the
``kind:<arg>`` spelling (``swf:<path>`` = a real Parallel Workloads Archive
log); ``--scenarios`` accepts ``+``-composed chains.

Examples::

    python -m repro policies
    python -m repro workloads
    python -m repro simulate --policy "GreedyPM */per/OPT=MIN/MINVT=600" \\
        --workload lublin --jobs 100 --nodes 32 --load 0.7 --bound
    python -m repro simulate --policy EASY --workload swf:tests/data/mini.swf \\
        --nodes 128 --scenario rack_failure+arrival_burst
    python -m repro sweep --policies "FCFS,EASY,EASY+OPT=MIN" \\
        --workload lublin --jobs 60 --nodes 16 --seeds 0,1 \\
        --scenarios baseline,rack_failure+arrival_burst --workers 4 \\
        --out sweep.json --cache cache.json
    printf '%s\\n' \\
        '{"op": "submit", "workload": "lublin", "jobs": 50}' \\
        '{"op": "step_until", "t": 3600}' \\
        '{"op": "inject", "kind": "fail", "t": 4000, "nodes": [0, 1]}' \\
        '{"op": "snapshot", "path": "snap.json"}' \\
        '{"op": "run"}' '{"op": "result"}' \\
        | python -m repro session --script - \\
              --policy "GreedyP */OPT=MIN" --nodes 32
    # chaos: seeded breakdown/cancel/noise streams, bit-reproducible
    printf '%s\\n' '{"op": "submit", "workload": "lublin", "jobs": 200}' \\
        '{"op": "run"}' '{"op": "result"}' \\
        | python -m repro session --script - --policy "GreedyP */OPT=MIN" \\
              --nodes 32 --narrator "breakdown(mtbf=2e4,repair=2e3)+noise" \\
              --narrator-seed 7
    python -m repro sweep --table1 --workload lublin --jobs 100 --nodes 32 \\
        --timeout 300 --retries 1   # hung cells quarantined, sweep completes
    # scheduler-as-a-service: server + two tenants
    python -m repro serve --store var/serve --port-file /tmp/port &
    printf '%s\\n' \\
        '{"op": "open", "session": "s0", "policy": "EASY", "nodes": 32}' \\
        '{"op": "submit", "session": "s0", "workload": "lublin", "jobs": 50}' \\
        '{"op": "run", "session": "s0"}' '{"op": "result", "session": "s0"}' \\
        | python -m repro client --port $(cat /tmp/port) --tenant acme \\
              --script -
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from . import api

_METRICS = [
    ("max_stretch", "max bounded stretch", "{:.2f}"),
    ("mean_stretch", "mean bounded stretch", "{:.2f}"),
    ("makespan", "makespan (s)", "{:.1f}"),
    ("underutilization", "normalized underutilization", "{:.4f}"),
    ("pmtn_per_job", "preemptions / job", "{:.3f}"),
    ("mig_per_job", "migrations / job", "{:.3f}"),
    ("bandwidth_gbps", "pmtn/mig bandwidth (GB/s)", "{:.4f}"),
    ("events", "engine events", "{:d}"),
]


def _workloads_from_args(args: argparse.Namespace) -> List["api.WorkloadSpec"]:
    try:
        seeds = [int(s) for s in str(args.seeds).split(",") if s.strip() != ""]
        if not seeds:
            raise ValueError("no seeds given (use --seeds 0,1,...)")
        loads: List[Optional[float]] = (
            [float(x) for x in args.loads.split(",") if x.strip() != ""]
            if args.loads else []) or [None]
        return [
            api.parse_workload(args.workload, n_jobs=args.jobs,
                               n_nodes=args.nodes, seed=seed, load=load)
            for seed in seeds for load in loads
        ]
    except ValueError as e:
        # covers malformed --seeds/--loads values and WorkloadSpec's own
        # validation (e.g. load scaling on kinds that ignore it, unknown
        # kinds, missing kind params like swf's path)
        print(f"invalid workload arguments: {e}", file=sys.stderr)
        raise SystemExit(2)


def _csv(text: str) -> List[str]:
    return [p.strip() for p in text.split(",") if p.strip()]


def _cmd_policies(args: argparse.Namespace) -> int:
    info = api.list_policies(include_paper_space=args.all)
    if args.json:
        print(json.dumps(info, indent=1))
        return 0
    print("Table-1 policies (canonical grammar strings):")
    for name in info["table1"]:
        print(f"  {name}")
    print(f"\nfull §6.1 policy space: {info['n_paper_space']} combinations"
          + ("" if args.all else "  (--all to list)"))
    if args.all:
        for name in info["paper_space"]:
            print(f"  {name}")
    print("\nregistered compositions (beyond the grammar):")
    if not info["registered"]:
        print("  (none)")
    for name, desc in info["registered"].items():
        print(f"  {name}")
        if desc:
            print(f"      {desc}")
    print("\ncomponent registry (kind: names):")
    for kind, names in info["components"].items():
        print(f"  {kind:9s} {', '.join(names)}")
    return 0


def _cmd_scenarios(args: argparse.Namespace) -> int:
    docs = api.scenario_docs()
    reactive = api.reactive_docs()
    if args.json:
        print(json.dumps({"trace": docs, "reactive": reactive}, indent=1))
        return 0
    width = max(len(n) for n in list(docs) + list(reactive))
    for name, doc in docs.items():
        print(f"{name:{width}s}  {doc}")
    print("\nscenarios compose with '+': e.g. rack_failure+arrival_burst "
          "(applied left to right, cluster scripts concatenated)")
    print("\nreactive scenarios (api.run_reactive over a live session):")
    for name, doc in reactive.items():
        print(f"{name:{width}s}  {doc}")
    return 0


def _cmd_workloads(args: argparse.Namespace) -> int:
    kinds = {}
    for name in api.list_workloads():
        wk = api.workload_kind(name)
        kinds[name] = {
            "doc": wk.doc,
            "supports_load": wk.supports_load,
            "params": list(wk.params),
            "required": list(wk.required),
            "cli": f"{name}:<{wk.path_param}>" if wk.path_param else name,
        }
    if args.json:
        print(json.dumps(kinds, indent=1))
        return 0
    width = max(len(v["cli"]) for v in kinds.values())
    for name, info in kinds.items():
        flags = []
        if info["supports_load"]:
            flags.append("load=")
        flags += [f"params[{p}]=" for p in info["params"]]
        suffix = f"  ({', '.join(flags)})" if flags else ""
        print(f"{info['cli']:{width}s}  {info['doc']}{suffix}")
    return 0


def _cmd_trace_smoke(args: argparse.Namespace) -> int:
    """Materialize every registered workload kind × every scenario at a
    small size; emit {cell: fingerprint} JSON (stable across processes) to
    stdout and the materialization wall time to stderr."""
    import time

    workloads, skipped = [], []
    for kind in api.list_workloads():
        wk = api.workload_kind(kind)
        if wk.required:
            if kind == "swf" and args.swf:
                workloads.append(api.parse_workload(
                    f"swf:{args.swf}", n_jobs=args.jobs, n_nodes=args.nodes))
            else:
                # required-param kinds cannot be materialized blind — say
                # so instead of silently shrinking the smoke matrix
                skipped.append(f"{kind} (requires params "
                               f"{list(wk.required)})")
            continue
        workloads.append(api.WorkloadSpec(kind, n_jobs=args.jobs,
                                          n_nodes=args.nodes, seed=0))
    if skipped:
        print(f"skipped kinds: {', '.join(skipped)}", file=sys.stderr)
    scenarios = api.list_scenarios() + [args.chain]
    fingerprints = {}
    t0 = time.perf_counter()
    for w in workloads:
        base = api.make_trace_ir(w)
        fingerprints[f"{w.name} × (workload)"] = base.fingerprint
        for sc in scenarios:
            tr, _events = api.apply_scenario_trace(sc, base, w.n_nodes,
                                                   seed=w.seed)
            fingerprints[f"{w.name} × {sc}"] = tr.fingerprint
    wall = time.perf_counter() - t0
    print(json.dumps(fingerprints, indent=1))
    print(f"{len(fingerprints)} traces ({len(workloads)} workloads x "
          f"{len(scenarios)} scenarios) materialized in {wall:.2f}s",
          file=sys.stderr)
    return 0


def _cmd_simulate(args: argparse.Namespace) -> int:
    workloads = _workloads_from_args(args)
    if len(workloads) > 1:
        print("simulate runs one cell — pass a single --seeds/--loads value "
              "(use the sweep subcommand for grids)", file=sys.stderr)
        return 2
    workload = workloads[0]
    overrides = {}
    if args.period is not None:
        overrides["period"] = args.period
    if args.penalty is not None:
        overrides["penalty"] = args.penalty
    r = api.simulate(workload, args.policy, scenario=args.scenario,
                     **overrides)
    if args.json:
        import dataclasses
        print(json.dumps(dataclasses.asdict(r), indent=1))
        return 0
    scen = f" × {args.scenario}" if args.scenario else ""
    print(f"cell: {workload.name} × {r.policy}{scen}")
    for key, label, fmt in _METRICS:
        print(f"  {label:28s} {fmt.format(getattr(r, key))}")
    if args.bound:
        specs = api.make_trace(workload)
        if args.scenario:
            specs, _ = api.apply_scenario(args.scenario, specs,
                                          workload.n_nodes,
                                          seed=workload.seed)
        bound = api.max_stretch_lower_bound(specs, workload.n_nodes)
        deg = r.max_stretch / bound if bound > 0 else float("inf")
        print(f"  {'Theorem-1 lower bound':28s} {bound:.2f}")
        print(f"  {'degradation from bound':28s} {deg:.2f}")
    return 0


def _session_submit(ses, ev: dict):
    """Materialize a session-script submit op into submittable jobs."""
    if "specs" in ev:
        return [api.JobSpec(**s) for s in ev["specs"]]
    return api.parse_workload(
        ev["workload"],
        n_jobs=int(ev.get("jobs", 100)),
        n_nodes=int(ev.get("nodes", ses.engine.params.n_nodes)),
        seed=int(ev.get("seed", 0)),
        load=ev.get("load"),
    )


def _cmd_session(args: argparse.Namespace) -> int:
    """Drive a streaming SimSession from a JSONL event script.

    Script ops (one JSON object per line; blank lines and ``#`` comments
    skipped): ``open`` (when no --policy/--restore was given), ``submit``
    (a registered workload or inline ``specs``, optional ``shift``),
    ``stream`` (a workload fed chunk-wise via ``stream_trace`` — pair
    with ``--compact-interval`` for bounded-memory million-job runs),
    ``step_until``/``step``/``run``, ``inject`` (fail/join/period),
    ``compact``, ``snapshot`` and ``result`` (``"light": true`` skips the
    per-job dicts).  Every op streams one JSONL metrics line (``kind``:
    submit/step/inject/compact/snapshot/result) to stdout or
    ``--metrics``.
    """
    import dataclasses

    out = open(args.metrics, "w") if args.metrics else sys.stdout

    def emit(obj: dict) -> None:
        print(json.dumps(obj), file=out, flush=True)

    def attach_narrator(ses) -> None:
        if args.narrator:
            ses.attach_narrator(api.parse_narrator(args.narrator,
                                                   seed=args.narrator_seed))

    def attach_tuner(ses) -> None:
        if args.autotune:
            api.autotune(ses, args.autotune, seed=args.autotune_seed,
                         log_path=args.decision_log)

    ses = None
    if args.restore:
        # a snapshot carries its narrator and autotuner (RNG state and
        # all); --narrator/--autotune on top of --restore would replace
        # them mid-stream, so refuse
        if args.narrator:
            print("--narrator cannot be combined with --restore (the "
                  "snapshot already carries the narrator state)",
                  file=sys.stderr)
            return 2
        if args.autotune:
            print("--autotune cannot be combined with --restore (the "
                  "snapshot already carries the autotuner state)",
                  file=sys.stderr)
            return 2
        ses = api.SimSession.restore(args.restore)
        # the JSONL sink path is process-local (not snapshot state):
        # --decision-log re-attaches it to a restored tuner
        if args.decision_log and ses.autotuner is not None:
            ses.autotuner.log_path = args.decision_log
    elif args.policy:
        overrides = {}
        if args.period is not None:
            overrides["period"] = args.period
        if args.penalty is not None:
            overrides["penalty"] = args.penalty
        if args.compact_interval is not None:
            overrides["compact_interval"] = args.compact_interval
        ses = api.open_session(args.nodes, args.policy, **overrides)
        attach_narrator(ses)
        attach_tuner(ses)

    script = sys.stdin if args.script == "-" else open(args.script)
    try:
        for lineno, raw in enumerate(script, start=1):
            raw = raw.strip()
            if not raw or raw.startswith("#"):
                continue
            try:
                ev = json.loads(raw)
                op = ev.get("op")
                if op == "open":
                    if ses is not None:
                        raise ValueError("session already open")
                    ses = api.open_session(
                        int(ev.get("nodes", args.nodes)), ev["policy"],
                        **{k: ev[k] for k in ("period", "penalty",
                                              "compact_interval")
                           if k in ev})
                    attach_narrator(ses)
                    attach_tuner(ses)
                    emit({"kind": "open", "policy": ses.policy_name,
                          **ses.observe()})
                    continue
                if ses is None:
                    raise ValueError("no session open (pass --policy or "
                                     "--restore, or start with an "
                                     "{\"op\": \"open\"} line)")
                if op == "submit":
                    idx = ses.submit(_session_submit(ses, ev),
                                     shift=ev.get("shift"))
                    emit({"kind": "submit", "n_submitted": len(idx),
                          **ses.observe()})
                elif op == "stream":
                    wspec = api.parse_workload(
                        ev["workload"],
                        n_jobs=int(ev.get("jobs", 0)),
                        n_nodes=int(ev.get("nodes",
                                           ses.engine.params.n_nodes)),
                        seed=int(ev.get("seed", 0)),
                        load=ev.get("load"))
                    window = ev.get("window")
                    ses.stream(api.stream_trace(
                        wspec, None if window is None else float(window)),
                        run_to_exhaustion=bool(ev.get("run", True)))
                    emit({"kind": "step", **ses.observe()})
                elif op == "compact":
                    n = ses.compact()
                    emit({"kind": "compact", "evicted": n,
                          **ses.observe()})
                elif op == "step_until":
                    ses.step_until(float(ev["t"]))
                    emit({"kind": "step", **ses.observe()})
                elif op == "step":
                    n = ses.step(int(ev.get("n", 1)))
                    emit({"kind": "step", "steps": n, **ses.observe()})
                elif op == "run":
                    ses.run_to_exhaustion()
                    emit({"kind": "step", **ses.observe()})
                elif op == "inject":
                    ses.inject({k: v for k, v in ev.items() if k != "op"})
                    emit({"kind": "inject", **ses.observe()})
                elif op == "period":
                    ses.set_period(float(ev["period"]))
                    emit({"kind": "inject", **ses.observe()})
                elif op == "tune":
                    tun = ses.autotuner
                    if tun is None:
                        raise ValueError("no autotuner attached (pass "
                                         "--autotune SPEC)")
                    swapped = tun.fire(ses, now=True)
                    d = tun.decisions[-1]
                    emit({"kind": "tune", "swapped": swapped,
                          "reason": d["reason"],
                          "decisions": len(tun.decisions),
                          "policy": ses.policy_name, **ses.observe()})
                elif op == "snapshot":
                    snap = ses.snapshot()
                    snap.save(ev["path"])
                    emit({"kind": "snapshot", "path": ev["path"],
                          "fingerprint": snap.fingerprint, "t": snap.time})
                elif op == "result":
                    r = ses.result(light=bool(ev.get("light", False)))
                    emit({"kind": "result", "partial": not ses.exhausted,
                          **dataclasses.asdict(r)})
                else:
                    raise ValueError(f"unknown op {op!r}")
            except (KeyError, TypeError, ValueError) as e:
                print(f"{args.script}:{lineno}: {e}", file=sys.stderr)
                return 2
    finally:
        if script is not sys.stdin:
            script.close()
        if out is not sys.stdout:
            out.close()
    return 0


def _cmd_tune(args: argparse.Namespace) -> int:
    """Run one autotuned session end to end: open, attach the
    fork-race-promote tuner (and optionally a chaos narrator and a
    scripted rack failure), run to exhaustion, report every tuning
    decision plus the final metrics."""
    import dataclasses

    workloads = _workloads_from_args(args)
    if len(workloads) > 1:
        print("tune runs one session — pass a single --seeds/--loads "
              "value", file=sys.stderr)
        return 2
    workload = workloads[0]
    overrides = {}
    if args.period is not None:
        overrides["period"] = args.period
    if args.penalty is not None:
        overrides["penalty"] = args.penalty
    try:
        ses = api.open_session(args.nodes, args.policy, **overrides)
        if args.narrator:
            ses.attach_narrator(api.parse_narrator(args.narrator,
                                                   seed=args.narrator_seed))
        tuner = api.autotune(ses, args.spec, seed=args.seed,
                             log_path=args.decision_log)
        ses.submit(api.make_trace(workload))
        if args.fail_at is not None:
            nodes = list(range(min(args.fail_nodes, args.nodes)))
            ses.inject({"kind": "fail", "t": args.fail_at, "nodes": nodes})
            if args.join_at is not None:
                ses.inject({"kind": "join", "t": args.join_at,
                            "nodes": nodes})
        ses.run_to_exhaustion()
    except ValueError as e:
        print(f"tune: {e}", file=sys.stderr)
        return 2
    r = ses.result()
    if args.json:
        print(json.dumps({"decisions": tuner.decisions,
                          "final_policy": ses.policy_name,
                          "result": dataclasses.asdict(r)}, indent=1))
        return 0
    swaps = [d for d in tuner.decisions if d["swapped"]]
    print(f"tuned session: {workload.name} × {args.policy} "
          f"(spec: {args.spec})")
    for d in tuner.decisions:
        line = (f"  t={d['t']:.0f}  {d['reason']:14s} "
                f"win={d.get('winner_score', float('nan')):.2f} "
                f"inc={d.get('incumbent_score', float('nan')):.2f}")
        if d["swapped"]:
            line += f"  -> {d['winner']['policy']}"
        print(line)
    print(f"{len(tuner.decisions)} decision(s), {len(swaps)} swap(s); "
          f"final policy: {ses.policy_name}")
    for key, label, fmt in _METRICS:
        print(f"  {label:28s} {fmt.format(getattr(r, key))}")
    return 0


def _cmd_serve(args: argparse.Namespace) -> int:
    """Run the multi-tenant session server until shutdown/SIGTERM."""
    from .serve import CreditParams, ServeConfig, run_server

    credit = CreditParams(alpha=args.alpha, beta=args.beta,
                          gamma=args.gamma, budget=args.budget,
                          max_pending=args.max_pending)

    def announce(server) -> None:
        line = {"event": "listening", "host": args.host,
                "port": server.port, "store": args.store,
                "recovered": server.n_recovered}
        print(json.dumps(line), flush=True)
        if args.port_file:
            # atomic: watchers polling the file never read a torn port
            from .core.ioutil import atomic_write_text
            atomic_write_text(args.port_file, str(server.port))

    try:
        run_server(ServeConfig(
            host=args.host, port=args.port, store=args.store,
            max_live=args.max_live, idle_evict_s=args.idle_evict,
            checkpoint_every=args.checkpoint_every, credit=credit,
            fsync=not args.no_fsync), announce=announce)
    except KeyboardInterrupt:
        pass
    return 0


def _cmd_client(args: argparse.Namespace) -> int:
    """Drive named server sessions from a JSONL script.

    Each line is one op object with a ``session`` field (except
    ``stats``/``ping``); responses stream out as JSONL.  Mutating ops are
    seq-stamped by the client, so re-running a script against a server
    that crashed mid-way dedupes the already-applied prefix and finishes
    the rest — the recovery drill CI exercises.
    """
    from .serve import Client, ServeError

    out = open(args.metrics, "w") if args.metrics else sys.stdout

    def emit(obj: dict) -> None:
        print(json.dumps(obj), file=out, flush=True)

    cli = Client(args.host, args.port, tenant=args.tenant,
                 retry_for=args.retry_for)
    script = sys.stdin if args.script == "-" else open(args.script)
    try:
        for lineno, raw in enumerate(script, start=1):
            raw = raw.strip()
            if not raw or raw.startswith("#"):
                continue
            try:
                ev = json.loads(raw)
                op = ev.pop("op")
                session = ev.pop("session", args.session)
                resp = cli.call(op, session=session, **ev)
                resp.pop("id", None)
                emit({"kind": op, **resp})
            except ServeError as e:
                if args.keep_going:
                    emit({"kind": "error", "code": e.code, "error": str(e)})
                    continue
                print(f"{args.script}:{lineno}: {e}", file=sys.stderr)
                return 2
            except (KeyError, TypeError, ValueError) as e:
                print(f"{args.script}:{lineno}: {e}", file=sys.stderr)
                return 2
    finally:
        if script is not sys.stdin:
            script.close()
        if out is not sys.stdout:
            out.close()
        cli.close()
    return 0


def _cmd_sweep(args: argparse.Namespace) -> int:
    workloads = _workloads_from_args(args)
    policies = _csv(args.policies)
    if args.table1:
        policies = [api.parse_policy(p).name
                    for p in api.TABLE1_POLICIES] + policies
    if not policies:
        print("no policies selected (use --policies and/or --table1)",
              file=sys.stderr)
        return 2
    scenarios = _csv(args.scenarios)
    periods = [float(p) for p in _csv(args.periods)]
    res = api.sweep(workloads, policies, scenarios, periods=periods,
                    n_workers=args.workers, compute_bound=args.bound,
                    cache_path=args.cache, json_path=args.out,
                    timeout_s=args.timeout, retries=args.retries)
    print(f"{res.n_cells} cells in {res.wall_s:.1f}s "
          f"({res.cells_per_sec:.2f} cells/s, {res.n_workers} workers)")
    summary = res.summary(by=args.by)
    if summary:
        width = max(len(g) for g in summary)
        print(f"{'group':{width}s}  {'cells':>5s}  {'mean stretch':>12s}  "
              f"{'max stretch':>11s}")
        for group, agg in summary.items():
            print(f"{group:{width}s}  {agg['n_cells']:5d}  "
                  f"{agg['mean_mean_stretch']:12.2f}  {agg['max_max_stretch']:11.2f}")
    # quarantined cells are reported, not fatal: the sweep completed and
    # every healthy record is valid (exit code stays 0)
    for rec in res.quarantined:
        print(f"quarantined: {rec['workload']} x {rec['policy']} x "
              f"{rec['scenario']} after {rec['attempts']} attempt(s): "
              f"{rec['error']}", file=sys.stderr)
    if res.n_quarantined:
        print(f"{res.n_quarantined} cell(s) quarantined "
              f"(see stderr; re-run to retry)", file=sys.stderr)
    if args.out:
        print(f"artifact: {args.out}")
    if args.cache:
        print(f"record cache: {args.cache}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="DFRS vs batch scheduling: policies, cells, sweeps.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("policies", help="list the policy surface")
    p.add_argument("--all", action="store_true",
                   help="expand the full 116-combination §6.1 space")
    p.add_argument("--json", action="store_true", help="machine-readable")
    p.set_defaults(fn=_cmd_policies)

    p = sub.add_parser("scenarios", help="list named cluster scenarios")
    p.add_argument("--json", action="store_true", help="machine-readable")
    p.set_defaults(fn=_cmd_scenarios)

    p = sub.add_parser("workloads", help="list registered workload kinds")
    p.add_argument("--json", action="store_true", help="machine-readable")
    p.set_defaults(fn=_cmd_workloads)

    p = sub.add_parser(
        "trace-smoke",
        help="materialize every workload kind x scenario; print fingerprints")
    p.add_argument("--jobs", type=int, default=25, help="jobs per trace")
    p.add_argument("--nodes", type=int, default=16, help="cluster nodes")
    p.add_argument("--swf", default=None, metavar="PATH",
                   help="also smoke the swf kind against this log")
    p.add_argument("--chain", default="rack_failure+arrival_burst",
                   help="composed scenario chain to include")
    p.set_defaults(fn=_cmd_trace_smoke)

    def add_workload_args(p: argparse.ArgumentParser, seeds_default: str):
        p.add_argument("--workload", default="lublin",
                       help="registered workload kind, optionally with a "
                            "kind:<arg> payload (e.g. swf:<path>); see "
                            "`python -m repro workloads`")
        p.add_argument("--jobs", type=int, default=100, help="jobs per trace")
        p.add_argument("--nodes", type=int, default=32, help="cluster nodes")
        p.add_argument("--seeds", default=seeds_default,
                       help="comma-separated trace seeds")
        p.add_argument("--loads", default="",
                       help="comma-separated target loads (lublin only)")

    p = sub.add_parser("simulate", help="run one simulation cell")
    p.add_argument("--policy", required=True,
                   help="grammar string or registered composition name")
    add_workload_args(p, seeds_default="0")
    p.add_argument("--scenario", default=None,
                   help="named cluster scenario, composable with '+' "
                        "(e.g. rack_failure+arrival_burst)")
    p.add_argument("--period", type=float, default=None,
                   help="periodic-pass period (s)")
    p.add_argument("--penalty", type=float, default=None,
                   help="rescheduling penalty (s)")
    p.add_argument("--bound", action="store_true",
                   help="also compute the Theorem-1 lower bound")
    p.add_argument("--json", action="store_true", help="full SimResult JSON")
    p.set_defaults(fn=_cmd_simulate)

    p = sub.add_parser(
        "session",
        help="drive a streaming SimSession from a JSONL event script")
    p.add_argument("--script", required=True, metavar="PATH",
                   help="JSONL event script ('-' for stdin); ops: open, "
                        "submit, stream, step_until, step, run, inject, "
                        "compact, snapshot, result")
    p.add_argument("--policy", default=None,
                   help="open the session with this policy (grammar string "
                        "or registered composition name)")
    p.add_argument("--nodes", type=int, default=64, help="cluster nodes")
    p.add_argument("--period", type=float, default=None,
                   help="periodic-pass period (s)")
    p.add_argument("--penalty", type=float, default=None,
                   help="rescheduling penalty (s)")
    p.add_argument("--compact-interval", type=int, default=None,
                   metavar="N",
                   help="auto-compact retired engine rows every N "
                        "retirements (0/absent: never); keeps long "
                        "streaming runs O(active jobs) in memory")
    p.add_argument("--restore", default=None, metavar="PATH",
                   help="resume from a saved session snapshot instead of "
                        "opening a fresh session")
    p.add_argument("--narrator", default=None, metavar="SPEC",
                   help="attach a seeded chaos narrator, e.g. "
                        "'breakdown(mtbf=2e4,repair=2e3)+cancel+noise'; "
                        "rides along in snapshots (not valid with "
                        "--restore)")
    p.add_argument("--narrator-seed", type=int, default=0,
                   help="narrator RNG seed (default: 0)")
    p.add_argument("--autotune", default=None, metavar="SPEC",
                   help="attach the fork-race-promote autotuner, e.g. "
                        "'every=5000;policies=GreedyP */OPT=MIN|GreedyPM "
                        "*/per/OPT=MIN/MINVT=600'; rides along in "
                        "snapshots (not valid with --restore); the "
                        "{\"op\": \"tune\"} script op forces a race now")
    p.add_argument("--autotune-seed", type=int, default=0,
                   help="autotuner RNG seed (default: 0)")
    p.add_argument("--decision-log", default=None, metavar="PATH",
                   help="append one JSONL line per autotune decision here "
                        "(process-local; also re-attachable on --restore)")
    p.add_argument("--metrics", default=None, metavar="PATH",
                   help="write the JSONL metrics stream here (default: "
                        "stdout)")
    p.set_defaults(fn=_cmd_session)

    p = sub.add_parser(
        "tune",
        help="run an autotuned session (fork-race-promote) end to end")
    p.add_argument("--policy", required=True,
                   help="starting (incumbent) policy")
    p.add_argument("--spec", required=True, metavar="SPEC",
                   help="autotune spec, e.g. 'every=5000;margin=0.02;"
                        "policies=GreedyP */OPT=MIN|GreedyPM "
                        "*/per/OPT=MIN/MINVT=600'")
    add_workload_args(p, seeds_default="0")
    p.add_argument("--seed", type=int, default=0,
                   help="autotuner RNG seed (default: 0)")
    p.add_argument("--period", type=float, default=None,
                   help="periodic-pass period (s)")
    p.add_argument("--penalty", type=float, default=None,
                   help="rescheduling penalty (s)")
    p.add_argument("--narrator", default=None, metavar="SPEC",
                   help="attach a seeded chaos narrator")
    p.add_argument("--narrator-seed", type=int, default=0,
                   help="narrator RNG seed (default: 0)")
    p.add_argument("--fail-at", type=float, default=None, metavar="T",
                   help="inject a rack failure at this sim time")
    p.add_argument("--fail-nodes", type=int, default=8,
                   help="nodes in the failing rack (default: 8)")
    p.add_argument("--join-at", type=float, default=None, metavar="T",
                   help="rejoin the failed rack at this sim time")
    p.add_argument("--decision-log", default=None, metavar="PATH",
                   help="append one JSONL line per tuning decision")
    p.add_argument("--json", action="store_true",
                   help="decisions + full SimResult as JSON")
    p.set_defaults(fn=_cmd_tune)

    p = sub.add_parser(
        "serve",
        help="run the multi-tenant session server (JSONL over TCP)")
    p.add_argument("--host", default="127.0.0.1", help="bind address")
    p.add_argument("--port", type=int, default=0,
                   help="TCP port (default: 0 = OS-assigned; the chosen "
                        "port is announced on stdout and via --port-file)")
    p.add_argument("--store", default=None, metavar="DIR",
                   help="durable snapshot + journal store; enables "
                        "eviction and kill -9 crash recovery")
    p.add_argument("--max-live", type=int, default=256,
                   help="live sessions kept in memory before LRU eviction "
                        "to the store (default: 256)")
    p.add_argument("--idle-evict", type=float, default=None, metavar="S",
                   help="evict sessions idle longer than this (s)")
    p.add_argument("--checkpoint-every", type=int, default=0, metavar="N",
                   help="auto-snapshot a session every N journaled ops "
                        "(bounds replay length; default: 0 = off)")
    p.add_argument("--port-file", default=None, metavar="PATH",
                   help="write the bound port here (atomically) once "
                        "listening — for shell scripts and CI")
    p.add_argument("--no-fsync", action="store_true",
                   help="skip fsync on journal appends (faster, loses the "
                        "crash-durability guarantee; for benchmarks)")
    p.add_argument("--alpha", type=float, default=0.5,
                   help="credit weight on budget use (default: 0.5)")
    p.add_argument("--beta", type=float, default=0.3,
                   help="credit weight on violations (default: 0.3)")
    p.add_argument("--gamma", type=float, default=0.2,
                   help="credit weight on tail latency (default: 0.2)")
    p.add_argument("--budget", type=float, default=500.0,
                   help="per-tenant cost budget per decay window "
                        "(default: 500)")
    p.add_argument("--max-pending", type=int, default=64,
                   help="per-tenant pending-op cap before admission "
                        "refuses (default: 64)")
    p.set_defaults(fn=_cmd_serve)

    p = sub.add_parser(
        "client",
        help="drive named sessions on a running server from a JSONL script")
    p.add_argument("--host", default="127.0.0.1", help="server address")
    p.add_argument("--port", type=int, required=True, help="server port")
    p.add_argument("--tenant", default="default", help="tenant name")
    p.add_argument("--script", required=True, metavar="PATH",
                   help="JSONL op script ('-' for stdin); each line is an "
                        "op object, e.g. {\"op\": \"open\", \"session\": "
                        "\"s0\", \"policy\": \"EASY\", \"nodes\": 32}")
    p.add_argument("--session", default=None,
                   help="default session name for lines that omit one")
    p.add_argument("--retry-for", type=float, default=0.0, metavar="S",
                   help="on connection loss, reconnect and resend (same "
                        "seq, deduped server-side) for up to this long — "
                        "rides through a server restart (default: 0)")
    p.add_argument("--keep-going", action="store_true",
                   help="emit server-refused ops as error lines and "
                        "continue instead of aborting")
    p.add_argument("--metrics", default=None, metavar="PATH",
                   help="write the JSONL response stream here (default: "
                        "stdout)")
    p.set_defaults(fn=_cmd_client)

    p = sub.add_parser("sweep", help="run a policy × workload × scenario grid")
    p.add_argument("--policies", default="",
                   help="comma-separated policy strings / composition names")
    p.add_argument("--table1", action="store_true",
                   help="include all 14 Table-1 policies")
    add_workload_args(p, seeds_default="0")
    p.add_argument("--scenarios", default="baseline",
                   help="comma-separated scenario names; each may be a "
                        "'+' chain (e.g. rack_failure+arrival_burst)")
    p.add_argument("--periods", default="600",
                   help="comma-separated periodic-pass periods (s)")
    p.add_argument("--workers", type=int, default=1, help="worker processes")
    p.add_argument("--timeout", type=float, default=None, metavar="S",
                   help="per-cell wall-clock budget (s); cells over budget "
                        "are retried then quarantined, the sweep completes")
    p.add_argument("--retries", type=int, default=0,
                   help="retries per failing/hung cell on a fresh worker "
                        "before quarantine (default: 0)")
    p.add_argument("--bound", action="store_true",
                   help="compute per-cell Theorem-1 bounds")
    p.add_argument("--by", default="policy",
                   help="summary grouping key (default: policy)")
    p.add_argument("--out", default=None, metavar="PATH",
                   help="write the repro.sweep/v1 JSON artifact")
    p.add_argument("--cache", default=None, metavar="PATH",
                   help="resumable on-disk record cache (JSON)")
    p.set_defaults(fn=_cmd_sweep)
    return ap


def main(argv: Optional[List[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
