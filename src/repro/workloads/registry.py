"""Open, declarative workload registry for sweep cells.

A sweep fans (workload × policy × scenario) cells across worker processes;
shipping full trace object graphs through pickles is wasteful and ties cell
identity to object graphs.  Instead a cell carries a :class:`WorkloadSpec` —
a small frozen record naming a generator *kind* plus its seed/size knobs and
an open ``params`` mapping — and each worker materializes (and memoizes) the
columnar :class:`~repro.workloads.trace.Trace` locally with
:func:`make_trace_ir`.  Two specs are the same workload iff they compare
equal, which also makes them usable as cache keys and JSON-friendly via
:func:`WorkloadSpec.to_dict`.

Workload kinds are an *open registry* (mirroring ``register_policy`` /
``register_scenario``): :func:`register_workload` binds a name to a
``spec -> Trace`` generator together with its knob contract — whether
``load=`` applies, which ``params`` keys it accepts/requires, and which
param a ``kind:<arg>`` CLI spelling fills (:func:`parse_workload`).

Built-in kinds:

* ``"lublin"`` — Lublin–Feitelson synthetic model (paper §5.3.2); with
  ``load`` set, inter-arrivals are rescaled to the target offered load
  (the paper's scaled trace sets).
* ``"hpc2n"``  — synthetic trace with HPC2N-like marginals run through the
  §5.3.1 preprocessing (cluster fixed at 120 dual-core nodes → specs use
  ``n_nodes=128`` by convention in the benchmarks).
* ``"swf"``    — a real Parallel Workloads Archive log (``params["path"]``,
  CLI spelling ``swf:<path>``) through ``parse_swf`` + the same §5.3.1
  preprocessing; ``n_jobs`` caps the prefix taken (0 = whole log).
* ``"swf-stream"`` — the same log/preprocessing as ``swf``, but with a
  native streamer for :func:`stream_trace`: the log is parsed in submit-time
  windows (``params["window"]`` seconds, default one day) and never
  materialized — the memory-bounded path for million-job archives.
* ``"tpu"``    — the roofline→scheduler bridge: a Poisson mixture over TPU
  job types (``workloads.jobgen``), ``load`` = target offered load;
  ``params["records"]`` points at a dry-run roofline artifact to derive
  job types from (defaults to the built-in deterministic mix).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from functools import lru_cache
from typing import Callable, Dict, List, Mapping, Optional, Tuple, Union

import numpy as np

from ..core.job import JobSpec
from .hpc2n import (hpc2n_like_trace, hpc2n_preprocess, iter_swf_windows,
                    parse_swf)
from .lublin import lublin_trace, scale_to_load
from .trace import Trace

__all__ = [
    "WorkloadSpec", "WorkloadKind", "register_workload", "list_workloads",
    "workload_kind", "parse_workload", "make_trace", "make_trace_ir",
    "stream_trace", "DEFAULT_STREAM_WINDOW_S",
    "trace_cache_info", "trace_cache_clear", "WORKLOAD_KINDS",
]

_SCALARS = (str, int, float, bool)
ParamsLike = Union[Mapping, Tuple[Tuple[str, object], ...]]


@dataclass(frozen=True)
class WorkloadKind:
    """One registered generator: the ``spec -> Trace`` function plus its
    knob contract (which WorkloadSpec fields/params it honours)."""

    name: str
    fn: Callable[["WorkloadSpec"], Trace]
    doc: str = ""
    supports_load: bool = False      # does ``load=`` mean anything?
    params: Tuple[str, ...] = ()     # accepted params keys
    required: Tuple[str, ...] = ()   # params keys that must be present
    path_param: Optional[str] = None  # param filled by a "kind:<arg>" spelling
    #: optional native streamer ``(spec, window_s) -> Iterator[Trace]``;
    #: kinds without one stream via materialize-then-``Trace.iter_chunks``
    stream: Optional[Callable[["WorkloadSpec", float], "object"]] = None


_REGISTRY: Dict[str, WorkloadKind] = {}


def register_workload(
    name: str,
    *,
    doc: str = "",
    supports_load: bool = False,
    params: Tuple[str, ...] = (),
    required: Tuple[str, ...] = (),
    path_param: Optional[str] = None,
    stream: Optional[Callable] = None,
):
    """Decorator: register a ``spec -> Trace`` generator under ``name``.
    ``stream`` optionally binds a native ``(spec, window_s) -> chunks``
    streamer (see :func:`stream_trace`)."""
    if required and not set(required) <= set(params):
        raise ValueError("required params must be a subset of params")
    if path_param is not None and path_param not in params:
        raise ValueError("path_param must be one of params")

    def deco(fn: Callable[["WorkloadSpec"], Trace]):
        if name in _REGISTRY:
            raise ValueError(f"workload kind {name!r} already registered")
        _REGISTRY[name] = WorkloadKind(
            name=name, fn=fn, doc=doc or (fn.__doc__ or "").strip(),
            supports_load=supports_load, params=tuple(params),
            required=tuple(required), path_param=path_param, stream=stream)
        return fn
    return deco


def list_workloads() -> List[str]:
    return sorted(_REGISTRY)


def workload_kind(name: str) -> WorkloadKind:
    if name not in _REGISTRY:
        raise ValueError(f"unknown workload kind {name!r}; "
                         f"expected one of {tuple(list_workloads())}")
    return _REGISTRY[name]


def __getattr__(name: str):
    # live view kept for compatibility with the pre-registry tuple constant
    if name == "WORKLOAD_KINDS":
        return tuple(list_workloads())
    raise AttributeError(name)


@dataclass(frozen=True)
class WorkloadSpec:
    """Declarative, hashable description of one generated trace."""

    kind: str                      # a registered workload kind
    n_jobs: int = 250
    n_nodes: int = 64
    seed: int = 0
    load: Optional[float] = None   # target offered load (load-aware kinds)
    params: ParamsLike = ()        # kind-specific knobs (normalized tuple)

    def __post_init__(self) -> None:
        wk = workload_kind(self.kind)
        norm = tuple(sorted((str(k), v) for k, v in dict(self.params).items()))
        object.__setattr__(self, "params", norm)
        if self.load is not None and not wk.supports_load:
            loadable = [k for k in list_workloads()
                        if _REGISTRY[k].supports_load]
            raise ValueError(
                f"workload kind {self.kind!r} ignores load= — refusing the "
                f"silent no-op (load scaling is defined for: "
                f"{', '.join(loadable)})")
        given = {k for k, _ in norm}
        unknown = given - set(wk.params)
        if unknown:
            raise ValueError(
                f"workload kind {self.kind!r} does not accept params "
                f"{sorted(unknown)}; accepted: {list(wk.params) or 'none'}")
        missing = set(wk.required) - given
        if missing:
            raise ValueError(
                f"workload kind {self.kind!r} requires params "
                f"{sorted(missing)} (e.g. the CLI spelling "
                f"'{self.kind}:<{wk.path_param or wk.required[0]}>')")
        for k, v in norm:
            if not isinstance(v, _SCALARS):
                raise ValueError(
                    f"param {k!r} must be a JSON scalar, got {type(v).__name__}")

    @property
    def params_dict(self) -> Dict[str, object]:
        return dict(self.params)

    def param(self, key: str, default=None):
        return self.params_dict.get(key, default)

    @property
    def name(self) -> str:
        load = f"@{self.load:g}" if self.load is not None else ""
        extra = "".join(f"+{k}={v}" for k, v in self.params)
        return f"{self.kind}-j{self.n_jobs}-n{self.n_nodes}-s{self.seed}{load}{extra}"

    def to_dict(self) -> dict:
        return {"kind": self.kind, "n_jobs": self.n_jobs,
                "n_nodes": self.n_nodes, "seed": self.seed, "load": self.load,
                "params": self.params_dict}


def parse_workload(
    text: str,
    n_jobs: int = 250,
    n_nodes: int = 64,
    seed: int = 0,
    load: Optional[float] = None,
    params: Optional[Mapping] = None,
) -> WorkloadSpec:
    """The CLI workload grammar: ``kind`` or ``kind:<arg>`` (the arg fills
    the kind's declared ``path_param``, e.g. ``swf:/data/HPC2N-2002.swf``)."""
    kind, sep, arg = text.partition(":")
    extra = dict(params or {})
    if sep:
        wk = workload_kind(kind)
        if wk.path_param is None:
            raise ValueError(
                f"workload kind {kind!r} takes no ':<arg>' "
                f"(spelled {text!r})")
        extra[wk.path_param] = arg
    return WorkloadSpec(kind, n_jobs=n_jobs, n_nodes=n_nodes, seed=seed,
                        load=load, params=tuple(sorted(extra.items())))


# --------------------------------------------------------------------------- #
# materialization (memoized per process)                                       #
# --------------------------------------------------------------------------- #
@lru_cache(maxsize=64)
def _cached_trace(spec: WorkloadSpec) -> Trace:
    return workload_kind(spec.kind).fn(spec)


def make_trace_ir(spec: WorkloadSpec) -> Trace:
    """Materialize the columnar trace for ``spec`` (memoized per process;
    the Trace is frozen, so the cache can hand out the same object)."""
    return _cached_trace(spec)


def make_trace(spec: WorkloadSpec) -> List[JobSpec]:
    """Materialize the trace for ``spec`` as a fresh ``JobSpec`` list."""
    return make_trace_ir(spec).to_specs()


def trace_cache_info():
    """Per-process memo statistics (hits/misses), for tests and diagnostics."""
    return _cached_trace.cache_info()


def trace_cache_clear() -> None:
    """Drop the per-process trace memo (cold-materialization benchmarks)."""
    _cached_trace.cache_clear()


# --------------------------------------------------------------------------- #
# built-in kinds                                                               #
# --------------------------------------------------------------------------- #
@register_workload(
    "lublin", supports_load=True,
    doc="Lublin–Feitelson synthetic model (§5.3.2); load= rescales "
        "inter-arrivals to the target offered load")
def _lublin(spec: WorkloadSpec) -> Trace:
    specs = lublin_trace(n_jobs=spec.n_jobs, n_nodes=spec.n_nodes,
                         seed=spec.seed)
    if spec.load is not None:
        specs = scale_to_load(specs, spec.n_nodes, spec.load)
    return Trace.from_specs(specs)


@register_workload(
    "hpc2n",
    doc="synthetic trace with HPC2N-like marginals through the §5.3.1 "
        "preprocessing (jobs wider than the cluster dropped)")
def _hpc2n(spec: WorkloadSpec) -> Trace:
    trace = Trace.from_specs(
        hpc2n_like_trace(n_jobs=spec.n_jobs, seed=spec.seed))
    # the generator models HPC2N's 120-node machine; on a smaller sweep
    # cluster, jobs wider than the cluster can never be placed — drop them
    return trace.select(trace.n_tasks <= spec.n_nodes)


@register_workload(
    "swf", params=("path",), required=("path",), path_param="path",
    doc="real Parallel Workloads Archive log (swf:<path>) through parse_swf "
        "+ §5.3.1 preprocessing; n_jobs caps the prefix (0 = whole log)")
def _swf(spec: WorkloadSpec) -> Trace:
    specs = hpc2n_preprocess(parse_swf(str(spec.param("path"))))
    trace = Trace.from_specs(specs)
    if spec.n_jobs and spec.n_jobs < len(trace):
        trace = trace.select(np.arange(spec.n_jobs))
    return trace.select(trace.n_tasks <= spec.n_nodes)


#: default streaming window: one day of release time per chunk
DEFAULT_STREAM_WINDOW_S = 86400.0


def stream_trace(spec: WorkloadSpec, window_s: Optional[float] = None):
    """Yield the workload as release-windowed :class:`Trace` chunks for
    :meth:`SimSession.stream <repro.sched.session.SimSession.stream>`.

    Kinds registered with a native streamer (``swf-stream``) never
    materialize the whole log; every other kind falls back to
    ``make_trace_ir(spec).iter_chunks(window_s)`` — same chunk contract,
    just without the memory bound.  ``window_s`` defaults to the spec's
    ``window`` param, else :data:`DEFAULT_STREAM_WINDOW_S`.
    """
    if window_s is None:
        window_s = float(spec.param("window", DEFAULT_STREAM_WINDOW_S))
    wk = workload_kind(spec.kind)
    if wk.stream is not None:
        yield from wk.stream(spec, float(window_s))
    else:
        yield from make_trace_ir(spec).iter_chunks(float(window_s))


def _swf_stream_chunks(spec: WorkloadSpec, window_s: float):
    """Native streamer for ``swf-stream``: chunked parse + §5.3.1
    preprocessing, one submit-time window resident at a time."""
    for specs in iter_swf_windows(str(spec.param("path")), window_s,
                                  n_jobs=spec.n_jobs):
        tr = Trace.from_specs(specs)
        tr = tr.select(tr.n_tasks <= spec.n_nodes)
        if len(tr):
            yield tr


@register_workload(
    "swf-stream", params=("path", "window"), required=("path",),
    path_param="path", stream=_swf_stream_chunks,
    doc="streaming variant of 'swf' (swf-stream:<path>): identical trace, "
        "but stream_trace() parses the log in release windows "
        "(params[window]= seconds, default one day) without ever "
        "materializing it; requires a submit-sorted log")
def _swf_stream(spec: WorkloadSpec) -> Trace:
    # materialized fallback (simulate/sweep paths): same rows as 'swf'
    specs = hpc2n_preprocess(parse_swf(str(spec.param("path"))))
    trace = Trace.from_specs(specs)
    if spec.n_jobs and spec.n_jobs < len(trace):
        trace = trace.select(np.arange(spec.n_jobs))
    return trace.select(trace.n_tasks <= spec.n_nodes)


@register_workload(
    "tpu", supports_load=True, params=("records", "chips_per_task"),
    doc="TPU-pod job mix from roofline job types (workloads.jobgen); "
        "load= is the target offered load (default 0.6), "
        "params[records]= derives types from a dry-run artifact")
def _tpu(spec: WorkloadSpec) -> Trace:
    from .jobgen import DEFAULT_TPU_JOB_TYPES, tpu_job_types, tpu_trace
    records_path = spec.param("records")
    if records_path:
        with open(str(records_path)) as f:
            types = tpu_job_types(json.load(f),
                                  chips_per_task=int(spec.param(
                                      "chips_per_task", 16)))
    else:
        types = DEFAULT_TPU_JOB_TYPES
    load = spec.load if spec.load is not None else 0.6
    return Trace.from_specs(
        tpu_trace(types, n_jobs=spec.n_jobs, n_nodes=spec.n_nodes,
                  seed=spec.seed, target_load=load))
