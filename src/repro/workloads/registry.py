"""Seeded, declarative workload generation for sweep cells.

A sweep fans (workload × policy × scenario) cells across worker processes;
shipping full ``JobSpec`` lists through pickles is wasteful and ties cell
identity to object graphs.  Instead a cell carries a :class:`WorkloadSpec` —
a small frozen record naming a generator kind + its seed/size knobs — and
each worker materializes (and memoizes) the trace locally with
:func:`make_trace`.  Two specs are the same workload iff they compare equal,
which also makes them usable as cache keys and JSON-friendly via
:func:`WorkloadSpec.to_dict`.

Kinds:

* ``"lublin"`` — Lublin–Feitelson synthetic model (paper §5.3.2); with
  ``load`` set, inter-arrivals are rescaled to the target offered load
  (the paper's scaled trace sets).
* ``"hpc2n"``  — synthetic trace with HPC2N-like marginals run through the
  §5.3.1 preprocessing (cluster fixed at 120 dual-core nodes → specs use
  ``n_nodes=128`` by convention in the benchmarks).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from functools import lru_cache
from typing import List, Optional

from ..core.job import JobSpec
from .hpc2n import hpc2n_like_trace
from .lublin import lublin_trace, scale_to_load

__all__ = ["WorkloadSpec", "make_trace", "WORKLOAD_KINDS"]

WORKLOAD_KINDS = ("lublin", "hpc2n")


@dataclass(frozen=True)
class WorkloadSpec:
    """Declarative, hashable description of one generated trace."""

    kind: str                      # "lublin" | "hpc2n"
    n_jobs: int = 250
    n_nodes: int = 64
    seed: int = 0
    load: Optional[float] = None   # target offered load (lublin only)

    def __post_init__(self) -> None:
        if self.kind not in WORKLOAD_KINDS:
            raise ValueError(f"unknown workload kind {self.kind!r}; "
                             f"expected one of {WORKLOAD_KINDS}")
        if self.kind == "hpc2n" and self.load is not None:
            raise ValueError("load scaling is only defined for lublin traces")

    @property
    def name(self) -> str:
        load = f"@{self.load:g}" if self.load is not None else ""
        return f"{self.kind}-j{self.n_jobs}-n{self.n_nodes}-s{self.seed}{load}"

    def to_dict(self) -> dict:
        return asdict(self)


@lru_cache(maxsize=64)
def _cached_trace(spec: WorkloadSpec) -> tuple:
    if spec.kind == "lublin":
        specs = lublin_trace(n_jobs=spec.n_jobs, n_nodes=spec.n_nodes,
                             seed=spec.seed)
        if spec.load is not None:
            specs = scale_to_load(specs, spec.n_nodes, spec.load)
        return tuple(specs)
    if spec.kind == "hpc2n":
        specs = hpc2n_like_trace(n_jobs=spec.n_jobs, seed=spec.seed)
        # the generator models HPC2N's 120-node machine; on a smaller sweep
        # cluster, jobs wider than the cluster can never be placed — drop them
        return tuple(s for s in specs if s.n_tasks <= spec.n_nodes)
    raise ValueError(spec.kind)


def make_trace(spec: WorkloadSpec) -> List[JobSpec]:
    """Materialize the trace for ``spec`` (memoized per process)."""
    return list(_cached_trace(spec))
