"""TPU job-type generation from dry-run roofline artifacts (DESIGN.md §2).

This is the bridge between the two halves of the framework: every
(architecture × input shape) cell that passes the multi-pod dry-run yields a
roofline record (compute/memory/collective seconds, bytes per device).  A
cell becomes a DFRS *job type* whose

* ``cpu_need``  = compute_term / max(compute, memory, collective)  — the
  fraction of the chip's MXU the step can actually use (a bandwidth-bound
  decode step cannot saturate compute, exactly the fractional-use phenomenon
  DFRS exploits);
* ``mem_req``   = bytes_per_device / HBM_BYTES — a hard constraint, like the
  paper's no-swap rule;
* ``n_tasks``   = the number of chips the job's mesh spans (scaled down by
  ``chips_per_task`` when simulating at pod-slice granularity).

``tpu_trace`` samples a Poisson mixture over job types to produce a cluster
workload for the scheduler benchmarks.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..core.job import JobSpec

__all__ = ["TpuJobType", "tpu_job_types", "tpu_trace", "HBM_BYTES",
           "DEFAULT_TPU_JOB_TYPES"]

HBM_BYTES = 16 * 1024**3   # v5e-class chip


@dataclass(frozen=True)
class TpuJobType:
    name: str
    cpu_need: float
    mem_req: float
    n_tasks: int
    typical_duration: float    # s; e.g. a training run segment / serve session


def tpu_job_types(
    roofline_records: Sequence[dict],
    chips_per_task: int = 16,
    duration_per_step_mult: float = 2_000.0,
) -> List[TpuJobType]:
    """Derive job types from `repro.launch.dryrun` roofline records."""
    out: List[TpuJobType] = []
    for rec in roofline_records:
        terms = [rec["compute_s"], rec["memory_s"], rec["collective_s"]]
        dom = max(terms)
        if dom <= 0:
            continue
        cpu_need = float(np.clip(rec["compute_s"] / dom, 0.01, 1.0))
        mem_req = float(np.clip(rec["bytes_per_device"] / HBM_BYTES, 0.01, 1.0))
        chips = int(rec.get("n_chips", 256))
        n_tasks = max(1, chips // chips_per_task)
        dur = max(60.0, dom * duration_per_step_mult)
        out.append(
            TpuJobType(
                name=f"{rec['arch']}:{rec['shape']}",
                cpu_need=cpu_need,
                mem_req=mem_req,
                n_tasks=n_tasks,
                typical_duration=dur,
            )
        )
    return out


#: Deterministic fallback job-type mix for the ``tpu`` workload kind when no
#: dry-run roofline artifact is available: values follow the same derivation
#: as ``tpu_job_types`` (cpu_need = compute fraction of the dominant roofline
#: term, mem_req = HBM footprint fraction) for archetypal cells — a
#: compute-bound trainer, a mid-size fine-tune, a bandwidth-bound decode
#: server (the fractional-use case DFRS exploits) and a prefill burst.
DEFAULT_TPU_JOB_TYPES = (
    TpuJobType("trainer-large", cpu_need=0.92, mem_req=0.78, n_tasks=16,
               typical_duration=14_400.0),
    TpuJobType("finetune-mid", cpu_need=0.85, mem_req=0.45, n_tasks=4,
               typical_duration=3_600.0),
    TpuJobType("serve-decode", cpu_need=0.18, mem_req=0.62, n_tasks=2,
               typical_duration=1_800.0),
    TpuJobType("serve-prefill", cpu_need=0.70, mem_req=0.30, n_tasks=1,
               typical_duration=600.0),
)


def tpu_trace(
    job_types: Sequence[TpuJobType],
    n_jobs: int = 200,
    n_nodes: int = 128,
    seed: int = 0,
    target_load: float = 0.6,
) -> List[JobSpec]:
    """Poisson mixture over TPU job types at a target offered load."""
    rng = np.random.default_rng(seed)
    types = [t for t in job_types if t.n_tasks <= n_nodes]
    if not types:
        raise ValueError("no job types fit the cluster")
    probs = np.ones(len(types)) / len(types)
    # expected work per job → arrival rate for the target load
    e_work = float(
        np.sum([p * t.n_tasks * t.cpu_need * t.typical_duration for p, t in zip(probs, types)])
    )
    mean_gap = e_work / (target_load * n_nodes)
    specs: List[JobSpec] = []
    t = 0.0
    for jid in range(n_jobs):
        t += float(rng.exponential(mean_gap))
        jt = types[int(rng.choice(len(types), p=probs))]
        dur = float(jt.typical_duration * rng.lognormal(0.0, 0.5))
        specs.append(
            JobSpec(
                jid=jid, release=t, proc_time=max(30.0, dur),
                n_tasks=jt.n_tasks, cpu_need=jt.cpu_need, mem_req=jt.mem_req,
            )
        )
    return specs
