"""Columnar Trace IR: the structure-of-arrays workload interchange format.

A :class:`Trace` is the frozen, columnar representation of one workload —
six parallel NumPy arrays (``jid``/``release``/``proc_time``/``n_tasks``/
``cpu_need``/``mem_req``) instead of a ``List[JobSpec]`` object graph.  It
is what workload generators produce, what scenario transforms map over
(vectorized, no per-spec Python loops), what the engine ingests column-wise
(``EngineState.from_trace``), and what sweep cells ship between processes.

Why an IR and not spec lists:

* **array-native everywhere** — generators, scenario transforms and the
  engine's SoA state share one memory layout; the object-graph round trip
  only happens at the policy boundary (``to_specs``), where the §4
  algorithms still consume ``JobSpec``.
* **content identity** — ``fingerprint`` is a SHA-256 over the column bytes,
  stable across processes and Python versions (no ``PYTHONHASHSEED``
  dependence), so caches can key on *what the trace is* rather than on how
  it was generated: a cached sweep record survives generator refactors
  safely (the fingerprint changes iff the jobs changed).
* **serializable** — lossless ``npz`` (binary, exact) and JSON (text,
  exact via float round-trip) round-trips for checked-in fixtures and
  cross-process smoke checks.

Validation happens once, vectorized, at construction (the same invariants
as ``JobSpec.__post_init__``); ``to_specs`` then rebuilds plain validated
``JobSpec`` objects.  All columns are read-only; transforms build new
traces via :meth:`replace` / :meth:`select`.
"""
from __future__ import annotations

import hashlib
import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.job import JobSpec

__all__ = ["Trace", "as_trace", "COLUMNS"]

_SCHEMA = "repro.trace/v1"

#: (column name, dtype) — the IR's canonical layout, in fingerprint order
COLUMNS: Tuple[Tuple[str, type], ...] = (
    ("jid", np.int64),
    ("release", np.float64),
    ("proc_time", np.float64),
    ("n_tasks", np.int64),
    ("cpu_need", np.float64),
    ("mem_req", np.float64),
)


class Trace:
    """Frozen columnar workload: parallel arrays, one row per job.

    ``proc_truth`` is an *optional* seventh column: the processing time the
    engine actually executes when it differs from the non-clairvoyant
    ``proc_time`` estimate the policies observe (scenario ``ptime_noise``).
    When absent (the default) the trace is clairvoyant and its fingerprint
    is byte-identical to the pre-truth-column format, so existing cache
    keys survive.
    """

    __slots__ = ("jid", "release", "proc_time", "n_tasks", "cpu_need",
                 "mem_req", "proc_truth", "_fingerprint")

    def __init__(
        self,
        jid: np.ndarray,
        release: np.ndarray,
        proc_time: np.ndarray,
        n_tasks: np.ndarray,
        cpu_need: np.ndarray,
        mem_req: np.ndarray,
        proc_truth: Optional[np.ndarray] = None,
        validate: bool = True,
    ):
        cols = dict(jid=jid, release=release, proc_time=proc_time,
                    n_tasks=n_tasks, cpu_need=cpu_need, mem_req=mem_req)
        n = len(cols["jid"])
        for (name, dtype) in COLUMNS:
            arr = np.ascontiguousarray(cols[name], dtype=dtype)
            if arr.ndim != 1 or len(arr) != n:
                raise ValueError(
                    f"column {name!r} must be 1-D of length {n}, "
                    f"got shape {arr.shape}")
            if arr is cols[name] and arr.flags.writeable:
                arr = arr.copy()
            arr.flags.writeable = False
            object.__setattr__(self, name, arr)
        if proc_truth is not None:
            arr = np.ascontiguousarray(proc_truth, dtype=np.float64)
            if arr.ndim != 1 or len(arr) != n:
                raise ValueError(
                    f"column 'proc_truth' must be 1-D of length {n}, "
                    f"got shape {arr.shape}")
            if arr is proc_truth and arr.flags.writeable:
                arr = arr.copy()
            arr.flags.writeable = False
            object.__setattr__(self, "proc_truth", arr)
        else:
            object.__setattr__(self, "proc_truth", None)
        object.__setattr__(self, "_fingerprint", None)
        if validate:
            self._validate()

    # Trace is frozen: columns are read-only arrays, attributes final.
    def __setattr__(self, name, value):
        raise AttributeError("Trace is frozen; build a new one with "
                             "replace()/select()")

    def _validate(self) -> None:
        """The JobSpec invariants, checked once over whole columns."""
        def bad(mask: np.ndarray, what: str) -> None:
            if mask.any():
                i = int(np.argmax(mask))
                raise ValueError(
                    f"{what} (first offender: row {i}, jid "
                    f"{int(self.jid[i])})")
        bad(~((self.cpu_need > 0.0) & (self.cpu_need <= 1.0)),
            "cpu_need must be in (0,1]")
        bad(~((self.mem_req > 0.0) & (self.mem_req <= 1.0)),
            "mem_req must be in (0,1]")
        bad(self.n_tasks < 1, "n_tasks must be >= 1")
        bad(self.proc_time <= 0.0, "proc_time must be > 0")
        bad(~np.isfinite(self.release), "release must be finite")
        if self.proc_truth is not None:
            bad(~(self.proc_truth > 0.0) | ~np.isfinite(self.proc_truth),
                "proc_truth must be finite and > 0")

    # ------------------------------------------------------------------ #
    # basics                                                              #
    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self.jid)

    def __repr__(self) -> str:
        return (f"Trace(n_jobs={len(self)}, "
                f"fingerprint={self.fingerprint[:12]}…)")

    def __eq__(self, other) -> bool:
        return isinstance(other, Trace) and self.fingerprint == other.fingerprint

    def __hash__(self) -> int:
        return hash(self.fingerprint)

    @property
    def fingerprint(self) -> str:
        """SHA-256 content hash of the columns (schema-tagged, process- and
        platform-stable for the fixed little-endian column dtypes)."""
        fp = self._fingerprint
        if fp is None:
            h = hashlib.sha256()
            h.update(f"{_SCHEMA}:{len(self)}".encode())
            for name, _ in COLUMNS:
                col = getattr(self, name)
                h.update(name.encode())
                h.update(col.astype(col.dtype.newbyteorder("<"),
                                    copy=False).tobytes())
            if self.proc_truth is not None:
                # appended only when present: clairvoyant traces keep their
                # pre-truth-column fingerprints (cache keys survive)
                h.update(b"proc_truth")
                h.update(self.proc_truth.astype(
                    self.proc_truth.dtype.newbyteorder("<"),
                    copy=False).tobytes())
            fp = h.hexdigest()
            object.__setattr__(self, "_fingerprint", fp)
        return fp

    @property
    def total_work(self) -> float:
        """Σ n_tasks · proc_time · cpu_need (CPU-seconds across the trace)."""
        return float((self.n_tasks * self.proc_time * self.cpu_need).sum())

    def span(self) -> Tuple[float, float]:
        """(first release, max(release span, 1.0)) — the scenario timebase."""
        if not len(self):
            return 0.0, 1.0
        lo = float(self.release.min())
        hi = float(self.release.max())
        return lo, max(hi - lo, 1.0)

    # ------------------------------------------------------------------ #
    # spec-list boundary                                                  #
    # ------------------------------------------------------------------ #
    @classmethod
    def from_specs(cls, specs: Iterable[JobSpec]) -> "Trace":
        specs = list(specs)
        return cls(
            jid=np.array([s.jid for s in specs], dtype=np.int64),
            release=np.array([s.release for s in specs], dtype=np.float64),
            proc_time=np.array([s.proc_time for s in specs], dtype=np.float64),
            n_tasks=np.array([s.n_tasks for s in specs], dtype=np.int64),
            cpu_need=np.array([s.cpu_need for s in specs], dtype=np.float64),
            mem_req=np.array([s.mem_req for s in specs], dtype=np.float64),
        )

    def to_specs(self) -> List[JobSpec]:
        """Rebuild the ``JobSpec`` list (row order preserved, exact values)."""
        return [
            JobSpec(jid=int(j), release=float(r), proc_time=float(p),
                    n_tasks=int(t), cpu_need=float(c), mem_req=float(m))
            for j, r, p, t, c, m in zip(
                self.jid, self.release, self.proc_time,
                self.n_tasks, self.cpu_need, self.mem_req)
        ]

    # ------------------------------------------------------------------ #
    # transforms (always produce a new Trace)                             #
    # ------------------------------------------------------------------ #
    def replace(self, **columns: np.ndarray) -> "Trace":
        """New trace with the given columns replaced (others shared).
        ``proc_truth=None`` drops the truth column."""
        known = {name for name, _ in COLUMNS} | {"proc_truth"}
        unknown = set(columns) - known
        if unknown:
            raise ValueError(f"unknown Trace columns: {sorted(unknown)}")
        kw = {name: columns.get(name, getattr(self, name))
              for name in known}
        return Trace(**kw)

    def select(self, index: np.ndarray) -> "Trace":
        """Row subset / reorder by boolean mask or integer index array."""
        index = np.asarray(index)
        truth = None if self.proc_truth is None else self.proc_truth[index]
        return Trace(*(getattr(self, name)[index] for name, _ in COLUMNS),
                     proc_truth=truth, validate=False)

    def sorted_by_release(self) -> "Trace":
        """Rows ordered by (release, jid) — the engine's arrival order."""
        order = np.lexsort((self.jid, self.release))
        if (order == np.arange(len(order))).all():
            return self
        return self.select(order)

    def iter_chunks(self, window_s: float):
        """Yield release-windowed sub-traces for streaming ingest.

        Rows are partitioned into half-open windows
        ``[lo + k*window_s, lo + (k+1)*window_s)`` anchored at the first
        release; empty windows are skipped.  Chunks come out in release
        order (each is a contiguous slice of :meth:`sorted_by_release`),
        so concatenating them reproduces the sorted trace exactly — the
        contract :meth:`SimSession.stream <repro.sched.session.SimSession.stream>`
        relies on for bit-identical results.
        """
        if not window_s > 0.0:
            raise ValueError(f"window_s must be > 0, got {window_s}")
        if not len(self):
            return
        t = self.sorted_by_release()
        lo = float(t.release[0])
        bucket = np.floor((t.release - lo) / float(window_s)).astype(np.int64)
        _, starts = np.unique(bucket, return_index=True)
        bounds = np.append(starts, len(t))
        for a, b in zip(bounds[:-1], bounds[1:]):
            yield t.select(np.arange(a, b))

    # ------------------------------------------------------------------ #
    # serialization                                                       #
    # ------------------------------------------------------------------ #
    def save_npz(self, path: str) -> str:
        cols = {name: getattr(self, name) for name, _ in COLUMNS}
        if self.proc_truth is not None:
            cols["proc_truth"] = self.proc_truth
        np.savez_compressed(path, schema=np.array(_SCHEMA), **cols)
        return path

    @classmethod
    def load_npz(cls, path: str) -> "Trace":
        with np.load(path) as z:
            schema = str(z["schema"]) if "schema" in z else None
            if schema != _SCHEMA:
                raise ValueError(f"{path} is not a {_SCHEMA} trace "
                                 f"(schema: {schema!r})")
            return cls(**{name: z[name] for name, _ in COLUMNS},
                       proc_truth=z["proc_truth"] if "proc_truth" in z
                       else None)

    def to_json_dict(self) -> Dict[str, object]:
        """Exact text form (floats survive via repr round-trip)."""
        columns = {name: getattr(self, name).tolist()
                   for name, _ in COLUMNS}
        if self.proc_truth is not None:
            columns["proc_truth"] = self.proc_truth.tolist()
        return {
            "schema": _SCHEMA,
            "n_jobs": len(self),
            "fingerprint": self.fingerprint,
            "columns": columns,
        }

    @classmethod
    def from_json_dict(cls, payload: Dict[str, object]) -> "Trace":
        if payload.get("schema") != _SCHEMA:
            raise ValueError(f"not a {_SCHEMA} payload "
                             f"(schema: {payload.get('schema')!r})")
        cols = payload["columns"]
        truth = cols.get("proc_truth")
        trace = cls(**{name: np.asarray(cols[name], dtype=dtype)
                       for name, dtype in COLUMNS},
                    proc_truth=None if truth is None
                    else np.asarray(truth, dtype=np.float64))
        want = payload.get("fingerprint")
        if want is not None and want != trace.fingerprint:
            raise ValueError("trace fingerprint mismatch after JSON "
                             "round-trip (corrupted payload?)")
        return trace

    def save_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_json_dict(), f)
        return path

    @classmethod
    def load_json(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_json_dict(json.load(f))


def as_trace(trace_or_specs) -> Trace:
    """Coerce either IR form (a Trace passes through untouched)."""
    if isinstance(trace_or_specs, Trace):
        return trace_or_specs
    return Trace.from_specs(trace_or_specs)
