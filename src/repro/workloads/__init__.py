"""repro.workloads — HPC workload generation and trace handling (paper §5.3),
plus the declarative seeded-generator registry used by sweep cells."""
from .lublin import lublin_trace, scale_to_load, offered_load
from .hpc2n import parse_swf, hpc2n_preprocess, hpc2n_like_trace
from .jobgen import tpu_job_types, tpu_trace
from .registry import WorkloadSpec, make_trace

__all__ = [
    "lublin_trace", "scale_to_load", "offered_load",
    "parse_swf", "hpc2n_preprocess", "hpc2n_like_trace",
    "tpu_job_types", "tpu_trace",
    "WorkloadSpec", "make_trace",
]
