"""repro.workloads — HPC workload generation and trace handling (paper §5.3):
the columnar Trace IR, the open registry of declarative seeded generators
used by sweep cells, and the individual generator modules."""
from .trace import Trace, as_trace
from .lublin import lublin_trace, scale_to_load, offered_load
from .hpc2n import (parse_swf, iter_swf, iter_swf_windows, hpc2n_preprocess,
                    hpc2n_like_trace)
from .jobgen import tpu_job_types, tpu_trace, DEFAULT_TPU_JOB_TYPES
from .registry import (WorkloadSpec, WorkloadKind, make_trace, make_trace_ir,
                       parse_workload, register_workload, list_workloads,
                       stream_trace, workload_kind)

__all__ = [
    "Trace", "as_trace",
    "lublin_trace", "scale_to_load", "offered_load",
    "parse_swf", "iter_swf", "iter_swf_windows", "hpc2n_preprocess",
    "hpc2n_like_trace",
    "tpu_job_types", "tpu_trace", "DEFAULT_TPU_JOB_TYPES",
    "WorkloadSpec", "WorkloadKind", "make_trace", "make_trace_ir",
    "parse_workload", "register_workload", "list_workloads", "workload_kind",
    "stream_trace",
]
