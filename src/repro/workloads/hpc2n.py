"""HPC2N real-world trace handling (paper §5.3.1).

The paper uses the cleaned HPC2N log from the Parallel Workloads Archive
(182 weeks, 120 dual-core 2 GB nodes).  ``parse_swf`` reads the standard swf
format; ``hpc2n_preprocess`` applies the paper's §5.3.1 transformation:

* per-processor memory = max(requested, used) / 2 GB, floored at 10 %;
* jobs with an even processor count and < 50 % per-processor memory are
  assumed multi-threaded: tasks = procs / 2, CPU need 1.0 (saturates both
  cores), memory doubled;
* otherwise: tasks = procs, CPU need 0.5 (one core), memory unchanged.

The archive is not redistributable inside this container, so
``hpc2n_like_trace`` synthesizes swf rows with the trace's published
marginals (job sizes heavy at small powers of two, > 95 % of jobs under
40 % memory, runtimes seconds→days) and runs them through the *same*
preprocessing.  A real log, when available, enters through the ``swf``
workload kind — ``repro.workloads.registry.parse_workload("swf:<path>")``,
``python -m repro {simulate,sweep} --workload swf:<path>``, or
``python -m benchmarks.run --swf <path>`` (which swaps it in as the
"real" trace set) — and is exercised against the checked-in miniature
``tests/data/mini.swf`` fixture by the golden tests in
``tests/test_hpc2n_swf.py``.
"""
from __future__ import annotations

import io
from typing import List, Optional, Sequence

import numpy as np

from ..core.job import JobSpec

__all__ = ["parse_swf", "iter_swf", "iter_swf_windows", "hpc2n_preprocess",
           "hpc2n_like_trace", "SwfJob"]

NODE_MEM_GB = 2.0
N_NODES = 120


class SwfJob:
    __slots__ = ("jid", "submit", "run", "procs", "used_mem_kb", "req_mem_kb")

    def __init__(self, jid, submit, run, procs, used_mem_kb, req_mem_kb):
        self.jid = jid
        self.submit = submit
        self.run = run
        self.procs = procs
        self.used_mem_kb = used_mem_kb
        self.req_mem_kb = req_mem_kb


def iter_swf(text_or_path):
    """Lazily yield :class:`SwfJob` rows from an swf log (same skip rules
    as :func:`parse_swf`; never holds more than one line in memory)."""
    if isinstance(text_or_path, str) and "\n" not in text_or_path:
        fh = open(text_or_path)
    else:
        fh = io.StringIO(text_or_path)
    with fh:
        for line in fh:
            line = line.strip()
            if not line or line.startswith(";"):
                continue
            f = line.split()
            if len(f) < 11:
                continue
            jid = int(f[0]); submit = float(f[1]); run = float(f[3])
            procs = int(f[4]); used_mem = float(f[6])
            req_mem = float(f[9])
            if run <= 0 or procs <= 0:
                continue
            yield SwfJob(jid, submit, run, procs, used_mem, req_mem)


def parse_swf(text_or_path) -> List[SwfJob]:
    """Parse the Standard Workload Format (fields per swf spec; -1 = n/a)."""
    return list(iter_swf(text_or_path))


def hpc2n_preprocess(swf_jobs: Sequence[SwfJob],
                     start_jid: int = 0) -> List[JobSpec]:
    """§5.3.1 transformation of swf rows into DFRS job specs.

    ``start_jid`` offsets the re-assigned contiguous jids so a chunked
    caller (:func:`iter_swf_windows`) can continue the numbering of an
    earlier chunk and reproduce exactly the jids a whole-log pass assigns.
    """
    specs: List[JobSpec] = []
    node_kb = NODE_MEM_GB * 1024 * 1024
    for k, j in enumerate(sorted(swf_jobs, key=lambda j: j.submit),
                          start=start_jid):
        per_proc = max(j.used_mem_kb, j.req_mem_kb)
        mem_frac = max(0.10, per_proc / node_kb) if per_proc > 0 else 0.10
        mem_frac = min(1.0, mem_frac)
        if j.procs % 2 == 0 and mem_frac < 0.5:
            n_tasks = j.procs // 2
            cpu_need = 1.0
            mem = min(1.0, 2 * mem_frac)
        else:
            n_tasks = j.procs
            cpu_need = 0.5
            mem = mem_frac
        specs.append(
            JobSpec(
                jid=k, release=float(j.submit), proc_time=float(j.run),
                n_tasks=n_tasks, cpu_need=cpu_need, mem_req=mem,
            )
        )
    return specs


def iter_swf_windows(
    text_or_path,
    window_s: float,
    n_jobs: int = 0,
) -> "iter":
    """Stream an swf log as per-release-window ``List[JobSpec]`` chunks.

    Reads the log line by line (never materializing it) and yields the
    §5.3.1-preprocessed specs of each half-open submit-time window
    ``[lo + k*window_s, lo + (k+1)*window_s)`` anchored at the first
    accepted row's submit time; empty windows are skipped.  ``n_jobs``
    caps the number of rows taken (0 = the whole log), counted *before*
    any downstream width filter — the same prefix semantics as the
    materialized ``swf`` workload kind.

    Because jids are re-assigned in submit order, the log must already be
    sorted by submit time (true of the cleaned Parallel Workloads Archive
    logs).  An out-of-order row raises — fall back to the materialized
    ``swf:<path>`` kind to handle unsorted logs.

    Concatenating the chunks reproduces ``hpc2n_preprocess(parse_swf(x))``
    row for row: ``sorted()`` is a stable identity on each already-sorted
    chunk, and every per-spec value depends only on its own row and jid.
    """
    if not window_s > 0.0:
        raise ValueError(f"window_s must be > 0, got {window_s}")
    buf: List[SwfJob] = []
    taken = 0           # rows accepted so far == jids already assigned
    lo = None           # first accepted submit time (window anchor)
    cur = None          # window index of the rows in buf
    last = -np.inf
    for row in iter_swf(text_or_path):
        if row.submit < last:
            raise ValueError(
                "swf log is not sorted by submit time (row jid "
                f"{row.jid}: submit {row.submit} after {last}); streaming "
                "ingest needs a sorted log — use the materialized "
                "'swf:<path>' workload kind instead")
        last = row.submit
        if lo is None:
            lo = row.submit
        k = int((row.submit - lo) // window_s)
        if buf and k != cur:
            yield hpc2n_preprocess(buf, start_jid=taken)
            taken += len(buf)
            buf = []
        cur = k
        buf.append(row)
        if n_jobs and taken + len(buf) >= n_jobs:
            break
    if buf:
        yield hpc2n_preprocess(buf, start_jid=taken)


def hpc2n_like_trace(
    n_jobs: int = 500,
    seed: int = 0,
    span_weeks: float = 1.0,
) -> List[JobSpec]:
    """Synthetic swf rows with HPC2N-like marginals, preprocessed per §5.3.1."""
    rng = np.random.default_rng(seed)
    node_kb = NODE_MEM_GB * 1024 * 1024
    rows: List[SwfJob] = []
    t = 0.0
    span = span_weeks * 7 * 86400.0
    mean_gap = span / max(1, n_jobs)
    for jid in range(n_jobs):
        t += float(rng.exponential(mean_gap))
        # sizes: mostly small, powers of two favoured, max 2*120 processors
        u = rng.random()
        if u < 0.35:
            procs = 1
        elif u < 0.85:
            procs = int(2 ** rng.integers(1, 6))      # 2..32
        else:
            procs = int(min(120, 2 ** rng.integers(5, 8)))
        # runtimes: log-uniform seconds..day, occasional multi-day
        lg = rng.uniform(np.log10(8.0), np.log10(86400.0))
        run = 10**lg * (10.0 if rng.random() < 0.02 else 1.0)
        # memory: >95% of jobs below 40% of node memory
        if rng.random() < 0.95:
            mem_frac = rng.uniform(0.01, 0.38)
        else:
            mem_frac = rng.uniform(0.4, 0.95)
        used_kb = mem_frac * node_kb
        rows.append(SwfJob(jid, t, run, procs, used_kb, 0.0))
    return hpc2n_preprocess(rows)
