"""Lublin–Feitelson synthetic workload model (paper §5.3.2).

Follows Lublin & Feitelson (JPDC 2003) for job sizes (two-stage log-uniform
with power-of-two rounding) and runtimes (hyper-gamma on log2 runtime whose
short/long mixture probability depends linearly on job size), with a
daily-cycle-modulated Poisson arrival process.  The paper's §5.3.2
augmentation is applied on top:

* quad-core nodes — a one-task job is sequential (CPU need 0.25), every task
  of a multi-task job is multi-threaded and CPU-bound (need 1.0);
* memory (Setia et al. model): 55 % of jobs need 10 % of node memory, the
  rest need 10·x % with x uniform over {2..10}.

``scale_to_load`` multiplies inter-arrival times by a computed constant so a
trace realizes a target offered load, reproducing the paper's 9 scaled
variants (0.1..0.9) per base trace.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from ..core.job import JobSpec

__all__ = ["lublin_trace", "offered_load", "scale_to_load"]

# Lublin-Feitelson batch-job constants
_SERIAL_PROB = 0.244
_POW2_PROB = 0.78
_ULOW, _UMED, _UPROB = 0.8, 4.5, 0.86
# hyper-gamma on log2(runtime):  short ~ Gamma(4.2, 0.94), long ~ Gamma(312, 0.03)
_A1, _B1 = 4.2, 0.94
_A2, _B2 = 312.0, 0.03
_PA, _PB = -0.0054, 0.78
_MEAN_INTERARRIVAL = 450.0   # s; gives the paper's ~4-6 day span for 1000 jobs
_RUNTIME_CAP = 6 * 86400.0


def _two_stage_uniform(rng, lo, med, hi, prob):
    if rng.random() <= prob:
        return rng.uniform(lo, med)
    return rng.uniform(med, hi)


def _job_size(rng, n_nodes: int) -> int:
    if rng.random() < _SERIAL_PROB:
        return 1
    uhi = np.log2(n_nodes)
    # Lublin's defaults (uMed=4.5) assume uHi=log2(128)=7, i.e. uMed=uHi-2.5;
    # keep that offset for smaller clusters so uLow <= uMed <= uHi.
    umed = min(_UMED, max(_ULOW, uhi - 2.5))
    u = _two_stage_uniform(rng, _ULOW, umed, uhi, _UPROB)
    if rng.random() <= _POW2_PROB:
        size = 2 ** int(round(u))
    else:
        size = int(round(2**u))
    return int(np.clip(size, 1, n_nodes))


def _runtime(rng, size: int) -> float:
    p = float(np.clip(_PA * size + _PB, 0.0, 1.0))
    if rng.random() <= p:
        lg = rng.gamma(_A1, _B1)
    else:
        lg = rng.gamma(_A2, _B2)
    return float(np.clip(2.0**lg, 1.0, _RUNTIME_CAP))


def lublin_trace(
    n_jobs: int = 1000,
    n_nodes: int = 128,
    seed: int = 0,
    mean_interarrival: float = _MEAN_INTERARRIVAL,
    daily_cycle: bool = True,
) -> List[JobSpec]:
    rng = np.random.default_rng(seed)
    specs: List[JobSpec] = []
    t = 0.0
    for jid in range(n_jobs):
        gap = rng.exponential(mean_interarrival)
        if daily_cycle:
            # rush-hour modulation: rate peaks mid-day
            phase = 2 * np.pi * ((t / 86400.0) % 1.0)
            gap *= 1.0 / (1.0 + 0.6 * np.sin(phase - np.pi / 2) + 0.6)
        t += float(gap)
        size = _job_size(rng, n_nodes)
        proc = _runtime(rng, size)
        cpu_need = 0.25 if size == 1 else 1.0
        if rng.random() < 0.55:
            mem = 0.10
        else:
            mem = 0.10 * int(rng.integers(2, 11))
        specs.append(
            JobSpec(
                jid=jid, release=t, proc_time=proc,
                n_tasks=size, cpu_need=cpu_need, mem_req=float(mem),
            )
        )
    return specs


def offered_load(specs: Sequence[JobSpec], n_nodes: int) -> float:
    """Total CPU work over cluster capacity x trace span ([3]'s offered load)."""
    if not specs:
        return 0.0
    work = sum(s.total_work for s in specs)
    span = max(s.release for s in specs) - min(s.release for s in specs)
    span = max(span, 1.0)
    return work / (n_nodes * span)


def scale_to_load(
    specs: Sequence[JobSpec], n_nodes: int, target_load: float
) -> List[JobSpec]:
    """Multiply inter-arrival times by a constant to hit ``target_load``."""
    base = offered_load(specs, n_nodes)
    factor = base / target_load
    t0 = min(s.release for s in specs)
    out = []
    for s in sorted(specs, key=lambda s: s.release):
        out.append(
            JobSpec(
                jid=s.jid,
                release=t0 + (s.release - t0) * factor,
                proc_time=s.proc_time,
                n_tasks=s.n_tasks,
                cpu_need=s.cpu_need,
                mem_req=s.mem_req,
            )
        )
    return out
