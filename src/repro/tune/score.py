"""Pluggable race objectives: score a (possibly partial) branch record.

An :class:`Objective` maps one ``run_branches`` record — the flat metric
dict of a what-if branch, full-run or horizon-bounded — to a single
*minimized* scalar.  Quarantined or metric-less records score ``inf``, so
a crashing variant loses a race instead of winning it by vacuity.

Objectives are either registered names (``max_stretch``,
``mean_stretch``, ``underutilization``, ``migration``) or weighted blends
in a tiny ``w*key[+w*key...]`` grammar::

    parse_objective("max_stretch")
    parse_objective("0.7*max_stretch+0.3*mean_stretch")
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass
from typing import Any, Dict, List, Tuple

__all__ = ["Objective", "parse_objective", "list_objectives",
           "SCORABLE_KEYS"]

#: record keys an objective term may reference — every one is
#: minimize-is-better on its own (utilization enters as UNDER-utilization)
SCORABLE_KEYS = (
    "max_stretch",
    "mean_stretch",
    "makespan",
    "underutilization",
    "pmtn_per_job",
    "mig_per_job",
    "bytes_moved_gb",
)


@dataclass(frozen=True)
class Objective:
    """A weighted sum of branch-record metrics, minimized."""

    name: str
    terms: Tuple[Tuple[float, str], ...]

    def score(self, record: Dict[str, Any]) -> float:
        """Scalar score of one branch record (``inf`` when any referenced
        metric is missing or non-finite — quarantined branches lose)."""
        total = 0.0
        for w, key in self.terms:
            v = record.get(key)
            if v is None or not math.isfinite(float(v)):
                return math.inf
            total += w * float(v)
        return total

    @property
    def prunable_by_max_stretch(self) -> bool:
        """True when a growing completed-job max stretch can only worsen
        the score — the single-term ``max_stretch`` objective, where a
        branch past the cutoff is safe to early-stop."""
        return self.terms == ((1.0, "max_stretch"),)

    def __str__(self) -> str:
        return self.name


_NAMED: Dict[str, Tuple[Tuple[float, str], ...]] = {
    "max_stretch": ((1.0, "max_stretch"),),
    "mean_stretch": ((1.0, "mean_stretch"),),
    "makespan": ((1.0, "makespan"),),
    "underutilization": ((1.0, "underutilization"),),
    # stretch with a disruption tax: racing should not reward a variant
    # that wins by migrating everything everywhere
    "migration": ((1.0, "max_stretch"), (0.1, "mig_per_job")),
}

_TERM = re.compile(r"^\s*(?:([0-9.eE+-]+)\s*\*\s*)?([a-z_]+)\s*$")


def list_objectives() -> List[str]:
    return sorted(_NAMED)


def parse_objective(spec) -> Objective:
    """Build an :class:`Objective` from a registered name or a
    ``w*key[+w*key...]`` blend; passes an :class:`Objective` through."""
    if isinstance(spec, Objective):
        return spec
    spec = str(spec).strip()
    if spec in _NAMED:
        return Objective(name=spec, terms=_NAMED[spec])
    terms: List[Tuple[float, str]] = []
    for part in spec.split("+"):
        m = _TERM.match(part)
        if not m:
            raise ValueError(
                f"malformed objective term {part!r} in {spec!r}; want "
                f"'key' or 'weight*key' terms joined by '+'")
        weight = float(m.group(1)) if m.group(1) else 1.0
        key = m.group(2)
        if key not in SCORABLE_KEYS:
            raise ValueError(
                f"unknown objective metric {key!r}; known: "
                f"{list(SCORABLE_KEYS)} (or a named objective from "
                f"{list_objectives()})")
        terms.append((weight, key))
    if not terms:
        raise ValueError("empty objective spec")
    return Objective(name=spec, terms=tuple(terms))
