"""Online what-if autotuning: fork-race-promote policy search.

The paper's premise is that *online* scheduling decisions beat static
batch policies; this package applies the same idea to the scheduler's own
configuration.  An :class:`AutoTuner` periodically forks the live
:class:`~repro.sched.session.SimSession` (via ``snapshot()``), races a
portfolio of policy/period variants over a bounded sim-time horizon with
successive halving (:mod:`~repro.tune.race`), scores the survivors with a
pluggable objective (:mod:`~repro.tune.score`), and hot-swaps the winner
into the running session (:meth:`SimSession.switch_policy`) — but only on
a decisive margin after a minimum dwell, so the live policy never
flip-flops.  Tuner RNG, schedule and decision log ride session snapshots
bit-exactly; see ARCHITECTURE.md "Autotuning layer".
"""
from .controller import AutoTuner, TuneConfig, parse_tune
from .race import RaceResult, Variant, race
from .score import Objective, list_objectives, parse_objective

__all__ = [
    "AutoTuner",
    "TuneConfig",
    "parse_tune",
    "RaceResult",
    "Variant",
    "race",
    "Objective",
    "list_objectives",
    "parse_objective",
]
