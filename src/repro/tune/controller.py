"""The AutoTuner: fork-race-promote driven from the session loop.

An :class:`AutoTuner` attaches to a live
:class:`~repro.sched.session.SimSession` exactly like the chaos narrator:
the stepping loop peeks its next scheduled time and fires it lazily at
the same partition-invariant boundary (due before the next engine event
and inside the step bound), so the fire points — and therefore the race
snapshots and the decision log — are identical no matter how the run is
chunked into ``step()``/``step_until()`` calls.

One firing:

1. **fork** — snapshot the live session (tuner state stripped from the
   race copies);
2. **race** — successive halving over the configured policy × period
   portfolio (:func:`repro.tune.race.race`), chaos reseeded with a
   deterministic per-decision ``branch_seed`` (oracle-free: the tuner
   knows the chaos *distribution*, never the live realization);
3. **promote** — hot-swap the winner (``switch_policy`` + ``set_period``)
   only if it beat the incumbent by the configured relative ``margin``
   AND at least ``dwell`` sim-seconds passed since the last swap
   (hysteresis: no flip-flopping on noise);
4. **log** — append one wall-clock-free decision record to the in-memory
   log (and an optional JSONL sink).

Tuner RNG, schedule, and decision log ride ``SimSession.snapshot()``
bit-exactly under the optional ``autotune`` payload key, so a restored
session re-fires, re-decides and re-logs identically — in the same or a
fresh process.
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from .race import RaceResult, Variant, race
from .score import parse_objective

__all__ = ["AutoTuner", "TuneConfig", "parse_tune"]


@dataclass(frozen=True)
class TuneConfig:
    """Static autotuner configuration (travels in snapshots verbatim)."""

    #: sim-seconds between scheduled races
    every: float = 7200.0
    #: first-rung race horizon (sim-seconds); rung r doubles it r times.
    #: None = every / 2.
    horizon: Optional[float] = None
    #: successive-halving rungs per race
    rungs: int = 2
    #: race objective (name or w*key+... blend, see tune.score)
    objective: str = "max_stretch"
    #: hysteresis: promote only when winner <= (1 - margin) * incumbent
    margin: float = 0.05
    #: min sim-seconds between promotions. None = 2 * every.
    dwell: Optional[float] = None
    #: portfolio policy strings (the incumbent is always raced too)
    policies: Tuple[str, ...] = ()
    #: portfolio period values crossed with the policies (() = keep each
    #: variant at the live period)
    periods: Tuple[float, ...] = ()
    #: per-branch wall-clock budget (supervised worker processes).
    #: Wall-clock supervision is nondeterministic — leave None where
    #: bit-identical replay matters (the default race is deterministic).
    timeout: Optional[float] = None
    #: supervised retries per branch (with timeout)
    retries: int = 0
    #: race branch backend: None (numpy) or "jax"/"pallas" lockstep
    backend: Optional[str] = None

    def __post_init__(self):
        if self.every <= 0:
            raise ValueError("tune: every must be > 0")
        if self.horizon is not None and self.horizon <= 0:
            raise ValueError("tune: horizon must be > 0")
        if self.rungs < 1:
            raise ValueError("tune: rungs must be >= 1")
        if not 0.0 <= self.margin < 1.0:
            raise ValueError("tune: margin must be in [0, 1)")
        if self.dwell is not None and self.dwell < 0:
            raise ValueError("tune: dwell must be >= 0")
        parse_objective(self.objective)     # fail fast

    @property
    def base_horizon(self) -> float:
        return self.horizon if self.horizon is not None else self.every / 2.0

    @property
    def min_dwell(self) -> float:
        return self.dwell if self.dwell is not None else 2.0 * self.every


_LIST_KEYS = {"policies", "periods"}


def parse_tune(spec: str) -> TuneConfig:
    """Build a :class:`TuneConfig` from the ``;``-separated spec grammar::

        every=5000;horizon=2500;rungs=2;objective=max_stretch;
        margin=0.05;dwell=10000;policies=GreedyP */OPT=MIN|EASY;
        periods=600,1200;timeout=30;retries=1;backend=jax

    ``policies`` is ``|``-separated (policy strings contain neither ``;``
    nor ``|``); ``periods`` is comma-separated floats.
    """
    kwargs: Dict[str, Any] = {}
    for part in spec.split(";"):
        part = part.strip()
        if not part:
            continue
        key, eq, val = part.partition("=")
        key, val = key.strip(), val.strip()
        if not eq or not key:
            raise ValueError(f"tune spec token {part!r} must be key=value")
        if key in ("every", "horizon", "margin", "dwell", "timeout"):
            kwargs[key] = float(val)
        elif key in ("rungs", "retries"):
            kwargs[key] = int(val)
        elif key == "policies":
            kwargs[key] = tuple(p.strip() for p in val.split("|")
                                if p.strip())
        elif key == "periods":
            kwargs[key] = tuple(float(p) for p in val.split(",") if p.strip())
        elif key in ("objective", "backend"):
            kwargs[key] = val
        else:
            raise ValueError(
                f"unknown tune spec key {key!r}; known: every, horizon, "
                f"rungs, objective, margin, dwell, policies, periods, "
                f"timeout, retries, backend")
    return TuneConfig(**kwargs)


class AutoTuner:
    """Fork-race-promote controller for one live session.

    Attach with :meth:`SimSession.attach_autotuner`; the stepping loop
    drives :meth:`peek`/:meth:`fire`.  ``state()``/``from_state``
    round-trip everything that determines future decisions (config, RNG,
    schedule, decision log) — the JSONL sink path is process-local and
    deliberately not part of snapshots, like the session's metrics sinks.
    """

    def __init__(self, config: Optional[TuneConfig] = None, *,
                 seed: int = 0, log_path: Optional[str] = None):
        if isinstance(config, str):
            config = parse_tune(config)
        self.config = config or TuneConfig()
        self.seed = int(seed)
        self._rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, 0x7E5E]))
        self._next_t: Optional[float] = None
        self._last_swap_t: Optional[float] = None
        self._n_fired = 0
        self.decisions: List[Dict[str, Any]] = []
        self.log_path = log_path
        #: last full RaceResult (ephemeral diagnostics, not snapshot state)
        self.last_race: Optional[RaceResult] = None

    # ---- the session-facing surface (narrator-shaped) -------------------- #
    def peek(self, session) -> float:
        """Next scheduled race time; primed lazily at the engine clock so
        a tuner attached mid-run starts counting from 'now'."""
        if self._next_t is None:
            self._next_t = session.engine.state.now + self.config.every
        return self._next_t

    def fire(self, session, *, now: bool = False) -> bool:
        """Run one fork-race-promote cycle; returns True when a variant
        was promoted (the session's policy/period changed in place).

        ``now=True`` is the manual trigger (the ``tune`` op): the race
        runs at the current engine clock and the periodic schedule
        restarts from it.  The next scheduled time always advances
        *before* racing, so a crashing race cannot wedge the schedule.
        """
        cfg = self.config
        st = session.engine.state
        t = float(st.now) if now else self.peek(session)
        self._next_t = t + cfg.every
        self._n_fired += 1
        # one deterministic seed per decision, drawn from the tuner RNG
        # (which rides snapshots): every branch of this race sees the same
        # reseeded chaos, and a restored session re-draws the same seed
        branch_seed = int(self._rng.integers(0, 2**31 - 1))
        incumbent = Variant(session.engine.policy_ref,
                            float(session.engine.params.period))
        variants, skipped = self._portfolio(session)
        decision: Dict[str, Any] = {
            "i": len(self.decisions),
            "t": t,
            "now": float(st.now),
            "incumbent": dataclasses.asdict(incumbent),
            "objective": cfg.objective,
            "branch_seed": branch_seed,
            "n_variants": len(variants) + 1,
            "skipped_variants": skipped,
        }
        swapped = False
        try:
            rr = race(
                session.snapshot(), variants, incumbent,
                objective=cfg.objective, base_horizon=cfg.base_horizon,
                rungs=cfg.rungs, branch_seed=branch_seed,
                timeout_s=cfg.timeout, retries=cfg.retries,
                backend=cfg.backend)
        except Exception as exc:  # noqa: BLE001 — a broken race loses, only
            self.last_race = None  # the decision record remembers it
            decision.update(swapped=False, reason="race-error",
                            error=f"{type(exc).__name__}: {exc}")
        else:
            self.last_race = rr
            swapped, reason = self._decide(rr, t)
            if swapped:
                session.switch_policy(rr.winner.policy)
                if (rr.winner.period is not None
                        and rr.winner.period != session.engine.params.period):
                    session.set_period(rr.winner.period)
                self._last_swap_t = t
            decision.update(
                swapped=swapped, reason=reason,
                winner=dataclasses.asdict(rr.winner),
                winner_score=rr.winner_score,
                incumbent_score=rr.incumbent_score,
                horizon_s=rr.horizon_s,
                rungs=rr.rungs)
        self.decisions.append(decision)
        if self.log_path is not None:
            with open(self.log_path, "a") as f:
                f.write(json.dumps(decision) + "\n")
        return swapped

    # ---- internals -------------------------------------------------------- #
    def _portfolio(self, session) -> Tuple[List[Variant], List[str]]:
        """The promotable variants for this session right now: the
        configured policy × period cross product, minus variants that
        could not be hot-swapped in (batch baselines while the session
        still needs cluster events)."""
        from ..sched.engine import resolve_policy_arg

        cfg = self.config
        st = session.engine.state
        needs_cev = (
            (session.narrator is not None
             and session.narrator.needs_cluster_events())
            or session._ci < len(session._cev)
            or not bool(st.alive.all()))
        policies = list(cfg.policies) or [session.engine.policy_ref]
        periods: List[Optional[float]] = list(cfg.periods) or [None]
        out: List[Variant] = []
        skipped: List[str] = []
        for pol in policies:
            if needs_cev:
                try:
                    handles = resolve_policy_arg(pol)[1].handles_cluster_events
                except ValueError as exc:
                    skipped.append(f"{pol}: {exc}")
                    continue
                if not handles:
                    skipped.append(f"{pol}: needs cluster-event support")
                    continue
            for per in periods:
                out.append(Variant(pol, per))
        return out, skipped

    def _decide(self, rr: RaceResult, t: float) -> Tuple[bool, str]:
        cfg = self.config
        if not rr.promoted:
            return False, "incumbent-best"
        win, inc = rr.winner_score, rr.incumbent_score
        if not (win <= (1.0 - cfg.margin) * inc):
            return False, "margin"
        if (self._last_swap_t is not None
                and t - self._last_swap_t < cfg.min_dwell):
            return False, "dwell"
        if math.isinf(win):
            return False, "no-finite-score"
        return True, "promoted"

    # ---- snapshot round-trip ---------------------------------------------- #
    def state(self) -> Dict[str, Any]:
        return {
            "config": dataclasses.asdict(self.config),
            "seed": self.seed,
            "rng": self._rng.bit_generator.state,
            "next_t": self._next_t,
            "last_swap_t": self._last_swap_t,
            "n_fired": self._n_fired,
            "decisions": self.decisions,
        }

    @classmethod
    def from_state(cls, payload: Dict[str, Any]) -> "AutoTuner":
        cfg_pl = dict(payload["config"])
        cfg_pl["policies"] = tuple(cfg_pl.get("policies") or ())
        cfg_pl["periods"] = tuple(float(p)
                                  for p in cfg_pl.get("periods") or ())
        tun = cls(TuneConfig(**cfg_pl), seed=int(payload["seed"]))
        tun._rng.bit_generator.state = payload["rng"]
        nt = payload["next_t"]
        tun._next_t = None if nt is None else float(nt)
        ls = payload["last_swap_t"]
        tun._last_swap_t = None if ls is None else float(ls)
        tun._n_fired = int(payload["n_fired"])
        tun.decisions = [dict(d) for d in payload["decisions"]]
        return tun

    def __repr__(self) -> str:
        return (f"AutoTuner(every={self.config.every:g}, "
                f"portfolio={len(self.config.policies) or 1}, "
                f"decisions={len(self.decisions)}, seed={self.seed})")
