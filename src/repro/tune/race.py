"""Successive-halving races over a live session snapshot.

One race takes a mid-run :class:`~repro.sched.session.SessionState`, a
portfolio of policy/period variants, and an objective, and answers "which
variant digs out of *this exact situation* best?" under a bounded sim-time
budget:

* **rung r** runs every surviving variant from the snapshot over horizon
  ``base_horizon * 2**r`` (``sweep.run_branches`` with ``horizon_s``) and
  scores the partial results;
* between rungs the worst half of the *challengers* is eliminated — the
  incumbent is exempt, so the final rung always compares champion and
  challenger at the same (largest) budget;
* a crashing or hung variant is quarantined by the branch driver and
  scores ``inf`` — it loses the race, it cannot kill it.

Branches race *oracle-free*: the snapshot's chaos narrator is reseeded
with one common ``branch_seed`` across all branches of a rung (common
random numbers — fair comparison, decorrelated from the future the live
session will actually see), and an attached autotuner never recurses into
its own race branches (the snapshot is stripped of tuner state first).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..sched.sweep import _canonical_policy, run_branches
from .score import Objective, parse_objective

__all__ = ["Variant", "RaceResult", "race"]


@dataclass(frozen=True)
class Variant:
    """One portfolio entry: a policy string plus an optional period
    override (``None`` = keep the snapshot's period)."""

    policy: str
    period: Optional[float] = None

    @property
    def label(self) -> str:
        if self.period is None:
            return self.policy
        return f"{self.policy} @period={self.period:g}"

    def key(self) -> Tuple[str, Optional[float]]:
        return (_canonical_policy(self.policy), self.period)

    def to_branch(self) -> Dict[str, Any]:
        return {"policy": self.policy, "period": self.period}


@dataclass
class RaceResult:
    """Outcome of one fork-race: the winner at full budget, the incumbent
    it was judged against, and the per-rung elimination history."""

    winner: Variant
    winner_score: float
    incumbent: Variant
    incumbent_score: float
    objective: str
    horizon_s: float                    # final-rung horizon
    branch_seed: Optional[int]
    rungs: List[Dict[str, Any]] = field(default_factory=list)
    records: List[Dict[str, Any]] = field(default_factory=list)

    @property
    def promoted(self) -> bool:
        return self.winner.key() != self.incumbent.key()


def _strip_tuner(snapshot):
    """A copy of the snapshot without the ``autotune`` key: race branches
    run under the tuner, they must never recursively run one (and the
    branch fingerprint should identify the *cluster* state being raced,
    not the racer)."""
    from ..sched.session import SessionState

    if "autotune" not in snapshot.payload:
        return snapshot
    payload = dict(snapshot.payload)
    payload.pop("autotune")
    return SessionState(payload)


def race(
    snapshot,
    variants: Sequence[Variant],
    incumbent: Variant,
    *,
    objective="max_stretch",
    base_horizon: float,
    rungs: int = 2,
    branch_seed: Optional[int] = None,
    timeout_s: Optional[float] = None,
    retries: int = 0,
    backend: Optional[str] = None,
    n_workers: int = 1,
) -> RaceResult:
    """Race ``variants`` (plus ``incumbent``) from ``snapshot`` and return
    the full-budget winner.

    ``timeout_s``/``retries`` supervise each branch in worker processes —
    robust against hangs but wall-clock-dependent; the default in-process
    mode is fully deterministic (crashes still quarantine, via
    ``run_branches(quarantine=True)``).
    """
    obj: Objective = parse_objective(objective)
    if rungs < 1:
        raise ValueError("race needs at least one rung")
    if base_horizon <= 0:
        raise ValueError("race base_horizon must be > 0")
    snap = _strip_tuner(snapshot)

    alive: List[Variant] = [incumbent]
    seen = {incumbent.key()}
    for v in variants:
        if v.key() not in seen:
            seen.add(v.key())
            alive.append(v)

    result = RaceResult(
        winner=incumbent, winner_score=math.inf,
        incumbent=incumbent, incumbent_score=math.inf,
        objective=obj.name,
        horizon_s=float(base_horizon) * 2 ** (rungs - 1),
        branch_seed=branch_seed)
    cutoff: Optional[float] = None
    records: List[Dict[str, Any]] = []
    scores: List[float] = []
    for r in range(rungs):
        horizon = float(base_horizon) * 2 ** r
        final = r == rungs - 1
        # prune a mid-rung challenger already past the survivors' worst
        # score — only when the objective makes that monotonically final,
        # and never on the final rung (true equal-budget scores decide)
        early = None
        if (cutoff is not None and not final and math.isfinite(cutoff)
                and obj.prunable_by_max_stretch):
            early = {"max_stretch_above": cutoff}
        res = run_branches(
            snap, [v.to_branch() for v in alive],
            horizon_s=horizon, early_stop=early, branch_seed=branch_seed,
            timeout_s=timeout_s, retries=retries, quarantine=True,
            backend=backend, n_workers=n_workers)
        records = res.records
        scores = [obj.score(rec) for rec in records]
        survivors = alive
        if not final:
            challengers = sorted(
                range(1, len(alive)), key=lambda i: (scores[i], i))
            keep = challengers[:max(1, math.ceil(len(challengers) / 2))]
            survivors = [alive[0]] + [alive[i] for i in sorted(keep)]
            kept_scores = [scores[0]] + [scores[i] for i in sorted(keep)]
            finite = [s for s in kept_scores if math.isfinite(s)]
            cutoff = max(finite) if finite else None
        result.rungs.append({
            "rung": r,
            "horizon_s": horizon,
            "variants": [v.label for v in alive],
            "scores": scores,
            "eliminated": [v.label for v in alive if v not in survivors],
        })
        alive = survivors
    result.records = records
    result.incumbent_score = scores[0]
    best = min(range(len(alive)), key=lambda i: (scores[i], i != 0, i))
    result.winner = alive[best]
    result.winner_score = scores[best]
    return result
