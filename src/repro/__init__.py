"""repro — Dynamic Fractional Resource Scheduling vs. Batch Scheduling.

Reproduction of Casanova/Stillwell/Vivien (cs.DC 2011) grown into a
JAX/Pallas-era cluster-scheduling playground.  The supported public
surface is :mod:`repro.api` (also scriptable as ``python -m repro``);
the layer modules (``repro.core``, ``repro.sched``, ``repro.workloads``)
remain importable for fine-grained use.
"""
from __future__ import annotations

__all__ = ["api"]


def __getattr__(name):
    # lazy: `import repro` stays cheap; `repro.api` loads on first touch
    if name == "api":
        import importlib
        return importlib.import_module(".api", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
