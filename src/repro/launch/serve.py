"""Serving launcher: batched continuous-batching decode on a reduced config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
        --requests 16 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, get_reduced
from ..models import backbone
from ..train.serve import BatchedServer, Request, ServeConfig


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    params, _ = backbone.init_params(cfg, jax.random.PRNGKey(args.seed))
    srv = BatchedServer(cfg, params, ServeConfig(
        slots=args.slots, cache_len=args.cache_len,
        temperature=args.temperature, seed=args.seed))

    rng = np.random.default_rng(args.seed)
    reqs = []
    for rid in range(args.requests):
        plen = int(rng.integers(4, min(64, args.cache_len // 2)))
        prompt = rng.integers(1, cfg.vocab, size=plen).astype(np.int32)
        req = Request(rid=rid, prompt=prompt, max_new=args.max_new)
        reqs.append(req)
        srv.submit(req)

    t0 = time.time()
    steps = toks = 0
    while srv.queue or any(r is not None for r in srv.slot_req):
        toks += srv.step()
        steps += 1
        if steps > 100_000:
            raise RuntimeError("serve loop did not drain")
    dt = time.time() - t0
    done = sum(r.done for r in reqs)
    print(f"[serve] {done}/{len(reqs)} requests, {toks} decode-tokens in "
          f"{dt:.1f}s ({toks/max(dt,1e-9):.1f} tok/s), {steps} steps")
    for r in reqs[:3]:
        print(f"  rid={r.rid} prompt[:6]={r.prompt[:6].tolist()} out={r.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
