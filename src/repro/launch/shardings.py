"""Logical-axis -> PartitionSpec rules for the production meshes.

The models annotate every parameter with logical axis names
(``backbone.param_axes``); this module maps those names onto the physical
mesh.  The same rules drive params, optimizer state (ZeRO-1), gradients,
batches and KV caches, so the whole (arch x shape x mesh) matrix is one
table instead of 40 hand-written sharding sets.

Baseline layout (paper-faithful "job shard" = Megatron-style TP + DP):

* ``model`` axis: tensor parallelism — vocab / heads / d_ff / d_expert /
  lru sharded when divisible, replicated otherwise (smollm's 15 heads,
  whisper's 20 heads, 49155/51866 vocabs);
* ``data`` (+ ``pod``) axes: batch;
* FSDP (``fsdp=True``, default for >=20B params): ``d_model`` additionally
  sharded over ``data`` — ZeRO-3-style weight gathering, required to fit
  deepseek-v3-671b / internvl2-76b;
* ZeRO-1 otherwise: optimizer moments get an extra ``data`` sharding on
  their first divisible replicated dim;
* EP (``n_experts % model == 0``): experts go on ``model`` (the all-to-all
  layout); TP over ``d_expert`` otherwise;
* SP: the residual stream between layers is sequence-sharded over ``model``
  (``with_sharding_constraint`` hook in the backbone) — activation memory
  / model_size per chip.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models import backbone
from ..models.config import ModelConfig
from . import mesh as meshmod

__all__ = ["ShardingPlan", "make_plan"]

Pytree = Any


@dataclass(frozen=True)
class Plan:
    """Resolved layout decisions for one (arch, mesh)."""

    cfg: ModelConfig
    mesh: Mesh
    dp: Tuple[str, ...]
    fsdp: bool
    ep: bool
    sp: bool
    rules: Dict[str, Optional[str]]
    # EP over BOTH mesh axes (DeepSeek-style EP-256): expert weights become
    # fully chip-local — no FSDP all-gathers for the expert slab; the only
    # expert collective left is the dispatch/combine all-to-all.
    ep2: bool = False

    # ---- pytree spec builders -------------------------------------------
    def param_specs(self) -> Pytree:
        axes = backbone.param_axes(self.cfg)
        return jax.tree.map(self._axes_to_spec, axes,
                            is_leaf=_is_axes_leaf)

    def opt_moment_specs(self, param_shapes: Pytree, param_specs: Pytree) -> Pytree:
        """ZeRO-1: moments inherit the param spec + an extra dp sharding on
        the first divisible, unsharded dim (no-op under FSDP, where d_model
        already carries ``data``)."""
        dsize = meshmod.dp_size(self.mesh)

        def f(shape, spec):
            dims = list(spec) + [None] * (len(shape.shape) - len(spec))
            if self.fsdp or dsize == 1:
                return P(*dims)
            used = {a for d in dims for a in ((d,) if isinstance(d, str) else (d or ()))}
            if "data" in used:
                return P(*dims)
            for i, d in enumerate(dims):
                if d is None and shape.shape[i] % dsize == 0 and shape.shape[i] > 0:
                    dims[i] = "data"
                    break
            return P(*dims)

        return jax.tree.map(f, param_shapes, param_specs,
                            is_leaf=lambda x: isinstance(x, P))

    def train_state_specs(self, state, factored: bool):
        """Specs for a whole TrainState (params + optimizer moments)."""
        from ..train.optimizer import opt_axes
        pspecs = self.param_specs()
        oax = opt_axes(backbone.param_axes(self.cfg), state.params, factored)
        mu_specs = jax.tree.map(self._axes_to_spec, oax.mu, is_leaf=_is_axes_leaf)
        nu_specs = jax.tree.map(self._axes_to_spec, oax.nu, is_leaf=_is_axes_leaf)
        mu_specs = self.opt_moment_specs(state.opt.mu, mu_specs)
        nu_specs = self.opt_moment_specs(state.opt.nu, nu_specs)
        return type(state)(
            params=pspecs,
            opt=type(state.opt)(step=P(), mu=mu_specs, nu=nu_specs),
            err=None)

    def batch_specs(self, batch_shapes: Dict[str, Any]) -> Dict[str, Any]:
        dp = self.dp if len(self.dp) > 1 else self.dp[0]

        def f(s):
            nd = len(s.shape)
            if s.shape[0] % max(1, meshmod.dp_size(self.mesh)) != 0:
                return P(*([None] * nd))           # e.g. batch 1 (long_500k)
            return P(dp, *([None] * (nd - 1)))

        return {k: f(v) for k, v in batch_shapes.items()}

    def cache_specs(self, cache_shapes: Pytree) -> Pytree:
        """KV/state cache layout: batch on dp, long axes on ``model``.

        Leaves are keyed dicts inside the group list; shapes are
        (layer_count, B, ...).  Sequence axes are model-sharded (flash-
        decode style partial-softmax reduction over ``model``), so a 550 GB
        llama3 decode_32k cache lands at ~2 GB/chip.
        """
        dp = self.dp if len(self.dp) > 1 else self.dp[0]
        msize = self.mesh.shape["model"]

        def leaf(path, s):
            key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
            nd = len(s.shape)
            batch_ok = s.shape[1] % max(1, meshmod.dp_size(self.mesh)) == 0
            b = dp if batch_ok else None
            if key in ("k", "v", "ks", "vs", "ckv", "kr", "ck", "cv"):
                # (L, B, S, ...): shard S over model when divisible
                seq = "model" if s.shape[2] % msize == 0 else None
                return P(None, b, seq, *([None] * (nd - 3)))
            if key == "s":          # rwkv state (L, B, H, dk, dv)
                h = "model" if s.shape[2] % msize == 0 else None
                return P(None, b, h, *([None] * (nd - 3)))
            if key == "h":          # rglru state (L, B, W)
                w = "model" if s.shape[2] % msize == 0 else None
                return P(None, b, w)
            if key == "conv":       # (L, B, 3, W)
                w = "model" if s.shape[3] % msize == 0 else None
                return P(None, b, None, w)
            return P(None, b, *([None] * (nd - 2)))

        # jax.tree.map_with_path only exists on jax >= 0.5
        tree_map_with_path = getattr(jax.tree, "map_with_path", None) \
            or jax.tree_util.tree_map_with_path
        return tree_map_with_path(leaf, cache_shapes)

    def act_spec(self):
        """Residual-stream constraint (B, T, D) for the SP toggle."""
        if not self.sp:
            return None
        dp = self.dp if len(self.dp) > 1 else self.dp[0]
        return P(dp, "model", None)

    def ep_spec(self):
        """MoE dispatch-buffer constraint (G, E, C, D): routing groups ride
        the data axes (rank-local dispatch); experts ride ``model`` under EP
        (GSPMD inserts the dispatch/combine all-to-all at this boundary).
        Under ep2 the experts take BOTH axes and G stays unsharded."""
        if not self.cfg.n_experts:
            return None
        if self.ep2:
            return P(None, tuple(self.dp) + ("model",), None, None)
        dp = self.dp if len(self.dp) > 1 else self.dp[0]
        return P(dp, "model" if self.ep else None, None, None)

    def moe_groups(self) -> int:
        """Routing groups = DP ranks (per-rank dispatch, as in real EP)."""
        from . import mesh as meshmod
        return meshmod.dp_size(self.mesh) if self.cfg.n_experts else 1

    # ---- helpers ----------------------------------------------------------
    def _axes_to_spec(self, axes: Tuple[Optional[str], ...]) -> P:
        dims = [self.rules.get(a) if a else None for a in axes]
        if self.ep2 and "experts" in axes:
            # expert tensors: E takes every mesh axis, other dims local
            # (a mesh axis may appear only once per spec)
            dims = [tuple(self.dp) + ("model",) if a == "experts" else None
                    for a in axes]
        return P(*dims)

    def shard(self, spec_tree: Pytree) -> Pytree:
        return jax.tree.map(
            lambda s: NamedSharding(self.mesh, s), spec_tree,
            is_leaf=lambda x: isinstance(x, P))


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(a is None or isinstance(a, str) for a in x)


def make_plan(
    cfg: ModelConfig,
    mesh: Mesh,
    *,
    fsdp: Optional[bool] = None,
    ep: Optional[bool] = None,
    sp: bool = True,
    ep2: Optional[bool] = None,
) -> Plan:
    """Resolve the layout for (arch, mesh).  ``None`` flags = auto."""
    msize = mesh.shape["model"]
    dsize = meshmod.dp_size(mesh)
    nparams = cfg.param_count()
    if fsdp is None:
        fsdp = nparams > 2e10
    e_alloc = cfg.n_experts + cfg.n_experts_pad
    if ep is None:
        ep = cfg.n_experts > 0 and e_alloc % msize == 0
    if ep2 is None:
        ep2 = False       # beyond-paper hillclimb toggle (see EXPERIMENTS.md)
    if ep2 and e_alloc % (msize * dsize) != 0:
        ep2 = False
    if ep2:
        ep = True

    div = lambda n: (n % msize == 0 and n > 0)
    rules: Dict[str, Optional[str]] = {
        "vocab": "model" if div(cfg.vocab) else None,
        "heads": "model" if div(cfg.n_heads) else None,
        "kv_heads": "model" if div(cfg.n_kv_heads) else None,
        "d_ff": "model" if div(cfg.d_ff) else None,
        "d_shared": "model" if div(cfg.d_shared) else None,
        "lru": "model" if div(cfg.lru_width) else None,
        "head_dim": None,
        "layers": None,
        "q_lora": None,
        "kv_lora": None,
        "d_model": "data" if (fsdp and cfg.d_model % dsize == 0) else None,
    }
    if cfg.n_experts:
        if ep:
            rules["experts"] = "model"
            rules["d_expert"] = None
        else:
            rules["experts"] = None
            rules["d_expert"] = "model" if div(cfg.d_expert) else None
    else:
        rules["experts"] = rules["d_expert"] = None

    return Plan(cfg=cfg, mesh=mesh, dp=meshmod.dp_axes(mesh),
                fsdp=fsdp, ep=ep, sp=sp, rules=rules, ep2=ep2)
