"""Roofline terms from a compiled dry-run artifact.

Hardware model (TPU v5e target):
    peak bf16 compute   197 TFLOP/s / chip
    HBM bandwidth       819 GB/s / chip
    ICI link bandwidth  ~50 GB/s / link / chip

Terms (seconds, lower bound per step):
    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = per-chip collective payload / ICI_BW

``cost_analysis`` supplies FLOPs and bytes.  Collective bytes are NOT in
cost_analysis: we parse the post-optimization HLO and, for every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
model the per-chip payload from the op's result shape, its replica-group
size and the standard ring-algorithm factor.
"""
from __future__ import annotations

import math
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

__all__ = ["HW", "CollectiveStats", "parse_collectives", "roofline_terms"]

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link / chip


@dataclass
class HW:
    chips: int
    peak_flops: float = PEAK_FLOPS
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW


_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# "bf16[2,16,128]{2,1,0} all-gather(" etc.  Result type precedes the op name.
_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, total: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))                   # [n_groups, group_size]
    m = _GROUPS_RE.search(line)
    if m:
        first = m.group(1).strip("{}")
        return len([x for x in first.split(",") if x.strip()]) or total
    return total


@dataclass
class CollectiveStats:
    """Byte accounting for one compiled program (per chip)."""

    op_bytes: Dict[str, float] = field(default_factory=dict)   # raw result bytes
    op_counts: Dict[str, int] = field(default_factory=dict)
    payload_bytes: float = 0.0      # ring-modeled per-chip traffic
    raw_bytes: float = 0.0          # plain sum of result-shape bytes

    def add(self, kind: str, nbytes: float, group: int) -> None:
        self.op_bytes[kind] = self.op_bytes.get(kind, 0.0) + nbytes
        self.op_counts[kind] = self.op_counts.get(kind, 0) + 1
        self.raw_bytes += nbytes
        g = max(group, 1)
        ring = (g - 1) / g
        if kind == "all-reduce":
            self.payload_bytes += 2.0 * ring * nbytes
        elif kind == "all-gather":
            self.payload_bytes += ring * nbytes            # result = gathered
        elif kind == "reduce-scatter":
            self.payload_bytes += ring * nbytes * g        # result = scattered
        elif kind == "all-to-all":
            self.payload_bytes += ring * nbytes
        else:                                               # collective-permute
            self.payload_bytes += nbytes


def parse_collectives(hlo_text: str, total_devices: int) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        tuple_body, dtype, dims, kind = m.groups()
        if tuple_body is not None:
            nbytes = sum(_shape_bytes(dt, dd)
                         for dt, dd in _SHAPE_RE.findall(tuple_body))
        else:
            nbytes = _shape_bytes(dtype, dims)
        stats.add(kind, float(nbytes), _group_size(line, total_devices))
    return stats


def roofline_terms(
    flops: float,
    bytes_accessed: float,
    coll: CollectiveStats,
    hw: HW,
) -> Dict[str, float]:
    """All three terms in seconds + bottleneck id.

    ``flops``/``bytes_accessed`` are whole-program totals (cost_analysis);
    collective payload is already per-chip.
    """
    compute = flops / (hw.chips * hw.peak_flops)
    memory = bytes_accessed / (hw.chips * hw.hbm_bw)
    collective = coll.payload_bytes / hw.ici_bw
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]).replace("_s", "")
    terms["step_s"] = max(compute, memory, collective)
    return terms
