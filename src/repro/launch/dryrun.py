import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST precede any jax-touching import: jax locks the device count on init.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell the step program (train_step / prefill / decode) is jit-ed
with the full production sharding plan, ``.lower().compile()`` is run on the
512-virtual-device CPU backend, and the artifact — memory analysis, HLO
cost analysis, and the collective-op byte ledger — is written to
``experiments/dryrun/<arch>__<shape>__<mesh>.json`` for the roofline tables
(benchmarks/roofline.py) and the DFRS TPU job generator
(repro.workloads.jobgen).

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import ARCHS, SHAPES, ShapeSpec, get_config, shape_applicable
from ..models import backbone
from ..models.config import ModelConfig
from ..train.optimizer import OptConfig
from ..train.trainer import init_train_state, make_train_step
from . import mesh as meshmod
from . import roofline
from .shardings import Plan, make_plan

DEFAULT_OUT = "experiments/dryrun"

# Per-cell knobs (microbatches for train; compute dtype).  Tuned in the
# EXPERIMENTS.md SSPerf loop; defaults are the paper-faithful baseline.
PRESETS: Dict[Tuple[str, str], Dict[str, Any]] = {}


def preset(arch: str, shape: str) -> Dict[str, Any]:
    base = {"microbatches": 1, "dtype": jnp.bfloat16, "sp": True,
            "fsdp": None, "ep": None, "ep2": None, "remat": True,
            "factored": None, "kv_int8": False}
    base.update(PRESETS.get(("*", "*"), {}))
    base.update(PRESETS.get((arch, shape), {}))
    return base


# --------------------------------------------------------------------------- #
# input specs                                                                  #
# --------------------------------------------------------------------------- #
def batch_shapes(cfg: ModelConfig, B: int, T: int) -> Dict[str, jax.ShapeDtypeStruct]:
    out = {"tokens": jax.ShapeDtypeStruct((B, T), jnp.int32)}
    if cfg.is_encdec:
        out["enc_embeds"] = jax.ShapeDtypeStruct((B, 1500, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "vision":
        nv = min(cfg.n_frontend_tokens or 256, T // 2)
        out["vision_embeds"] = jax.ShapeDtypeStruct((B, nv, cfg.d_model), jnp.bfloat16)
    return out


def auto_factored(cfg: ModelConfig) -> bool:
    """Adafactor moments for >=100B-param models (HBM fit; DESIGN.md SS7)."""
    return cfg.param_count() > 1e11


def input_specs(arch: str, shape_name: str, *, dtype=jnp.bfloat16,
                factored: Optional[bool] = None):
    """ShapeDtypeStruct stand-ins for every model input of one cell —
    weak-type-correct, shardable, zero device allocation."""
    return input_specs_for(get_config(arch), shape_name, dtype=dtype,
                           factored=factored)


def input_specs_for(cfg: ModelConfig, shape_name: str, *, dtype=jnp.bfloat16,
                    factored: Optional[bool] = None, kv_int8: bool = False):
    shape = SHAPES[shape_name]
    B, T = shape.global_batch, shape.seq_len
    cache_dtype = jnp.int8 if kv_int8 else dtype
    if shape.kind == "train":
        fact = auto_factored(cfg) if factored is None else factored
        state = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.PRNGKey(0), dtype=dtype,
                                     factored=fact))
        return {"state": state, "batch": batch_shapes(cfg, B, T)}
    if shape.kind == "prefill":
        params = jax.eval_shape(
            lambda: backbone.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype)[0])
        caches = jax.eval_shape(
            lambda: backbone.init_cache(cfg, B, T,
                                        S_enc=1500 if cfg.is_encdec else 0,
                                        dtype=cache_dtype))
        return {"params": params, "batch": batch_shapes(cfg, B, T), "caches": caches}
    # decode: one new token against a T-long cache
    params = jax.eval_shape(
        lambda: backbone.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype)[0])
    caches = jax.eval_shape(
        lambda: backbone.init_cache(cfg, B, T,
                                    S_enc=1500 if cfg.is_encdec else 0,
                                    dtype=cache_dtype))
    return {
        "params": params,
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "caches": caches,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


# --------------------------------------------------------------------------- #
# step builders                                                                #
# --------------------------------------------------------------------------- #
def build_step(cfg: ModelConfig, shape: ShapeSpec, plan: Plan, knobs):
    """(fn, in_specs, in_shardings, donate) for the cell."""
    # resolve ``factored`` against the FULL config once so the shallow
    # extrapolation points build the same optimizer-state structure
    fact = knobs.get("factored")
    if fact is None:
        fact = auto_factored(get_config(cfg.name))
    specs = input_specs_for(cfg, shape.name, dtype=knobs["dtype"],
                            factored=fact, kv_int8=knobs.get("kv_int8", False))
    backbone.set_act_spec(plan.act_spec())
    backbone.set_ep_spec(plan.ep_spec())
    from ..models import moe
    moe.set_groups(plan.moe_groups())

    pspecs = plan.param_specs()
    if shape.kind == "train":
        opt_cfg = OptConfig(factored=fact)
        fn = make_train_step(cfg, opt_cfg, microbatches=knobs["microbatches"],
                             remat=knobs["remat"])
        state = specs["state"]
        state_sh = plan.train_state_specs(state, fact)
        in_sh = (state_sh, plan.batch_specs(specs["batch"]))
        args = (state, specs["batch"])
        return fn, args, in_sh, (state_sh, None), (0,)
    if shape.kind == "prefill":
        def fn(params, batch, caches):
            return backbone.prefill(cfg, params, batch, caches)
        csh = plan.cache_specs(specs["caches"])
        in_sh = (pspecs, plan.batch_specs(specs["batch"]), csh)
        args = (specs["params"], specs["batch"], specs["caches"])
        return fn, args, in_sh, (None, csh), (2,)
    # decode
    def fn(params, tokens, caches, pos):
        return backbone.decode_step(cfg, params, tokens, caches, pos)
    csh = plan.cache_specs(specs["caches"])
    tok_sh = list(plan.batch_specs(
        {"tokens": jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)}).values())[0]
    in_sh = (pspecs, tok_sh, csh, P())
    args = (specs["params"], specs["tokens"], specs["caches"], specs["pos"])
    return fn, args, in_sh, (None, csh), (2,)


# --------------------------------------------------------------------------- #
# depth extrapolation                                                          #
#                                                                              #
# XLA's cost_analysis counts a while-loop (lax.scan) body ONCE regardless of   #
# trip count, so the scanned production program under-reports FLOPs / bytes /  #
# collectives by ~depth x.  Per-layer costs are affine in the layer count, so  #
# we lower *unrolled* shallow variants (1 and 2 periods of the layer pattern,  #
# prefix layers kept) and extrapolate:  F(k) = c0 + c1*k (+ c2*enc_layers).    #
# The full-depth scanned program is still compiled — that is the deliverable   #
# (sharding coherence + memory_analysis); only the cost numbers come from      #
# the extrapolation.                                                           #
# --------------------------------------------------------------------------- #
def _measure_point(cfg: ModelConfig, shape: ShapeSpec, plan: Plan, knobs) -> Dict:
    """Lower+compile one unrolled shallow variant; return per-device costs.

    Everything (state, caches, shardings) is built for the *shallow* config —
    optimizer/param cost is affine in depth too, so the slope/intercept solve
    still recovers the exact full-depth totals."""
    plan = make_plan(cfg, plan.mesh, fsdp=plan.fsdp, ep=plan.ep, sp=plan.sp,
                     ep2=plan.ep2)
    backbone.set_unroll(True)
    try:
        fn, args, in_sh, out_sh, _ = build_step(cfg, shape, plan, knobs)
        jitted = jax.jit(fn, in_shardings=plan.shard(in_sh),
                         out_shardings=plan.shard(out_sh) if out_sh else None)
        compiled = jitted.lower(*_treeify(args)).compile()
    finally:
        backbone.set_unroll(False)
    cost = compiled.cost_analysis()
    chips = int(np.prod(list(plan.mesh.shape.values())))
    coll = roofline.parse_collectives(compiled.as_text(), chips)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_payload": coll.payload_bytes,
        "coll_raw": coll.raw_bytes,
        "coll_ops": coll.op_bytes,
    }


def _combine(points: List[Dict], weights: List[float]) -> Dict:
    """Linear combination of measurement dicts."""
    out: Dict[str, Any] = {}
    for key in ("flops", "bytes", "coll_payload", "coll_raw"):
        out[key] = max(0.0, sum(w * p[key] for p, w in zip(points, weights)))
    ops: Dict[str, float] = {}
    for p, w in zip(points, weights):
        for k, v in p["coll_ops"].items():
            ops[k] = ops.get(k, 0.0) + w * v
    out["coll_ops"] = {k: max(0.0, v) for k, v in ops.items()}
    return out


def extrapolate_costs(cfg: ModelConfig, shape: ShapeSpec, plan: Plan, knobs) -> Dict:
    """True per-device cost estimates for the full-depth model."""
    p = len(cfg.attn_pattern) or 1
    prefix = cfg.first_dense
    k_full = (cfg.n_layers - prefix) / p
    mk = lambda nl, ne: dataclasses.replace(
        cfg, n_layers=nl, encoder_layers=ne)
    knobs = dict(knobs, microbatches=1)   # grad-accum scan has the same bug

    ne0 = 1 if cfg.encoder_layers else 0
    f1 = _measure_point(mk(prefix + p, ne0), shape, plan, knobs)
    f2 = _measure_point(mk(prefix + 2 * p, ne0), shape, plan, knobs)
    # F = c0 + c1*k (+ c2*ne):  c1 = F2-F1;  c0 = F1 - c1 - c2*ne0
    if cfg.encoder_layers:
        f3 = _measure_point(mk(prefix + p, 2), shape, plan, knobs)
        # c2 = F3-F1; F_full = F1 + c1*(k_full-1) + c2*(ne_full-1)
        est = _combine(
            [f1, f2, f3],
            [1.0 - (k_full - 1.0) - (cfg.encoder_layers - 1.0),
             (k_full - 1.0), (cfg.encoder_layers - 1.0)])
    else:
        est = _combine([f1, f2], [1.0 - (k_full - 1.0), (k_full - 1.0)])
    est["k_full"] = k_full
    est["period"] = p
    return est


# --------------------------------------------------------------------------- #
# one cell                                                                     #
# --------------------------------------------------------------------------- #
def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: Optional[str] = DEFAULT_OUT, verbose: bool = True,
             extrap: bool = True) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "multi" if multi_pod else "single"
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "seq_len": shape.seq_len,
        "global_batch": shape.global_batch,
    }
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        record["status"] = "skipped"
        record["reason"] = why
        _dump(record, out_dir)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: SKIP ({why})")
        return record

    knobs = preset(arch, shape_name)
    if knobs.get("ep_pad"):
        cfg = dataclasses.replace(cfg, n_experts_pad=int(knobs["ep_pad"]))
    mesh = meshmod.make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    plan = make_plan(cfg, mesh, fsdp=knobs["fsdp"], ep=knobs["ep"],
                     sp=knobs["sp"], ep2=knobs["ep2"])
    record["plan"] = {"fsdp": plan.fsdp, "ep": plan.ep, "sp": plan.sp,
                      "ep2": plan.ep2,
                      "rules": {k: v for k, v in plan.rules.items() if v}}
    t0 = time.time()
    try:
        with mesh:
            fn, args, in_sh, out_sh, donate = build_step(cfg, shape, plan, knobs)
            jitted = jax.jit(fn, in_shardings=plan.shard(in_sh),
                             out_shardings=plan.shard(out_sh) if out_sh else None,
                             donate_argnums=donate)
            lowered = jitted.lower(*_treeify(args))
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
    finally:
        backbone.set_act_spec(None)
        backbone.set_ep_spec(None)
        __import__("repro.models.moe", fromlist=["moe"]).set_groups(1)

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = roofline.parse_collectives(hlo, chips)

    # exact per-device costs via unrolled shallow extrapolation (the scanned
    # program's cost_analysis under-counts loop bodies)
    t1 = time.time()
    if extrap:
        with mesh:
            try:
                est = extrapolate_costs(cfg, shape, plan, knobs)
            finally:
                backbone.set_act_spec(None)
                backbone.set_ep_spec(None)
                from ..models import moe
                moe.set_groups(1)
    else:        # multi-pod pass: compile-only (roofline table is single-pod)
        est = {"flops": float(cost.get("flops", 0.0)),
               "bytes": float(cost.get("bytes accessed", 0.0)),
               "coll_payload": coll.payload_bytes, "coll_raw": coll.raw_bytes,
               "coll_ops": coll.op_bytes}
    t_extrap = time.time() - t1

    hw = roofline.HW(chips=chips)
    flops_global = est["flops"] * chips
    bytes_global = est["bytes"] * chips
    est_coll = roofline.CollectiveStats(
        op_bytes=est["coll_ops"], payload_bytes=est["coll_payload"],
        raw_bytes=est["coll_raw"])
    terms = roofline.roofline_terms(flops_global, bytes_global, est_coll, hw)
    mflops = model_flops(cfg, shape)

    record.update({
        "status": "ok",
        "chips": chips,
        "extrapolated": extrap,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "extrapolate_s": round(t_extrap, 2),
        "flops": flops_global,
        "bytes_accessed": bytes_global,
        "flops_scanned_raw": float(cost.get("flops", 0.0)) * chips,
        "memory_analysis": _mem_dict(mem),
        "collectives": {
            "per_op_bytes": est["coll_ops"],
            "per_op_counts_scanned": coll.op_counts,
            "raw_bytes": est["coll_raw"],
            "payload_bytes_per_chip": est["coll_payload"],
        },
        "roofline": terms,
        "model_flops": mflops,
        "model_vs_hlo_flops": mflops / flops_global if flops_global else 0.0,
        "knobs": {k: str(v) for k, v in knobs.items()},
    })
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s extrap {t_extrap:.1f}s)")
        print(f"  memory_analysis: {record['memory_analysis']}")
        print(f"  cost_analysis (extrapolated, global): flops={flops_global:.3e} "
              f"bytes={bytes_global:.3e} model/hlo={record['model_vs_hlo_flops']:.3f}")
        print(f"  collectives: {coll.op_counts} payload/chip={est['coll_payload']:.3e}B")
        print(f"  roofline: { {k: (f'{v:.4g}' if isinstance(v, float) else v) for k, v in terms.items()} }")
    _dump(record, out_dir)
    return record


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = active params), 2*N*D for a
    forward-only step (prefill/decode)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch            # one token / request


def _mem_dict(mem) -> Dict[str, float]:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = float(v)
    if not out and isinstance(mem, dict):
        out = {k: float(v) for k, v in mem.items()}
    return out


def _treeify(args):
    return args if isinstance(args, tuple) else (args,)


def _dump(record: Dict, out_dir: Optional[str]) -> None:
    if out_dir is None:
        return
    os.makedirs(out_dir, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(record, f, indent=1)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--no-extrap", action="store_true",
                    help="compile-only (no shallow cost extrapolation)")
    ap.add_argument("--set", action="append", default=[],
                    help="knob override k=v (microbatches=8, ep2=1, sp=0...)"
                         " applied to every cell in this invocation")
    args = ap.parse_args()
    for kv in args.set:
        k, v = kv.split("=", 1)
        cast = {"microbatches": int, "ep_pad": int}.get(
            k, lambda x: bool(int(x)))
        PRESETS.setdefault(("*", "*"), {})[k] = cast(v)

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    from ..configs import ALIASES
    norm = lambda a: ALIASES.get(a, a.replace("-", "_"))
    cells = ([(a, s) for a in ARCHS for s in SHAPES] if args.all
             else [(norm(args.arch), args.shape)])
    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "multi" if mp else "single"
            path = os.path.join(args.out, f"{arch}__{shape}__{mesh_name}.json")
            if args.skip_existing and os.path.exists(path):
                try:
                    if json.load(open(path)).get("status") in ("ok", "skipped"):
                        print(f"[dryrun] {arch} x {shape} x {mesh_name}: cached")
                        continue
                except Exception:
                    pass
            try:
                run_cell(arch, shape, mp, out_dir=args.out,
                         extrap=not args.no_extrap)
            except Exception as e:  # noqa: BLE001 — report, continue sweep
                failures += 1
                print(f"[dryrun] {arch} x {shape} x {mesh_name}: FAIL {e!r}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
