"""Launchers: production mesh, sharding plans, dry-run, train/serve CLIs.

NOTE: ``dryrun`` sets XLA_FLAGS at import; do not import it from code that
wants the real device count (tests, benches).  ``mesh``/``shardings`` are
safe to import anywhere.
"""
from . import mesh, roofline, shardings  # noqa: F401
