"""Training launcher.

On real hardware this runs under the production mesh; on this CPU container
it runs reduced configs on the single local device (the full configs are
exercised via the dry-run).  The launcher is the DFRS *job* side: it
checkpoints on schedule and restarts from the newest checkpoint, which is
exactly the pause/resume contract the scheduler (repro.sched) relies on.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_reduced
from ..models.config import reduce_config
from ..train import checkpoint as ckpt
from ..train.data import data_for
from ..train.ft import FailureInjector, run_restartable
from ..train.optimizer import OptConfig
from ..train.trainer import init_train_state, make_train_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU container)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--factored", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failures", default="",
                    help="comma-separated steps at which to fail (FT demo)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                        total_steps=args.steps, factored=args.factored)
    data = data_for(cfg, args.batch, args.seq, seed=args.seed,
                    n_enc=64 if args.reduced else None)
    step_fn = jax.jit(make_train_step(
        cfg, opt_cfg, microbatches=args.microbatches,
        compress_grads=args.compress_grads))

    def new_state():
        return init_train_state(cfg, jax.random.PRNGKey(args.seed),
                                compress=args.compress_grads,
                                factored=args.factored)

    if args.ckpt_dir:
        fails = tuple(int(x) for x in args.inject_failures.split(",") if x)
        rep = run_restartable(
            train_step=step_fn, init_state=new_state,
            batch_for_step=data.batch_for_step, total_steps=args.steps,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
            injector=FailureInjector(at_steps=fails) if fails else None)
        print(f"[train] done: step {rep.final_step}, {rep.n_restarts} restarts, "
              f"loss {rep.losses[0]:.4f} -> {rep.losses[-1]:.4f}, "
              f"stragglers {rep.straggler.n_stragglers}")
        return 0

    state = new_state()
    t0 = time.time()
    for i in range(args.steps):
        state, m = step_fn(state, data.batch_for_step(i))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"[train] step {i:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"({(time.time()-t0)/(i+1)*1e3:.0f} ms/step)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
