"""Production meshes.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "dp_axes", "DP_SINGLE", "DP_MULTI"]

DP_SINGLE = ("data",)
DP_MULTI = ("pod", "data")


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a production mesh ('pod' included)."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh) -> int:
    out = 1
    for a in dp_axes(mesh):
        out *= mesh.shape[a]
    return out
