"""Pallas TPU kernel: blocked RG-LRU linear recurrence (Griffin).

h_t = a_t * h_{t-1} + b_t, elementwise over the LRU width.  The width is
tiled into VPU-lane-aligned blocks (block_w), time into chunks (block_t)
swept by the sequential innermost grid dimension with the (block_w,) state
in VMEM scratch.  Inside a chunk the recurrence runs as an unrolled
log-depth prefix combine over the time axis (Blelloch-style), so the kernel
issues O(log block_t) fused elementwise ops instead of block_t dependent
steps — the VPU-friendly formulation of a diagonal linear RNN.

Oracle: ``ref.linear_recurrence_ref`` (associative scan).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    def _scratch(shape, dtype):
        return pltpu.VMEM(shape, dtype)
except Exception:  # pragma: no cover
    def _scratch(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)


def _rglru_kernel(a_ref, b_ref, h0_ref, y_ref, hT_ref, h_ref,
                  *, block_t: int, nt: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)          # (bt, bw)
    b = b_ref[0].astype(jnp.float32)
    h = h_ref[...]                            # (bw,)

    # fold carry into the first step, then log-depth inclusive scan
    b = b.at[0].add(a[0] * h)
    aa, bb = a, b
    shift = 1
    while shift < block_t:
        aa_s = jnp.concatenate([jnp.ones_like(aa[:shift]), aa[:-shift]], axis=0)
        bb_s = jnp.concatenate([jnp.zeros_like(bb[:shift]), bb[:-shift]], axis=0)
        bb = aa * bb_s + bb
        aa = aa * aa_s
        shift *= 2

    y_ref[0] = bb.astype(y_ref.dtype)
    h_ref[...] = bb[-1]

    @pl.when(it == nt - 1)
    def _done():
        hT_ref[0] = bb[-1].astype(hT_ref.dtype)


def rglru_scan(a, b, h0, *, block_t: int = 128, block_w: int = 512,
               interpret: bool = True):
    """a, b: (B, T, W); h0: (B, W).  Returns (h: (B,T,W) fp32, hT)."""
    B, T, W = a.shape
    block_t = min(block_t, T)
    while T % block_t:
        block_t //= 2
    block_w = min(block_w, W)
    while W % block_w:
        block_w //= 2
    nt, nw = T // block_t, W // block_w

    kernel = functools.partial(_rglru_kernel, block_t=block_t, nt=nt)
    grid = (B * nw, nt)

    def idx_tw(g, it):
        return (g // nw, it, g % nw)

    def idx_w(g, it):
        return (g // nw, g % nw)

    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_w), idx_tw),
            pl.BlockSpec((1, block_t, block_w), idx_tw),
            pl.BlockSpec((1, block_w), idx_w),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_w), idx_tw),
            pl.BlockSpec((1, block_w), idx_w),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[_scratch((block_w,), jnp.float32)],
        interpret=interpret,
    )(a, b, h0)
    return y, hT
