"""Pure-jnp oracles for every Pallas kernel (and the CPU execution path).

These are the ground truth the kernel sweep tests assert against, and what
models execute on CPU / lower in the dry-run (bounded-memory formulations).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..models.layers import chunked_attention, decode_attention
# the scheduler matvec oracle lives next to its kernel (it is also imported
# by repro.core.alloc_jax, which must not pull the model stack in)
from .alloc_matvec import alloc_matvec_ref

__all__ = [
    "flash_attention_ref", "flash_decode_ref", "wkv6_ref",
    "linear_recurrence_ref", "alloc_matvec_ref",
]


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0,
                        scale=None, chunk=1024):
    return chunked_attention(q, k, v, causal=causal, window=window,
                             q_offset=q_offset, scale=scale, chunk=chunk)


def flash_decode_ref(q, k_cache, v_cache, cur_len, *, scale=None):
    return decode_attention(q, k_cache, v_cache, cur_len, scale=scale)


def wkv6_ref(r, k, v, w, u, s0):
    """Exact sequential RWKV6 WKV recurrence (the oracle).

    r, k, w: (B, T, H, dk); v: (B, T, H, dv); u: (H, dk);
    s0: (B, H, dk, dv) fp32.  Returns (y: (B,T,H,dv) fp32, sT).

        y_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
        S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                          # (B,H,dk/dv)
        kv = k_t[..., :, None] * v_t[..., None, :]        # (B,H,dk,dv)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + uf[None, :, :, None] * kv)
        s_new = w_t[..., None] * s + kv
        return s_new, y

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (rf, kf, vf, wf))
    sT, ys = lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2, 3), sT


def linear_recurrence_ref(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t (elementwise), h_0 from carry.

    a, b: (B, T, W); h0: (B, W).  Returns (h: (B,T,W), hT: (B,W)) in fp32.
    Uses an associative scan (parallel depth log T).
    """
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    bf = bf.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def comb(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(comb, (af, bf), axis=1)
    return h, h[:, -1]
