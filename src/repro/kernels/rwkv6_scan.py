"""Pallas TPU kernel: chunked RWKV6 (Finch) WKV scan.

TPU adaptation of the data-dependent-decay recurrence: instead of a
length-T sequential scan (latency-bound on the VPU), time is split into
chunks of ``block_t``; within a chunk the contribution is computed with two
MXU matmuls (intra-chunk "attention" with decay-scaled r'/k' and the
carry-in state product), and the (dk x dv) state is carried across chunks in
VMEM scratch over the sequential innermost grid dimension.

    la_i   = cumsum(log w)_i          (per channel, fp32)
    r'_i   = r_i * exp(la_{i-1}),  k'_j = k_j * exp(-la_j)
    att    = tril(r' k'^T, -1) + diag(sum r_i u k_i)
    y      = att @ v + r' @ S_in
    S_out  = diag(exp(la_T)) S_in + (k * exp(la_T - la))^T @ v

Bounded exp arguments require modest block_t (default 32); validated against
the exact sequential oracle ``ref.wkv6_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu

    def _scratch(shape, dtype):
        return pltpu.VMEM(shape, dtype)
except Exception:  # pragma: no cover
    def _scratch(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                y_ref, sT_ref, s_ref, *, block_t: int, nt: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        s_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)        # (bt, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)        # (bt, dv)
    w = w_ref[0, 0].astype(jnp.float32)        # (bt, dk) decay in (0,1)
    u = u_ref[0].astype(jnp.float32)           # (dk,)
    s = s_ref[...]                             # (dk, dv)

    la = jnp.cumsum(jnp.log(jnp.maximum(w, 1e-30)), axis=0)   # (bt, dk)
    la_prev = la - jnp.log(jnp.maximum(w, 1e-30))             # exclusive
    r_s = r * jnp.exp(la_prev)
    k_s = k * jnp.exp(-la)

    att = r_s @ k_s.T                                          # (bt, bt)
    bt = att.shape[0]
    row = lax.broadcasted_iota(jnp.int32, (bt, bt), 0)
    col = lax.broadcasted_iota(jnp.int32, (bt, bt), 1)
    att = jnp.where(col < row, att, 0.0)
    att = att + jnp.diag(jnp.sum(r * u[None] * k, axis=-1))

    y = att @ v + r_s @ s                                      # (bt, dv)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    la_T = la[-1]
    s_new = jnp.exp(la_T)[:, None] * s + (k * jnp.exp(la_T[None] - la)).T @ v
    s_ref[...] = s_new

    @pl.when(it == nt - 1)
    def _done():
        sT_ref[0, 0] = s_new.astype(sT_ref.dtype)


def wkv6(r, k, v, w, u, s0, *, block_t: int = 32, interpret: bool = True):
    """r,k,w: (B,T,H,dk); v: (B,T,H,dv); u: (H,dk); s0: (B,H,dk,dv) fp32.

    Returns (y: (B,T,H,dv) fp32, sT: (B,H,dk,dv) fp32).
    """
    B, T, H, dk = r.shape
    dv = v.shape[-1]
    block_t = min(block_t, T)
    while T % block_t:
        block_t //= 2
    nt = T // block_t

    tr = lambda x: x.transpose(0, 2, 1, 3)        # (B,H,T,d)
    kernel = functools.partial(_wkv_kernel, block_t=block_t, nt=nt)
    y, sT = pl.pallas_call(
        kernel,
        grid=(B, H, nt),
        in_specs=[
            pl.BlockSpec((1, 1, block_t, dk), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, block_t, dk), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, block_t, dv), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, block_t, dk), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, dk), lambda b, h, it: (h, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, it: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_t, dv), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, dk, dv), lambda b, h, it: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, dv), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dk, dv), jnp.float32),
        ],
        scratch_shapes=[_scratch((dk, dv), jnp.float32)],
        interpret=interpret,
    )(tr(r), tr(k), tr(v), tr(w), u, s0)
    return y.transpose(0, 2, 1, 3), sT
