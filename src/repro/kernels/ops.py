"""jit-friendly kernel entry points with backend selection.

Models call these; the backend is chosen once per process:

* ``"ref"``     — pure-jnp oracles (CPU execution, dry-run lowering; the
                  default off-TPU so compiled HLO stays backend-portable);
* ``"pallas"``  — Pallas kernels, ``interpret=True`` off-TPU (correctness
                  validation) and compiled on real TPU.

Gradients always flow through the ref formulation (``custom_vjp`` with the
oracle backward), which keeps training correct while the forward hot-path
uses the kernel.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref as _ref

_BACKEND = "ref"


def set_backend(name: str) -> None:
    global _BACKEND
    if name not in ("ref", "pallas"):
        raise ValueError(name)
    global _BACKEND
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# --------------------------------------------------------------------------- #
# flash attention                                                              #
# --------------------------------------------------------------------------- #
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, scale: Optional[float] = None):
    if _BACKEND == "pallas":
        from .flash_attention import flash_attention as fa

        fwd = functools.partial(fa, causal=causal, window=window,
                                q_offset=q_offset, scale=scale,
                                interpret=_interpret())
        ref_fn = functools.partial(_ref.flash_attention_ref, causal=causal,
                                   window=window, q_offset=q_offset, scale=scale)

        @jax.custom_vjp
        def op(q, k, v):
            return fwd(q, k, v)

        def op_fwd(q, k, v):
            return fwd(q, k, v), (q, k, v)

        def op_bwd(res, g):
            _, vjp = jax.vjp(ref_fn, *res)
            return vjp(g)

        op.defvjp(op_fwd, op_bwd)
        return op(q, k, v)
    return _ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                    q_offset=q_offset, scale=scale)


def flash_decode(q, k_cache, v_cache, cur_len, *, scale: Optional[float] = None):
    if _BACKEND == "pallas":
        from .flash_attention import flash_decode as fd

        return fd(q, k_cache, v_cache, cur_len, scale=scale,
                  interpret=_interpret())
    return _ref.flash_decode_ref(q, k_cache, v_cache, cur_len, scale=scale)


# --------------------------------------------------------------------------- #
# RWKV6 WKV scan                                                               #
# --------------------------------------------------------------------------- #
def wkv6(r, k, v, w, u, s0):
    if _BACKEND == "pallas" and r.shape[1] > 1:
        from .rwkv6_scan import wkv6 as kk

        fwd = functools.partial(kk, interpret=_interpret())

        @jax.custom_vjp
        def op(r, k, v, w, u, s0):
            return fwd(r, k, v, w, u, s0)

        def op_fwd(*args):
            return fwd(*args), args

        def op_bwd(res, g):
            _, vjp = jax.vjp(_ref.wkv6_ref, *res)
            return vjp(g)

        op.defvjp(op_fwd, op_bwd)
        return op(r, k, v, w, u, s0)
    return _ref.wkv6_ref(r, k, v, w, u, s0)


# --------------------------------------------------------------------------- #
# scheduler allocation matvec (§4.6 water-filling inner loop)                  #
# --------------------------------------------------------------------------- #
def alloc_matvec(weight, x):
    """Sequential masked matvec over job columns — bit-exact vs the numpy
    CSR accumulation (see ``kernels/alloc_matvec.py``).  No custom_vjp: the
    scheduler path is forward-only f64 arithmetic, never differentiated."""
    if _BACKEND == "pallas":
        from .alloc_matvec import alloc_matvec as kk

        return kk(weight, x, interpret=_interpret())
    return _ref.alloc_matvec_ref(weight, x)


# --------------------------------------------------------------------------- #
# RG-LRU linear recurrence                                                     #
# --------------------------------------------------------------------------- #
def linear_recurrence(a, b, h0):
    if _BACKEND == "pallas" and a.shape[1] > 1:
        from .rglru_scan import rglru_scan as kk

        fwd = functools.partial(kk, interpret=_interpret())

        @jax.custom_vjp
        def op(a, b, h0):
            return fwd(a, b, h0)

        def op_fwd(*args):
            return fwd(*args), args

        def op_bwd(res, g):
            _, vjp = jax.vjp(_ref.linear_recurrence_ref, *res)
            return vjp(g)

        op.defvjp(op_fwd, op_bwd)
        return op(a, b, h0)
    return _ref.linear_recurrence_ref(a, b, h0)
