"""Pallas TPU flash attention (forward + single-token decode).

TPU-native blocking: queries are tiled (block_q x head_dim) in VMEM, the
KV range is swept by the innermost grid dimension (block_k), and the online
softmax state (running max m, normalizer l, accumulator acc) lives in VMEM
scratch that persists across the KV sweep — the standard MXU-friendly
flash schedule.  GQA-aware: one kernel instance serves the G = H/Hkv query
heads of one KV head, so K/V tiles are loaded once per group.

Causal + sliding-window masking is applied per tile from the grid indices;
fully-masked tiles still execute (documented trade-off; skipping them is a
future hillclimb).  Validated on CPU with ``interpret=True`` against
``ref.flash_attention_ref``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:                                 # TPU scratch memory spaces
    from jax.experimental.pallas import tpu as pltpu

    def _scratch(shape, dtype):
        return pltpu.VMEM(shape, dtype)
except Exception:                    # pragma: no cover - CPU-only fallback
    def _scratch(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype)

_NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                 causal: bool, window: int, q_offset: int,
                 scale: float, block_q: int, block_k: int, nk: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, hdv)
    s = jnp.einsum("gqh,kh->gqk", q, k) * scale      # (G, bq, bk)

    row = q_offset + iq * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    col = ik * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), dtype=bool)
    if causal:
        mask &= col <= row
    if window > 0:
        mask &= col > row - window
    s = jnp.where(mask[None], s, _NEG_INF)

    m_prev = m_ref[...]                              # (G, bq)
    m_new = jnp.maximum(m_prev, s.max(-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum("gqk,kh->gqh", p, v)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    scale: Optional[float] = None,
    block_q: int = 256,
    block_k: int = 512,
    interpret: bool = True,
):
    """q: (B, Tq, H, hd); k, v: (B, Tk, Hkv, hd[, hdv]) -> (B, Tq, H, hdv)."""
    B, Tq, H, hd = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Tq)
    block_k = min(block_k, Tk)
    while Tq % block_q:
        block_q //= 2
    while Tk % block_k:
        block_k //= 2
    nq, nk = Tq // block_q, Tk // block_k

    qg = q.reshape(B, Tq, Hkv, G, hd).transpose(0, 2, 3, 1, 4)   # (B,Hkv,G,Tq,hd)
    kg = k.transpose(0, 2, 1, 3)                                  # (B,Hkv,Tk,hd)
    vg = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _attn_kernel, causal=causal, window=window, q_offset=q_offset,
        scale=scale, block_q=block_q, block_k=block_k, nk=nk,
    )
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, block_q, hd), lambda b, h, iq, ik: (b, h, 0, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hdv), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, block_q, hdv), lambda b, h, iq, ik: (b, h, 0, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, Tq, hdv), q.dtype),
        scratch_shapes=[
            _scratch((G, block_q), jnp.float32),
            _scratch((G, block_q), jnp.float32),
            _scratch((G, block_q, hdv), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kg, vg)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, hdv)


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_k: int, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    cur_len = len_ref[0]
    q = q_ref[0, 0].astype(jnp.float32)              # (G, hd)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, hdv)
    s = jnp.einsum("gh,kh->gk", q, k) * scale        # (G, bk)
    col = ik * block_k + lax.broadcasted_iota(jnp.int32, (1, block_k), 1)[0]
    s = jnp.where(col < cur_len + 1, s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_ref[...] = l_ref[...] * alpha + p.sum(-1)
    acc_ref[...] = acc_ref[...] * alpha[..., None] + jnp.einsum("gk,kh->gh", p, v)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_decode(
    q, k_cache, v_cache, cur_len, *,
    scale: Optional[float] = None,
    block_k: int = 512,
    interpret: bool = True,
):
    """q: (B, H, hd); caches: (B, S, Hkv, hd) -> (B, H, hdv)."""
    B, H, hd = q.shape
    S, Hkv = k_cache.shape[1], k_cache.shape[2]
    hdv = v_cache.shape[-1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_k = min(block_k, S)
    while S % block_k:
        block_k //= 2
    nk = S // block_k

    qg = q.reshape(B, Hkv, G, hd)
    kg = k_cache.transpose(0, 2, 1, 3)
    vg = v_cache.transpose(0, 2, 1, 3)
    # scalar or per-request (B,) cur_len (continuous batching)
    lens = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (b,)),
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hdv), lambda b, h, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hdv), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hdv), q.dtype),
        scratch_shapes=[
            _scratch((G,), jnp.float32),
            _scratch((G,), jnp.float32),
            _scratch((G, hdv), jnp.float32),
        ],
        interpret=interpret,
    )(lens, qg, kg, vg)
    return out.reshape(B, H, hdv)
