"""Pallas kernel for the water-filling inner matvec (§4.6, batched).

The batched max-min water-filling (``repro.core.alloc_jax``) spends its
rounds in one primitive: a *sequential* masked matvec — for every lane and
node, accumulate ``weight[n, j] * x[j]`` over job columns ``j`` in strictly
ascending order.  The order is the bit-identity contract: the numpy oracle
(``CSRIncidence.matvec``) accumulates left to right, so any reformulation
(pairwise ``jnp.sum``, ``dot``) rounds differently.

Both implementations here keep that contract, in the same two-step shape:

1. materialize every product with one vectorized multiply, **outside** the
   accumulation loop;
2. run an adds-only ``fori_loop`` over columns.

Step 1 is not a style choice — it is what makes the result bit-exact.  XLA
CPU contracts a ``mul`` feeding an ``add`` inside one loop body into a
single-rounding FMA (``fma(a, b, acc)`` instead of ``round(a*b) + acc``),
which is 1 ulp off the numpy sequence on ~12% of operand triples, and
``lax.optimization_barrier`` does not prevent it.  A multiply whose result
crosses the ``fori_loop``/``pallas`` computation boundary cannot be
contracted, and an adds-only loop reproduces numpy's operation sequence
exactly (padding columns contribute an exact ``+0.0``, which never changes
a finite partial sum).  ``tests/test_alloc_jax.py`` pins this down.

Following the ``kernels/ops.py`` pattern: ``interpret=True`` off-TPU
(CPU validation), compiled on real TPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

__all__ = ["alloc_matvec", "alloc_matvec_ref"]


def alloc_matvec_ref(weight, x):
    """Sequential masked matvec, pure jnp (the oracle formulation).

    weight: (B, N, W); x: (B, W).  Returns (B, N): per-lane per-node
    left-to-right accumulation of ``weight[b, n, j] * x[b, j]`` over j.
    """
    weight, x = jnp.asarray(weight), jnp.asarray(x)  # numpy in → traceable
    B, N, W = weight.shape
    if W == 0:                              # static: fori_loop traces its
        return jnp.zeros((B, N), weight.dtype)  # body even over 0 columns
    prods = weight * x[:, None, :]          # one multiply, materialized
    def body(j, acc):
        return acc + prods[:, :, j]         # adds only: no FMA contraction
    return lax.fori_loop(0, W, body, jnp.zeros((B, N), weight.dtype))


def _mv_kernel(w_ref, x_ref, o_ref):
    w = w_ref[0]                            # (N, W)
    x = x_ref[0]                            # (W,)
    prods = w * x[None, :]                  # separate multiply (see module doc)
    N, W = w.shape
    def body(j, acc):
        return acc + prods[:, j]
    o_ref[0] = lax.fori_loop(0, W, body, jnp.zeros((N,), w.dtype))


def alloc_matvec(weight, x, *, interpret: bool = True):
    """Pallas version of :func:`alloc_matvec_ref`: grid over lanes, one
    sequential accumulation per (lane, node) block."""
    weight, x = jnp.asarray(weight), jnp.asarray(x)
    B, N, W = weight.shape
    if W == 0:
        return jnp.zeros((B, N), weight.dtype)
    return pl.pallas_call(
        _mv_kernel,
        out_shape=jax.ShapeDtypeStruct((B, N), weight.dtype),
        grid=(B,),
        in_specs=[pl.BlockSpec((1, N, W), lambda b: (b, 0, 0)),
                  pl.BlockSpec((1, W), lambda b: (b, 0))],
        out_specs=pl.BlockSpec((1, N), lambda b: (b, 0)),
        interpret=interpret,
    )(weight, x)
