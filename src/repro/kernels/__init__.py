"""Pallas TPU kernels for the workload substrate's compute hot-spots.

The paper's contribution is a *scheduler* (no kernel-level contribution), so
``kernels/`` serves the model substrate: flash attention (train/prefill +
decode), the RWKV6 chunked WKV scan, and the RG-LRU linear recurrence.
``ops`` is the backend-switching entry point; ``ref`` holds the pure-jnp
oracles every kernel is validated against (interpret mode on CPU).
"""
from . import ops, ref

__all__ = ["ops", "ref"]
