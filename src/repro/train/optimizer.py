"""Pure-JAX AdamW optimizer with the trimmings a production trainer needs.

No optax dependency: state is a plain pytree (works transparently under
pjit — optimizer state inherits the parameter sharding, i.e. ZeRO-1-style
sharded optimizer state falls out of ``out_shardings`` in the launcher).

* global-norm gradient clipping,
* decoupled weight decay (skipped for 1-D tensors: norms/biases),
* warmup + cosine-decay schedule,
* optional int8 gradient compression hook (repro.train.compression) applied
  before the DP all-reduce when microbatching.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "OptState", "init_opt_state", "adamw_update",
           "adafactor_update", "update", "opt_axes", "lr_schedule",
           "global_norm"]

Pytree = Any


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # Adafactor mode: factored second moment for ndim>=2 tensors + bf16 first
    # moment.  Drops optimizer-state bytes from 8/param to ~2/param — what
    # makes deepseek-v3-671b fit 16 GiB HBM chips (see DESIGN.md SS7).
    factored: bool = False


class OptState(NamedTuple):
    step: jnp.ndarray       # int32 scalar
    mu: Pytree              # first moment
    nu: Pytree              # second moment (factored: {"vr","vc"} per leaf)


def _factored_leaf(p) -> bool:
    return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1


def init_opt_state(params: Pytree, factored: bool = False) -> OptState:
    if not factored:
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params),
            nu=jax.tree.map(zeros, params),
        )

    def nu_leaf(p):
        if _factored_leaf(p):
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return jnp.zeros_like(p, dtype=jnp.float32)

    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(lambda p: jnp.zeros_like(p, jnp.bfloat16), params),
        nu=jax.tree.map(nu_leaf, params),
    )


def opt_axes(param_axes: Pytree, param_shapes: Pytree, factored: bool = False):
    """Logical-axes tree for OptState (drives sharding like param_axes)."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x)
    if not factored:
        return OptState(step=(), mu=param_axes, nu=param_axes)

    def nu_axes(ax, shape):
        if _factored_leaf(shape):
            return {"vr": ax[:-1], "vc": ax[:-2] + ax[-1:]}
        return ax

    return OptState(
        step=(),
        mu=param_axes,
        nu=jax.tree.map(nu_axes, param_axes, param_shapes, is_leaf=is_axes),
    )


def lr_schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup then cosine decay to ``min_lr_frac * lr``."""
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    t = (s - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree: Pytree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(
    cfg: OptConfig,
    params: Pytree,
    grads: Pytree,
    state: OptState,
) -> Tuple[Pytree, OptState, Dict[str, jnp.ndarray]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    lr = lr_schedule(cfg, state.step)

    mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda n, g: cfg.b2 * n + (1 - cfg.b2) * g * g, state.nu, grads)

    def upd(p, m, n):
        mhat = m / b1c
        nhat = n / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:                        # decoupled decay, no 1-D tensors
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, mu, nu), metrics


def adafactor_update(
    cfg: OptConfig,
    params: Pytree,
    grads: Pytree,
    state: OptState,
) -> Tuple[Pytree, OptState, Dict[str, jnp.ndarray]]:
    """Adafactor (Shazeer & Stern 2018) with bf16 first moment.

    Factored second moment for >=2-D tensors, per-tensor update clipping,
    decoupled weight decay — the optimizer-state footprint that lets 671 B
    parameters train on 16 GiB chips."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta2 = 1.0 - t ** (-0.8)                    # Adafactor's schedule
    lr = lr_schedule(cfg, state.step)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        g2 = g * g + 1e-30
        if isinstance(v, dict):                  # factored
            vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(axis=-1)
            vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(axis=-2)
            denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
            vhat = (vr / denom)[..., None] * vc[..., None, :]
            v_new = {"vr": vr, "vc": vc}
        else:
            vhat = beta2 * v + (1 - beta2) * g2
            v_new = vhat
        u = g * jax.lax.rsqrt(vhat + cfg.eps)
        # RMS update clipping (threshold 1.0)
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        m_new = (cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * u)
        delta = m_new
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p_new, m_new.astype(jnp.bfloat16), v_new

    is_v_leaf = lambda x: isinstance(x, dict) and set(x) == {"vr", "vc"}
    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step, new_mu, new_nu), metrics


def update(cfg: OptConfig, params, grads, state):
    """Dispatch on cfg.factored."""
    if cfg.factored:
        return adafactor_update(cfg, params, grads, state)
    return adamw_update(cfg, params, grads, state)
