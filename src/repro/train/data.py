"""Deterministic synthetic data pipeline.

Offline container: no real corpora.  The pipeline is nevertheless shaped like
a production one — sharded, stateless-resumable, and deterministic:

* ``batch_for_step(step)`` is a pure function of (seed, step, shape), so a
  restarted trainer regenerates exactly the batch it crashed on (checkpoint
  only needs the step counter — the same property real pipelines get from
  deterministic samplers + skip counts);
* tokens follow a Zipf-like unigram draw (more realistic logits/loss decay
  than uniform) with document boundaries every ``doc_len`` positions;
* per-modality extras (``enc_embeds``/``vision_embeds`` stub frontends) are
  generated alongside.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.config import ModelConfig

__all__ = ["DataConfig", "SyntheticData"]


@dataclass(frozen=True)
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0
    doc_len: int = 512
    zipf_a: float = 1.2
    n_enc_tokens: int = 0     # >0: audio frames (whisper stub)
    n_vis_tokens: int = 0     # >0: vision patches (internvl stub)


class SyntheticData:
    """Stateless deterministic batch source (step -> batch)."""

    def __init__(self, cfg: DataConfig, model: ModelConfig):
        self.cfg = cfg
        self.model = model
        # static Zipf unigram distribution over the vocab
        ranks = np.arange(1, model.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._probs = jnp.asarray(p / p.sum(), dtype=jnp.float32)

    def batch_for_step(self, step: int) -> Dict[str, jnp.ndarray]:
        c, m = self.cfg, self.model
        key = jax.random.fold_in(jax.random.PRNGKey(c.seed), step)
        kt, ke, kv = jax.random.split(key, 3)
        tokens = jax.random.choice(
            kt, m.vocab, shape=(c.batch, c.seq_len), p=self._probs
        ).astype(jnp.int32)
        # document boundaries: BOS token 0 at every doc_len-th position
        pos = jnp.arange(c.seq_len)
        tokens = jnp.where((pos % c.doc_len == 0)[None, :], 0, tokens)
        out = {"tokens": tokens}
        if c.n_enc_tokens:
            out["enc_embeds"] = 0.02 * jax.random.normal(
                ke, (c.batch, c.n_enc_tokens, m.d_model), jnp.float32)
        if c.n_vis_tokens:
            out["vision_embeds"] = 0.02 * jax.random.normal(
                kv, (c.batch, c.n_vis_tokens, m.d_model), jnp.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_for_step(step)
            step += 1


def data_for(model: ModelConfig, batch: int, seq_len: int, seed: int = 0,
             n_enc: Optional[int] = None) -> SyntheticData:
    """Data source with the right stub-frontend extras for ``model``.

    ``n_enc``: number of encoder frames for enc-dec models (default 1500,
    whisper's 30-s log-mel frame count after the conv stub; pass a small
    value for reduced smoke configs)."""
    if n_enc is None:
        n_enc = 1500 if model.is_encdec else 0
    n_enc = n_enc if model.is_encdec else 0
    n_vis = model.n_frontend_tokens if model.frontend == "vision" else 0
    n_vis = min(n_vis, max(1, seq_len // 2)) if n_vis else 0
    return SyntheticData(
        DataConfig(batch=batch, seq_len=seq_len, seed=seed,
                   n_enc_tokens=n_enc, n_vis_tokens=n_vis), model)
