"""Training/serving substrate: optimizer, data, checkpoint, FT, serving."""
from . import checkpoint, compression, data, ft, optimizer, serve, trainer  # noqa: F401
from .optimizer import OptConfig                                            # noqa: F401
from .trainer import TrainState, init_train_state, make_train_step         # noqa: F401
