"""Sharded-pytree checkpointing with atomic commits and async save.

Layout (one directory per step):

    ckpt_dir/
      step_000123/
        arrays.npz        # flattened pytree leaves (key = escaped tree path)
        manifest.json     # treedef + dtypes/shapes + user metadata
      LATEST              # text file: "step_000123" (atomically replaced)

Guarantees:
* a checkpoint directory becomes visible only when complete (tmp + rename);
* LATEST is updated after the directory rename — a crash anywhere leaves the
  previous checkpoint intact (restart-safety for repro.train.ft);
* ``save_async`` runs serialization off the training thread (device->host
  transfer happens synchronously, the disk write does not);
* restore validates shapes/dtypes against an optional template pytree.

On a multi-host cluster each host writes its own addressable shards under
``host_<k>/`` (same protocol); this container is single-host so that path
degenerates to one directory.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "wait_pending"]

Pytree = Any

_pending: list[threading.Thread] = []


def _flatten(tree: Pytree) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    return arrays, treedef


def _step_dir(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:09d}")


def save(ckpt_dir: str, step: int, tree: Pytree, metadata: Optional[Dict] = None) -> str:
    """Synchronous atomic save.  Returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    arrays, treedef = _flatten(tree)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "n_leaves": len(arrays),
        "metadata": metadata or {},
    }
    final = _step_dir(ckpt_dir, step)
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=ckpt_dir)
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # commit LATEST last (atomic rename of a small file)
    ptr = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(ptr, "w") as f:
        f.write(os.path.basename(final))
    os.replace(ptr, os.path.join(ckpt_dir, "LATEST"))
    return final


def save_async(ckpt_dir: str, step: int, tree: Pytree,
               metadata: Optional[Dict] = None) -> threading.Thread:
    """Device->host transfer now; disk write on a background thread."""
    host_tree = jax.tree.map(np.asarray, tree)   # blocks on transfer only
    t = threading.Thread(
        target=save, args=(ckpt_dir, step, host_tree, metadata), daemon=True)
    t.start()
    _pending.append(t)
    return t


def wait_pending() -> None:
    while _pending:
        _pending.pop().join()


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[-1])


def restore(ckpt_dir: str, template: Optional[Pytree] = None,
            step: Optional[int] = None) -> Tuple[int, Pytree, Dict]:
    """Load (step, tree, metadata).  With ``template``, the stored leaves are
    validated and restored into the template's treedef."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = _step_dir(ckpt_dir, step)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(d, "arrays.npz")) as z:
        leaves = [z[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
    if template is not None:
        t_leaves, treedef = jax.tree.flatten(template)
        if len(t_leaves) != len(leaves):
            raise ValueError(
                f"checkpoint has {len(leaves)} leaves, template {len(t_leaves)}")
        for i, (a, b) in enumerate(zip(leaves, t_leaves)):
            if tuple(a.shape) != tuple(np.shape(b)):
                raise ValueError(f"leaf {i}: shape {a.shape} != {np.shape(b)}")
        tree = jax.tree.unflatten(treedef, leaves)
    else:
        tree = leaves
    return manifest["step"], tree, manifest.get("metadata", {})
