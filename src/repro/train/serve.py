"""Batched serving driver: prefill + decode with a continuous-batching queue.

The serving analogue of the trainer: requests arrive with prompts, get
packed into fixed-shape decode slots (the compiled ``serve_step`` shape never
changes — one (B, cache_len) program), finished slots are refilled from the
queue.  Greedy or temperature sampling.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import backbone
from ..models.config import ModelConfig

__all__ = ["Request", "ServeConfig", "BatchedServer"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new: int = 32
    out: List[int] = field(default_factory=list)
    done: bool = False


@dataclass(frozen=True)
class ServeConfig:
    slots: int = 4                     # decode batch
    cache_len: int = 256
    temperature: float = 0.0           # 0 -> greedy
    eos_id: int = -1                   # -1 -> never stop on token
    seed: int = 0


class BatchedServer:
    """Continuous batching over a fixed slot count.

    Production notes: prefill runs per-request at a bucketed length (one
    compiled program per bucket); decode is a single fixed-shape program.
    Slot admission is FCFS — scheduling *between* models/jobs is DFRS's job
    (repro.sched), not the server's.
    """

    def __init__(self, cfg: ModelConfig, params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.caches = backbone.init_cache(cfg, scfg.slots, scfg.cache_len)
        self.pos = np.zeros(scfg.slots, dtype=np.int32)       # next position
        self.slot_req: List[Optional[Request]] = [None] * scfg.slots
        self.queue: List[Request] = []
        self.last_tok = np.zeros(scfg.slots, dtype=np.int32)
        self._rng = jax.random.PRNGKey(scfg.seed)
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl, static_argnums=(2,))

    # ---- compiled pieces -------------------------------------------------
    def _prefill_impl(self, tokens, caches_slot, true_len: int):
        """Prefill one request into a single-slot cache pytree."""
        batch = {"tokens": tokens[None, :]}
        if self.cfg.is_encdec:
            batch["enc_embeds"] = jnp.zeros((1, 8, self.cfg.d_model))
        logits, caches = backbone.prefill(self.cfg, self.params, batch, caches_slot)
        return logits[0], caches

    def _decode_impl(self, tokens, caches, pos):
        logits, caches = backbone.decode_step(
            self.cfg, self.params, tokens, caches, pos)
        return logits, caches

    # ---- queue management --------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def _admit(self) -> None:
        for slot in self._free_slots():
            if not self.queue:
                return
            req = self.queue.pop(0)
            L = len(req.prompt)
            assert L < self.scfg.cache_len, "prompt longer than cache"
            # cache leaves are (layer_count, B, ...): batch is axis 1
            slot_cache = jax.tree.map(lambda c: c[:, slot:slot + 1], self.caches)
            tokens = jnp.asarray(req.prompt, jnp.int32)
            logits, slot_cache = self._prefill(tokens, slot_cache, L)
            self.caches = jax.tree.map(
                lambda c, s: c.at[:, slot:slot + 1].set(s), self.caches, slot_cache)
            tok = int(self._sample(logits))
            req.out.append(tok)                 # first generated token
            if len(req.out) >= req.max_new or tok == self.scfg.eos_id:
                req.done = True
                continue
            self.slot_req[slot] = req
            self.pos[slot] = L
            self.last_tok[slot] = tok

    def _sample(self, logits) -> int:
        if self.scfg.temperature <= 0.0:
            return int(jnp.argmax(logits))
        self._rng, k = jax.random.split(self._rng)
        return int(jax.random.categorical(k, logits / self.scfg.temperature))

    # ---- main loop ---------------------------------------------------------
    def step(self) -> int:
        """One decode step over all occupied slots.  Returns #active slots."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        # decode positions differ per slot; the compiled program takes the
        # max and each slot's cache was written at its own position, so we
        # decode per unique position group (fixed shape, B = slots).
        tokens = jnp.asarray(self.last_tok, jnp.int32)
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.caches = self._decode(tokens, self.caches, pos)
        for i in active:
            req = self.slot_req[i]
            tok = self._sample(logits[i])
            req.out.append(tok)
            self.last_tok[i] = tok
            self.pos[i] += 1
            if (len(req.out) >= req.max_new
                    or tok == self.scfg.eos_id
                    or self.pos[i] >= self.scfg.cache_len):
                req.done = True
                self.slot_req[i] = None
        return len(active)

    def run_until_drained(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
        return done
