"""train_step / eval_step factories.

``make_train_step`` builds the jit-able step used by the launcher, the
examples and the dry-run.  Features:

* gradient accumulation over ``microbatches`` via ``lax.scan`` (keeps the
  HLO size constant in the accumulation depth);
* optional int8 gradient compression of the accumulated gradient before the
  optimizer (error feedback carried in the step state) — the distributed-
  optimization knob for DP meshes: under pjit the compressed representative
  is what crosses the data axis;
* bf16 compute with f32 master weights is the caller's choice via the
  ``params`` dtype (optimizer state is always f32).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..models import backbone
from ..models.config import ModelConfig
from .compression import compress_int8, decompress_int8
from . import optimizer
from .optimizer import OptConfig, OptState, init_opt_state

__all__ = ["TrainState", "make_train_step", "make_eval_step", "init_train_state"]

Pytree = Any


class TrainState(NamedTuple):
    params: Pytree
    opt: OptState
    err: Optional[Pytree]      # int8-compression error feedback (or None)


def init_train_state(cfg: ModelConfig, rng, dtype=jnp.float32,
                     compress: bool = False, factored: bool = False) -> TrainState:
    params, _ = backbone.init_params(cfg, rng, dtype=dtype)
    err = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params) if compress else None
    return TrainState(params=params, opt=init_opt_state(params, factored), err=err)


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: OptConfig,
    microbatches: int = 1,
    remat: bool = True,
    compress_grads: bool = False,
) -> Callable[[TrainState, Dict[str, jnp.ndarray]], Tuple[TrainState, Dict[str, jnp.ndarray]]]:
    """Build ``(state, batch) -> (state, metrics)``.

    ``batch["tokens"]``: (B, T); with ``microbatches=k`` the batch is split
    into k slices along B and gradients are accumulated with a scan.
    """

    def loss_fn(params, batch):
        loss, metrics = backbone.lm_loss(cfg, params, batch, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def split_mb(batch):
        def f(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        return jax.tree.map(f, batch)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(state.params, batch)
        else:
            mbs = split_mb(batch)

            def body(acc, mb):
                (l, m), g = grad_fn(state.params, mb)
                acc_g, acc_l = acc
                return (jax.tree.map(jnp.add, acc_g, g), acc_l + l), m

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (gsum, lsum), ms = lax.scan(body, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = jax.tree.map(lambda x: x[-1], ms)

        err = state.err
        if compress_grads:
            # int8 + error feedback: quantize (grad + carried error); the
            # residual goes back into the carry.  Under a DP mesh the int8
            # representative is the all-reduced payload.
            comp, err = compress_int8(jax.tree.map(jnp.add, grads, err))
            grads = decompress_int8(comp)

        new_params, new_opt, opt_metrics = optimizer.update(
            opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt, err), metrics

    return train_step


def make_eval_step(cfg: ModelConfig, remat: bool = False):
    def eval_step(params, batch):
        loss, metrics = backbone.lm_loss(cfg, params, batch, remat=remat)
        return {"loss": loss, **metrics}
    return eval_step
