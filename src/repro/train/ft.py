"""Fault tolerance: restartable training loop, failure injection, straggler
mitigation hooks.

This is the runtime half of the paper's preemption/migration machinery on
the TPU adaptation: DFRS pauses a job = the job checkpoints and exits; DFRS
resumes/migrates = the job restarts from the latest checkpoint on a (possibly
different) slice.  ``run_restartable`` implements the job-side contract:

* checkpoint every ``ckpt_every`` steps (async) + on SIGTERM-like requests;
* on (re)start, resume from the newest complete checkpoint — and because
  the data pipeline is deterministic in the step counter, the trajectory is
  bit-identical to an uninterrupted run;
* a ``FailureInjector`` drives chaos tests (raise at step k / random rate);
* straggler detection: per-step wall-time EMA; steps slower than
  ``straggler_factor``x the EMA are counted and surfaced so a cluster-level
  scheduler can re-place the job (on real pods this feeds DFRS's migration
  trigger; see repro.sched.cluster).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from . import checkpoint as ckpt

__all__ = ["FailureInjector", "RunReport", "run_restartable", "StragglerStats"]


class InjectedFailure(RuntimeError):
    """A simulated node failure."""


@dataclass
class FailureInjector:
    """Deterministic failure schedule: fail the run when the *global* step
    first reaches each entry of ``at_steps`` (each fires once)."""

    at_steps: Tuple[int, ...] = ()
    _fired: set = field(default_factory=set)

    def maybe_fail(self, step: int) -> None:
        if step in self._fired:
            return
        if step in self.at_steps:
            self._fired.add(step)
            raise InjectedFailure(f"injected node failure at step {step}")


@dataclass
class StragglerStats:
    ema: float = 0.0
    n_steps: int = 0
    n_stragglers: int = 0
    worst_ratio: float = 1.0

    def observe(self, dt: float, factor: float = 3.0, beta: float = 0.9) -> bool:
        self.n_steps += 1
        if self.ema == 0.0:
            self.ema = dt
            return False
        is_straggler = dt > factor * self.ema
        if is_straggler:
            self.n_stragglers += 1
            self.worst_ratio = max(self.worst_ratio, dt / self.ema)
            # do not pollute the EMA with the outlier
        else:
            self.ema = beta * self.ema + (1 - beta) * dt
        return is_straggler


@dataclass
class RunReport:
    final_step: int
    n_restarts: int
    losses: List[float]
    straggler: StragglerStats
    restored_from: List[int]


def run_restartable(
    train_step: Callable[[Any, Any], Tuple[Any, Dict]],
    init_state: Callable[[], Any],
    batch_for_step: Callable[[int], Any],
    total_steps: int,
    ckpt_dir: str,
    ckpt_every: int = 50,
    injector: Optional[FailureInjector] = None,
    max_restarts: int = 16,
    straggler_factor: float = 3.0,
) -> RunReport:
    """Run ``total_steps`` of training, surviving injected failures by
    restarting from the newest checkpoint."""
    losses: List[float] = []
    restored_from: List[int] = []
    strag = StragglerStats()
    restarts = 0

    while True:
        # ---- (re)start: restore or init ---------------------------------
        state = init_state()
        start = ckpt.latest_step(ckpt_dir)
        if start is not None:
            _, state, _ = ckpt.restore(ckpt_dir, template=state)
            restored_from.append(start)
            step = start
        else:
            step = 0
        try:
            while step < total_steps:
                if injector is not None:
                    injector.maybe_fail(step)
                t0 = time.perf_counter()
                state, metrics = train_step(state, batch_for_step(step))
                loss = float(metrics["loss"])
                dt = time.perf_counter() - t0
                strag.observe(dt, straggler_factor)
                losses.append(loss)
                step += 1
                if step % ckpt_every == 0 or step == total_steps:
                    ckpt.save_async(ckpt_dir, step, state,
                                    metadata={"loss": loss})
            ckpt.wait_pending()
            return RunReport(step, restarts, losses, strag, restored_from)
        except InjectedFailure:
            restarts += 1
            ckpt.wait_pending()
            if restarts > max_restarts:
                raise
