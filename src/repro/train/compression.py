"""Gradient compression for data-parallel all-reduce.

int8 per-tensor symmetric quantization with error feedback (1-bit-Adam-style
residual carry).  Under pjit the quantized tensor is what crosses the ``data``
axis; at 512 chips the DP all-reduce payload drops 4x (f32) / 2x (bf16).

The compression is deliberately simple and exactly invertible in structure
(scale carried alongside), so tests can assert the error-feedback invariant:
    decompress(compress(g + e)) + e' == g + e   (up to quantization rounding)
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Compressed", "compress_int8", "decompress_int8"]

Pytree = Any


class Compressed(NamedTuple):
    q: Pytree        # int8 tensors
    scale: Pytree    # f32 scalars


def _q_one(g: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_int8(grads: Pytree) -> Tuple[Compressed, Pytree]:
    """Quantize; return (compressed, new_error_feedback)."""
    qs = jax.tree.map(_q_one, grads)
    q = jax.tree.map(lambda t: t[0], qs, is_leaf=lambda x: isinstance(x, tuple))
    scale = jax.tree.map(lambda t: t[1], qs, is_leaf=lambda x: isinstance(x, tuple))
    deq = decompress_int8(Compressed(q, scale))
    err = jax.tree.map(lambda g, d: g.astype(jnp.float32) - d, grads, deq)
    return Compressed(q, scale), err


def decompress_int8(comp: Compressed) -> Pytree:
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, comp.q, comp.scale)
