"""Wire protocol + deterministic op semantics for the session server.

One JSON object per line over a TCP stream (the same JSONL convention as
the ``session`` CLI).  Requests carry::

    {"id": 7, "tenant": "acme", "op": "step_until", "session": "s0",
     "seq": 3, "t": 3600.0}

and responses echo the ``id``::

    {"id": 7, "ok": true, ...payload}                    # success
    {"id": 7, "ok": false, "code": "...", "error": "…"}  # failure

``op`` semantics are split into:

* **mutating ops** (:data:`MUTATING_OPS`) — they advance simulation
  state, are journaled *before* application, and carry a per-session
  monotonically increasing ``seq``.  Re-sending an already-applied seq is
  answered ``{"ok": true, "dup": true}`` without re-applying, which makes
  client retries after a connection loss (or a server ``kill -9`` +
  restart) exactly-once: the journal replay plus seq dedup reproduce the
  uninterrupted run bit for bit.  Every mutating response — success,
  dup or error — also carries ``next_seq``, the session's authoritative
  next expected seq, so clients resync instead of guessing whether a
  failed op consumed one (a journaled op that the engine rejected did).
* **read-only ops** (``observe``/``result``/``snapshot``/``stats``/…) —
  never journaled, no seq.
* ``delete`` — reclamation: forget a *closed* session (registry entry +
  snapshot/journal files), freeing its name for reuse.  Not journaled —
  its effect is removing the journal — and naturally idempotent (a
  repeat answers ``unknown-session``).

Everything a mutating op does must be a *deterministic* function of its
journaled ``(op, args)`` — that is what makes crash recovery a replay.
:func:`build_session` and :func:`apply_op` are that function, shared by
the live dispatch path and the journal-replay path so the two can never
drift.
"""
from __future__ import annotations

import json
import re
from typing import Any, Dict

from ..core.job import JobSpec
from ..sched.session import SimSession, open_session

SCHEMA = "repro.serve/v1"

#: ops that advance session state; journaled with a per-session ``seq``
MUTATING_OPS = frozenset({
    "open", "submit", "step_until", "step", "run", "inject", "period",
    "tune", "close",
})
#: ops that only read (or persist a checkpoint of) existing state
READ_OPS = frozenset({"observe", "result", "snapshot"})
#: tenant/server-level ops outside any session
CONTROL_OPS = frozenset({"hello", "ping", "stats", "shutdown"})

#: error codes a client can branch on
E_BAD_REQUEST = "bad-request"          # malformed frame / unknown op
E_ADMISSION = "admission-denied"       # queue full / tenant over limits
E_OVER_BUDGET = "over-budget"          # credit budget exhausted this window
E_UNKNOWN_SESSION = "unknown-session"
E_SESSION_CLOSED = "session-closed"
E_SEQ_GAP = "seq-gap"                  # seq from the future: lost request
E_OP_ERROR = "op-error"                # the op itself raised (deterministic)

_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9_.\-]{0,63}$")


class ProtocolError(ValueError):
    """A request the server refuses; carries a machine-readable code."""

    def __init__(self, code: str, message: str):
        super().__init__(message)
        self.code = code


def check_name(kind: str, name: Any) -> str:
    """Tenant and session names become directory/file names in the
    snapshot store — constrain them to a path-safe alphabet."""
    if not isinstance(name, str) or not _NAME_RE.match(name):
        raise ProtocolError(
            E_BAD_REQUEST,
            f"invalid {kind} name {name!r}: need 1-64 chars of "
            f"[A-Za-z0-9_.-], starting alphanumeric")
    return name


def encode(obj: Dict[str, Any]) -> bytes:
    return (json.dumps(obj, separators=(",", ":")) + "\n").encode()


def decode(line: bytes) -> Dict[str, Any]:
    try:
        obj = json.loads(line)
    except json.JSONDecodeError as exc:
        raise ProtocolError(E_BAD_REQUEST, f"undecodable frame: {exc}")
    if not isinstance(obj, dict):
        raise ProtocolError(E_BAD_REQUEST, "frame must be a JSON object")
    return obj


def error_response(req_id: Any, code: str, message: str) -> Dict[str, Any]:
    return {"id": req_id, "ok": False, "code": code, "error": message}


# --------------------------------------------------------------------------- #
# deterministic op semantics (shared by live dispatch and journal replay)      #
# --------------------------------------------------------------------------- #
def build_session(args: Dict[str, Any]) -> SimSession:
    """Materialize an ``open`` op: a fresh session from journaled args.

    Deterministic: policy strings, node counts, param overrides and the
    (seeded) narrator spec fully determine the session.
    """
    overrides = {k: args[k] for k in ("period", "penalty") if k in args}
    ses = open_session(int(args.get("nodes", 64)), args["policy"],
                       **overrides)
    spec = args.get("narrator")
    if spec:
        from ..sched.narrator import parse_narrator
        ses.attach_narrator(
            parse_narrator(spec, seed=int(args.get("narrator_seed", 0))))
    tune_spec = args.get("autotune")
    if tune_spec:
        # seeded and wall-clock-free, so an autotuned session replays
        # bit-identically from its journal like any other
        from ..tune.controller import AutoTuner
        ses.attach_autotuner(
            AutoTuner(tune_spec, seed=int(args.get("autotune_seed", 0))))
    return ses


def materialize_submit(ses: SimSession, args: Dict[str, Any]):
    """A ``submit`` op's jobs: inline ``specs`` or a registered workload
    kind (the registry materialization is seeded and deterministic)."""
    if "specs" in args:
        return [JobSpec(**{k: s[k] for k in
                           ("jid", "release", "proc_time", "n_tasks",
                            "cpu_need", "mem_req") if k in s})
                for s in args["specs"]]
    from ..workloads.registry import parse_workload
    return parse_workload(
        args["workload"],
        n_jobs=int(args.get("jobs", 100)),
        n_nodes=int(args.get("nodes", ses.engine.params.n_nodes)),
        seed=int(args.get("seed", 0)),
        load=args.get("load"),
    )


def apply_op(ses: SimSession, op: str, args: Dict[str, Any]) -> Dict[str, Any]:
    """Apply one journaled mutating op (except ``open``/``close``, which
    the registry handles) to a live session; returns the response payload.
    Raising is part of the contract: an op that fails live fails
    identically on replay, leaving the same session state either way.
    """
    if op == "submit":
        idx = ses.submit(materialize_submit(ses, args),
                         shift=args.get("shift"))
        return {"n_submitted": len(idx), **ses.observe()}
    if op == "step_until":
        ses.step_until(float(args["t"]))
        return ses.observe()
    if op == "step":
        n = ses.step(int(args.get("n", 1)))
        return {"steps": n, **ses.observe()}
    if op == "run":
        ses.run_to_exhaustion()
        return ses.observe()
    if op == "inject":
        ses.inject({k: v for k, v in args.items()
                    if k not in ("op", "id", "tenant", "session", "seq")})
        return ses.observe()
    if op == "period":
        ses.set_period(float(args["period"]))
        return ses.observe()
    if op == "tune":
        tun = ses.autotuner
        if tun is None:
            raise ProtocolError(
                E_OP_ERROR, "no autotuner attached (open the session "
                "with an 'autotune' spec)")
        swapped = tun.fire(ses, now=True)
        d = tun.decisions[-1]
        return {"swapped": swapped, "reason": d["reason"],
                "decisions": len(tun.decisions),
                "policy": ses.policy_name, **ses.observe()}
    raise ProtocolError(E_BAD_REQUEST, f"unknown mutating op {op!r}")


def op_args(req: Dict[str, Any]) -> Dict[str, Any]:
    """The journalable argument dict of a request: everything except the
    transport envelope (id/tenant/session/op/seq)."""
    return {k: v for k, v in req.items()
            if k not in ("id", "tenant", "session", "op", "seq")}


def result_payload(ses: SimSession) -> Dict[str, Any]:
    import dataclasses
    r = ses.result()
    d = dataclasses.asdict(r)
    d["partial"] = not ses.exhausted
    return d
