"""Credit-based admission control + weighted-DRF fair queueing.

Every tenant carries a **credit score**

    credit_t = clamp(1 − α·budget_used − β·violations − γ·tail_latency,
                     min_credit, 1)

whose three pressure terms are normalized to [0, 1]:

* ``budget_used`` — the tenant's consumed cost units (ops weighted by
  engine events advanced and wall time) over its per-window budget, with
  exponential decay so bursts are forgiven over ``window_s``;
* ``violations`` — a decayed count of misbehaviour (ops that error out,
  queue-overflow spam);
* ``tail_latency`` — the tenant's own recent p99 service latency over the
  target (a tenant whose ops hog the dispatcher sees its credit fall).

The credit is the tenant's **weight in a weighted-DRF queue**: the
dispatcher always services the pending tenant with the smallest
``dominant_share / credit``, where the dominant share is the classic DRF
max-over-resources of the tenant's (decayed) usage against the whole
server's usage.  A hot tenant's share grows and its credit falls, so its
effective priority collapses quadratically while an idle tenant's first
op is serviced almost immediately — starvation-free without hard
partitioning.  ``min_credit > 0`` guarantees even a fully misbehaving
tenant eventually drains.

Admission control proper happens *before* enqueue: a tenant over its
pending-queue cap or out of budget is refused with a typed error
(``admission-denied`` / ``over-budget``) instead of being queued, so a
misbehaving tenant cannot occupy dispatcher memory.
"""
from __future__ import annotations

import math
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from .protocol import E_ADMISSION, E_OVER_BUDGET, ProtocolError

__all__ = ["CreditParams", "TenantState", "FairQueue"]

_DIMS = ("ops", "events", "wall")
_EPS = 1e-12


@dataclass
class CreditParams:
    """Knobs of the credit model (defaults match the docs above)."""

    alpha: float = 0.5              # weight of budget pressure
    beta: float = 0.3               # weight of violation pressure
    gamma: float = 0.2              # weight of tail-latency pressure
    budget: float = 500.0           # cost units per decay window
    window_s: float = 30.0          # exponential-decay horizon (wall s)
    target_latency_s: float = 0.05  # p99 target for the tail term
    min_credit: float = 0.05        # starvation-free floor
    max_pending: int = 64           # per-tenant dispatcher queue cap
    max_sessions: int = 100000      # per-tenant session cap
    latency_window: int = 128       # samples for the p99 estimate


def _clamp01(x: float) -> float:
    return 0.0 if x < 0.0 else (1.0 if x > 1.0 else x)


class TenantState:
    """Per-tenant accounting: decayed usage, violations, latency tail,
    pending ops, and the derived credit."""

    def __init__(self, name: str, params: CreditParams,
                 clock: Callable[[], float]):
        self.name = name
        self.params = params
        self._clock = clock
        self._stamp = clock()
        self.usage: Dict[str, float] = {d: 0.0 for d in _DIMS}
        self.cost_used = 0.0        # decayed cost units this window
        self.violations = 0.0       # decayed misbehaviour count
        self.latencies: Deque[float] = deque(maxlen=params.latency_window)
        self.pending: Deque[Any] = deque()
        self.sessions: set = set()
        # lifetime counters (stats only, never decayed)
        self.n_ops = 0
        self.n_rejected = 0
        self.n_errors = 0

    # -- decay --------------------------------------------------------------
    def _decay(self) -> None:
        now = self._clock()
        dt = now - self._stamp
        if dt <= 0:
            return
        self._stamp = now
        k = math.exp(-dt / max(self.params.window_s, _EPS))
        self.cost_used *= k
        self.violations *= k
        for d in _DIMS:
            self.usage[d] *= k

    # -- the three pressure terms -------------------------------------------
    def budget_used(self) -> float:
        self._decay()
        return _clamp01(self.cost_used / max(self.params.budget, _EPS))

    def violations_norm(self) -> float:
        self._decay()
        return _clamp01(self.violations / 10.0)

    def tail_latency_norm(self) -> float:
        if not self.latencies:
            return 0.0
        xs = sorted(self.latencies)
        p99 = xs[min(len(xs) - 1, int(0.99 * len(xs)))]
        return _clamp01(p99 / max(self.params.target_latency_s, _EPS))

    def credit(self) -> float:
        p = self.params
        raw = (1.0 - p.alpha * self.budget_used()
               - p.beta * self.violations_norm()
               - p.gamma * self.tail_latency_norm())
        return max(p.min_credit, min(1.0, raw))

    # -- charging -----------------------------------------------------------
    def charge(self, *, ops: float = 1.0, events: float = 0.0,
               wall: float = 0.0) -> None:
        """Account one serviced op: cost units against the budget, the DRF
        usage vector, and the latency tail."""
        self._decay()
        self.n_ops += 1
        self.usage["ops"] += ops
        self.usage["events"] += events
        self.usage["wall"] += wall
        # cost units: an op is 1, plus its simulation and wall footprint
        self.cost_used += ops + events / 1000.0 + wall * 10.0
        self.latencies.append(wall)

    def violation(self, n: float = 1.0) -> None:
        self._decay()
        self.violations += n

    def snapshot(self) -> Dict[str, Any]:
        return {
            "credit": self.credit(),
            "budget_used": self.budget_used(),
            "violations": self.violations_norm(),
            "tail_latency": self.tail_latency_norm(),
            "pending": len(self.pending),
            "sessions": len(self.sessions),
            "n_ops": self.n_ops,
            "n_rejected": self.n_rejected,
            "n_errors": self.n_errors,
        }


class FairQueue:
    """Weighted-DRF dispatcher queue over per-tenant pending deques."""

    def __init__(self, params: Optional[CreditParams] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.params = params or CreditParams()
        self._clock = clock
        self.tenants: Dict[str, TenantState] = {}

    def tenant(self, name: str) -> TenantState:
        t = self.tenants.get(name)
        if t is None:
            t = self.tenants[name] = TenantState(name, self.params,
                                                 self._clock)
        return t

    # -- admission ----------------------------------------------------------
    def admit(self, name: str, item: Any) -> TenantState:
        """Admit one op into ``name``'s pending queue or refuse with a
        typed :class:`ProtocolError` (refusals never occupy queue space)."""
        t = self.tenant(name)
        if len(t.pending) >= self.params.max_pending:
            t.n_rejected += 1
            t.violation()           # queue-overflow spam is misbehaviour
            raise ProtocolError(
                E_ADMISSION,
                f"tenant {name!r} has {len(t.pending)} ops pending "
                f"(max_pending={self.params.max_pending}); drain before "
                f"submitting more")
        if t.budget_used() >= 1.0:
            t.n_rejected += 1       # throttling, not misbehaviour
            raise ProtocolError(
                E_OVER_BUDGET,
                f"tenant {name!r} exhausted its credit budget "
                f"({self.params.budget:g} cost units / "
                f"{self.params.window_s:g}s window); retry after backoff")
        t.pending.append(item)
        return t

    # -- scheduling ---------------------------------------------------------
    def _dominant_share(self, t: TenantState,
                        totals: Dict[str, float]) -> float:
        return max(t.usage[d] / (totals[d] + _EPS) for d in _DIMS)

    def pick(self) -> Optional[Tuple[TenantState, Any]]:
        """Pop the next op to service: the pending tenant minimizing
        ``dominant_share / credit`` (deterministic name tie-break)."""
        ready = [t for t in self.tenants.values() if t.pending]
        if not ready:
            return None
        totals = {d: sum(t.usage[d] for t in self.tenants.values())
                  for d in _DIMS}
        best = min(ready, key=lambda t: (
            self._dominant_share(t, totals) / t.credit(), t.name))
        return best, best.pending.popleft()

    def backlog(self) -> int:
        return sum(len(t.pending) for t in self.tenants.values())

    def stats(self) -> Dict[str, Any]:
        return {name: t.snapshot() for name, t in sorted(self.tenants.items())}
