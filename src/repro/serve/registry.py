"""Session registry: named live sessions, snapshot-backed eviction, and
journal-based crash recovery.

Every (tenant, session) pair owns two files under the store root::

    <store>/<tenant>/<name>.snap.json   # {"schema", "seq", "closed", "session"}
    <store>/<tenant>/<name>.journal     # JSONL: {"seq", "op", "args"}

and the invariant tying them together: **the snapshot covers every
mutating op with ``seq < snap_seq``; the journal holds (at least) every
applied op with ``seq >= snap_seq``.**  Each mutating op is appended to
the journal — flushed and fsynced — *before* it is applied, so after any
crash the durable state implies the applied state:

* op journaled + applied + acked            → replayed, ``dup`` on resend
* op journaled, crash before apply/ack      → replayed; the client's
  resend of the same seq is answered ``dup`` — the op happened once
* crash before the journal write            → op never happened; the
  client's resend applies it fresh

Because :func:`~repro.serve.protocol.apply_op` is deterministic and
:meth:`SimSession.restore` is bit-exact, ``snapshot ∘ journal-replay``
reproduces the uninterrupted session bit for bit — ``kill -9`` mid-run
included.  The same mechanism is the **eviction** path: an idle session
is persisted (snapshot at the current seq, journal truncated) and its
live object dropped; the next touch rehydrates it transparently.  The
server holds thousands of named sessions while only ``max_live`` engine
states exist in memory.
"""
from __future__ import annotations

import json
import os
import sys
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..core.ioutil import atomic_write_json, atomic_write_text
from ..sched.session import SimSession
from .protocol import (E_BAD_REQUEST, E_SEQ_GAP, E_SESSION_CLOSED,
                       E_UNKNOWN_SESSION, ProtocolError, apply_op,
                       build_session)

__all__ = ["SessionStore", "SessionRegistry"]

SNAP_SCHEMA = "repro.serve-snap/v1"


# --------------------------------------------------------------------------- #
# durable store                                                                #
# --------------------------------------------------------------------------- #
class SessionStore:
    """The on-disk half: snapshot + journal files per (tenant, session)."""

    def __init__(self, root: Optional[str], *, fsync: bool = True):
        self.root = root
        self.fsync = fsync
        if root is not None:
            os.makedirs(root, exist_ok=True)

    @property
    def persistent(self) -> bool:
        return self.root is not None

    # -- paths --------------------------------------------------------------
    def snap_path(self, tenant: str, name: str) -> str:
        return os.path.join(self.root, tenant, f"{name}.snap.json")

    def journal_path(self, tenant: str, name: str) -> str:
        return os.path.join(self.root, tenant, f"{name}.journal")

    # -- journal ------------------------------------------------------------
    def open_journal(self, tenant: str, name: str):
        path = self.journal_path(tenant, name)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        return open(path, "a")

    def append(self, fh, entry: Dict[str, Any]) -> None:
        """Durable journal append: the entry is on disk before the op it
        describes is applied (write-ahead)."""
        fh.write(json.dumps(entry, separators=(",", ":")) + "\n")
        fh.flush()
        if self.fsync:
            os.fsync(fh.fileno())

    def reset_journal(self, tenant: str, name: str) -> None:
        """Truncate the journal (atomically) — called right after a
        snapshot persist makes its entries redundant."""
        atomic_write_text(self.journal_path(tenant, name), "")

    def read_journal(self, tenant: str, name: str) -> List[Dict[str, Any]]:
        """Journal entries, tolerating a torn trailing line (a crash mid-
        append): parsing stops at the first undecodable line — by the
        write-ahead rule nothing after it was ever applied."""
        path = self.journal_path(tenant, name)
        if not os.path.exists(path):
            return []
        out: List[Dict[str, Any]] = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    print(f"warning: {path}: torn trailing journal entry "
                          f"dropped (crash mid-append)", file=sys.stderr)
                    break
        return out

    # -- snapshots ----------------------------------------------------------
    def persist_snapshot(self, tenant: str, name: str, seq: int,
                         session_payload: Dict[str, Any],
                         closed: bool) -> None:
        atomic_write_json(self.snap_path(tenant, name), {
            "schema": SNAP_SCHEMA,
            "seq": int(seq),
            "closed": bool(closed),
            "session": session_payload,
        }, indent=None)
        self.reset_journal(tenant, name)

    def read_snapshot(self, tenant: str,
                      name: str) -> Optional[Dict[str, Any]]:
        path = self.snap_path(tenant, name)
        if not os.path.exists(path):
            return None
        with open(path) as f:
            payload = json.load(f)
        if payload.get("schema") != SNAP_SCHEMA:
            raise ValueError(f"{path} is not a {SNAP_SCHEMA} snapshot "
                             f"(schema: {payload.get('schema')!r})")
        return payload

    def delete(self, tenant: str, name: str) -> None:
        for path in (self.snap_path(tenant, name),
                     self.journal_path(tenant, name)):
            try:
                os.unlink(path)
            except FileNotFoundError:
                pass

    def scan(self) -> List[Tuple[str, str]]:
        """Every (tenant, session) with durable state on disk."""
        if not self.persistent or not os.path.isdir(self.root):
            return []
        found = set()
        for tenant in sorted(os.listdir(self.root)):
            tdir = os.path.join(self.root, tenant)
            if not os.path.isdir(tdir):
                continue
            for fname in sorted(os.listdir(tdir)):
                if fname.endswith(".snap.json"):
                    found.add((tenant, fname[:-len(".snap.json")]))
                elif fname.endswith(".journal"):
                    found.add((tenant, fname[:-len(".journal")]))
        return sorted(found)


# --------------------------------------------------------------------------- #
# registry                                                                     #
# --------------------------------------------------------------------------- #
class _Entry:
    __slots__ = ("tenant", "name", "session", "seq", "snap_seq", "closed",
                 "last_touch", "journal_fh", "dirty")

    def __init__(self, tenant: str, name: str):
        self.tenant = tenant
        self.name = name
        self.session: Optional[SimSession] = None
        self.seq = 0                # next expected mutating-op seq
        self.snap_seq = 0           # ops covered by the on-disk snapshot
        self.closed = False
        self.last_touch = 0.0
        self.journal_fh = None
        self.dirty = False          # mutations not yet in a snapshot

    @property
    def live(self) -> bool:
        return self.session is not None


class SessionRegistry:
    """Live-session cache over the durable :class:`SessionStore`.

    All mutating traffic funnels through :meth:`apply_mutating` — seq
    dedup, write-ahead journaling, lazy rehydration and the apply itself —
    so the live path and the crash-recovery path share one code path and
    cannot drift.  Not thread-safe by design: the server's single asyncio
    dispatcher is the only caller.
    """

    def __init__(self, store: SessionStore, *, max_live: int = 256,
                 idle_evict_s: Optional[float] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.store = store
        self.max_live = max(1, int(max_live))
        self.idle_evict_s = idle_evict_s
        self._clock = clock
        self.entries: Dict[Tuple[str, str], _Entry] = {}
        self.n_evictions = 0
        self.n_rehydrations = 0

    # -- introspection ------------------------------------------------------
    @property
    def n_sessions(self) -> int:
        return len(self.entries)

    @property
    def n_live(self) -> int:
        return sum(1 for e in self.entries.values() if e.live)

    def stats(self) -> Dict[str, Any]:
        return {
            "sessions": self.n_sessions,
            "live": self.n_live,
            "closed": sum(1 for e in self.entries.values() if e.closed),
            "evictions": self.n_evictions,
            "rehydrations": self.n_rehydrations,
            "max_live": self.max_live,
        }

    def sessions_of(self, tenant: str) -> List[str]:
        return sorted(n for (t, n) in self.entries if t == tenant)

    # -- crash recovery -----------------------------------------------------
    def recover(self) -> int:
        """Scan the store and register every persisted session as a cold
        entry (rehydrated lazily on first touch).  Returns how many were
        recovered."""
        n = 0
        for tenant, name in self.store.scan():
            if (tenant, name) in self.entries:
                continue
            ent = _Entry(tenant, name)
            snap = self.store.read_snapshot(tenant, name)
            if snap is not None:
                ent.snap_seq = ent.seq = int(snap["seq"])
                ent.closed = bool(snap.get("closed", False))
            entries = self.store.read_journal(tenant, name)
            for rec in entries:
                if int(rec["seq"]) >= ent.seq:
                    ent.seq = int(rec["seq"]) + 1
                    ent.dirty = True
                if rec["op"] == "close":
                    ent.closed = True
            if snap is None and not entries:
                continue            # empty files: nothing durable happened
            ent.last_touch = self._clock()
            self.entries[(tenant, name)] = ent
            n += 1
        return n

    # -- the one mutating entry point ---------------------------------------
    def apply_mutating(self, tenant: str, name: str, op: str,
                       args: Dict[str, Any],
                       seq: Optional[int] = None) -> Dict[str, Any]:
        """Seq-checked, journaled application of one mutating op.

        Raises :class:`ProtocolError` for requests refused *before* the
        journal write (unknown/closed session, seq gap, duplicate open) —
        those consume no seq.  Once journaled, the op consumes its seq
        even if the simulation rejects it (the failure replays
        identically), and the error propagates to the caller.
        """
        key = (tenant, name)
        ent = self.entries.get(key)
        if op == "open":
            if ent is not None:
                if seq is not None and seq < ent.seq:
                    return self._dup(ent, seq)  # idempotent re-open
                if ent.closed:
                    raise ProtocolError(
                        E_SESSION_CLOSED,
                        f"session {tenant}/{name} is closed "
                        f"(seq={ent.seq}); delete it to reuse the name")
                raise ProtocolError(
                    E_BAD_REQUEST,
                    f"session {tenant}/{name} already exists "
                    f"(seq={ent.seq}); close it or pick a fresh name")
            ent = self.entries[key] = _Entry(tenant, name)
        else:
            if ent is None:
                raise ProtocolError(
                    E_UNKNOWN_SESSION,
                    f"unknown session {tenant}/{name}; open it first")
            if ent.closed:
                if seq is not None and seq < ent.seq:
                    return self._dup(ent, seq)
                raise ProtocolError(
                    E_SESSION_CLOSED,
                    f"session {tenant}/{name} is closed")
        if seq is None:
            seq = ent.seq
        if seq < ent.seq:
            return self._dup(ent, seq)
        if seq > ent.seq:
            raise ProtocolError(
                E_SEQ_GAP,
                f"seq {seq} is ahead of session {tenant}/{name} "
                f"(next expected: {ent.seq}); an earlier op was lost")
        self._touch(ent)
        if op != "open":
            # rehydrate BEFORE journaling the new entry: replay must only
            # see ops that were applied in a previous life, never the one
            # about to be applied (it would run twice)
            self._live(ent)
        self._journal(ent, {"seq": seq, "op": op, "args": args})
        ent.seq += 1
        ent.dirty = True
        return self._apply_live(ent, op, args)

    def _dup(self, ent: _Entry, seq: int) -> Dict[str, Any]:
        return {"dup": True, "seq": seq, "applied_seq": ent.seq}

    # -- read-only paths ----------------------------------------------------
    def live_session(self, tenant: str, name: str) -> SimSession:
        """The live session object, rehydrating a cold entry on demand."""
        ent = self.entries.get((tenant, name))
        if ent is None:
            raise ProtocolError(
                E_UNKNOWN_SESSION,
                f"unknown session {tenant}/{name}; open it first")
        self._touch(ent)
        return self._live(ent)

    def checkpoint(self, tenant: str, name: str) -> Dict[str, Any]:
        """Persist a snapshot now (the ``snapshot`` op): returns seq and
        the session fingerprint."""
        ent = self.entries.get((tenant, name))
        if ent is None:
            raise ProtocolError(
                E_UNKNOWN_SESSION,
                f"unknown session {tenant}/{name}; open it first")
        if not self.store.persistent:
            raise ProtocolError(
                E_BAD_REQUEST, "server has no snapshot store (started "
                "without --store); snapshots are unavailable")
        self._touch(ent)
        fp = self._persist(ent)
        return {"seq": ent.seq, "fingerprint": fp,
                "path": self.store.snap_path(tenant, name)}

    # -- reclamation --------------------------------------------------------
    def delete_session(self, tenant: str, name: str) -> Dict[str, Any]:
        """Forget a *closed* session entirely: drop its registry entry and
        remove its snapshot/journal files, freeing the name for reuse.
        The reclamation path for long-lived servers — without it closed
        entries (and their disk state) accumulate forever."""
        key = (tenant, name)
        ent = self.entries.get(key)
        if ent is None:
            raise ProtocolError(
                E_UNKNOWN_SESSION,
                f"unknown session {tenant}/{name}; nothing to delete")
        if not ent.closed:
            raise ProtocolError(
                E_BAD_REQUEST,
                f"session {tenant}/{name} is still open; close it "
                f"before deleting")
        self._drop(ent)
        return {"deleted": True, "seq": ent.seq}

    def _drop(self, ent: _Entry) -> None:
        """Remove an entry and all its durable state (the point of no
        return: the name is fresh afterwards)."""
        if ent.journal_fh is not None:
            ent.journal_fh.close()
            ent.journal_fh = None
        if ent.live:
            ses, ent.session = ent.session, None
            ses.close()
        self.entries.pop((ent.tenant, ent.name), None)
        if self.store.persistent:
            self.store.delete(ent.tenant, ent.name)

    # -- eviction -----------------------------------------------------------
    def evict(self, tenant: str, name: str) -> None:
        ent = self.entries[(tenant, name)]
        if not ent.live:
            return
        if not self.store.persistent:
            raise ProtocolError(
                E_BAD_REQUEST, "cannot evict without a snapshot store")
        self._persist(ent)
        ses, ent.session = ent.session, None
        ses.close()                 # run close hooks, free the live object
        self.n_evictions += 1

    def evict_over_cap(self) -> int:
        """LRU-evict live sessions until at most ``max_live`` remain."""
        n = 0
        while self.store.persistent and self.n_live > self.max_live:
            victims = sorted(
                (e for e in self.entries.values() if e.live),
                key=lambda e: e.last_touch)
            self.evict(victims[0].tenant, victims[0].name)
            n += 1
        return n

    def evict_idle(self) -> int:
        """Evict live sessions untouched for ``idle_evict_s``."""
        if self.idle_evict_s is None or not self.store.persistent:
            return 0
        cutoff = self._clock() - self.idle_evict_s
        n = 0
        for ent in list(self.entries.values()):
            if ent.live and ent.last_touch < cutoff:
                self.evict(ent.tenant, ent.name)
                n += 1
        return n

    def close_all(self) -> None:
        """Server shutdown: persist every dirty live session and drop it."""
        for ent in self.entries.values():
            if ent.live and self.store.persistent:
                self._persist(ent)
            if ent.live:
                ent.session.close()
                ent.session = None
            if ent.journal_fh is not None:
                ent.journal_fh.close()
                ent.journal_fh = None

    # -- internals ----------------------------------------------------------
    def _touch(self, ent: _Entry) -> None:
        ent.last_touch = self._clock()

    def _journal(self, ent: _Entry, entry: Dict[str, Any]) -> None:
        if not self.store.persistent:
            return
        if ent.journal_fh is None:
            ent.journal_fh = self.store.open_journal(ent.tenant, ent.name)
        self.store.append(ent.journal_fh, entry)

    def _persist(self, ent: _Entry) -> str:
        """Snapshot the entry at its current seq and truncate the journal
        (snapshot-then-truncate: a crash in between leaves stale journal
        entries with seq < snap_seq, which replay skips)."""
        ses = self._live(ent)
        snap = ses.snapshot()
        self.store.persist_snapshot(ent.tenant, ent.name, ent.seq,
                                    snap.to_json_dict(), ent.closed)
        if ent.journal_fh is not None:
            ent.journal_fh.close()  # reopen against the truncated file
            ent.journal_fh = None
        ent.snap_seq = ent.seq
        ent.dirty = False
        return snap.fingerprint

    def _live(self, ent: _Entry) -> SimSession:
        if ent.session is not None:
            return ent.session
        ent.session = self._rehydrate(ent)
        self.n_rehydrations += 1
        return ent.session

    def _rehydrate(self, ent: _Entry) -> SimSession:
        """snapshot ∘ journal-replay: rebuild the live session exactly."""
        snap = self.store.read_snapshot(ent.tenant, ent.name)
        ses: Optional[SimSession] = None
        base_seq = 0
        if snap is not None:
            ses = SimSession.restore(snap["session"])
            base_seq = int(snap["seq"])
        for rec in self.store.read_journal(ent.tenant, ent.name):
            seq, op, args = int(rec["seq"]), rec["op"], rec["args"]
            if seq < base_seq:
                continue            # covered by the snapshot
            if op == "open":
                ses = build_session(args)
                continue
            if op == "close":
                continue            # terminal marker; ent.closed has it
            if ses is None:
                raise ValueError(
                    f"journal for {ent.tenant}/{ent.name} starts mid-"
                    f"stream (seq {seq} {op!r}) with no snapshot")
            try:
                apply_op(ses, op, args)
            except Exception:       # noqa: BLE001 — deterministic: the op
                pass                # failed identically when applied live
        if ses is None:
            raise ValueError(
                f"no durable state for session {ent.tenant}/{ent.name}")
        return ses

    def _apply_live(self, ent: _Entry, op: str,
                    args: Dict[str, Any]) -> Dict[str, Any]:
        if op == "open":
            try:
                ent.session = build_session(args)
            except Exception:
                # a failed open can never yield a usable session, and its
                # journaled op would poison every later rehydrate of the
                # entry — erase it (entry + journal) so the name stays
                # fresh and a corrected open can apply at seq 0
                self._drop(ent)
                raise
            return {"policy": ent.session.policy_name,
                    **ent.session.observe()}
        if op == "close":
            ent.closed = True
            if self.store.persistent:
                self._persist(ent)  # final durable state carries closed=True
            if ent.live:
                ses, ent.session = ent.session, None
                ses.close()
            if ent.journal_fh is not None:
                ent.journal_fh.close()
                ent.journal_fh = None
            return {"closed": True, "seq": ent.seq}
        return apply_op(self._live(ent), op, args)
