"""The scheduler-as-a-service server: asyncio JSONL-over-TCP, stdlib only.

One process holds thousands of named :class:`SimSession`\\ s behind a
:class:`~repro.serve.registry.SessionRegistry`.  Connections are thin:
a reader task per connection parses frames and runs **admission control**
(queue caps, credit budget) — everything admitted lands in the
:class:`~repro.serve.admission.FairQueue`, and a single dispatcher task
services it in weighted-DRF order, so one hot tenant saturating its
connection cannot starve the others no matter how fast it writes.

Simulation ops run inline on the event loop: the engine is process-wide
single-threaded anyway (numpy releases the GIL only transiently) and the
fair queue — not connection order — already decides *whose* op runs next.
Durability (write-ahead journal + snapshot-backed eviction + crash
recovery) lives in the registry; the server adds the transport, the
fairness layer, and the idle/cap eviction policy.
"""
from __future__ import annotations

import asyncio
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

from .admission import CreditParams, FairQueue
from .protocol import (CONTROL_OPS, E_BAD_REQUEST, E_OP_ERROR, MUTATING_OPS,
                       ProtocolError, check_name, decode, encode,
                       error_response, op_args, result_payload)
from .registry import SessionRegistry, SessionStore

__all__ = ["ServeConfig", "SchedServer", "ServerThread", "run_server"]


@dataclass
class ServeConfig:
    host: str = "127.0.0.1"
    port: int = 0                   # 0 = ephemeral (announced on start)
    store: Optional[str] = None     # snapshot/journal root; None = RAM only
    max_live: int = 256             # live engine states held in memory
    idle_evict_s: Optional[float] = None   # evict sessions idle this long
    checkpoint_every: int = 0       # auto-snapshot every N ops per session
    fsync: bool = True              # fsync journal appends (durability)
    allow_shutdown: bool = True     # honor the "shutdown" control op
    credit: CreditParams = field(default_factory=CreditParams)


class _Pending:
    __slots__ = ("req", "writer", "enqueued")

    def __init__(self, req: Dict[str, Any], writer: asyncio.StreamWriter,
                 enqueued: float):
        self.req = req
        self.writer = writer
        self.enqueued = enqueued


class SchedServer:
    """The long-lived service.  ``await start()`` binds the socket (and
    replays any persisted sessions), ``await serve_forever()`` blocks
    until a ``shutdown`` op or :meth:`request_stop`."""

    def __init__(self, config: Optional[ServeConfig] = None, **overrides):
        if config is None:
            config = ServeConfig(**overrides)
        self.config = config
        self.store = SessionStore(config.store, fsync=config.fsync)
        self.registry = SessionRegistry(
            self.store, max_live=config.max_live,
            idle_evict_s=config.idle_evict_s)
        self.queue = FairQueue(config.credit)
        self.port: Optional[int] = None
        self.n_recovered = 0
        self._server: Optional[asyncio.base_events.Server] = None
        self._wake = asyncio.Event()
        self._stopped = asyncio.Event()
        self._dispatcher: Optional[asyncio.Task] = None
        self._events_seen: Dict[Tuple[str, str], int] = {}
        self._last_idle_sweep = time.monotonic()
        self.started_at = time.monotonic()

    # -- lifecycle ----------------------------------------------------------
    async def start(self) -> None:
        self.n_recovered = self.registry.recover()
        # rebuild per-tenant session sets so max_sessions keeps counting
        # recovered (still-open) sessions across restarts
        for (tenant, name), ent in self.registry.entries.items():
            if not ent.closed:
                self.queue.tenant(tenant).sessions.add(name)
        self._server = await asyncio.start_server(
            self._on_connection, self.config.host, self.config.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._dispatcher = asyncio.ensure_future(self._dispatch_loop())

    async def serve_forever(self) -> None:
        await self._stopped.wait()

    def request_stop(self) -> None:
        self._stopped.set()
        self._wake.set()

    async def stop(self) -> None:
        self.request_stop()
        if self._dispatcher is not None:
            await self._dispatcher
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        self.registry.close_all()

    # -- connection reader ---------------------------------------------------
    async def _on_connection(self, reader: asyncio.StreamReader,
                             writer: asyncio.StreamWriter) -> None:
        try:
            while not self._stopped.is_set():
                line = await reader.readline()
                if not line:
                    break
                if not line.strip():
                    continue
                await self._on_frame(line, writer)
        except (ConnectionError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass

    async def _on_frame(self, line: bytes,
                        writer: asyncio.StreamWriter) -> None:
        req_id: Any = None
        try:
            req = decode(line)
            req_id = req.get("id")
            op = req.get("op")
            tenant = check_name("tenant", req.get("tenant", "default"))
            if op in CONTROL_OPS:
                resp = self._control(tenant, op, req)
                writer.write(encode({"id": req_id, "ok": True, **resp}))
                await writer.drain()
                return
            if op not in MUTATING_OPS and op not in (
                    "observe", "result", "snapshot", "sessions", "delete"):
                raise ProtocolError(E_BAD_REQUEST, f"unknown op {op!r}")
            # admission happens here, on the reader: refused ops never
            # enter the dispatcher queue
            self.queue.admit(tenant,
                             _Pending(req, writer, time.monotonic()))
            self._wake.set()
        except ProtocolError as exc:
            writer.write(encode(error_response(req_id, exc.code, str(exc))))
            await writer.drain()

    # -- control ops (cheap, serviced inline) --------------------------------
    def _control(self, tenant: str, op: str,
                 req: Dict[str, Any]) -> Dict[str, Any]:
        if op == "ping":
            return {"pong": True}
        if op == "hello":
            t = self.queue.tenant(tenant)
            return {"tenant": tenant, "credit": t.credit(),
                    "schema": "repro.serve/v1",
                    "limits": {
                        "max_pending": self.queue.params.max_pending,
                        "max_sessions": self.queue.params.max_sessions,
                        "budget": self.queue.params.budget,
                    }}
        if op == "stats":
            return {"registry": self.registry.stats(),
                    "tenants": self.queue.stats(),
                    "backlog": self.queue.backlog(),
                    "uptime_s": time.monotonic() - self.started_at,
                    "recovered": self.n_recovered}
        if op == "shutdown":
            if not self.config.allow_shutdown:
                raise ProtocolError(E_BAD_REQUEST,
                                    "shutdown is disabled on this server")
            self.request_stop()
            return {"stopping": True}
        raise ProtocolError(E_BAD_REQUEST, f"unknown control op {op!r}")

    # -- dispatcher ----------------------------------------------------------
    async def _dispatch_loop(self) -> None:
        while not self._stopped.is_set():
            picked = self.queue.pick()
            if picked is None:
                self._wake.clear()
                idle = asyncio.ensure_future(self._wake.wait())
                done = asyncio.ensure_future(self._stopped.wait())
                try:
                    await asyncio.wait({idle, done}, timeout=1.0,
                                       return_when=asyncio.FIRST_COMPLETED)
                finally:
                    idle.cancel()
                    done.cancel()
                self.registry.evict_idle()
                self._last_idle_sweep = time.monotonic()
                continue
            tenant_state, pending = picked
            t0 = time.perf_counter()
            resp = self._execute(tenant_state.name, pending.req)
            wall = time.perf_counter() - t0
            events = self._events_delta(tenant_state.name, pending.req, resp)
            tenant_state.charge(ops=1.0, events=events, wall=wall)
            if not resp.get("ok", False):
                tenant_state.n_errors += 1
                tenant_state.violation()
            try:
                pending.writer.write(encode(resp))
                await pending.writer.drain()
            except (ConnectionError, OSError):
                pass                # client went away; the op still counts
            self.registry.evict_over_cap()
            # idle eviction must also fire under sustained load, not only
            # when the queue drains — sweep at most once a second
            now = time.monotonic()
            if now - self._last_idle_sweep >= 1.0:
                self.registry.evict_idle()
                self._last_idle_sweep = now
            # yield so reader tasks can enqueue between ops (fairness is
            # decided by the queue, not by who holds the loop)
            await asyncio.sleep(0)

    def _events_delta(self, tenant: str, req: Dict[str, Any],
                      resp: Dict[str, Any]) -> float:
        """Engine events this op advanced (the DRF 'simulation work' dim)."""
        total = resp.get("events")
        session = req.get("session")
        if total is None or not isinstance(session, str):
            return 0.0
        key = (tenant, session)
        prev = self._events_seen.get(key)
        self._events_seen[key] = int(total)
        if prev is None:
            # first sighting establishes the baseline: a freshly opened
            # session reports ~0 anyway, and a session recovered after a
            # restart must not have its lifetime count charged as a delta
            return 0.0
        return float(max(0, int(total) - prev))

    # -- op execution --------------------------------------------------------
    def _execute(self, tenant: str, req: Dict[str, Any]) -> Dict[str, Any]:
        req_id = req.get("id")
        op = req["op"]
        try:
            resp = self._execute_inner(tenant, req_id, op, req)
        except ProtocolError as exc:
            resp = error_response(req_id, exc.code, str(exc))
        except Exception as exc:    # noqa: BLE001 — op failed in the engine
            resp = error_response(
                req_id, E_OP_ERROR, f"{type(exc).__name__}: {exc}")
        if op in MUTATING_OPS and isinstance(req.get("session"), str):
            # every mutating response carries the session's authoritative
            # next expected seq — an engine-rejected op still consumed its
            # seq (it was journaled), and the client resyncs from this
            # instead of guessing which failures consumed one
            ent = self.registry.entries.get((tenant, req["session"]))
            if ent is not None:
                resp.setdefault("next_seq", ent.seq)
        return resp

    def _execute_inner(self, tenant: str, req_id: Any, op: str,
                       req: Dict[str, Any]) -> Dict[str, Any]:
        if op == "sessions":
            return {"id": req_id, "ok": True,
                    "sessions": self.registry.sessions_of(tenant)}
        name = check_name("session", req.get("session"))
        if op in MUTATING_OPS:
            t = self.queue.tenant(tenant)
            if op == "open":
                if (name not in t.sessions and len(t.sessions)
                        >= self.queue.params.max_sessions):
                    raise ProtocolError(
                        E_BAD_REQUEST,
                        f"tenant {tenant!r} is at its session cap "
                        f"({self.queue.params.max_sessions})")
            payload = self.registry.apply_mutating(
                tenant, name, op, op_args(req), seq=req.get("seq"))
            if op == "close":
                t.sessions.discard(name)
                self._events_seen.pop((tenant, name), None)
            else:
                t.sessions.add(name)
            ce = self.config.checkpoint_every
            if (ce > 0 and not payload.get("dup")
                    and self.store.persistent):
                ent = self.registry.entries.get((tenant, name))
                if (ent is not None and not ent.closed
                        and ent.seq - ent.snap_seq >= ce):
                    self.registry.checkpoint(tenant, name)
            return {"id": req_id, "ok": True, **payload}
        if op == "observe":
            ses = self.registry.live_session(tenant, name)
            return {"id": req_id, "ok": True, **ses.observe()}
        if op == "result":
            ses = self.registry.live_session(tenant, name)
            return {"id": req_id, "ok": True, **result_payload(ses)}
        if op == "snapshot":
            payload = self.registry.checkpoint(tenant, name)
            return {"id": req_id, "ok": True, **payload}
        if op == "delete":
            payload = self.registry.delete_session(tenant, name)
            self.queue.tenant(tenant).sessions.discard(name)
            self._events_seen.pop((tenant, name), None)
            return {"id": req_id, "ok": True, **payload}
        raise ProtocolError(E_BAD_REQUEST, f"unknown op {op!r}")


async def _amain(config: ServeConfig, announce) -> None:
    server = SchedServer(config)
    await server.start()
    if announce is not None:
        announce(server)
    try:
        await server.serve_forever()
    finally:
        await server.stop()


def run_server(config: Optional[ServeConfig] = None, *, announce=None,
               **overrides) -> None:
    """Blocking entry point (the ``python -m repro serve`` path).
    ``announce(server)`` is called once the socket is bound (port known).
    """
    if config is None:
        config = ServeConfig(**overrides)
    asyncio.run(_amain(config, announce))


class ServerThread:
    """An in-process server on a background thread (tests, benchmarks).

    Context manager: ``with ServerThread(store=...) as srv:`` yields the
    running server with ``srv.port`` bound; exit stops it cleanly.
    """

    def __init__(self, config: Optional[ServeConfig] = None, **overrides):
        self.config = config if config is not None else ServeConfig(
            **overrides)
        self.server: Optional[SchedServer] = None
        self.port: Optional[int] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._ready = threading.Event()
        self._error: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self) -> None:
        try:
            asyncio.run(self._main())
        except BaseException as exc:  # noqa: BLE001 — surfaced via start()
            self._error = exc
            self._ready.set()

    async def _main(self) -> None:
        self._loop = asyncio.get_running_loop()
        self.server = SchedServer(self.config)
        await self.server.start()
        self.port = self.server.port
        self._ready.set()
        try:
            await self.server.serve_forever()
        finally:
            await self.server.stop()

    def start(self) -> "ServerThread":
        self._thread.start()
        self._ready.wait(timeout=30)
        if self._error is not None:
            raise RuntimeError("server thread failed to start") \
                from self._error
        if self.port is None:
            raise RuntimeError("server thread did not bind within 30s")
        return self

    def stop(self) -> None:
        if self._loop is not None and self._thread.is_alive():
            self._loop.call_soon_threadsafe(self.server.request_stop)
        self._thread.join(timeout=30)

    def __enter__(self) -> "ServerThread":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.stop()
        return False
