"""Synchronous stdlib client for the session server.

:class:`Client` speaks the JSONL protocol over one TCP connection and
adds the two things a caller should never hand-roll:

* **per-session sequence numbers** — every mutating op is stamped with
  the next ``seq`` for its session, making it idempotent on the wire;
  the counter resyncs from the ``next_seq`` the server echoes on every
  mutating response (success, dup or error), so an engine-rejected op —
  which still consumed its journaled seq — cannot desync the stream;
* **reconnect-and-resend** — with ``retry_for > 0`` a dropped connection
  (server restart, ``kill -9`` + recover) is retried transparently: the
  in-flight op is re-sent with its original seq, so an op the server
  journaled before dying is answered ``dup`` instead of applied twice.

Together with the server's write-ahead journal this gives exactly-once
op application end to end, which is what makes a client script re-run
against a recovered server finish bit-identically.
"""
from __future__ import annotations

import json
import socket
import time
from typing import Any, Dict, List, Optional

from .protocol import MUTATING_OPS

__all__ = ["Client", "ServeError", "connect"]


class ServeError(RuntimeError):
    """An ``ok: false`` response; ``code`` is the protocol error code."""

    def __init__(self, code: str, message: str):
        super().__init__(f"[{code}] {message}")
        self.code = code


class Client:
    """One tenant's connection to a session server."""

    def __init__(self, host: str = "127.0.0.1", port: int = 7463, *,
                 tenant: str = "default", timeout: float = 60.0,
                 retry_for: float = 0.0):
        self.host = host
        self.port = int(port)
        self.tenant = tenant
        self.timeout = timeout
        self.retry_for = float(retry_for)
        self._sock: Optional[socket.socket] = None
        self._fh = None
        self._next_id = 0
        self._seq: Dict[str, int] = {}      # per-session next mutating seq

    # -- connection ---------------------------------------------------------
    def _connect(self) -> None:
        self.close()
        self._sock = socket.create_connection((self.host, self.port),
                                              timeout=self.timeout)
        self._fh = self._sock.makefile("rwb")

    def close(self) -> None:
        if self._fh is not None:
            try:
                self._fh.close()
            except OSError:
                pass
            self._fh = None
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def __enter__(self) -> "Client":
        self.call("hello")
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False

    # -- the one wire primitive ---------------------------------------------
    def _roundtrip(self, req: Dict[str, Any]) -> Dict[str, Any]:
        if self._fh is None:
            self._connect()
        data = (json.dumps(req, separators=(",", ":")) + "\n").encode()
        self._fh.write(data)
        self._fh.flush()
        line = self._fh.readline()
        if not line:
            raise ConnectionError("server closed the connection")
        resp = json.loads(line)
        if resp.get("id") != req["id"]:
            raise ConnectionError(
                f"response id {resp.get('id')} != request id {req['id']}")
        return resp

    def call(self, op: str, session: Optional[str] = None,
             **args: Any) -> Dict[str, Any]:
        """Issue one op; raises :class:`ServeError` on ``ok: false``.

        Mutating ops are stamped with the session's next seq (unless the
        caller passes an explicit ``seq=``) and survive reconnects: the
        same request — same seq — is re-sent until ``retry_for`` runs out.
        """
        self._next_id += 1
        req: Dict[str, Any] = {"id": self._next_id, "tenant": self.tenant,
                               "op": op, **args}
        if session is not None:
            req["session"] = session
        mutating = op in MUTATING_OPS
        if mutating and session is not None and "seq" not in req:
            req["seq"] = self._seq.get(session, 0)
        deadline = time.monotonic() + self.retry_for
        while True:
            try:
                resp = self._roundtrip(req)
                break
            except (ConnectionError, OSError, json.JSONDecodeError):
                self.close()
                if time.monotonic() >= deadline:
                    raise
                time.sleep(0.2)     # server restarting; resend same seq
        if mutating and session is not None and "next_seq" in resp:
            # the server's authoritative next expected seq — present on
            # success, dup AND error responses.  An op the engine rejected
            # (op-error) still consumed its seq (it was journaled), so
            # syncing only on success would leave every later op answered
            # as a stale dup; resync unconditionally instead
            self._seq[session] = int(resp["next_seq"])
        if not resp.get("ok", False):
            raise ServeError(resp.get("code", "error"),
                             resp.get("error", "unknown server error"))
        if mutating and session is not None and "next_seq" not in resp:
            self._seq[session] = int(req["seq"]) + 1
        if op == "delete" and session is not None:
            self._seq.pop(session, None)    # a reused name restarts at 0
        return resp

    # -- convenience wrappers -----------------------------------------------
    def ping(self) -> bool:
        return bool(self.call("ping").get("pong"))

    def hello(self) -> Dict[str, Any]:
        return self.call("hello")

    def stats(self) -> Dict[str, Any]:
        return self.call("stats")

    def sessions(self) -> List[str]:
        return list(self.call("sessions").get("sessions", []))

    def open(self, session: str, policy: str, *, nodes: int = 64,
             **params: Any) -> Dict[str, Any]:
        return self.call("open", session, policy=policy, nodes=nodes,
                         **params)

    def submit(self, session: str, **args: Any) -> Dict[str, Any]:
        return self.call("submit", session, **args)

    def step_until(self, session: str, t: float) -> Dict[str, Any]:
        return self.call("step_until", session, t=float(t))

    def step(self, session: str, n: int = 1) -> Dict[str, Any]:
        return self.call("step", session, n=int(n))

    def run(self, session: str) -> Dict[str, Any]:
        return self.call("run", session)

    def inject(self, session: str, **event: Any) -> Dict[str, Any]:
        return self.call("inject", session, **event)

    def observe(self, session: str) -> Dict[str, Any]:
        return self.call("observe", session)

    def result(self, session: str) -> Dict[str, Any]:
        return self.call("result", session)

    def snapshot(self, session: str) -> Dict[str, Any]:
        return self.call("snapshot", session)

    def close_session(self, session: str) -> Dict[str, Any]:
        return self.call("close", session)

    def delete_session(self, session: str) -> Dict[str, Any]:
        """Forget a closed session server-side, freeing its name and
        reclaiming its snapshot/journal files."""
        return self.call("delete", session)

    def shutdown_server(self) -> Dict[str, Any]:
        return self.call("shutdown")


def connect(host: str = "127.0.0.1", port: int = 7463, *,
            tenant: str = "default", timeout: float = 60.0,
            retry_for: float = 0.0) -> Client:
    """Open a client connection (the :mod:`repro.api` facade spelling)."""
    return Client(host, port, tenant=tenant, timeout=timeout,
                  retry_for=retry_for)
