"""repro.serve — scheduler-as-a-service: a multi-tenant SimSession server.

An asyncio JSONL-over-TCP server (stdlib only) holding thousands of named
streaming sessions behind credit-based admission and a weighted-DRF fair
queue, with snapshot-backed eviction of idle sessions and write-ahead
journal crash recovery (``kill -9`` mid-run resumes bit-identically).

    from repro import api
    api.serve(store="var/serve", max_live=256)          # blocking server
    c = api.connect(port=PORT, tenant="acme")           # a tenant client
    c.open("s0", "GreedyP */OPT=MIN", nodes=32)
    c.submit("s0", workload="lublin", jobs=100, seed=1)
    c.step_until("s0", 3600.0)
    print(c.result("s0")["max_stretch"])

See ARCHITECTURE.md "Service layer" for the design.
"""
from .admission import CreditParams, FairQueue, TenantState
from .client import Client, ServeError, connect
from .protocol import ProtocolError
from .registry import SessionRegistry, SessionStore
from .server import SchedServer, ServeConfig, ServerThread, run_server

__all__ = [
    "CreditParams", "FairQueue", "TenantState",
    "Client", "ServeError", "connect", "ProtocolError",
    "SessionRegistry", "SessionStore",
    "SchedServer", "ServeConfig", "ServerThread", "run_server",
]
