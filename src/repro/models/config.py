"""Model configuration for the assigned architectures.

A single ``ModelConfig`` dataclass describes every family handled by this
framework (dense / MoE / MLA / RWKV6 / RG-LRU hybrid / enc-dec / VLM-backbone).
The per-layer *plan* (``layer_plan``) lists each layer's block kind and MLP
kind; consecutive identical layers are grouped (``layer_groups``) so the
backbone can ``jax.lax.scan`` over stacked parameters — this keeps the HLO
(and therefore XLA compile time and program size) independent of depth, which
matters at 61-80 layers on 512 partitioned devices.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

__all__ = ["ModelConfig", "BlockSpec", "layer_plan", "layer_groups", "reduce_config"]


# Block kinds: how a layer mixes across the sequence dimension.
ATTN = "attn"          # full (causal or bidirectional) softmax attention
LOCAL_ATTN = "local"   # sliding-window attention (sub-quadratic)
MLA = "mla"            # DeepSeek multi-head latent attention
RWKV6 = "rwkv6"        # Finch data-dependent-decay linear attention
RGLRU = "rglru"        # RecurrentGemma real-gated LRU recurrence

# MLP kinds.
DENSE = "dense"        # gated (SwiGLU) or plain (GELU) feed-forward
MOE = "moe"            # shared + routed top-k mixture of experts


@dataclass(frozen=True)
class BlockSpec:
    """One layer's static structure."""

    kind: str            # ATTN | LOCAL_ATTN | MLA | RWKV6 | RGLRU
    mlp: str             # DENSE | MOE
    cross_attn: bool = False   # decoder layer attends to encoder output


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int

    head_dim: int = 0              # 0 -> d_model // n_heads
    qk_norm: bool = False
    mlp_act: str = "swiglu"        # swiglu | gelu
    tie_embeddings: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    norm_kind: str = "rmsnorm"     # rmsnorm | layernorm

    # ---- attention pattern -------------------------------------------------
    window: int = 0                # sliding window size for LOCAL_ATTN
    attn_pattern: Tuple[str, ...] = ()   # repeating kinds; () -> all ATTN
    causal: bool = True

    # ---- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_experts_pad: int = 0         # dead experts appended so E shards evenly
    n_shared_experts: int = 0
    top_k: int = 0
    d_expert: int = 0              # routed-expert hidden size
    d_shared: int = 0              # total shared-expert hidden size
    first_dense: int = 0           # first k layers stay dense (DeepSeek)
    router_aux_coef: float = 0.01  # load-balance loss coefficient
    router_z_coef: float = 1e-4
    shared_gate: bool = False      # Qwen2-MoE sigmoid gate on shared experts
    capacity_factor: float = 1.25

    # ---- MLA (DeepSeek) ----------------------------------------------------
    mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- MTP (DeepSeek multi-token prediction) ------------------------------
    mtp: bool = False
    mtp_coef: float = 0.3

    # ---- RWKV6 / RG-LRU ----------------------------------------------------
    rwkv_head_dim: int = 64
    lru_width: int = 0             # 0 -> d_model

    # ---- encoder-decoder / frontend stubs ----------------------------------
    encoder_layers: int = 0        # >0 -> enc-dec (whisper)
    frontend: str = ""             # "audio" | "vision" | "" (stub embeddings)
    n_frontend_tokens: int = 0     # vision stub: # of patch embeddings

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        if self.n_experts and self.d_expert == 0:
            object.__setattr__(self, "d_expert", self.d_ff)

    # ---- derived -----------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when decode cost does not grow with context length (needed
        for the long_500k shape): every layer is recurrent or local."""
        kinds = {b.kind for b in layer_plan(self)}
        return kinds <= {LOCAL_ATTN, RWKV6, RGLRU}

    def param_count(self) -> int:
        """Analytical parameter count (backbone; frontends are stubs)."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        hd = self.head_dim
        total = V * D                                  # embed
        if not self.tie_embeddings:
            total += D * V                             # lm head
        def attn_params() -> int:
            if self.mla:
                qr, kvr = self.q_lora_rank, self.kv_lora_rank
                nd, rd, vd = self.qk_nope_head_dim, self.qk_rope_head_dim, self.v_head_dim
                p = D * qr + qr * self.n_heads * (nd + rd)          # q loras
                p += D * (kvr + rd)                                  # kv down + k_rope
                p += kvr * self.n_heads * (nd + vd)                  # kv up
                p += self.n_heads * vd * D                           # out
                return p
            p = D * self.n_heads * hd + 2 * D * self.n_kv_heads * hd
            p += self.n_heads * hd * D
            return p
        def mlp_params(kind: str) -> int:
            if kind == MOE:
                e = self.n_experts * 3 * D * self.d_expert
                e += D * self.n_experts                              # router
                if self.d_shared:
                    e += 3 * D * self.d_shared
                return e
            mult = 3 if self.mlp_act == "swiglu" else 2
            return mult * D * F
        def seqmix_params(kind: str) -> int:
            if kind in (ATTN, LOCAL_ATTN):
                return attn_params()
            if kind == MLA:
                return attn_params()
            if kind == RWKV6:
                # r,k,v,g,o projections + decay/bonus + token-shift loras
                return 5 * D * D + 2 * D + 6 * D * 32 * 2
            if kind == RGLRU:
                W = self.lru_width
                # in/out proj x2 branches + gates
                return 2 * D * W + W * D + 2 * W * (W // max(1, self.n_heads))
            raise ValueError(kind)
        for blk in layer_plan(self):
            total += seqmix_params(blk.kind) + mlp_params(blk.mlp)
            if blk.cross_attn:
                total += attn_params()
        if self.encoder_layers:
            enc_blk = BlockSpec(ATTN, DENSE)
            total += self.encoder_layers * (seqmix_params(ATTN) + mlp_params(DENSE))
        if self.mtp:
            total += seqmix_params(ATTN) + mlp_params(DENSE) + 2 * D * D
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: shared + top-k routed only)."""
        if not self.n_experts:
            return self.param_count()
        D = self.d_model
        inactive_per_moe = (self.n_experts - self.top_k) * 3 * D * self.d_expert
        n_moe = sum(1 for b in layer_plan(self) if b.mlp == MOE)
        return self.param_count() - n_moe * inactive_per_moe


def layer_plan(cfg: ModelConfig) -> List[BlockSpec]:
    """Per-layer block specs for the decoder stack (encoder handled apart)."""
    plan: List[BlockSpec] = []
    pattern = cfg.attn_pattern or (ATTN,)
    for i in range(cfg.n_layers):
        kind = pattern[i % len(pattern)]
        if cfg.mla:
            kind = MLA if kind == ATTN else kind
        mlp = DENSE
        if cfg.n_experts and i >= cfg.first_dense:
            mlp = MOE
        plan.append(BlockSpec(kind, mlp, cross_attn=cfg.is_encdec))
    return plan


def layer_groups(cfg: ModelConfig) -> List[Tuple[BlockSpec, int]]:
    """Group *consecutive identical* BlockSpecs → (spec, count) for scanning.

    For repeating patterns (e.g. RecurrentGemma's rec,rec,attn), the groups
    alternate; we instead group by the full repeating super-block when that
    yields fewer groups (better scan utilization).
    """
    plan = layer_plan(cfg)
    groups: List[Tuple[BlockSpec, int]] = []
    for blk in plan:
        if groups and groups[-1][0] == blk:
            groups = groups[:-1] + [(blk, groups[-1][1] + 1)]
        else:
            groups.append((blk, 1))
    return groups


def reduce_config(cfg: ModelConfig, *, layers: int = 0, d_model: int = 64,
                  vocab: int = 256) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    pattern = cfg.attn_pattern or (ATTN,)
    n_layers = layers or max(2, len(pattern))
    n_heads = 4
    n_kv = min(cfg.n_kv_heads, n_heads) if cfg.n_kv_heads < cfg.n_heads else n_heads
    n_kv = max(1, min(n_kv, 2))
    changes = dict(
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=d_model // n_heads,
        d_ff=d_model * 2,
        vocab=vocab,
        window=min(cfg.window, 16) if cfg.window else 0,
        lru_width=d_model,
        rwkv_head_dim=d_model // n_heads,
    )
    if cfg.n_experts:
        changes.update(
            n_experts=4, top_k=2, d_expert=d_model,
            d_shared=d_model if cfg.d_shared else 0,
            first_dense=min(cfg.first_dense, 1),
        )
    if cfg.mla:
        changes.update(
            q_lora_rank=d_model // 2, kv_lora_rank=d_model // 2,
            qk_nope_head_dim=d_model // n_heads,
            qk_rope_head_dim=(d_model // n_heads) // 2,
            v_head_dim=d_model // n_heads,
        )
    if cfg.encoder_layers:
        changes["encoder_layers"] = 2
    if cfg.n_frontend_tokens:
        changes["n_frontend_tokens"] = 8
    return dataclasses.replace(cfg, **changes)
