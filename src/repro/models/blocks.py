"""Per-kind sequence-mixing blocks: init / apply / cache for one layer.

Block contract
--------------
``<kind>_init(rng, cfg) -> (params, axes)`` — parameters for ONE layer and a
mirror tree of logical-axis name tuples (used to derive PartitionSpecs).

``<kind>_apply(cfg, p, x, mode, cache, pos, enc_out) -> (y, new_cache)`` —
``mode`` is "train" | "prefill" | "decode"; x is (B, T, D) ((B, 1, D) for
decode).  ``pos`` is a scalar int32: tokens already in context.

``<kind>_cache(cfg, B, S, dtype)`` — zeroed per-layer cache structs.

RWKV6 blocks also own their channel-mix (the RWKV "FFN" needs its own
token-shift state), so the backbone skips the generic MLP for them.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import (
    chunked_attention, decode_attention, mlp_apply, mlp_init, norm, rope,
    split_tree, uinit,
)
from ..kernels import ops as kops

Axes = Tuple[str, ...]


# =========================================================================== #
# softmax attention (full + local window)                                      #
# =========================================================================== #
def attn_init(rng, cfg: ModelConfig, cross: bool = False):
    D, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    r = split_tree(rng, 6)
    p = {
        "ln": jnp.zeros((D,)),
        "wq": uinit(r[0], (D, H, hd), scale=1 / math.sqrt(D)),
        "wk": uinit(r[1], (D, Hkv, hd), scale=1 / math.sqrt(D)),
        "wv": uinit(r[2], (D, Hkv, hd), scale=1 / math.sqrt(D)),
        "wo": uinit(r[3], (H, hd, D), scale=1 / math.sqrt(H * hd)),
    }
    a = {
        "ln": ("d_model",),
        "wq": ("d_model", "heads", "head_dim"),
        "wk": ("d_model", "kv_heads", "head_dim"),
        "wv": ("d_model", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "d_model"),
    }
    if cfg.qk_norm and not cross:
        p["qn"] = jnp.zeros((hd,))
        p["kn"] = jnp.zeros((hd,))
        a["qn"] = ("head_dim",)
        a["kn"] = ("head_dim",)
    return p, a


def attn_cache(cfg: ModelConfig, B: int, S: int, dtype):
    """KV cache.  dtype int8 selects the quantized layout (per-token,
    per-head symmetric scales) — halves the HBM stream a decode step is
    bound by (EXPERIMENTS.md SSPerf cell C)."""
    Hkv, hd = cfg.n_kv_heads, cfg.head_dim
    cache = {
        "k": jnp.zeros((B, S, Hkv, hd), dtype),
        "v": jnp.zeros((B, S, Hkv, hd), dtype),
    }
    if dtype == jnp.int8:
        cache["ks"] = jnp.zeros((B, S, Hkv), jnp.float32)
        cache["vs"] = jnp.zeros((B, S, Hkv), jnp.float32)
    return cache


def _kv_quant(x):
    """x: (B, T, H, hd) -> (int8 values, (B, T, H) scales)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _kv_dequant(q, scale, dtype=jnp.bfloat16):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def _qkv(cfg: ModelConfig, p, x, positions, *, use_rope=True):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"])
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"])
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"])
    if cfg.qk_norm and "qn" in p:
        q = norm(q, p["qn"], "rmsnorm", cfg.norm_eps)
        k = norm(k, p["kn"], "rmsnorm", cfg.norm_eps)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply(cfg: ModelConfig, p, x, mode: str, cache, pos,
               *, window: int = 0, causal: bool = True, use_rope: bool = True):
    h = norm(x, p["ln"], cfg.norm_kind, cfg.norm_eps)
    B, T, D = h.shape
    if mode == "decode":
        pos = jnp.asarray(pos, jnp.int32)
        batched_pos = pos.ndim == 1        # per-request positions (serving)
        positions = pos[:, None] if batched_pos else jnp.full((1,), pos)
        q, k, v = _qkv(cfg, p, h, positions, use_rope=use_rope)
        quant = "ks" in cache              # int8 KV layout
        S = cache["k"].shape[1]
        slot = jnp.where(window > 0, pos % S, jnp.minimum(pos, S - 1))
        new_cache = {}
        if quant:
            kq, ks1 = _kv_quant(k)
            vq, vs1 = _kv_quant(v)
            writes = [("k", kq, 1), ("ks", ks1, 1), ("v", vq, 1), ("vs", vs1, 1)]
        else:
            writes = [("k", k, 1), ("v", v, 1)]
        for name, val, ax in writes:
            buf = cache[name]
            val = val.astype(buf.dtype)
            if batched_pos:
                new_cache[name] = buf.at[jnp.arange(B), slot].set(val[:, 0])
            else:
                new_cache[name] = lax.dynamic_update_slice_in_dim(
                    buf, val, slot, axis=ax)
        if quant:
            k_c = _kv_dequant(new_cache["k"], new_cache["ks"], h.dtype)
            v_c = _kv_dequant(new_cache["v"], new_cache["vs"], h.dtype)
        else:
            k_c, v_c = new_cache["k"], new_cache["v"]
        o = kops.flash_decode(q[:, 0], k_c, v_c, pos)
        y = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
        return x + y, new_cache

    positions = pos + jnp.arange(T)
    q, k, v = _qkv(cfg, p, h, positions, use_rope=use_rope)
    o = kops.flash_attention(q, k, v, causal=causal, window=window, q_offset=0)
    y = jnp.einsum("bthk,hkd->btd", o, p["wo"])
    new_cache = cache
    if mode == "prefill" and cache is not None:
        S = cache["k"].shape[1]
        quant = "ks" in cache
        if quant:
            (k, ks1), (v, vs1) = _kv_quant(k), _kv_quant(v)
        pairs = [("k", k), ("v", v)] + ([("ks", ks1), ("vs", vs1)] if quant else [])
        new_cache = {}
        for name, val in pairs:
            buf = cache[name]
            if T >= S:      # keep the last S tokens (ring window fully filled)
                new_cache[name] = val[:, T - S:].astype(buf.dtype)
            else:
                new_cache[name] = lax.dynamic_update_slice_in_dim(
                    buf, val.astype(buf.dtype), 0, axis=1)
    return x + y, new_cache


# --------------------------------------------------------------------------- #
# cross-attention (whisper decoder): KV comes from the encoder output,         #
# cached once at prefill.                                                      #
# --------------------------------------------------------------------------- #
def cross_cache(cfg: ModelConfig, B: int, S_enc: int, dtype):
    return {
        "ck": jnp.zeros((B, S_enc, cfg.n_heads, cfg.head_dim), dtype),
        "cv": jnp.zeros((B, S_enc, cfg.n_heads, cfg.head_dim), dtype),
    }


def cross_apply(cfg: ModelConfig, p, x, mode: str, cache, enc_out):
    """p: attn-style params (no qk_norm).  enc_out: (B, S_enc, D) or None
    (decode mode reads cached cross-KV)."""
    h = norm(x, p["ln"], cfg.norm_kind, cfg.norm_eps)
    q = jnp.einsum("btd,dhk->bthk", h, p["wq"])
    if mode == "decode":
        ck, cv = cache["ck"], cache["cv"]
        o = decode_attention(q[:, 0], ck, cv, jnp.asarray(ck.shape[1], jnp.int32))
        y = jnp.einsum("bhk,hkd->bd", o, p["wo"])[:, None]
        return x + y, cache
    ck = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    cv = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    o = chunked_attention(q, ck, cv, causal=False)
    y = jnp.einsum("bthk,hkd->btd", o, p["wo"])
    new_cache = cache
    if mode == "prefill" and cache is not None:
        new_cache = {"ck": ck.astype(cache["ck"].dtype), "cv": cv.astype(cache["cv"].dtype)}
    return x + y, new_cache


# =========================================================================== #
# MLA — DeepSeek multi-head latent attention                                   #
# =========================================================================== #
def mla_init(rng, cfg: ModelConfig):
    D, H = cfg.d_model, cfg.n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    r = split_tree(rng, 8)
    p = {
        "ln": jnp.zeros((D,)),
        "wdq": uinit(r[0], (D, qr)),
        "qn": jnp.zeros((qr,)),
        "wuq": uinit(r[1], (qr, H, nd + rd)),
        "wdkv": uinit(r[2], (D, kvr + rd)),
        "kvn": jnp.zeros((kvr,)),
        "wuk": uinit(r[3], (kvr, H, nd)),
        "wuv": uinit(r[4], (kvr, H, vd)),
        "wo": uinit(r[5], (H, vd, D), scale=1 / math.sqrt(H * vd)),
    }
    a = {
        "ln": ("d_model",), "wdq": ("d_model", "q_lora"), "qn": ("q_lora",),
        "wuq": ("q_lora", "heads", "head_dim"),
        "wdkv": ("d_model", "kv_lora"), "kvn": ("kv_lora",),
        "wuk": ("kv_lora", "heads", "head_dim"),
        "wuv": ("kv_lora", "heads", "head_dim"),
        "wo": ("heads", "head_dim", "d_model"),
    }
    return p, a


def mla_cache(cfg: ModelConfig, B: int, S: int, dtype):
    return {
        "ckv": jnp.zeros((B, S, cfg.kv_lora_rank), dtype),
        "kr": jnp.zeros((B, S, cfg.qk_rope_head_dim), dtype),
    }


def _mla_qkv_latent(cfg, p, h, positions):
    nd, rd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = norm(h @ p["wdq"], p["qn"], "rmsnorm", cfg.norm_eps)
    q = jnp.einsum("btr,rhk->bthk", cq, p["wuq"])          # (B,T,H,nd+rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    dkv = h @ p["wdkv"]                                     # (B,T,kvr+rd)
    ckv = norm(dkv[..., : cfg.kv_lora_rank], p["kvn"], "rmsnorm", cfg.norm_eps)
    k_rope = rope(dkv[..., cfg.kv_lora_rank:], positions, cfg.rope_theta,
                  heads=False)
    return q_nope, q_rope, ckv, k_rope


def mla_apply(cfg: ModelConfig, p, x, mode: str, cache, pos):
    nd, rd, vd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    scale = 1.0 / math.sqrt(nd + rd)
    h = norm(x, p["ln"], cfg.norm_kind, cfg.norm_eps)
    B, T, D = h.shape

    if mode == "decode":
        pos = jnp.asarray(pos, jnp.int32)
        batched_pos = pos.ndim == 1        # per-request positions (serving)
        positions = pos[:, None] if batched_pos else jnp.full((1,), pos)
        q_nope, q_rope, ckv, k_rope = _mla_qkv_latent(cfg, p, h, positions)
        S = cache["ckv"].shape[1]
        slot = jnp.minimum(pos, S - 1)
        if batched_pos:
            bidx = jnp.arange(B)
            ckv_c = cache["ckv"].at[bidx, slot].set(ckv[:, 0].astype(cache["ckv"].dtype))
            kr_c = cache["kr"].at[bidx, slot].set(k_rope[:, 0].astype(cache["kr"].dtype))
        else:
            ckv_c = lax.dynamic_update_slice_in_dim(cache["ckv"], ckv.astype(cache["ckv"].dtype), slot, axis=1)
            kr_c = lax.dynamic_update_slice_in_dim(cache["kr"], k_rope.astype(cache["kr"].dtype), slot, axis=1)
        # absorbed decode: score in latent space (cache stays rank-kvr)
        q_lat = jnp.einsum("bhk,rhk->bhr", q_nope[:, 0], p["wuk"])   # (B,H,kvr)
        s = jnp.einsum("bhr,bsr->bhs", q_lat, ckv_c, preferred_element_type=jnp.float32)
        s = s + jnp.einsum("bhk,bsk->bhs", q_rope[:, 0], kr_c, preferred_element_type=jnp.float32)
        pos_b = jnp.broadcast_to(pos, (B,))
        valid = jnp.arange(S)[None, :] < jnp.minimum(pos_b + 1, S)[:, None]
        s = jnp.where(valid[:, None, :], s * scale, -1e30)
        w = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhs,bsr->bhr", w.astype(ckv_c.dtype), ckv_c)
        o = jnp.einsum("bhr,rhv->bhv", o_lat, p["wuv"])              # (B,H,vd)
        y = jnp.einsum("bhv,hvd->bd", o, p["wo"])[:, None]
        return x + y, {"ckv": ckv_c, "kr": kr_c}

    positions = pos + jnp.arange(T)
    q_nope, q_rope, ckv, k_rope = _mla_qkv_latent(cfg, p, h, positions)
    k_nope = jnp.einsum("btr,rhk->bthk", ckv, p["wuk"])
    v = jnp.einsum("btr,rhv->bthv", ckv, p["wuv"])
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, T, cfg.n_heads, rd))],
        axis=-1,
    )
    o = kops.flash_attention(q, k, v, causal=True, scale=scale)
    y = jnp.einsum("bthv,hvd->btd", o, p["wo"])
    new_cache = cache
    if mode == "prefill" and cache is not None:
        S = cache["ckv"].shape[1]
        ckv_c = lax.dynamic_update_slice_in_dim(cache["ckv"], ckv.astype(cache["ckv"].dtype), 0, axis=1)
        kr_c = lax.dynamic_update_slice_in_dim(cache["kr"], k_rope.astype(cache["kr"].dtype), 0, axis=1)
        new_cache = {"ckv": ckv_c, "kr": kr_c}
    return x + y, new_cache


# =========================================================================== #
# RWKV6 (Finch) — time-mix + channel-mix                                       #
# =========================================================================== #
_LORA_R = 32
_DECAY_R = 64


def rwkv6_init(rng, cfg: ModelConfig):
    D, F = cfg.d_model, cfg.d_ff
    H = cfg.n_heads
    dk = cfg.rwkv_head_dim
    r = split_tree(rng, 14)
    p = {
        "ln_t": jnp.zeros((D,)),
        "mu_x": jnp.zeros((D,)),                 # ddlerp base mix
        "mu": jnp.zeros((5, D)),                 # per-target lerp (w,k,v,r,g)
        "lora_a": uinit(r[0], (D, 5 * _LORA_R)),
        "lora_b": uinit(r[1], (5, _LORA_R, D), scale=0.01),
        "w0": jnp.full((D,), -3.0),              # decay base (soft init)
        "wa": uinit(r[2], (D, _DECAY_R)),
        "wb": uinit(r[3], (_DECAY_R, D), scale=0.01),
        "u": uinit(r[4], (H, dk), scale=0.5),    # bonus
        "wr": uinit(r[5], (D, D)),
        "wk": uinit(r[6], (D, D)),
        "wv": uinit(r[7], (D, D)),
        "wg": uinit(r[8], (D, D)),
        "wo": uinit(r[9], (D, D)),
        "gn": jnp.zeros((H, dk)),                # per-head groupnorm scale
        # channel mix
        "ln_c": jnp.zeros((D,)),
        "cmu_k": jnp.zeros((D,)),
        "cmu_r": jnp.zeros((D,)),
        "cwk": uinit(r[10], (D, F)),
        "cwv": uinit(r[11], (F, D)),
        "cwr": uinit(r[12], (D, D)),
    }
    a = {
        "ln_t": ("d_model",), "mu_x": ("d_model",), "mu": (None, "d_model"),
        "lora_a": ("d_model", None), "lora_b": (None, None, "d_model"),
        "w0": ("d_model",), "wa": ("d_model", None), "wb": (None, "d_model"),
        "u": ("heads", None),
        "wr": ("d_model", "rwkv_d2"), "wk": ("d_model", "rwkv_d2"),
        "wv": ("d_model", "rwkv_d2"), "wg": ("d_model", "rwkv_d2"),
        "wo": ("rwkv_d2", "d_model"), "gn": ("heads", None),
        "ln_c": ("d_model",), "cmu_k": ("d_model",), "cmu_r": ("d_model",),
        "cwk": ("d_model", "d_ff"), "cwv": ("d_ff", "d_model"),
        "cwr": ("d_model", "rwkv_d2"),
    }
    return p, a


def rwkv6_cache(cfg: ModelConfig, B: int, S: int, dtype):
    H, dk = cfg.n_heads, cfg.rwkv_head_dim
    return {
        "x_tm": jnp.zeros((B, cfg.d_model), dtype),
        "x_cm": jnp.zeros((B, cfg.d_model), dtype),
        "s": jnp.zeros((B, H, dk, dk), jnp.float32),
    }


def _token_shift(x, x_prev):
    """x: (B,T,D); x_prev: (B,D) last token of the previous segment."""
    return jnp.concatenate([x_prev[:, None], x[:, :-1]], axis=1)


def _headify(x, H, d):
    B, T = x.shape[:2]
    return x.reshape(B, T, H, d)


def rwkv6_apply(cfg: ModelConfig, p, x, mode: str, cache, pos):
    B, T, D = x.shape
    H, dk = cfg.n_heads, cfg.rwkv_head_dim
    dtype = x.dtype
    zeros_prev = jnp.zeros((B, D), dtype)
    x_tm_prev = cache["x_tm"].astype(dtype) if cache is not None else zeros_prev
    x_cm_prev = cache["x_cm"].astype(dtype) if cache is not None else zeros_prev
    s0 = cache["s"] if cache is not None else jnp.zeros((B, H, dk, dk), jnp.float32)

    # ---- time mix ----------------------------------------------------------
    h = norm(x, p["ln_t"], cfg.norm_kind, cfg.norm_eps)
    h_shift = _token_shift(h, x_tm_prev)
    dx = h_shift - h
    xxx = h + dx * p["mu_x"]
    mix = jnp.tanh(xxx @ p["lora_a"]).reshape(B, T, 5, _LORA_R)
    mix = jnp.einsum("btfr,frd->btfd", mix, p["lora_b"])
    tgt = h[:, :, None] + dx[:, :, None] * (p["mu"][None, None] + mix)  # (B,T,5,D)
    x_w, x_k, x_v, x_r, x_g = [tgt[:, :, i] for i in range(5)]
    w_log = p["w0"] + jnp.tanh(x_w @ p["wa"]) @ p["wb"]
    w = jnp.exp(-jnp.exp(w_log.astype(jnp.float32)))           # decay in (0,1)
    r = _headify(x_r @ p["wr"], H, dk)
    k = _headify(x_k @ p["wk"], H, dk)
    v = _headify(x_v @ p["wv"], H, dk)
    g = jax.nn.silu(x_g @ p["wg"])
    w = _headify(w, H, dk)

    y, sT = kops.wkv6(r, k, v, w, p["u"], s0)                  # (B,T,H,dk)
    # per-head group norm
    yf = y.astype(jnp.float32)
    mu_ = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    yn = (yf - mu_) * lax.rsqrt(var + 64e-5) * (1.0 + p["gn"][None, None])
    out_t = (yn.reshape(B, T, D).astype(dtype) * g) @ p["wo"]
    x = x + out_t

    # ---- channel mix --------------------------------------------------------
    hc = norm(x, p["ln_c"], cfg.norm_kind, cfg.norm_eps)
    hc_shift = _token_shift(hc, x_cm_prev)
    dxc = hc_shift - hc
    xk = hc + dxc * p["cmu_k"]
    xr = hc + dxc * p["cmu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["cwk"]))
    out_c = jax.nn.sigmoid(xr @ p["cwr"]) * (kk @ p["cwv"])
    x = x + out_c

    new_cache = cache
    if cache is not None and mode in ("prefill", "decode"):
        new_cache = {
            "x_tm": h[:, -1].astype(cache["x_tm"].dtype),
            "x_cm": hc[:, -1].astype(cache["x_cm"].dtype),
            "s": sT,
        }
    return x, new_cache


# =========================================================================== #
# RG-LRU (Griffin / RecurrentGemma recurrent block)                            #
# =========================================================================== #
_CONV_W = 4
_LRU_C = 8.0


def rglru_init(rng, cfg: ModelConfig):
    D, W, H = cfg.d_model, cfg.lru_width, cfg.n_heads
    bw = W // H
    r = split_tree(rng, 7)
    p = {
        "ln": jnp.zeros((D,)),
        "w_x": uinit(r[0], (D, W)),
        "w_g": uinit(r[1], (D, W)),
        "conv_w": uinit(r[2], (_CONV_W, W), scale=0.5),
        "conv_b": jnp.zeros((W,)),
        "rg_a": uinit(r[3], (H, bw, bw)),        # recurrence gate (block diag)
        "rg_x": uinit(r[4], (H, bw, bw)),        # input gate (block diag)
        "rg_a_b": jnp.zeros((W,)),
        "rg_x_b": jnp.zeros((W,)),
        "lam": jnp.linspace(0.2, 0.9, W),        # softplus^-1-ish spread init
        "w_out": uinit(r[5], (W, D)),
    }
    a = {
        "ln": ("d_model",), "w_x": ("d_model", "lru"), "w_g": ("d_model", "lru"),
        "conv_w": (None, "lru"), "conv_b": ("lru",),
        "rg_a": ("heads", None, None), "rg_x": ("heads", None, None),
        "rg_a_b": ("lru",), "rg_x_b": ("lru",), "lam": ("lru",),
        "w_out": ("lru", "d_model"),
    }
    return p, a


def rglru_cache(cfg: ModelConfig, B: int, S: int, dtype):
    W = cfg.lru_width
    return {
        "conv": jnp.zeros((B, _CONV_W - 1, W), dtype),
        "h": jnp.zeros((B, W), jnp.float32),
    }


def _causal_conv(x, w, b, x_prev):
    """Depthwise causal conv, width 4.  x: (B,T,W); x_prev: (B,3,W)."""
    xx = jnp.concatenate([x_prev.astype(x.dtype), x], axis=1)
    T = x.shape[1]
    out = b + sum(w[i] * lax.dynamic_slice_in_dim(xx, (_CONV_W - 1 - i), T, axis=1)
                  for i in range(_CONV_W))
    return out


def _block_diag(x, w, b, H):
    """x: (B,T,W) -> block-diagonal linear with H blocks."""
    B, T, W = x.shape
    bw = W // H
    xh = x.reshape(B, T, H, bw)
    return (jnp.einsum("bthi,hij->bthj", xh, w).reshape(B, T, W) + b)


def rglru_apply(cfg: ModelConfig, p, x, mode: str, cache, pos):
    B, T, D = x.shape
    W, H = cfg.lru_width, cfg.n_heads
    h_in = norm(x, p["ln"], cfg.norm_kind, cfg.norm_eps)
    xb = h_in @ p["w_x"]                                     # recurrent branch
    gb = jax.nn.gelu(h_in @ p["w_g"])                        # gate branch
    conv_prev = (cache["conv"].astype(xb.dtype) if cache is not None
                 else jnp.zeros((B, _CONV_W - 1, W), xb.dtype))
    xc = _causal_conv(xb, p["conv_w"], p["conv_b"], conv_prev)
    rg = jax.nn.sigmoid(_block_diag(xc, p["rg_a"], p["rg_a_b"], H))
    ig = jax.nn.sigmoid(_block_diag(xc, p["rg_x"], p["rg_x_b"], H))
    log_a = -_LRU_C * jax.nn.softplus(p["lam"]) * rg.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated_x = (ig * xc).astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bt = beta * gated_x
    h0 = cache["h"] if cache is not None else jnp.zeros((B, W), jnp.float32)

    h_seq, hT = kops.linear_recurrence(a, bt, h0)            # (B,T,W) fp32
    y = (gb * h_seq.astype(gb.dtype)) @ p["w_out"]
    new_cache = cache
    if cache is not None and mode in ("prefill", "decode"):
        tail = jnp.concatenate([conv_prev, xb], axis=1)[:, -(_CONV_W - 1):]
        new_cache = {"conv": tail.astype(cache["conv"].dtype), "h": hT}
    return x + y, new_cache


# =========================================================================== #
# dispatch tables                                                              #
# =========================================================================== #
def mlp_block_init(rng, cfg: ModelConfig):
    p, a = mlp_init(rng, cfg.d_model, cfg.d_ff, cfg.mlp_act)
    p = {"ln": jnp.zeros((cfg.d_model,)), **p}
    a = {"ln": ("d_model",), **a}
    return p, a


def mlp_block_apply(cfg: ModelConfig, p, x):
    h = norm(x, p["ln"], cfg.norm_kind, cfg.norm_eps)
    return x + mlp_apply(p, h, cfg.mlp_act)
