"""Primitive layers shared by every architecture (pure-jnp, shard-friendly).

Attention here is the *chunked* formulation (bounded memory: each query chunk
attends to the full — or windowed — key range with fp32 softmax).  It is both
the CPU/dry-run execution path and the jnp oracle for the Pallas flash
kernels in ``repro.kernels``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "rmsnorm", "layernorm", "norm", "rope", "rope_angles", "sinusoid_pos",
    "mlp_apply", "mlp_init", "chunked_attention", "decode_attention",
    "uinit", "split_tree",
]

_NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# init helpers                                                                 #
# --------------------------------------------------------------------------- #
def uinit(rng, shape, scale: Optional[float] = None, dtype=jnp.float32):
    """Scaled-uniform (LeCun-ish) initializer; scale defaults to 1/sqrt(fan_in)."""
    if scale is None:
        fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
        scale = 1.0 / math.sqrt(max(1, fan_in))
    return jax.random.uniform(rng, shape, dtype, -scale, scale)


def split_tree(rng, n: int):
    return list(jax.random.split(rng, n))


# --------------------------------------------------------------------------- #
# norms                                                                        #
# --------------------------------------------------------------------------- #
def rmsnorm(x, w, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps)
    return (out * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def layernorm(x, w, b=None, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * lax.rsqrt(var + eps) * (1.0 + w.astype(jnp.float32))
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out.astype(x.dtype)


def norm(x, w, kind: str = "rmsnorm", eps: float = 1e-6):
    return layernorm(x, w, eps=eps) if kind == "layernorm" else rmsnorm(x, w, eps)


# --------------------------------------------------------------------------- #
# positions                                                                    #
# --------------------------------------------------------------------------- #
def rope_angles(positions, head_dim: int, theta: float):
    """(..., hd/2) angles for the given integer positions."""
    freqs = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))
    return positions.astype(jnp.float32)[..., None] * freqs  # (..., hd/2)


def rope(x, positions, theta: float = 1e4, *, heads: bool = True):
    """Rotary embedding.  x: (..., T, H, hd) when ``heads`` (default) else
    (..., T, hd); positions: (T,) (or (1,) during decode)."""
    hd = x.shape[-1]
    ang = rope_angles(positions, hd, theta)            # (T, hd/2)
    if heads:
        ang = ang[..., None, :]                        # (T, 1, hd/2)
    while ang.ndim < x.ndim:
        ang = ang[None, ...]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoid_pos(T: int, d: int, offset: int = 0):
    pos = jnp.arange(offset, offset + T, dtype=jnp.float32)
    ang = rope_angles(pos, d, 1e4)                     # (T, d/2)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)  # (T, d)


# --------------------------------------------------------------------------- #
# MLP                                                                          #
# --------------------------------------------------------------------------- #
def mlp_init(rng, d: int, f: int, act: str):
    r = split_tree(rng, 3)
    if act in ("swiglu", "gelu_gated"):
        p = {"wg": uinit(r[0], (d, f)), "wu": uinit(r[1], (d, f)),
             "wd": uinit(r[2], (f, d))}
        a = {"wg": ("d_model", "d_ff"), "wu": ("d_model", "d_ff"),
             "wd": ("d_ff", "d_model")}
    else:  # plain gelu (whisper)
        p = {"wi": uinit(r[0], (d, f)), "wo": uinit(r[1], (f, d)),
             "bi": jnp.zeros((f,)), "bo": jnp.zeros((d,))}
        a = {"wi": ("d_model", "d_ff"), "wo": ("d_ff", "d_model"),
             "bi": ("d_ff",), "bo": ("d_model",)}
    return p, a


def mlp_apply(p, x, act: str):
    if act in ("swiglu", "gelu_gated"):
        g = x @ p["wg"]
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        return (g * (x @ p["wu"])) @ p["wd"]
    h = jax.nn.gelu(x @ p["wi"] + p["bi"])
    return h @ p["wo"] + p["bo"]


# --------------------------------------------------------------------------- #
# attention (chunked oracle)                                                   #
# --------------------------------------------------------------------------- #
def _pick_chunk(T: int, target: int = 1024) -> int:
    c = min(T, target)
    while T % c:
        c //= 2
    return max(c, 1)


def chunked_attention(
    q, k, v, *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
    scale: Optional[float] = None,
    chunk: int = 1024,
):
    """Chunked multi-head attention with GQA.

    q: (B, Tq, H, hd); k, v: (B, Tk, Hkv, hd_k/hd_v).  Each query chunk
    attends to the full key range (or the sliding window for local
    attention), with fp32 softmax.  Memory: O(chunk x window-or-Tk).
    """
    B, Tq, H, hd = q.shape
    Tk, Hkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    G = H // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    c = _pick_chunk(Tq, chunk)
    nq = Tq // c

    qc = q.reshape(B, nq, c, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)

    use_window = window > 0 and window < Tk
    kv_span = min(Tk, window + c) if use_window else Tk

    def one_chunk(ci, q_blk):
        # q_blk: (B, c, Hkv, G, hd)
        row = q_offset + ci * c + jnp.arange(c)                    # (c,)
        if use_window:
            start = jnp.clip(ci * c + q_offset - window + 1, 0, Tk - kv_span)
            k_blk = lax.dynamic_slice_in_dim(k, start, kv_span, axis=1)
            v_blk = lax.dynamic_slice_in_dim(v, start, kv_span, axis=1)
            col = start + jnp.arange(kv_span)
        else:
            k_blk, v_blk, col = k, v, jnp.arange(Tk)
        s = jnp.einsum("bckgh,btkh->bckgt", q_blk, k_blk,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((c, s.shape[-1]), dtype=bool)
        if causal:
            mask &= col[None, :] <= row[:, None]
        if window > 0:
            mask &= col[None, :] > row[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, _NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bckgt,btkh->bckgh", p.astype(v.dtype), v_blk,
                       preferred_element_type=jnp.float32)
        return o.astype(q.dtype)

    if nq == 1:
        out = one_chunk(0, qc[0])[None]
    else:
        out = lax.map(lambda args: one_chunk(*args), (jnp.arange(nq), qc))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Tq, H, hdv)
    return out


def decode_attention(q, k_cache, v_cache, cur_len, *, scale=None, ring: bool = False):
    """Single-token attention against a (possibly ring-buffered) KV cache.

    q: (B, H, hd); k_cache/v_cache: (B, S, Hkv, hd); cur_len: () or (B,)
    int32 — number of tokens already in context (the new token's position;
    per-request when (B,), for continuous batching).  For ring buffers the
    cache *is* the window; every slot < min(cur_len+1, S) is valid (the new
    token has been written before attention).
    """
    B, S, Hkv, hd = k_cache.shape
    H = q.shape[1]
    G = H // Hkv
    hdv = v_cache.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    qg = q.reshape(B, Hkv, G, -1)
    s = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    cur = jnp.broadcast_to(jnp.asarray(cur_len, jnp.int32), (B,))
    valid = jnp.arange(S)[None, :] < jnp.minimum(cur + 1, S)[:, None]
    s = jnp.where(valid[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, H, hdv).astype(q.dtype)
