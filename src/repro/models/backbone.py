"""Model backbone: parameter init, scanned layer stacks, train/prefill/decode.

The layer stack is organized as *groups* of consecutive identical layers
(``config.layer_groups``); each group's parameters are stacked with a
leading ``count`` axis and the group is executed with ``jax.lax.scan`` —
HLO size (and XLA compile time at 512 partitioned devices) stays O(#groups),
not O(depth).  Heterogeneous patterns (RecurrentGemma's rec/rec/attn,
DeepSeek's 3-dense prefix) simply produce a few more groups.

Caches mirror the group structure: ``caches["groups"][i]`` is the stacked
per-layer cache pytree for group i, threaded through the same scan.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import blocks
from .config import (
    ATTN, DENSE, LOCAL_ATTN, MLA, MOE, RGLRU, RWKV6, BlockSpec, ModelConfig,
    layer_groups,
)
from .layers import norm, split_tree, uinit
from .moe import moe_apply, moe_init

Params = Dict[str, Any]

# A (logical-name) sharding hint for EP mode, set by repro.launch.shardings.
_EP_SPEC = None
# Residual-stream sharding constraint (sequence parallelism), ditto.
_ACT_SPEC = None


def set_ep_spec(spec) -> None:
    """Expert-parallel sharding constraint for the MoE dispatch buffer."""
    global _EP_SPEC
    _EP_SPEC = spec


def set_act_spec(spec) -> None:
    """Sharding constraint applied to the (B, T, D) residual stream between
    layers (sequence parallelism when the spec shards T over 'model')."""
    global _ACT_SPEC
    _ACT_SPEC = spec


# True layer unrolling (Python loop instead of lax.scan).  Only used by the
# dry-run's shallow cost-extrapolation lowerings: XLA's cost_analysis counts
# a while-loop body ONCE regardless of trip count, so exact per-layer
# FLOPs/bytes/collective costs are only visible in unrolled HLO.
_UNROLL = False


def set_unroll(flag: bool) -> None:
    global _UNROLL
    _UNROLL = bool(flag)


def _constrain_act(h):
    if _ACT_SPEC is not None and h.ndim == len(_ACT_SPEC) and h.shape[1] > 1:
        h = jax.lax.with_sharding_constraint(h, _ACT_SPEC)
    return h


# =========================================================================== #
# init                                                                         #
# =========================================================================== #
def _block_init(rng, cfg: ModelConfig, spec: BlockSpec):
    """Params+axes for ONE layer of this spec."""
    p: Params = {}
    a: Params = {}
    if spec.kind in (ATTN, LOCAL_ATTN):
        p["mix"], a["mix"] = blocks.attn_init(rng, cfg)
    elif spec.kind == MLA:
        p["mix"], a["mix"] = blocks.mla_init(rng, cfg)
    elif spec.kind == RWKV6:
        p["mix"], a["mix"] = blocks.rwkv6_init(rng, cfg)
    elif spec.kind == RGLRU:
        p["mix"], a["mix"] = blocks.rglru_init(rng, cfg)
    else:
        raise ValueError(spec.kind)
    r2, r3 = jax.random.split(jax.random.fold_in(rng, 7))
    if spec.cross_attn:
        p["cross"], a["cross"] = blocks.attn_init(r2, cfg, cross=True)
    if spec.kind != RWKV6:  # RWKV6 owns its channel mix
        if spec.mlp == MOE:
            p["mlp"], a["mlp"] = moe_init(r3, cfg)
        else:
            p["mlp"], a["mlp"] = blocks.mlp_block_init(r3, cfg)
    return p, a


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)


def _block_axes(cfg: ModelConfig, spec: BlockSpec):
    """Axes tree for one layer WITHOUT allocating parameters (the axes tree
    is static python; capture it from an abstract trace)."""
    box = {}

    def f(r):
        p, a = _block_init(r, cfg, spec)
        box["a"] = a
        return p

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["a"]


def _group_init(rng, cfg: ModelConfig, spec: BlockSpec, count: int):
    """Stacked params for a group (leading ``count`` axis)."""
    axes_one = _block_axes(cfg, spec)
    keys = jax.random.split(rng, count)
    params = jax.vmap(lambda r: _block_init(r, cfg, spec)[0])(keys)
    axes = jax.tree.map(lambda ax: ("layers",) + tuple(ax), axes_one,
                        is_leaf=_is_axes_leaf)
    return params, axes


def init_params(cfg: ModelConfig, rng, dtype=jnp.float32):
    """Full parameter pytree + logical-axes pytree."""
    r = split_tree(rng, 8)
    params: Params = {}
    axes: Params = {}
    params["embed"] = uinit(r[0], (cfg.vocab, cfg.d_model), scale=0.02)
    axes["embed"] = ("vocab", "d_model")

    groups = layer_groups(cfg)
    gp, ga = [], []
    for i, (spec, count) in enumerate(groups):
        p, a = _group_init(jax.random.fold_in(r[1], i), cfg, spec, count)
        gp.append(p)
        ga.append(a)
    params["groups"] = gp
    axes["groups"] = ga
    params["final_norm"] = jnp.zeros((cfg.d_model,))
    axes["final_norm"] = ("d_model",)

    if not cfg.tie_embeddings:
        params["head"] = uinit(r[2], (cfg.d_model, cfg.vocab), scale=0.02)
        axes["head"] = ("d_model", "vocab")

    if cfg.is_encdec:
        spec = BlockSpec(ATTN, DENSE)
        p, a = _group_init(r[3], cfg, spec, cfg.encoder_layers)
        params["enc"] = {"groups": [p], "final_norm": jnp.zeros((cfg.d_model,))}
        axes["enc"] = {"groups": [a], "final_norm": ("d_model",)}

    if cfg.mtp:
        rr = split_tree(r[4], 4)
        blk_p, blk_a = _block_init(rr[0], cfg, BlockSpec(ATTN, DENSE))
        params["mtp"] = {
            "proj": uinit(rr[1], (2 * cfg.d_model, cfg.d_model)),
            "ln_h": jnp.zeros((cfg.d_model,)),
            "ln_e": jnp.zeros((cfg.d_model,)),
            "block": blk_p,
        }
        axes["mtp"] = {
            "proj": (None, "d_model"), "ln_h": ("d_model",),
            "ln_e": ("d_model",), "block": blk_a,
        }
    params = jax.tree.map(lambda x: x.astype(dtype) if x.dtype == jnp.float32 else x, params)
    return params, axes


def param_axes(cfg: ModelConfig):
    """Logical axes without materializing parameters."""
    box = {}

    def f(r):
        p, a = init_params(cfg, r)
        box["a"] = a
        return p

    jax.eval_shape(f, jax.random.PRNGKey(0))
    return box["a"]


def param_shapes(cfg: ModelConfig, dtype=jnp.float32):
    return jax.eval_shape(
        lambda r: init_params(cfg, r, dtype=dtype)[0], jax.random.PRNGKey(0))


# =========================================================================== #
# caches                                                                       #
# =========================================================================== #
def _block_cache(cfg: ModelConfig, spec: BlockSpec, B: int, S: int,
                 S_enc: int, dtype):
    c: Params = {}
    # int8 (quantized) layout exists for plain attention KV only; recurrent
    # state / MLA latents / cross-KV stay bf16 under an int8 request.
    alt = jnp.bfloat16 if dtype == jnp.int8 else dtype
    if spec.kind == ATTN:
        c["mix"] = blocks.attn_cache(cfg, B, S, dtype)
    elif spec.kind == LOCAL_ATTN:
        c["mix"] = blocks.attn_cache(cfg, B, min(S, cfg.window), dtype)
    elif spec.kind == MLA:
        c["mix"] = blocks.mla_cache(cfg, B, S, alt)
    elif spec.kind == RWKV6:
        c["mix"] = blocks.rwkv6_cache(cfg, B, S, alt)
    elif spec.kind == RGLRU:
        c["mix"] = blocks.rglru_cache(cfg, B, S, alt)
    if spec.cross_attn:
        c["cross"] = blocks.cross_cache(cfg, B, S_enc, alt)
    return c


def init_cache(cfg: ModelConfig, B: int, S: int, S_enc: int = 0,
               dtype=jnp.bfloat16):
    """Decode caches, group-structured (stacked leading ``count`` axis)."""
    out = []
    for spec, count in layer_groups(cfg):
        one = _block_cache(cfg, spec, B, S, S_enc, dtype)
        out.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (count,) + x.shape), one))
    return {"groups": out}


def cache_shapes(cfg: ModelConfig, B: int, S: int, S_enc: int = 0,
                 dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(init_cache, cfg, B, S, S_enc=S_enc, dtype=dtype))


# =========================================================================== #
# forward                                                                      #
# =========================================================================== #
def _apply_block(cfg: ModelConfig, spec: BlockSpec, p, h, mode, cache, pos,
                 enc_out):
    aux = jnp.zeros((), jnp.float32)
    new_cache = dict(cache) if cache is not None else None
    mix_c = cache.get("mix") if cache is not None else None
    if spec.kind == ATTN:
        h, c = blocks.attn_apply(cfg, p["mix"], h, mode, mix_c, pos,
                                 causal=cfg.causal)
    elif spec.kind == LOCAL_ATTN:
        h, c = blocks.attn_apply(cfg, p["mix"], h, mode, mix_c, pos,
                                 window=cfg.window)
    elif spec.kind == MLA:
        h, c = blocks.mla_apply(cfg, p["mix"], h, mode, mix_c, pos)
    elif spec.kind == RWKV6:
        h, c = blocks.rwkv6_apply(cfg, p["mix"], h, mode, mix_c, pos)
    elif spec.kind == RGLRU:
        h, c = blocks.rglru_apply(cfg, p["mix"], h, mode, mix_c, pos)
    else:
        raise ValueError(spec.kind)
    if new_cache is not None:
        new_cache["mix"] = c
    if spec.cross_attn:
        cc = cache.get("cross") if cache is not None else None
        h, c2 = blocks.cross_apply(cfg, p["cross"], h, mode, cc, enc_out)
        if new_cache is not None:
            new_cache["cross"] = c2
    if spec.kind != RWKV6:
        if spec.mlp == MOE:
            h, a = moe_apply(cfg, p["mlp"], h, ep_spec=_EP_SPEC)
            aux = aux + a
        else:
            h = blocks.mlp_block_apply(cfg, p["mlp"], h)
    return h, new_cache, aux


def _run_groups(cfg: ModelConfig, groups_p, h, mode, caches, pos, enc_out,
                specs, remat: bool = False):
    total_aux = jnp.zeros((), jnp.float32)
    new_caches = []
    for gi, ((spec, count), gp) in enumerate(zip(specs, groups_p)):
        gcache = caches[gi] if caches is not None else None

        if count == 1:
            p1 = jax.tree.map(lambda x: x[0], gp)
            c1 = jax.tree.map(lambda x: x[0], gcache) if gcache is not None else None
            fn = functools.partial(_apply_block, cfg, spec, mode=mode, pos=pos,
                                   enc_out=enc_out)
            if remat:
                fn = jax.checkpoint(
                    lambda p_, h_, c_: _apply_block(cfg, spec, p_, h_, mode, c_, pos, enc_out))
                h, c_new, aux = fn(p1, h, c1)
            else:
                h, c_new, aux = _apply_block(cfg, spec, p1, h, mode, c1, pos, enc_out)
            h = _constrain_act(h)
            total_aux = total_aux + aux
            if gcache is not None:
                new_caches.append(jax.tree.map(lambda x: x[None], c_new))
            continue

        has_cache = gcache is not None

        if _UNROLL:
            cs = []
            for li in range(count):
                lp = jax.tree.map(lambda x: x[li], gp)
                lc = (jax.tree.map(lambda x: x[li], gcache) if has_cache else None)
                h, c_new, aux = _apply_block(cfg, spec, lp, h, mode, lc, pos, enc_out)
                h = _constrain_act(h)
                total_aux = total_aux + aux
                if has_cache:
                    cs.append(c_new)
            if has_cache:
                new_caches.append(
                    jax.tree.map(lambda *xs: jnp.stack(xs), *cs))
            continue

        def body(carry, xs):
            hh, aux_acc = carry
            lp, lc = xs
            lc = lc if has_cache else None
            hh, c_new, aux = _apply_block(cfg, spec, lp, hh, mode, lc, pos, enc_out)
            hh = _constrain_act(hh)
            return (hh, aux_acc + aux), (c_new if has_cache else 0.0)

        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        # lax.scan needs a concrete xs pytree; use a dummy zeros array when
        # there is no cache so the structure stays static.
        xs = (gp, gcache if has_cache else jnp.zeros((count,), jnp.float32))
        (h, total_aux), ys = lax.scan(body, (h, total_aux), xs)
        if gcache is not None:
            new_caches.append(ys)
    return h, (new_caches if caches is not None else None), total_aux


def encode(cfg: ModelConfig, params, enc_embeds, remat: bool = False):
    """Whisper-style encoder over stub frame embeddings (B, S, D)."""
    from .layers import sinusoid_pos

    h = enc_embeds + sinusoid_pos(enc_embeds.shape[1], cfg.d_model).astype(
        enc_embeds.dtype)
    specs = [(BlockSpec(ATTN, DENSE), cfg.encoder_layers)]
    cfg_enc = cfg
    # encoder attention is bidirectional
    object.__setattr__ if False else None
    import dataclasses as _dc
    cfg_enc = _dc.replace(cfg, causal=False)
    h, _, _ = _run_groups(cfg_enc, params["enc"]["groups"], h, "train", None,
                          0, None, specs, remat=remat)
    return norm(h, params["enc"]["final_norm"], cfg.norm_kind, cfg.norm_eps)


def forward(cfg: ModelConfig, params, h, mode: str, caches=None, pos=0,
            enc_out=None, remat: bool = False):
    """Backbone over input embeddings h (B, T, D).  Returns (h, caches, aux)."""
    specs = layer_groups(cfg)
    g_caches = caches["groups"] if caches is not None else None
    h, new_g, aux = _run_groups(cfg, params["groups"], h, mode, g_caches, pos,
                                enc_out, specs, remat=remat)
    h = norm(h, params["final_norm"], cfg.norm_kind, cfg.norm_eps)
    new_caches = {"groups": new_g} if caches is not None else None
    return h, new_caches, aux


def embed_tokens(cfg: ModelConfig, params, tokens):
    return jnp.take(params["embed"], tokens, axis=0)


def logits_fn(cfg: ModelConfig, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return h @ w


# =========================================================================== #
# losses                                                                       #
# =========================================================================== #
def _xent(logits, labels, mask=None):
    """Mean next-token cross-entropy in fp32.  logits: (B,T,V)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def _mtp_loss(cfg: ModelConfig, params, h, tokens):
    """DeepSeek MTP: one extra depth predicting token t+2 from
    [norm(h_t); norm(emb(tok_{t+1}))]."""
    m = params["mtp"]
    B, T, D = h.shape
    h_in = norm(h[:, : T - 2], m["ln_h"], cfg.norm_kind, cfg.norm_eps)
    e_in = norm(embed_tokens(cfg, params, tokens[:, 1: T - 1]), m["ln_e"],
                cfg.norm_kind, cfg.norm_eps)
    hm = jnp.concatenate([h_in, e_in], axis=-1) @ m["proj"]
    hm, _, _ = _apply_block(cfg, BlockSpec(ATTN, DENSE), m["block"], hm,
                            "train", None, 0, None)
    logits = logits_fn(cfg, params, hm)
    return _xent(logits, tokens[:, 2:])


def lm_loss(cfg: ModelConfig, params, batch, remat: bool = True):
    """Causal-LM training loss for every family.

    batch: {"tokens": (B,T) int32} (+ "enc_embeds" for enc-dec,
    "vision_embeds" for VLM — stub frontends).  Returns (loss, metrics).
    """
    tokens = batch["tokens"]
    h = embed_tokens(cfg, params, tokens)
    enc_out = None
    mask = None
    if cfg.is_encdec:
        enc_out = encode(cfg, params, batch["enc_embeds"], remat=remat)
    if cfg.frontend == "vision":
        ve = batch["vision_embeds"].astype(h.dtype)     # (B, Nv, D)
        nv = ve.shape[1]
        h = jnp.concatenate([ve, h[:, nv:]], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros((h.shape[0], nv - 1)), jnp.ones((h.shape[0], h.shape[1] - nv))],
            axis=1)
    h, _, aux = forward(cfg, params, h, "train", enc_out=enc_out, remat=remat)
    logits = logits_fn(cfg, params, h[:, :-1])
    loss = _xent(logits, tokens[:, 1:], mask)
    metrics = {"xent": loss, "aux": aux}
    loss = loss + aux
    if cfg.mtp:
        mtp = _mtp_loss(cfg, params, h, tokens)
        metrics["mtp"] = mtp
        loss = loss + cfg.mtp_coef * mtp
    return loss, metrics


# =========================================================================== #
# serving                                                                      #
# =========================================================================== #
def prefill(cfg: ModelConfig, params, batch, caches):
    """Process the full prompt, fill caches, return last-token logits."""
    tokens = batch["tokens"]
    h = embed_tokens(cfg, params, tokens)
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(cfg, params, batch["enc_embeds"])
    if cfg.frontend == "vision":
        ve = batch["vision_embeds"].astype(h.dtype)
        h = jnp.concatenate([ve, h[:, ve.shape[1]:]], axis=1)
    h, caches, _ = forward(cfg, params, h, "prefill", caches=caches,
                           enc_out=enc_out)
    return logits_fn(cfg, params, h[:, -1]), caches


def decode_step(cfg: ModelConfig, params, tokens, caches, pos):
    """One decode step.  tokens: (B,) int32; pos: scalar int32."""
    h = embed_tokens(cfg, params, tokens[:, None])
    h, caches, _ = forward(cfg, params, h, "decode", caches=caches, pos=pos)
    return logits_fn(cfg, params, h[:, 0]), caches
