"""Model definitions for the assigned architectures.

``backbone`` provides the family-agnostic stack (init / lm_loss / prefill /
decode_step); ``config.ModelConfig`` describes every family; per-arch configs
live in ``repro.configs``.
"""
from . import backbone, blocks, config, layers, moe  # noqa: F401
from .config import ModelConfig, reduce_config        # noqa: F401
