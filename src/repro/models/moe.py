"""Mixture-of-Experts layer: shared experts + routed top-k (sort-based dispatch).

TPU-native dispatch: instead of the (tokens x experts x capacity) one-hot
einsum (memory O(T*E*C) — prohibitive at 256 experts), tokens are *sorted* by
assigned expert and scattered into a dense (E, C, D) buffer with per-expert
capacity C = ceil(cf * T * k / E); expert compute is then one batched matmul
(E, C, D) x (E, D, F) whose FLOPs match the *active* parameter count (plus
the capacity-factor slack).  Tokens over capacity are dropped (standard
Switch-style behaviour; the aux loss keeps the router balanced).

Sharding: with experts replicated, the buffer's D/F dims are TP-sharded
(baseline).  Setting ``ep_axis`` adds a sharding constraint placing experts
on the model axis — GSPMD then inserts the all-to-all dispatch/combine
(expert parallelism, the DeepSeek-style layout) — the EP hillclimb toggle.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .config import ModelConfig
from .layers import norm, split_tree, uinit

__all__ = ["moe_init", "moe_apply"]


def moe_init(rng, cfg: ModelConfig):
    D, Fe = cfg.d_model, cfg.d_expert
    # padded ("dead") experts make E divide the mesh's model axis (e.g.
    # qwen2-moe's 60 -> 64); the router never selects them (masked logits)
    E = cfg.n_experts + cfg.n_experts_pad
    r = split_tree(rng, 8)
    p = {
        "ln": jnp.zeros((D,)),
        "router": uinit(r[0], (D, E), scale=0.02),
        "wg": uinit(r[1], (E, D, Fe), scale=1 / math.sqrt(D)),
        "wu": uinit(r[2], (E, D, Fe), scale=1 / math.sqrt(D)),
        "wd": uinit(r[3], (E, Fe, D), scale=1 / math.sqrt(Fe)),
    }
    a = {
        "ln": ("d_model",),
        "router": ("d_model", None),
        "wg": ("experts", "d_model", "d_expert"),
        "wu": ("experts", "d_model", "d_expert"),
        "wd": ("experts", "d_expert", "d_model"),
    }
    if cfg.d_shared:
        p.update({
            "swg": uinit(r[4], (D, cfg.d_shared)),
            "swu": uinit(r[5], (D, cfg.d_shared)),
            "swd": uinit(r[6], (cfg.d_shared, D)),
        })
        a.update({
            "swg": ("d_model", "d_shared"), "swu": ("d_model", "d_shared"),
            "swd": ("d_shared", "d_model"),
        })
        if cfg.shared_gate:
            p["sgate"] = uinit(r[7], (D, 1), scale=0.02)
            a["sgate"] = ("d_model", None)
    return p, a


# Number of independent routing groups.  Real systems dispatch per DP rank:
# each rank routes only its own tokens, so the scatter/gather stays rank-
# local and the only cross-device movement is the intended dispatch
# all-to-all.  Expressed in GSPMD by giving the token set a static leading
# ``groups`` axis sharded over the data axes (repro.launch.shardings sets
# this + the buffer constraint); a single global sort-scatter is
# unpartitionable and forces XLA to replicate the (E, C, D) buffer.
_GROUPS = 1


def set_groups(g: int) -> None:
    global _GROUPS
    _GROUPS = max(1, int(g))


def get_groups() -> int:
    return _GROUPS


def _dispatch_compute(cfg: ModelConfig, p, x3d, probs, ep_spec):
    """x3d: (G, Tg, D); probs: (G, Tg, E).  Returns routed output (G, Tg, D).

    Per-group sort-based dispatch: tokens are sorted by assigned expert and
    scattered into a dense (G, E, C, D) buffer with per-expert, per-group
    capacity C = ceil(cf * Tg * k / E); expert compute is one batched matmul
    whose FLOPs match the active parameter count (+ capacity slack)."""
    G, Tg, D = x3d.shape
    E = cfg.n_experts + cfg.n_experts_pad     # buffer spans padded experts
    k = cfg.top_k
    # capacity per *real* expert (padded ones receive no tokens)
    C = max(1, int(math.ceil(cfg.capacity_factor * Tg * k / cfg.n_experts)))

    topv, topi = lax.top_k(probs, k)                         # (G, Tg, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    flat_e = topi.reshape(G, Tg * k)
    order = jnp.argsort(flat_e, axis=1, stable=True)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    seg_start = jax.vmap(
        lambda s: jnp.searchsorted(s, jnp.arange(E)))(sorted_e)   # (G, E)
    pos_in_e = jnp.arange(Tg * k)[None] - jnp.take_along_axis(
        seg_start, sorted_e, axis=1)
    keep = pos_in_e < C
    slot = jnp.where(keep, sorted_e * C + pos_in_e, E * C)   # E*C = drop slot
    token_of = order // k

    buf = jax.vmap(
        lambda xg, sl, tk: jnp.zeros((E * C, D), x3d.dtype).at[sl].set(
            xg[tk], mode="drop")
    )(x3d, slot, token_of).reshape(G, E, C, D)
    if ep_spec is not None:
        buf = lax.with_sharding_constraint(buf, ep_spec)

    h_g = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wg"]))
    h_u = jnp.einsum("gecd,edf->gecf", buf, p["wu"])
    out = jnp.einsum("gecf,efd->gecd", h_g * h_u, p["wd"])   # (G, E, C, D)
    if ep_spec is not None:
        out = lax.with_sharding_constraint(out, ep_spec)
    out = out.reshape(G, E * C, D)

    gathered = jnp.take_along_axis(
        out, jnp.minimum(slot, E * C - 1)[..., None], axis=1)
    gathered = jnp.where(keep[..., None], gathered, 0.0)
    w = jnp.take_along_axis(topv.reshape(G, Tg * k), order, axis=1)
    gathered = gathered * w[..., None].astype(gathered.dtype)
    y = jax.vmap(
        lambda tk, ga: jnp.zeros((Tg, D), x3d.dtype).at[tk].add(ga)
    )(token_of, gathered)
    return y


def moe_apply(cfg: ModelConfig, p, x, ep_spec=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, T, D) -> (y, aux_loss).  aux = load-balance + router-z."""
    B, T, D = x.shape
    h = norm(x, p["ln"], cfg.norm_kind, cfg.norm_eps)
    x2d = h.reshape(B * T, D)

    logits = (x2d @ p["router"]).astype(jnp.float32)         # (T', E_alloc)
    if cfg.n_experts_pad:
        pad_mask = jnp.arange(logits.shape[-1]) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e9, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    G = _GROUPS if (B * T) % _GROUPS == 0 else 1
    y = _dispatch_compute(cfg, p, x2d.reshape(G, (B * T) // G, D),
                          probs.reshape(G, (B * T) // G, -1), ep_spec)
    y = y.reshape(B * T, D)

    if cfg.d_shared:
        sg = jax.nn.silu(x2d @ p["swg"]) * (x2d @ p["swu"])
        s_out = sg @ p["swd"]
        if cfg.shared_gate:
            s_out = s_out * jax.nn.sigmoid(x2d @ p["sgate"])
        y = y + s_out

    # aux losses (Switch-style load balance + z-loss)
    E = cfg.n_experts
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(jax.nn.one_hot(top1, E, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs[..., :E], axis=0)   # real experts only
    lb = E * jnp.sum(frac_tokens * frac_probs)
    zl = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = cfg.router_aux_coef * lb + cfg.router_z_coef * zl

    return x + y.reshape(B, T, D), aux
