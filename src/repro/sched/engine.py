"""Unified scheduling engine: one event loop for DFRS *and* batch baselines.

The engine owns the simulation clock, the structure-of-arrays job state
(``repro.core.state.EngineState``), the node pool, cluster (failure/elastic)
events and all accounting (penalties, bandwidth, utilization integrals,
metrics).  Scheduling behaviour is a pluggable :class:`Policy`:

* :class:`DFRSPolicy` — the paper's dynamic fractional policies (§4):
  greedy/GreedyP/GreedyPM submission, opportunistic completion handling,
  periodic MCB8 / MCB8-stretch, OPT yield post-passes, MINVT/MINFT pins.
* :class:`BatchPolicy` — FCFS and EASY backfilling (§5.2): integral,
  exclusive node allocation with perfect runtime estimates for EASY.

Both share the same event loop, fluid-progress advance, and
:class:`SimResult` metrics pipeline, so DFRS and batch numbers are produced
by literally the same accounting code.  Fluid model (§5.1): between events
every running job j progresses at its yield (vt += y_j·dt) and completes
when vt reaches p_j; preemption-resumes and migrations cost a rescheduling
penalty of zero progress; pauses/resumes/migrations move memory images and
are charged to the bandwidth tally.

``SimParams.max_events`` bounds the event loop: the engine raises a
``RuntimeError`` with diagnostics when exceeded, or — with
``on_max_events="truncate"`` — stops early and returns a partial
``SimResult`` with ``hit_max_events=True`` (completions then cover only the
jobs that finished).
"""
from __future__ import annotations

import heapq
import math
from collections import Counter, deque
from dataclasses import dataclass, field, replace as dc_replace
from itertools import islice
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import alloc_kernels
from ..core.greedy import greedy_p, greedy_place, greedy_pm
from ..core.job import COMPLETED, PAUSED, PENDING, RUNNING, JobSpec
from ..core.mcb8 import mcb8
from ..core.policies import PolicySpec, parse_policy
from ..core.state import (
    S_CANCELLED,
    S_COMPLETED,
    S_NOT_ARRIVED,
    S_RUNNING,
    EngineState,
    JobView,
)
from ..core.stretch_opt import improve_avg_stretch, improve_max_stretch, mcb8_stretch
from ..core.yield_alloc import allocate, allocate_incidence
from ..workloads.trace import Trace
from .cluster import ClusterEvent

__all__ = ["SimParams", "SimResult", "Engine", "Policy", "DFRSPolicy",
           "BatchPolicy", "make_policy", "make_seed_policy",
           "resolve_policy_arg"]

_EPS = 1e-9


@dataclass
class SimParams:
    n_nodes: int = 128
    penalty: float = 300.0          # rescheduling penalty (s), §5.1
    period: float = 600.0           # periodic MCB8 period (default 2x penalty)
    node_mem_gb: float = 8.0        # bandwidth accounting only
    stretch_tau: float = 10.0       # bounded-stretch threshold (s)
    max_events: int = 20_000_000    # hard event-loop bound
    on_max_events: str = "raise"    # "raise" | "truncate"
    # compact COMPLETED/CANCELLED rows out of the SoA state whenever at
    # least this many are evictable (0 = never; results are bit-identical
    # either way — see EngineState.compact / RetiredLog)
    compact_interval: int = 0

    def __post_init__(self) -> None:
        if self.max_events < 1:
            raise ValueError("max_events must be >= 1")
        if self.on_max_events not in ("raise", "truncate"):
            raise ValueError(f"on_max_events must be 'raise' or 'truncate', "
                             f"got {self.on_max_events!r}")
        if self.compact_interval < 0:
            raise ValueError("compact_interval must be >= 0")


@dataclass
class SimResult:
    policy: str
    completions: Dict[int, float]
    stretches: Dict[int, float]
    max_stretch: float
    mean_stretch: float
    n_pmtn: int
    n_mig: int
    pmtn_per_job: float
    mig_per_job: float
    pmtn_per_hour: float
    mig_per_hour: float
    bytes_moved_gb: float
    bandwidth_gbps: float
    underutilization: float         # normalized (§6.4)
    makespan: float
    events: int
    hit_max_events: bool = False    # True only with on_max_events="truncate"
    n_cancelled: int = 0            # jobs withdrawn mid-run (never in metrics)
    # observability: final simulation clock and the engine-loop wall time.
    # ``sim_wall_s`` is a measurement, not a simulation outcome, so it is
    # excluded from equality (bit-identity comparisons stay meaningful).
    final_time: float = 0.0
    sim_wall_s: float = field(default=0.0, compare=False)

    @property
    def n_events(self) -> int:
        """Alias of ``events`` (the sweep-record observability spelling)."""
        return self.events


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------
class Policy:
    """Scheduling behaviour plugged into the engine's event loop.

    Hook order per event timestamp: job completions (``on_job_completed``
    per job, then ``on_complete`` per batch), cluster events, arrivals
    (``on_submit``), periodic tick (``on_tick``), then ``finalize(acted)``.
    """

    #: does the policy react to node failures / elastic capacity events?
    handles_cluster_events = False
    #: None | "mcb8" | "mcb8-stretch" — enables the periodic tick
    periodic_kind: Optional[str] = None

    def bind(self, engine: "Engine") -> None:
        self.e = engine

    def validate(self, specs: Sequence[JobSpec], params: SimParams) -> None:
        pass

    def on_submit(self, js: JobView) -> None:
        pass

    def on_job_completed(self, js: JobView) -> None:
        pass

    def on_job_cancelled(self, js: JobView) -> None:
        """Called just before the engine drops a cancelled job (mapping and
        pool space still intact) so queue-holding policies can forget it."""
        pass

    def on_complete(self) -> None:
        pass

    def on_tick(self) -> None:
        pass

    def finalize(self, acted: bool) -> None:
        pass


class DFRSPolicy(Policy):
    """Dynamic fractional resource scheduling (paper §4), parameterized by a
    :class:`repro.core.policies.PolicySpec`."""

    handles_cluster_events = True

    def __init__(self, spec: PolicySpec):
        if spec.is_batch:
            raise ValueError("BatchPolicy handles FCFS/EASY")
        self.spec = spec
        self.periodic_kind = spec.periodic
        self._stretch_yields_set = False

    def bind(self, engine: "Engine") -> None:
        super().bind(engine)
        self._stretch_yields_set = False    # reset per engine run

    # ---- helpers --------------------------------------------------------
    def _pinned(self) -> Dict[int, List[int]]:
        """Jobs protected from remapping by MINVT/MINFT (§4.3)."""
        spec = self.spec
        pins: Dict[int, List[int]] = {}
        if spec.minvt is None and spec.minft is None:
            return pins
        now = self.e.state.now
        for js in self.e.state.running():
            if spec.minvt is not None and js.vt < spec.minvt:
                pins[js.spec.jid] = list(js.mapping)
            elif spec.minft is not None and js.flow_time(now) < spec.minft:
                pins[js.spec.jid] = list(js.mapping)
        return pins

    def _apply_mcb8(self) -> None:
        e = self.e
        cands = e.state.uncompleted()
        if not cands:
            return
        res = mcb8(
            cands, e.params.n_nodes, e.state.now,
            pinned=self._pinned(), alive=e.state.alive,
        )
        self._apply_global_mapping(res.mappings, cands)

    def _apply_global_mapping(
        self, mappings: Dict[int, List[int]], cands: Sequence[JobView]
    ) -> None:
        """Apply a from-scratch MCB8 mapping transactionally: the mapping is
        feasible as a whole, so all removals happen before any placement."""
        e = self.e
        migrations: List[Tuple[JobView, List[int]]] = []
        starts: List[Tuple[JobView, List[int]]] = []
        for js in cands:
            new_map = mappings.get(js.spec.jid)
            if js.status == RUNNING:
                if new_map is None:
                    e.pause(js)
                elif _node_multiset(js.mapping) != _node_multiset(new_map):
                    migrations.append((js, new_map))
            elif new_map is not None:
                starts.append((js, new_map))
        e.migrate_many(migrations)
        for js, new_map in starts:
            e.start(js, new_map)

    def _apply_stretch_per(self) -> None:
        e = self.e
        cands = e.state.uncompleted()
        if not cands:
            return
        res = mcb8_stretch(
            cands, e.params.n_nodes, e.state.now, e.params.period,
            pinned=self._pinned(), alive=e.state.alive,
        )
        self._apply_global_mapping(res.mappings, cands)
        running = e.state.running()
        mappings = {js.spec.jid: js.mapping for js in running}
        ylds = {js.spec.jid: res.yields.get(js.spec.jid, 0.0) for js in running}
        if self.spec.opt == "MAX":
            ylds = improve_max_stretch(
                running, mappings, ylds, e.params.n_nodes, e.state.now,
                e.params.period,
            )
        else:
            ylds = improve_avg_stretch(
                running, mappings, ylds, e.params.n_nodes, e.state.now,
                e.params.period,
            )
        for js in running:
            js.yld = float(min(1.0, ylds.get(js.spec.jid, 0.0)))
        self._stretch_yields_set = True

    # ---- hooks ----------------------------------------------------------
    def on_submit(self, js: JobView) -> None:
        e = self.e
        kind = self.spec.on_submit
        if kind is None:
            return
        if kind == "greedy":
            mapping = greedy_place(e.state.pool.copy(), js.spec)
            if mapping is not None:
                e.start(js, mapping)
            return
        if kind in ("greedyP", "greedyPM"):
            fn = greedy_p if kind == "greedyP" else greedy_pm
            running = e.state.running()
            adm = fn(e.state.pool.copy(), js.spec, running, e.state.now)
            if adm.mapping is None:
                return
            by_jid = {j.spec.jid: j for j in running}
            for jid in adm.paused:
                e.pause(by_jid[jid])
            e.migrate_many(
                [(by_jid[jid], new_map) for jid, new_map in adm.moved.items()])
            e.start(js, adm.mapping)
            return
        if kind == "mcb8":
            self._apply_mcb8()
            return
        raise ValueError(kind)

    def on_complete(self) -> None:
        e = self.e
        kind = self.spec.on_complete
        if kind is None:
            return
        if kind == "greedy":
            waiting = sorted(
                (j for j in e.state.uncompleted() if j.status in (PENDING, PAUSED)),
                key=lambda j: j.priority_key(e.state.now),
                reverse=True,
            )
            for js in waiting:
                mapping = greedy_place(e.state.pool.copy(), js.spec)
                if mapping is not None:
                    e.start(js, mapping)
            return
        if kind == "mcb8":
            self._apply_mcb8()
            return
        raise ValueError(kind)

    def on_tick(self) -> None:
        if self.periodic_kind == "mcb8":
            self._apply_mcb8()
        else:
            self._apply_stretch_per()

    def finalize(self, acted: bool) -> None:
        if acted:
            self._reallocate()

    def _reallocate(self) -> None:
        """Recompute yields for running jobs (§4.6) unless /stretch-per just
        set them explicitly."""
        if self._stretch_yields_set:
            self._stretch_yields_set = False
            return
        opt = self.spec.opt if self.spec.opt in ("MIN", "AVG") else "MIN"
        _reallocate_yields(self.e, opt)


def _reallocate_yields(e: "Engine", opt: str) -> None:
    """The §4.6 yield recomputation for every running job (shared by
    ``DFRSPolicy`` and the ``opt`` policy components)."""
    st = e.state
    run = st.running_indices()
    if alloc_kernels.reference_kernels_active():
        views = [st.views[i] for i in run]
        ylds = allocate([js.spec for js in views],
                        [js.mapping for js in views],
                        e.params.n_nodes, opt=opt)
    elif e.alloc_backend is not None:
        # pluggable kernel backend (bit-identical contract): e.g. the
        # batched JAX path, or a lockstep lane of a batched sweep
        ylds = e.alloc_backend.allocate(st.inc.csr(), run, opt)
    else:
        # hot path: the incrementally maintained incidence matrix already
        # holds every running task — no mapping rescan, no table rebuild
        ylds = allocate_incidence(st.inc.csr(), run, opt=opt)
    st.yld[run] = ylds


class BatchPolicy(Policy):
    """FCFS / EASY backfilling (paper §5.2) on the unified engine.

    Nodes are allocated integrally and exclusively: job j occupies n_j whole
    nodes at yield 1 for exactly p_j seconds.  EASY gives the queue head a
    reservation at the earliest time it could start under FCFS and backfills
    any job that does not interfere with it; as in the paper, EASY is given
    *perfect* processing-time estimates (a best case for the baseline).
    Cluster events are ignored — the baselines do not model failures.
    """

    def __init__(self, algo: str):
        algo = algo.upper()
        if algo not in ("FCFS", "EASY"):
            raise ValueError(algo)
        self.algo = algo
        self.queue: deque = deque()                     # FIFO: O(1) head pops
        self.free: List[int] = []                       # free node ids (heap)
        self.running: List[Tuple[float, int, int]] = [] # (end, jid, n_tasks)
        self._dirty = False

    def bind(self, engine: "Engine") -> None:
        # bind() is the per-engine reset: a Policy instance may be reused
        # across Engine runs, so no run state can survive it
        super().bind(engine)
        self.queue = deque()
        self.running = []
        self._dirty = False
        self.free = list(range(engine.params.n_nodes))
        heapq.heapify(self.free)

    def validate(self, specs: Sequence[JobSpec], params: SimParams) -> None:
        for s in specs:
            if s.n_tasks > params.n_nodes:
                raise ValueError(
                    f"job {s.jid} needs {s.n_tasks} > {params.n_nodes} nodes")

    def on_submit(self, js: JobView) -> None:
        self.queue.append(js)
        self._dirty = True

    def on_job_completed(self, js: JobView) -> None:
        # called before the engine clears the mapping — reclaim the nodes
        jid = js.spec.jid
        self.running = [r for r in self.running if r[1] != jid]
        for node in js.mapping:
            heapq.heappush(self.free, node)
        self._dirty = True

    def finalize(self, acted: bool) -> None:
        if self._dirty:
            self._try_start()
            self._dirty = False

    # ---- allocation -----------------------------------------------------
    def _start_job(self, js: JobView) -> None:
        nodes = [heapq.heappop(self.free) for _ in range(js.spec.n_tasks)]
        now = self.e.state.now
        self.running.append((now + js.spec.proc_time, js.spec.jid,
                             js.spec.n_tasks))
        self.e.start(js, nodes)
        js.yld = 1.0            # dedicated nodes, full speed

    def _try_start(self) -> None:
        now = self.e.state.now
        q = self.queue
        # FCFS part: start queue head(s) while they fit.
        while q and q[0].spec.n_tasks <= len(self.free):
            self._start_job(q.popleft())
        if self.algo == "FCFS" or not q:
            return
        # EASY backfilling against the head's reservation.
        changed = True
        while changed:
            changed = False
            head = q[0]
            ends = sorted(self.running)
            avail = len(self.free)
            shadow, extra = math.inf, 0
            for end, _, n in ends:
                avail += n
                if avail >= head.spec.n_tasks:
                    shadow = end
                    extra = avail - head.spec.n_tasks
                    break
            for i, js in enumerate(islice(q, 1, None), start=1):
                free = len(self.free)
                if js.spec.n_tasks <= free and (
                    now + js.spec.proc_time <= shadow + 1e-9
                    or js.spec.n_tasks <= min(free, extra)
                ):
                    del q[i]
                    self._start_job(js)
                    changed = True
                    break   # recompute the reservation after each backfill
        return


def make_policy(spec: PolicySpec) -> Policy:
    """The engine's default policy for a spec: the canonical component
    composition (``repro.sched.components``).  The monolithic seed classes
    above remain importable as the bit-identity oracle."""
    from .components import compose_from_spec
    return compose_from_spec(spec)


def make_seed_policy(spec: PolicySpec) -> Policy:
    """The pre-redesign monolithic classes (golden-equivalence oracle)."""
    return BatchPolicy(spec.name) if spec.is_batch else DFRSPolicy(spec)


def resolve_policy_arg(
    policy: "PolicySpec | str | Policy",
) -> Tuple[Optional[PolicySpec], Policy, Optional[str]]:
    """Resolve any policy argument to ``(spec, policy_object, ref)``.

    ``ref`` is a string that rebuilds an equivalent fresh policy later (the
    canonical grammar spelling or a registered composition name) — it is
    what session snapshots persist.  Raw :class:`Policy` instances resolve
    to ``ref=None`` unless their ``.name`` is a registered composition.
    """
    if isinstance(policy, Policy):
        from .components import registered_policies
        name = getattr(policy, "name", None)
        ref = name if name in registered_policies() else None
        return None, policy, ref
    if isinstance(policy, str):
        from .components import resolve_policy
        named = resolve_policy(policy)
        if named is not None:
            return None, named, policy
    spec = parse_policy(policy) if isinstance(policy, str) else policy
    return spec, make_policy(spec), spec.name


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------
class Engine:
    """Event-driven simulation of one (trace, policy, cluster-script) cell."""

    def __init__(
        self,
        specs: Sequence[JobSpec] | Trace,
        policy: PolicySpec | str | Policy,
        params: Optional[SimParams] = None,
        cluster_events: Sequence[ClusterEvent] = (),
        alloc_backend: Optional[object] = None,
    ):
        self.params = params or SimParams()
        # optional kernel backend for the §4.6 reallocation: any object with
        # ``allocate(inc: CSRIncidence, cols, opt) -> yields`` (e.g.
        # ``repro.core.alloc_jax.JaxAllocBackend`` or a lockstep lane).
        # None = the numpy hot path; reference_kernels() overrides either.
        self.alloc_backend = alloc_backend
        self.policy_spec, self.policy, self.policy_ref = resolve_policy_arg(policy)
        if isinstance(specs, Trace):
            # array-native ingest: columns feed the SoA state directly
            self.state = EngineState.from_trace(specs, self.params.n_nodes)
        else:
            self.state = EngineState(
                sorted(specs, key=lambda s: (s.release, s.jid)),
                self.params.n_nodes,
            )
        self.cluster_events = sorted(cluster_events, key=lambda e: e.time)
        self.bytes_moved_gb = 0.0
        self.n_pmtn = 0
        self.n_mig = 0
        self._events = 0
        self.policy.validate(self.state.specs, self.params)
        self.policy.bind(self)

    # ------------------------------------------------------------------ #
    # state transitions (shared accounting)                               #
    # ------------------------------------------------------------------ #
    def _job_mem_gb(self, spec: JobSpec, n_tasks: Optional[int] = None) -> float:
        k = spec.n_tasks if n_tasks is None else n_tasks
        return k * spec.mem_req * self.params.node_mem_gb

    def pause(self, js: JobView) -> None:
        assert js.status == RUNNING
        self.state.pool.remove(js.spec, js.mapping)
        self.state.inc.remove(js.i, js.mapping)
        js.status = PAUSED
        js.mapping = None
        js.yld = 0.0
        js.n_pmtn += 1
        self.n_pmtn += 1
        self.bytes_moved_gb += self._job_mem_gb(js.spec)  # save image

    def start(self, js: JobView, mapping: List[int]) -> bool:
        assert js.status in (PENDING, PAUSED)
        st = self.state
        if not st.alive.all() and not all(st.alive[n] for n in mapping):
            # a target node died under the policy's feet (stale mapping or
            # mid-allocation failure): degrade gracefully — re-place on the
            # survivors instead of oversubscribing a dead node's zeroed
            # memory.  If nothing fits the job stays pending/paused and the
            # next scheduling event retries.
            mapping = greedy_place(st.pool.copy(), js.spec)
            if mapping is None:
                return False
        resume = js.status == PAUSED
        st.pool.place(js.spec, mapping)
        st.inc.place(js.i, mapping)
        js.status = RUNNING
        js.mapping = list(mapping)
        if resume:
            js.penalty_until = st.now + self.params.penalty
            self.bytes_moved_gb += self._job_mem_gb(js.spec)  # restore image
        return True

    def migrate_many(self, pairs: Sequence[Tuple[JobView, List[int]]]) -> None:
        """Transactionally migrate several running jobs: the new mappings are
        feasible *as a set* (computed against a pool copy), so all removals
        must happen before any placement."""
        moves = []
        degraded = not self.state.alive.all()
        for js, new_mapping in pairs:
            assert js.status == RUNNING
            if degraded and not all(self.state.alive[n] for n in new_mapping):
                continue    # target died mid-allocation: keep the old placement
            old = _node_multiset(js.mapping)
            new = _node_multiset(new_mapping)
            moved = js.spec.n_tasks - sum(
                min(old.get(n, 0), new.get(n, 0)) for n in old)
            moves.append((js, new_mapping, moved))
        for js, _, _ in moves:
            self.state.pool.remove(js.spec, js.mapping)
            self.state.inc.remove(js.i, js.mapping)
        for js, new_mapping, moved in moves:
            self.state.pool.place(js.spec, new_mapping)
            self.state.inc.place(js.i, new_mapping)
            js.mapping = list(new_mapping)
            if moved == 0:
                continue
            js.n_mig += 1
            self.n_mig += 1
            js.penalty_until = self.state.now + self.params.penalty
            self.bytes_moved_gb += 2.0 * self._job_mem_gb(js.spec, moved)

    def complete(self, js: JobView) -> None:
        self.state.pool.remove(js.spec, js.mapping)
        self.state.inc.remove(js.i, js.mapping)
        js.status = COMPLETED
        js.mapping = None
        js.yld = 0.0
        js.completed_at = self.state.now

    def cancel(self, js: JobView) -> None:
        """Withdraw a job at the current time.  Frees its nodes and drops it
        from every in-system mask (``S_CANCELLED > S_COMPLETED``); the job
        keeps ``completed_at = None`` and is excluded from all metrics."""
        st = self.state
        code = int(st.status[js.i])
        if code in (S_COMPLETED, S_CANCELLED):
            return              # tolerant: pre-scripted streams may overlap
        if code != S_NOT_ARRIVED:
            self.policy.on_job_cancelled(js)
        if code == S_RUNNING:
            st.pool.remove(js.spec, js.mapping)
            st.inc.remove(js.i, js.mapping)
        st.set_status(js.i, S_CANCELLED)
        js.mapping = None
        js.yld = 0.0

    def resize(self, js: JobView, n_tasks: int) -> None:
        """Malleable grow/shrink of a job's task count.  A running job is
        preempted and re-placed at the new width by the next scheduling
        event — the exact path a node failure takes, so policies need no new
        logic.  Specs are memoized per trace and shared across engines, so
        the resized spec is a fresh object swapped into this state only."""
        st = self.state
        code = int(st.status[js.i])
        if code in (S_COMPLETED, S_CANCELLED):
            return
        n_tasks = max(1, min(int(n_tasks), self.params.n_nodes))
        if n_tasks == js.spec.n_tasks:
            return
        if code == S_RUNNING:
            self.pause(js)
        spec = dc_replace(js.spec, n_tasks=n_tasks)
        st.specs[js.i] = spec
        js.spec = spec
        st.set_demand(js.i, spec.n_tasks * spec.cpu_need)

    # ------------------------------------------------------------------ #
    # cluster (failure / elastic) events                                  #
    # ------------------------------------------------------------------ #
    def _apply_cluster_event(self, ev: ClusterEvent) -> None:
        st = self.state
        if ev.kind == "fail":
            for node in ev.nodes:
                if not st.alive[node]:
                    continue
                st.alive[node] = False
                # force-preempt every job with a task on the node
                for js in list(st.running()):
                    if node in (js.mapping or ()):
                        self.pause(js)
                # node can no longer host anything (0.0, not a negative
                # sentinel: NodePool.place validates global non-negativity)
                st.pool.mem_free[node] = 0.0
                st.pool.load[node] = np.inf
        elif ev.kind == "join":
            for node in ev.nodes:
                if st.alive[node]:
                    continue
                st.alive[node] = True
                st.pool.mem_free[node] = 1.0
                st.pool.load[node] = 0.0
        elif ev.kind in ("cancel", "resize"):
            # rare events: the jid→index map is built on demand, not kept
            jid_to_i = {s.jid: i for i, s in enumerate(st.specs)}
            for jid in ev.jids:
                i = jid_to_i.get(int(jid))
                if i is None:
                    continue    # unknown jid: tolerant, like dup fail/join
                if ev.kind == "cancel":
                    self.cancel(st.views[i])
                else:
                    self.resize(st.views[i], int(ev.value))
        else:
            raise ValueError(ev.kind)

    # ------------------------------------------------------------------ #
    # main loop                                                           #
    # ------------------------------------------------------------------ #
    def run(self) -> SimResult:
        """Closed-world wrapper over the streaming session core: open a
        :class:`repro.sched.session.SimSession` on this engine, step it to
        exhaustion, finalize.  The session drives the exact event-iteration
        sequence of the historical monolithic loop — where step boundaries
        fall never changes a ``SimResult`` bit."""
        from .session import SimSession
        return SimSession.from_engine(self).run()

    # ------------------------------------------------------------------ #
    def _result(self, hit_cap: bool = False, partial: bool = False,
                sim_wall_s: float = 0.0, light: bool = False) -> SimResult:
        """Metrics over the completed jobs.  ``partial`` permits uncompleted
        jobs (a mid-run session result); a finished run still treats them as
        a deadlock unless the event cap truncated it.

        Under compaction the evicted rows live in ``st.retired``; the two
        populations are merged back in global-arrival (``gidx``) order, so
        every float accumulation below performs the identical operation
        sequence as the uncompacted single loop — bit-identical results.
        ``light`` skips materializing the O(jobs) per-job dicts (aggregates
        only, computed by the same ops) for bounded-RSS scale runs.
        """
        from .metrics import bounded_stretch

        p = self.params
        st = self.state
        completions: Dict[int, float] = {}
        stretches: Dict[int, float] = {}
        ret = st.retired
        if len(ret):
            order = np.argsort(ret.col("gidx"), kind="stable")
            r_gidx = ret.col("gidx")[order].tolist()
            r_jid = ret.col("jid")[order].tolist()
            r_rel = ret.col("release")[order].tolist()
            r_done = ret.col("completed_at")[order].tolist()
            r_pt = ret.col("proc_truth")[order].tolist()
            r_work = ret.col("work")[order].tolist()
        else:
            r_gidx = r_jid = r_rel = r_done = r_pt = r_work = []
        n_ret = len(r_gidx)
        specs = st.specs
        status = st.status
        pt_arr = st.proc_truth
        cat = st.completed_at
        live_gidx = st.gidx.tolist()
        svals: List[float] = []
        last = -np.inf                  # running max over completion times
        total_work = 0                  # int start, exactly like sum(genexp)
        ri = 0
        for i, s in enumerate(specs):
            g = live_gidx[i]
            while ri < n_ret and r_gidx[ri] < g:
                done = r_done[ri]
                if done == done:        # NaN marks cancelled (no metrics)
                    # stretch normalizes by the *executed* time — under
                    # truth noise the estimate would mis-scale the metric
                    sv = bounded_stretch(done - r_rel[ri], r_pt[ri],
                                         p.stretch_tau)
                    if not light:
                        completions[r_jid[ri]] = done
                        stretches[r_jid[ri]] = sv
                    svals.append(sv)
                    if done > last:
                        last = done
                    total_work = total_work + r_work[ri]
                ri += 1
            if int(status[i]) == S_CANCELLED:
                continue                # withdrawn: never in the metrics
            c = cat[i]
            if np.isnan(c):
                if not (hit_cap or partial):
                    raise RuntimeError(
                        f"job {s.jid} never completed (deadlock?)")
                # partial run: report finished jobs, but the uncompleted
                # ones still carry executed work (same as the genexp did)
                total_work = total_work + (
                    s.n_tasks * float(pt_arr[i]) * s.cpu_need)
                continue
            c = float(c)
            sv = bounded_stretch(c - s.release, float(pt_arr[i]),
                                 p.stretch_tau)
            if not light:
                completions[s.jid] = c
                stretches[s.jid] = sv
            svals.append(sv)
            if c > last:
                last = c
            # executed CPU-seconds (truth) — the same multiply order as
            # JobSpec.total_work so the clairvoyant case is bit-identical
            # to the historical spec-side sum
            total_work = total_work + s.n_tasks * float(pt_arr[i]) * s.cpu_need
        while ri < n_ret:
            done = r_done[ri]
            if done == done:
                sv = bounded_stretch(done - r_rel[ri], r_pt[ri], p.stretch_tau)
                if not light:
                    completions[r_jid[ri]] = done
                    stretches[r_jid[ri]] = sv
                svals.append(sv)
                if done > last:
                    last = done
                total_work = total_work + r_work[ri]
            ri += 1
        first = st.first_release if st.n_total else 0.0
        last = last if svals else 0.0
        makespan = max(0.0, last - first)
        hours = max(makespan / 3600.0, 1e-9)
        if not total_work:
            total_work = 1.0
        if self.policy_spec is not None:
            name = self.policy_spec.name
        else:
            # ComposedPolicy carries .name, BatchPolicy .algo, DFRSPolicy .spec
            name = (getattr(self.policy, "name", None)
                    or getattr(self.policy, "algo", None)
                    or getattr(getattr(self.policy, "spec", None), "name", None)
                    or self.policy.__class__.__name__)
        return SimResult(
            policy=name,
            completions=completions,
            stretches=stretches,
            max_stretch=max(svals) if svals else 0.0,
            mean_stretch=float(np.mean(svals)) if svals else 0.0,
            n_pmtn=self.n_pmtn,
            n_mig=self.n_mig,
            pmtn_per_job=self.n_pmtn / max(1, st.n_total),
            mig_per_job=self.n_mig / max(1, st.n_total),
            pmtn_per_hour=self.n_pmtn / hours,
            mig_per_hour=self.n_mig / hours,
            bytes_moved_gb=self.bytes_moved_gb,
            bandwidth_gbps=self.bytes_moved_gb / max(makespan, 1e-9),
            underutilization=(st.demand_integral - st.util_integral) / total_work,
            makespan=makespan,
            events=self._events,
            hit_max_events=hit_cap,
            n_cancelled=int((st.status == S_CANCELLED).sum()) + ret.n_cancelled,
            final_time=st.now,
            sim_wall_s=sim_wall_s,
        )


def _node_multiset(mapping: Sequence[int]) -> Counter:
    return Counter(mapping)
