"""Chaos narrator: seeded stochastic fault/perturbation event streams.

The paper's setting is *online and non-clairvoyant*, but scripted scenarios
only perturb a cell where the script says so.  A :class:`Narrator` is the
generative counterpart: a composition of seeded stochastic *streams*
(exponential node breakdown/repair, Poisson job cancellation, lognormal
processing-time noise, malleable grow/shrink of ``n_tasks``) that emit
events into a live :class:`repro.sched.session.SimSession` lazily as the
simulation clock advances.

Design contract (mirrors the session's bit-identity rules):

* **lazy + boundary-safe** — a stream holds exactly one pre-drawn firing
  time (``next_t``); the session's loop fires streams only for times
  ``<= min(next event, step bound)``, so where step boundaries fall never
  changes what the narrator does.
* **snapshot round-trip** — ``Narrator.state()`` serializes every stream's
  RNG (``bit_generator.state``, a JSON-able dict) plus its pending firing
  time; :meth:`Narrator.from_state` rebuilds the narrator bit-exactly, so a
  session restored mid-chaos replays the identical future.
* **compose, never corrupt** — streams pick victims from the session's
  *projected* state (pending injections included) and skip a firing rather
  than inject a contradictory event, so narrator streams stack safely with
  scripted scenarios and reactive rules.

Streams are registered by kind (:func:`register_stream`) and composable
through the same ``+`` grammar as scenarios::

    nar = parse_narrator(
        "breakdown(mtbf=2e4,repair=2e3)+cancel(rate=1e-4)+noise(sigma=0.3)",
        seed=7)
    session.attach_narrator(nar)

Each stream draws from its own ``SeedSequence([seed, salt(kind), k])``
stream (``k`` = position in the composition), so adding a stream never
re-times the others.
"""
from __future__ import annotations

import math
import re
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .cluster import ClusterEvent

__all__ = [
    "Narrator",
    "Stream",
    "parse_narrator",
    "register_stream",
    "list_streams",
    "narrator_docs",
]

#: guaranteed minimum inter-firing gap: keeps the lazy loop strictly
#: progressing even on a pathological zero draw from the RNG
_MIN_DT = 1e-6

_STREAMS: Dict[str, type] = {}


def _code(name: str) -> int:
    # stable (non-PYTHONHASHSEED) stream salt, same scheme as scenarios
    return sum((i + 1) * ord(c) for i, c in enumerate(name)) % (2**31)


def register_stream(kind: str):
    """Decorator: register a :class:`Stream` subclass under ``kind``."""
    def deco(cls):
        if kind in _STREAMS:
            raise ValueError(f"narrator stream {kind!r} already registered")
        cls.kind = kind
        _STREAMS[kind] = cls
        return cls
    return deco


def list_streams() -> List[str]:
    return sorted(_STREAMS)


def narrator_docs() -> Dict[str, str]:
    """kind -> first docstring line of the registered stream class."""
    return {k: (cls.__doc__ or "").strip().split("\n")[0]
            for k, cls in sorted(_STREAMS.items())}


# --------------------------------------------------------------------------- #
# stream protocol                                                              #
# --------------------------------------------------------------------------- #
class Stream:
    """One stochastic event process.

    Subclasses implement ``_draw_dt(rng)`` (inter-firing gap) and
    ``_emit(session, t)`` (materialize injections at firing time ``t``);
    purely submission-driven streams (``noise``) override
    :meth:`on_submitted` instead and keep ``next_t = inf``.
    """

    kind = "?"
    #: does the stream inject cluster events (breakdown/cancel/malleable)?
    #: noise only rewrites the truth column and works under batch policies.
    needs_cluster_events = True

    def __init__(self, **params: float):
        self.params = {k: float(v) for k, v in params.items()}
        self.rng: Optional[np.random.Generator] = None
        self.next_t: Optional[float] = None     # None until primed

    def seed(self, seed: int, k: int) -> None:
        self.rng = np.random.default_rng(
            np.random.SeedSequence([int(seed), _code(self.kind), int(k)]))

    # ---- the lazy clock ------------------------------------------------- #
    def peek(self, session) -> float:
        """Next firing time; primed lazily at the session clock so a
        narrator attached mid-run starts counting from 'now'."""
        if self.next_t is None:
            self.next_t = session.now + max(self._draw_dt(self.rng), _MIN_DT)
        return self.next_t

    def fire(self, session) -> None:
        """Materialize this firing's injections, then pre-draw the next."""
        t = self.next_t
        self._emit(session, t)
        self.next_t = t + max(self._draw_dt(self.rng), _MIN_DT)

    def _draw_dt(self, rng: np.random.Generator) -> float:
        return math.inf

    def _emit(self, session, t: float) -> None:
        pass

    def on_submitted(self, session, idx: Sequence[int]) -> None:
        """Hook: jobs were just submitted at dense indices ``idx``."""
        pass

    # ---- snapshot round-trip -------------------------------------------- #
    def state(self) -> Dict[str, Any]:
        return {
            "kind": self.kind,
            "params": dict(self.params),
            "rng": self.rng.bit_generator.state,
            "next_t": self.next_t,
        }

    def load_state(self, payload: Dict[str, Any]) -> None:
        self.rng.bit_generator.state = payload["rng"]
        t = payload["next_t"]
        self.next_t = None if t is None else float(t)

    def __repr__(self) -> str:
        args = ",".join(f"{k}={v:g}" for k, v in sorted(self.params.items()))
        return f"{self.kind}({args})"

    # shared helper: inject tolerantly (a scripted event may already cover
    # the transition — skipping beats corrupting, and stays deterministic
    # because the RNG draws happened before the attempt)
    @staticmethod
    def _inject(session, event: ClusterEvent) -> bool:
        try:
            session.inject(event)
            return True
        except ValueError:
            return False


# --------------------------------------------------------------------------- #
# built-in streams                                                             #
# --------------------------------------------------------------------------- #
@register_stream("breakdown")
class BreakdownStream(Stream):
    """Exponential node breakdown with exponential repair (snippet-2 style).

    ``mtbf`` is the cluster-wide mean time between failures; each firing
    kills one uniformly random *projected-alive* node and schedules its
    repair ``Exp(repair)`` seconds later.
    """

    def __init__(self, mtbf: float = 20_000.0, repair: float = 2_000.0):
        if mtbf <= 0 or repair <= 0:
            raise ValueError("breakdown needs mtbf > 0 and repair > 0")
        super().__init__(mtbf=mtbf, repair=repair)

    def _draw_dt(self, rng):
        return float(rng.exponential(self.params["mtbf"]))

    def _emit(self, session, t):
        # draw order is fixed (victim, then repair) so the stream stays
        # deterministic even when the injection is skipped
        alive = session._projected_alive(t)
        candidates = np.nonzero(alive)[0]
        pick = int(self.rng.integers(len(candidates))) if len(candidates) else 0
        dt_repair = float(self.rng.exponential(self.params["repair"]))
        if not len(candidates):
            return                      # whole cluster already down: skip
        node = int(candidates[pick])
        if self._inject(session, ClusterEvent(t, "fail", (node,))):
            self._inject(session, ClusterEvent(
                t + max(dt_repair, _MIN_DT), "join", (node,)))


@register_stream("cancel")
class CancelStream(Stream):
    """Poisson job cancellation: in-system victims withdraw mid-run.

    ``rate`` is cancellations per second of simulated time; each firing
    cancels one uniformly random job among those currently in the system
    (pending cancellations excluded).
    """

    def __init__(self, rate: float = 1e-4):
        if rate <= 0:
            raise ValueError("cancel needs rate > 0")
        super().__init__(rate=rate)

    def _draw_dt(self, rng):
        return float(rng.exponential(1.0 / self.params["rate"]))

    def _emit(self, session, t):
        st = session.engine.state
        pending = session._pending_cancels()
        ins = [i for i in st.in_system_indices()
               if st.specs[i].jid not in pending]
        pick = int(self.rng.integers(len(ins))) if ins else 0
        if not ins:
            return                      # nothing to withdraw: skip
        jid = st.specs[ins[pick]].jid
        self._inject(session, ClusterEvent(t, "cancel", jids=(int(jid),)))


@register_stream("malleable")
class MalleableStream(Stream):
    """Poisson malleable grow/shrink: a running/waiting job changes width.

    ``rate`` is resizes per second; each firing picks a uniformly random
    in-system job and redraws its ``n_tasks`` uniformly in
    ``[1, 2 * current]`` (clamped to the cluster size by the engine).
    """

    def __init__(self, rate: float = 5e-5):
        if rate <= 0:
            raise ValueError("malleable needs rate > 0")
        super().__init__(rate=rate)

    def _draw_dt(self, rng):
        return float(rng.exponential(1.0 / self.params["rate"]))

    def _emit(self, session, t):
        st = session.engine.state
        pending = session._pending_cancels()
        ins = [i for i in st.in_system_indices()
               if st.specs[i].jid not in pending]
        pick = int(self.rng.integers(len(ins))) if ins else 0
        hi = 2 * (st.specs[ins[pick]].n_tasks if ins else 1)
        new_n = int(self.rng.integers(1, hi + 1))
        if not ins:
            return
        jid = st.specs[ins[pick]].jid
        self._inject(session, ClusterEvent(
            t, "resize", jids=(int(jid),), value=float(new_n)))


@register_stream("noise")
class NoiseStream(Stream):
    """Lognormal processing-time noise: estimate vs truth divergence.

    Not clock-driven: on every :meth:`SimSession.submit` the stream rewrites
    the new jobs' *truth* column ``proc_truth = proc_time * LogN(sigma)``
    (mean-preserving, ``mu = -sigma^2/2``) while policies keep observing the
    clean ``proc_time`` estimate.  Works under batch policies too (no
    cluster events involved).
    """

    needs_cluster_events = False

    def __init__(self, sigma: float = 0.35):
        if sigma <= 0:
            raise ValueError("noise needs sigma > 0")
        super().__init__(sigma=sigma)

    def on_submitted(self, session, idx):
        st = session.engine.state
        sigma = self.params["sigma"]
        for i in idx:                   # index order: deterministic
            st.proc_truth[i] = st.proc_time[i] * float(
                self.rng.lognormal(mean=-0.5 * sigma * sigma, sigma=sigma))


# --------------------------------------------------------------------------- #
# the narrator                                                                 #
# --------------------------------------------------------------------------- #
class Narrator:
    """A seeded composition of event streams driving one session.

    Attach with :meth:`SimSession.attach_narrator`; the session's loop
    peeks/fires it between events.  ``state()``/``from_state`` round-trip
    the full RNG state bit-exactly through session snapshots.
    """

    def __init__(self, streams: Sequence[Stream], seed: int = 0):
        self.seed = int(seed)
        self.streams = list(streams)
        if not self.streams:
            raise ValueError("narrator needs at least one stream")
        for k, s in enumerate(self.streams):
            s.seed(self.seed, k)

    def needs_cluster_events(self) -> bool:
        return any(s.needs_cluster_events for s in self.streams)

    # ---- the session-facing surface -------------------------------------- #
    def peek(self, session) -> float:
        """Earliest pending firing time across the streams."""
        return min((s.peek(session) for s in self.streams),
                   default=math.inf)

    def fire(self, session) -> None:
        """Fire the single earliest stream (ties: composition order)."""
        best, t = None, math.inf
        for s in self.streams:
            ts = s.peek(session)
            if ts < t:
                best, t = s, ts
        if best is not None and math.isfinite(t):
            best.fire(session)

    def on_submitted(self, session, idx: Sequence[int]) -> None:
        for s in self.streams:
            s.on_submitted(session, idx)

    def reseed(self, seed: int) -> None:
        """Re-derive every stream's RNG from a fresh seed and drop the
        pending firing times (they re-prime lazily at the session clock).

        This is how what-if branches race *oracle-free*: every branch of
        one race shares the same reseeded chaos (common random numbers,
        fair comparison) while being decorrelated from the future the live
        session will actually experience."""
        self.seed = int(seed)
        for k, s in enumerate(self.streams):
            s.seed(self.seed, k)
            s.next_t = None

    # ---- snapshot round-trip -------------------------------------------- #
    def state(self) -> Dict[str, Any]:
        return {"seed": self.seed,
                "streams": [s.state() for s in self.streams]}

    @classmethod
    def from_state(cls, payload: Dict[str, Any]) -> "Narrator":
        streams = []
        for sp in payload["streams"]:
            kind = sp["kind"]
            if kind not in _STREAMS:
                raise ValueError(
                    f"unknown narrator stream {kind!r} in snapshot; "
                    f"known: {list_streams()}")
            streams.append(_STREAMS[kind](**sp["params"]))
        nar = cls(streams, seed=payload["seed"])
        for s, sp in zip(nar.streams, payload["streams"]):
            s.load_state(sp)
        return nar

    def __repr__(self) -> str:
        return (f"Narrator({'+'.join(map(repr, self.streams))}, "
                f"seed={self.seed})")


def parse_narrator(spec: str, seed: int = 0) -> Narrator:
    """Build a narrator from the ``+`` grammar, e.g.
    ``"breakdown(mtbf=2e4,repair=2e3)+cancel(rate=1e-4)+noise(sigma=0.3)"``.
    A bare kind uses the stream's default parameters."""
    streams: List[Stream] = []
    for part in spec.split("+"):
        part = part.strip()
        m = re.fullmatch(r"([A-Za-z_][\w]*)\s*(?:\((.*)\))?", part)
        if not m:
            raise ValueError(f"malformed narrator stream {part!r}")
        kind, argstr = m.group(1), m.group(2)
        if kind not in _STREAMS:
            raise ValueError(f"unknown narrator stream {kind!r}; "
                             f"known: {list_streams()}")
        kwargs: Dict[str, float] = {}
        for kv in (argstr or "").split(","):
            kv = kv.strip()
            if not kv:
                continue
            key, eq, val = kv.partition("=")
            if not eq:
                raise ValueError(
                    f"narrator stream argument {kv!r} must be key=value")
            kwargs[key.strip()] = float(val)
        streams.append(_STREAMS[kind](**kwargs))
    return Narrator(streams, seed=seed)
