"""repro.sched — online scheduling engine: DFRS discrete-event simulator,
batch-scheduling baselines (FCFS/EASY), evaluation metrics, cluster model."""
from .simulator import DFRSSimulator, SimParams, SimResult, simulate
from .batch import batch_schedule
from .metrics import (
    bounded_stretch,
    max_bounded_stretch,
    degradation_from_bound,
    normalized_underutilization,
)
from .cluster import ClusterEvent, failure_trace

__all__ = [
    "DFRSSimulator", "SimParams", "SimResult", "simulate",
    "batch_schedule",
    "bounded_stretch", "max_bounded_stretch", "degradation_from_bound",
    "normalized_underutilization",
    "ClusterEvent", "failure_trace",
]
