"""repro.sched — unified scheduling engine (DFRS policies + FCFS/EASY batch
baselines behind one event loop), the composable policy-component registry,
evaluation metrics, cluster model, named cluster scenarios, and the parallel
scenario-sweep subsystem."""
from .engine import (BatchPolicy, DFRSPolicy, Engine, Policy, SimParams,
                     SimResult, make_policy, make_seed_policy)
from .components import (
    ComposedPolicy,
    Component,
    compose,
    compose_from_spec,
    get_component,
    list_components,
    register_component,
    register_policy,
    registered_policies,
    resolve_policy,
)
from .simulator import DFRSSimulator, simulate
from .batch import batch_schedule
from .metrics import (
    bounded_stretch,
    max_bounded_stretch,
    degradation_from_bound,
    normalized_underutilization,
)
from .cluster import ClusterEvent, failure_trace
from .scenarios import (apply_scenario, apply_scenario_trace,
                        list_scenarios, parse_scenario_chain,
                        register_scenario, scenario_docs)
from .sweep import Cell, RecordCache, SweepResult, grid, run_grid

__all__ = [
    "Engine", "Policy", "DFRSPolicy", "BatchPolicy",
    "make_policy", "make_seed_policy",
    "ComposedPolicy", "Component", "compose", "compose_from_spec",
    "get_component", "list_components", "register_component",
    "register_policy", "registered_policies", "resolve_policy",
    "DFRSSimulator", "SimParams", "SimResult", "simulate",
    "batch_schedule",
    "bounded_stretch", "max_bounded_stretch", "degradation_from_bound",
    "normalized_underutilization",
    "ClusterEvent", "failure_trace",
    "apply_scenario", "apply_scenario_trace", "parse_scenario_chain",
    "list_scenarios", "scenario_docs", "register_scenario",
    "Cell", "RecordCache", "SweepResult", "grid", "run_grid",
]
