"""Streaming simulation sessions: the open step/ingest driver API.

The paper's premise is *online, non-clairvoyant* scheduling, but the
historical ``Engine.run()`` was closed-world batch: full trace in, one
``SimResult`` out.  :class:`SimSession` re-exposes the same event loop as a
resumable session:

* :meth:`SimSession.submit` — true online arrivals: feed jobs (a
  ``Trace``, ``JobSpec`` list or declarative ``WorkloadSpec``) at any sim
  time, in any number of batches;
* :meth:`SimSession.step_until` / :meth:`SimSession.step` — advance the
  simulation to a time bound or by an event count, observing live state
  between steps;
* :meth:`SimSession.inject` — live perturbations (node fail/restore
  scripts, period changes) conditioned on *observed* session state;
* :meth:`SimSession.snapshot` / :meth:`SimSession.restore` — a
  serializable, fingerprinted :class:`SessionState` (the full SoA
  ``EngineState`` including the CSR incidence, the policy's internal
  state, and the session's own loop cursor) that resumes *bit-identically*
  in the same or a fresh process;
* :meth:`SimSession.fork` — what-if branching: clone the live state
  mid-run, optionally under a *different* policy, and compare outcomes
  from an identical starting point (a scenario axis no batch run can
  produce);
* :meth:`SimSession.result` — finalize partial or complete metrics.

Bit-identity contract: the session executes the exact event-iteration
sequence of the pre-refactor monolithic loop.  ``step_until(t)`` never
advances the engine clock to ``t`` itself — it only processes the event
timestamps ``<= t`` — so the fluid-progress integrals see the identical
sequence of ``advance()`` windows no matter where step boundaries fall,
and ``Engine.run()`` (open → step to exhaustion → result) reproduces the
historical results bit for bit.
"""
from __future__ import annotations

import dataclasses
import hashlib
import heapq
import json
import math
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..core.job import JobSpec
from ..core.state import (S_CANCELLED, S_COMPLETED, S_NOT_ARRIVED, S_PAUSED,
                          S_PENDING)
from ..workloads.trace import Trace, as_trace
from .cluster import ClusterEvent
from .engine import (_EPS, BatchPolicy, DFRSPolicy, Engine, Policy, SimParams,
                     SimResult, resolve_policy_arg)
from .narrator import Narrator

__all__ = ["SimSession", "SessionState", "open_session"]

SCHEMA = "repro.session/v1"

#: payload-shape version *within* the schema.  Bump when keys are added,
#: renamed or re-typed; :meth:`SimSession.restore` refuses versions it does
#: not know with a clear ``ValueError`` instead of failing key-by-key.
#: Version 1 = the pre-versioned PR5–PR7 shape (``version`` key absent).
#: Version 3 adds the compaction keys (``gidx``/``n_total``/
#: ``first_release``/``retired``); v1/v2 snapshots restore with an empty
#: retired log and ``gidx = arange(n)`` (their state was never compacted).
SNAPSHOT_VERSION = 3
_SUPPORTED_VERSIONS = (1, 2, 3)

#: keys every supported payload version carries — validated up front so a
#: stale or hand-edited snapshot raises one actionable error, not an
#: opaque ``KeyError`` deep inside restore
_REQUIRED_KEYS = frozenset({
    "params", "policy", "jobs", "vt", "yld", "penalty_until",
    "completed_at", "status", "job_pmtn", "job_mig", "mappings",
    "pool_load", "pool_mem_free", "alive", "now", "util_integral",
    "demand_integral", "bytes_moved_gb", "n_pmtn", "n_mig", "events",
    "arrivals", "cluster_events", "next_tick", "tick_armed", "horizon",
    "exhausted", "hit_cap", "wall_s", "policy_state",
})

_JOB_COLS = ("jid", "release", "proc_time", "n_tasks", "cpu_need", "mem_req")


# --------------------------------------------------------------------------- #
# snapshots                                                                    #
# --------------------------------------------------------------------------- #
class SessionState:
    """Serializable snapshot of a :class:`SimSession` at one event boundary.

    Wraps a JSON-able payload (exact float round-trips via ``repr``;
    ``Infinity``/``NaN`` use the ``json`` module's standard extensions).
    ``fingerprint`` is a SHA-256 over the canonical payload text — two
    snapshots with equal fingerprints resume into bit-identical sessions.
    """

    __slots__ = ("payload", "_fingerprint")

    def __init__(self, payload: Dict[str, Any]):
        if payload.get("schema") != SCHEMA:
            raise ValueError(f"not a {SCHEMA} snapshot "
                             f"(schema: {payload.get('schema')!r})")
        self.payload = payload
        self._fingerprint: Optional[str] = None

    @property
    def fingerprint(self) -> str:
        fp = self._fingerprint
        if fp is None:
            canon = json.dumps(self.payload, sort_keys=True)
            fp = hashlib.sha256(canon.encode()).hexdigest()
            self._fingerprint = fp
        return fp

    # convenience accessors -------------------------------------------------
    @property
    def time(self) -> float:
        """Engine clock at snapshot time."""
        return float(self.payload["now"])

    @property
    def policy(self) -> Optional[str]:
        """Rebuildable policy reference (grammar/registered spelling)."""
        return self.payload["policy"]

    @property
    def n_jobs(self) -> int:
        return len(self.payload["jobs"]["jid"])

    def __repr__(self) -> str:
        return (f"SessionState(t={self.time:.6g}, n_jobs={self.n_jobs}, "
                f"policy={self.policy!r}, fingerprint={self.fingerprint[:12]}…)")

    # serialization ---------------------------------------------------------
    def to_json_dict(self) -> Dict[str, Any]:
        return {"fingerprint": self.fingerprint, **self.payload}

    @classmethod
    def from_json_dict(cls, payload: Dict[str, Any]) -> "SessionState":
        payload = dict(payload)
        want = payload.pop("fingerprint", None)
        snap = cls(payload)
        if want is not None and want != snap.fingerprint:
            raise ValueError("session snapshot fingerprint mismatch after "
                             "round-trip (corrupted payload?)")
        return snap

    def save(self, path: str) -> str:
        # unique-temp-name atomic replace: the serve layer snapshots many
        # tenants' sessions into one shared store, possibly concurrently
        from ..core.ioutil import atomic_write_json
        return atomic_write_json(path, self.to_json_dict(), indent=None)

    @classmethod
    def load(cls, path: str) -> "SessionState":
        with open(path) as f:
            return cls.from_json_dict(json.load(f))


# --------------------------------------------------------------------------- #
# policy-state capture                                                         #
#                                                                              #
# Policies keep private scheduling state (the batch FIFO queue / free-node     #
# heap, the stretch-pass yield flag).  Snapshots persist it exactly; what-if   #
# forks that *switch* policy instead rebuild a fresh state from the live       #
# engine.  Custom policies/components opt in via snapshot_state() /            #
# restore_state(payload, engine) (and adopt_state(engine) for switches).       #
# --------------------------------------------------------------------------- #
def _snapshot_policy_state(pol: Policy) -> Dict[str, Any]:
    from .components import ComposedPolicy, _BatchState, batch_state_payload

    if hasattr(pol, "snapshot_state"):
        return {"kind": "custom", "payload": pol.snapshot_state()}
    if isinstance(pol, ComposedPolicy):
        shared: Dict[str, Any] = {}
        for k, v in pol.shared.items():
            if isinstance(v, _BatchState):
                shared[k] = {"__batch__": batch_state_payload(v)}
            elif v is None or isinstance(v, (bool, int, float, str)):
                shared[k] = v
            else:
                raise TypeError(
                    f"policy shared state {k!r} ({type(v).__name__}) is not "
                    f"snapshottable; give the owning component "
                    f"snapshot_state()/restore_state()")
        comps: Dict[str, Any] = {}
        for idx, c in enumerate(pol.components):
            if hasattr(c, "snapshot_state"):
                comps[str(idx)] = c.snapshot_state()
        return {"kind": "composed", "shared": shared, "components": comps}
    if isinstance(pol, BatchPolicy):
        return {
            "kind": "batch-seed",
            "queue": [js.i for js in pol.queue],
            "free": list(pol.free),
            "running": [list(r) for r in pol.running],
            "dirty": pol._dirty,
        }
    if isinstance(pol, DFRSPolicy):
        return {"kind": "dfrs-seed",
                "stretch_yields_set": pol._stretch_yields_set}
    raise TypeError(
        f"policy {pol!r} is not snapshottable; implement "
        f"snapshot_state()/restore_state(payload, engine)")


def _restore_policy_state(pol: Policy, payload: Dict[str, Any],
                          engine: Engine) -> None:
    from collections import deque

    from .components import ComposedPolicy, batch_state_from_payload

    kind = payload["kind"]
    st = engine.state
    if kind == "custom":
        pol.restore_state(payload["payload"], engine)
        return
    if kind == "composed":
        assert isinstance(pol, ComposedPolicy)
        for k, v in payload["shared"].items():
            if isinstance(v, dict) and "__batch__" in v:
                pol.shared[k] = batch_state_from_payload(
                    v["__batch__"], st.views, engine.params.n_nodes)
            else:
                pol.shared[k] = v
        for idx, cp in payload["components"].items():
            pol.components[int(idx)].restore_state(cp, engine)
        return
    if kind == "batch-seed":
        assert isinstance(pol, BatchPolicy)
        pol.queue = deque(st.views[int(i)] for i in payload["queue"])
        pol.free = [int(n) for n in payload["free"]]
        pol.running = [(float(e), int(j), int(n))
                       for e, j, n in payload["running"]]
        pol._dirty = bool(payload["dirty"])
        return
    if kind == "dfrs-seed":
        assert isinstance(pol, DFRSPolicy)
        pol._stretch_yields_set = bool(payload["stretch_yields_set"])
        return
    raise ValueError(f"unknown policy-state kind {kind!r}")


def _adopt_policy_state(pol: Policy, engine: Engine) -> None:
    """Rebuild a freshly-bound policy's internal state from the *live*
    engine state — the what-if fork path, where the restored session runs a
    different policy than the one that produced the snapshot.

    §4 DFRS compositions are stateless between events, so nothing needs
    rebuilding.  Batch-queue compositions get a reconstructed queue state:
    waiting (pending/paused) jobs queue FIFO by ``(release, jid)``; running
    jobs that hold whole nodes exclusively are adopted as batch-started
    (yield pinned to 1, completion estimated at ``now + remaining_vt``);
    co-located fractional jobs go through the fractional-backfill
    bookkeeping, so their nodes return to the free pool only when they
    drain.
    """
    from .components import ComposedPolicy, _BatchState

    if hasattr(pol, "adopt_state"):
        pol.adopt_state(engine)
        return
    if isinstance(pol, DFRSPolicy):
        return
    if isinstance(pol, ComposedPolicy):
        if not any(c.kind == "submit" and c.component_name == "fcfs-queue"
                   for c in pol.components):
            return                      # DFRS composition: event-driven only
        st = engine.state
        n_nodes = engine.params.n_nodes
        bs = _BatchState(n_nodes)
        from collections import deque
        waiting = sorted(
            (st.views[i] for i in st.in_system_indices()
             if int(st.status[i]) in (S_PENDING, S_PAUSED)),
            key=lambda js: (js.spec.release, js.spec.jid))
        bs.queue = deque(waiting)
        occupied = {n for n in range(n_nodes) if st.inc.rows[n]}
        bs.free = [n for n in range(n_nodes)
                   if n not in occupied and st.alive[n]]
        heapq.heapify(bs.free)
        now = st.now
        for js in st.running():
            nodes = set(js.mapping)
            exclusive = (len(nodes) == js.spec.n_tasks
                         and all(len(st.inc.rows[n]) == 1 for n in nodes))
            if exclusive:
                bs.running.append((now + max(js.remaining_vt(), 0.0),
                                   js.spec.jid, js.spec.n_tasks))
                for n in nodes:
                    bs.excl_owner[n] = js.spec.jid
                js.yld = 1.0            # batch semantics: dedicated nodes
            else:
                bs.frac_jobs[js.spec.jid] = list(js.mapping)
                for n in js.mapping:
                    bs.frac_count[n] += 1
        bs.dirty = True                 # drain the queue at the next event
        pol.shared["batch"] = bs
        return
    raise TypeError(
        f"cannot adopt live state into policy {pol!r}; implement "
        f"adopt_state(engine) (seed BatchPolicy is oracle-only — fork onto "
        f"the composed spelling instead)")


# --------------------------------------------------------------------------- #
# the session                                                                  #
# --------------------------------------------------------------------------- #
class SimSession:
    """A resumable simulation: the engine's event loop as an open API.

    Build one with :func:`repro.api.open_session` (empty cluster, submit
    jobs online) or :meth:`from_engine` (adopt a fully-constructed
    :class:`Engine` — what ``Engine.run()`` does).  All stepping entry
    points share one loop implementation, so results never depend on how
    the run was partitioned.
    """

    # -- construction -------------------------------------------------------
    def __init__(
        self,
        policy,
        params: Optional[SimParams] = None,
        *,
        cluster_events: Sequence[ClusterEvent] = (),
        **param_overrides: Any,
    ):
        if params is None:
            params = SimParams(**param_overrides)
        else:
            params = dataclasses.replace(params, **param_overrides)
        self._init_from_engine(Engine((), policy, params, cluster_events))

    @classmethod
    def from_engine(cls, engine: Engine) -> "SimSession":
        """Adopt a constructed engine (its not-yet-arrived jobs become the
        session's arrival stream; the closed-world ``Engine.run()`` path)."""
        ses = cls.__new__(cls)
        ses._init_from_engine(engine)
        return ses

    def _init_from_engine(self, engine: Engine) -> None:
        self.engine = engine
        st = engine.state
        pol = engine.policy
        self._arrivals: List[Tuple[float, int, int]] = [
            (s.release, s.jid, i) for i, s in enumerate(st.specs)
            if int(st.status[i]) == S_NOT_ARRIVED
        ]
        heapq.heapify(self._arrivals)
        self._jids = {s.jid for s in st.specs}
        self._cev: List[ClusterEvent] = (
            list(engine.cluster_events) if pol.handles_cluster_events else [])
        self._ci = 0
        self._periodic = pol.periodic_kind is not None
        self._next_tick = math.inf
        self._tick_armed = False
        if self._periodic and self._arrivals:
            self._next_tick = self._arrivals[0][0] + engine.params.period
            self._tick_armed = True
        self._exhausted = False
        self._hit_cap = False
        self._horizon = st.now
        self._wall = 0.0
        #: True while a stream() driver still holds future chunks: the tick
        #: train and narrator stay armed through inter-chunk gaps exactly as
        #: they would with the whole trace submitted upfront
        self._stream_pending = False
        self._narrator: Optional[Narrator] = None
        #: optional repro.tune.AutoTuner driven from the stepping loop
        self._tuner = None
        self._closed = False
        self._close_hooks: List[Any] = []
        #: ephemeral driver scratchpad (reactive rules keep per-session
        #: state here); deliberately NOT part of snapshots
        self.scratch: Dict[str, Any] = {}

    # -- lifecycle ----------------------------------------------------------
    @property
    def closed(self) -> bool:
        """True once :meth:`close` has run; mutating entry points then
        raise, read-only ones (``observe``/``result``) keep working."""
        return self._closed

    def add_close_hook(self, callback) -> None:
        """Register ``callback(session)`` to run exactly once at
        :meth:`close` (servers/registries release journals, files, slots
        here; hooks registered after close are invoked immediately)."""
        if self._closed:
            callback(self)
            return
        self._close_hooks.append(callback)

    def close(self) -> None:
        """Idempotent close: mark the session finished and run the close
        hooks (each exactly once).  Further ``submit``/``step``/``inject``/
        ``snapshot`` calls raise ``ValueError``; ``observe()`` and
        ``result()`` stay readable so a holder can still collect metrics.
        """
        if self._closed:
            return
        self._closed = True
        hooks, self._close_hooks = self._close_hooks, []
        first_err: Optional[BaseException] = None
        for cb in hooks:            # run every hook even if one raises
            try:
                cb(self)
            except BaseException as exc:  # noqa: BLE001 — re-raised below
                if first_err is None:
                    first_err = exc
        if first_err is not None:
            raise first_err

    def __enter__(self) -> "SimSession":
        self._require_open("enter a context with")
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        self.close()
        return False

    def _require_open(self, what: str) -> None:
        if self._closed:
            raise ValueError(f"session is closed; cannot {what} it")

    # -- introspection ------------------------------------------------------
    @property
    def now(self) -> float:
        """Session clock: the engine clock or the last ``step_until``
        target, whichever is later."""
        return max(self.engine.state.now, self._horizon)

    @property
    def n_events(self) -> int:
        return self.engine._events

    @property
    def exhausted(self) -> bool:
        """No future event exists (until new jobs/events are submitted)."""
        return self._exhausted

    @property
    def handles_cluster_events(self) -> bool:
        return self.engine.policy.handles_cluster_events

    @property
    def policy_name(self) -> str:
        e = self.engine
        if e.policy_spec is not None:
            return e.policy_spec.name
        return (getattr(e.policy, "name", None)
                or getattr(e.policy, "algo", None)
                or getattr(getattr(e.policy, "spec", None), "name", None)
                or e.policy.__class__.__name__)

    def next_event_time(self) -> float:
        """Peek the next event timestamp (``inf`` when nothing is left).
        Pure: a peek is not an engine event and never perturbs the run."""
        st = self.engine.state
        t_arr = self._arrivals[0][0] if self._arrivals else math.inf
        t_cev = (self._cev[self._ci].time
                 if self._ci < len(self._cev) else math.inf)
        t_tick = (self._next_tick
                  if (self._periodic
                      and (st.any_in_system() or self._arrivals
                           or self._stream_pending))
                  else math.inf)
        return min(t_arr, st.next_completion_time(), t_tick, t_cev)

    def observe(self) -> Dict[str, Any]:
        """Scheduler-visible live state (what reactive rules and the
        streaming CLI see between steps)."""
        st = self.engine.state
        status = st.status
        ret = st.retired
        run = st.running_indices()
        alive = float(st.alive.sum())
        util = float((st.yld[run] * st.demand[run]).sum())
        return {
            "t": self.now,
            "engine_t": st.now,
            "events": self.engine._events,
            "n_future": len(self._arrivals),
            "n_pending": int((status == S_PENDING).sum()),
            "n_running": int(run.size),
            "n_paused": int((status == S_PAUSED).sum()),
            "n_completed": int((status == S_COMPLETED).sum())
                           + ret.n_completed,
            "queue_depth": int(((status == S_PENDING)
                                | (status == S_PAUSED)).sum()),
            "n_cancelled": int((status == S_CANCELLED).sum())
                           + ret.n_cancelled,
            # jobs whose executed (truth) time diverges from the estimate
            # policies observe — the non-clairvoyance the narrator injects
            "n_noisy": int((st.proc_truth != st.proc_time).sum())
                       + ret.n_noisy,
            "alive_nodes": int(alive),
            "utilization": util / max(alive, 1e-9),
            "n_pmtn": self.engine.n_pmtn,
            "n_mig": self.engine.n_mig,
            "bytes_moved_gb": self.engine.bytes_moved_gb,
            "exhausted": self._exhausted,
        }

    # -- online ingest ------------------------------------------------------
    def submit(self, jobs: Union[Trace, Sequence[JobSpec], Any],
               *, shift: Union[None, float, str] = None) -> List[int]:
        """Feed jobs into the running simulation (true online arrivals).

        ``jobs`` is a :class:`Trace`, a ``JobSpec`` sequence, or a
        declarative ``WorkloadSpec`` (materialized via the registry).
        ``shift`` offsets every release time: a float adds seconds,
        ``"now"`` aligns the batch's first release with the session clock.
        Releases must not predate the engine clock (history is immutable);
        job ids must be globally unique within the session.  Returns the
        dense engine indices assigned to the new jobs.
        """
        self._require_open("submit jobs into")
        from ..workloads.registry import WorkloadSpec, make_trace_ir
        if isinstance(jobs, WorkloadSpec):
            trace = make_trace_ir(jobs)
        else:
            trace = as_trace(jobs)
        if len(trace) and shift is not None:
            if shift == "now":
                delta = self.now - float(trace.release.min())
            else:
                delta = float(shift)
            trace = trace.replace(release=trace.release + delta)
        specs = trace.sorted_by_release().to_specs()
        if not specs:
            return []
        st = self.engine.state
        if specs[0].release < st.now - _EPS:
            raise ValueError(
                f"job {specs[0].jid} released at t={specs[0].release:.6g} "
                f"but the engine clock is already at {st.now:.6g}; pass "
                f"shift='now' (or a float offset) to submit live")
        jids = [s.jid for s in specs]
        # live jids are a set; compacted-away jids live in the retired log
        # (sorted array + searchsorted), so the dup check stays O(batch)
        # without an O(jobs-ever) Python set
        dup = self._jids.intersection(jids)
        if not dup:
            dup = set(st.retired.contains(jids))
        if dup or len(set(jids)) != len(jids):
            dup = sorted(dup) or "within the batch"
            raise ValueError(f"duplicate job ids {dup}; session job ids "
                             f"must be unique")
        self.engine.policy.validate(specs, self.engine.params)
        idx = st.extend(specs)
        for i, s in zip(idx, specs):
            heapq.heappush(self._arrivals, (s.release, s.jid, i))
            self._jids.add(s.jid)
        if self._periodic and not self._tick_armed:
            # mirror the closed-world loop: the tick train starts one
            # period after the first release the session ever saw
            self._next_tick = specs[0].release + self.engine.params.period
            self._tick_armed = True
        if self._narrator is not None:
            self._narrator.on_submitted(self, idx)
        self._exhausted = False         # new future work re-arms the loop
        return idx

    def inject(self, event: Union[ClusterEvent, Dict[str, Any]]) -> None:
        """Schedule a live perturbation.

        ``event`` is a :class:`ClusterEvent` (or a dict like
        ``{"kind": "fail", "t": 1200, "nodes": [0, 1]}``); ``kind``
        ``"period"`` with a ``"period"`` value changes the periodic-pass
        period immediately instead.  Fail/join events are processed by the
        stepping loop at their timestamp (which must not predate the engine
        clock) exactly like a pre-scripted scenario event.
        """
        self._require_open("inject events into")
        if isinstance(event, dict):
            kind = event.get("kind")
            if kind == "period":
                self.set_period(event["period"])
                return
            jids = event.get("jids")
            if jids is None:
                jids = [event["jid"]] if "jid" in event else ()
            value = event.get("value", event.get("n_tasks"))
            event = ClusterEvent(
                time=float(event.get("t", event.get("time", self.now))),
                kind=kind,
                nodes=tuple(int(n) for n in event.get("nodes", ())),
                jids=tuple(int(j) for j in jids),
                value=None if value is None else float(value),
            )
        if not self.engine.policy.handles_cluster_events:
            raise ValueError(
                f"policy {self.policy_name!r} does not handle cluster "
                f"events (batch baselines do not model failures)")
        st = self.engine.state
        if event.time < st.now - _EPS:
            raise ValueError(
                f"cannot inject an event at t={event.time:.6g}: the engine "
                f"clock is already at {st.now:.6g}")
        bad = [n for n in event.nodes
               if not (0 <= n < self.engine.params.n_nodes)]
        if bad:
            raise ValueError(f"nodes {bad} outside the "
                             f"{self.engine.params.n_nodes}-node cluster")
        # contradiction checks against the *projected* state (everything
        # already pending at event.time applied): a duplicate fail/join or
        # a double cancel would silently corrupt incidence/pool accounting
        if event.kind in ("fail", "join"):
            alive = self._projected_alive(event.time)
            for n in event.nodes:
                if event.kind == "fail" and not alive[n]:
                    raise ValueError(
                        f"node {n} is already dead at t={event.time:.6g}; "
                        f"injecting a duplicate 'fail' would corrupt "
                        f"incidence state")
                if event.kind == "join" and alive[n]:
                    raise ValueError(
                        f"node {n} is already alive at t={event.time:.6g}; "
                        f"injecting a duplicate 'join' would corrupt "
                        f"incidence state")
                alive[n] = event.kind == "join"     # within-event dups too
        elif event.kind in ("cancel", "resize"):
            jid_to_i = {s.jid: i for i, s in enumerate(st.specs)}
            pending = self._pending_cancels(event.time)
            for jid in event.jids:
                i = jid_to_i.get(int(jid))
                if i is None:
                    raise ValueError(
                        f"unknown job id {jid} at t={event.time:.6g}; "
                        f"known jobs only can be {event.kind}ed")
                code = int(st.status[i])
                if code == S_COMPLETED:
                    raise ValueError(
                        f"job {jid} already completed; cannot {event.kind} "
                        f"it at t={event.time:.6g}")
                if code == S_CANCELLED or int(jid) in pending:
                    raise ValueError(
                        f"job {jid} is already cancelled at "
                        f"t={event.time:.6g}; duplicate '{event.kind}' "
                        f"rejected")
        # keep the pending suffix time-sorted (stable after equal times)
        pos = self._ci
        while pos < len(self._cev) and self._cev[pos].time <= event.time:
            pos += 1
        self._cev.insert(pos, event)
        self._exhausted = False
        return

    def set_period(self, period: float) -> None:
        """Change the periodic-pass period live (takes effect from the next
        tick; no-op for compositions without a periodic component).

        The engine's ``SimParams`` is *replaced*, never mutated in place:
        a params object shared with other engines or sessions (the
        ``from_engine`` path, sweep cell templates) never sees the change,
        and a snapshot taken at any point — including before the next
        periodic event fires — carries exactly the period this session is
        running.
        """
        self._require_open("change the period of")
        period = float(period)
        if period <= 0:
            raise ValueError("period must be > 0")
        self.engine.params = dataclasses.replace(self.engine.params,
                                                 period=period)

    def attach_narrator(self, narrator: Narrator) -> None:
        """Attach a chaos :class:`~repro.sched.narrator.Narrator`: its
        streams fire lazily as the loop advances and ride along in
        snapshots (bit-exact RNG round-trip).  Attach before submitting so
        truth-noise streams see every job."""
        self._require_open("attach a narrator to")
        if (narrator.needs_cluster_events()
                and not self.engine.policy.handles_cluster_events):
            raise ValueError(
                f"policy {self.policy_name!r} does not handle cluster "
                f"events; only truth-noise narrator streams work under "
                f"batch baselines")
        self._narrator = narrator
        self._exhausted = False         # a new event source re-arms the loop

    @property
    def narrator(self) -> Optional[Narrator]:
        return self._narrator

    def switch_policy(self, policy) -> None:
        """Hot-swap the scheduling policy in place, mid-run.

        The live engine state — running set, queue, virtual times, pending
        arrivals, the event counter — is untouched; the new policy rebuilds
        its private state from it exactly like a what-if fork
        (``restore(snap, policy=...)``) would, so a live swap and a
        fork-and-continue from the same event boundary behave identically.
        This is the promotion primitive behind :mod:`repro.tune`.

        Refused for policies that do not handle cluster events while the
        session still needs them (an attached chaos narrator, pending
        injected events, or dead nodes) — batch baselines do not model
        failures.
        """
        self._require_open("switch the policy of")
        e = self.engine
        st = e.state
        spec, pol, ref = resolve_policy_arg(policy)
        if not pol.handles_cluster_events:
            if (self._narrator is not None
                    and self._narrator.needs_cluster_events()):
                raise ValueError(
                    f"cannot switch to {policy!r}: it does not handle "
                    f"cluster events but the attached narrator injects them")
            if self._ci < len(self._cev):
                raise ValueError(
                    f"cannot switch to {policy!r}: it does not handle "
                    f"cluster events and "
                    f"{len(self._cev) - self._ci} are still pending")
            if not bool(st.alive.all()):
                raise ValueError(
                    f"cannot switch to {policy!r}: it does not handle "
                    f"cluster events and the cluster has dead nodes")
            self._cev = []
            self._ci = 0
        pol.validate(st.specs, e.params)
        e.policy_spec, e.policy, e.policy_ref = spec, pol, ref
        pol.bind(e)
        _adopt_policy_state(pol, e)
        self._periodic = pol.periodic_kind is not None
        if not self._periodic:
            self._next_tick = math.inf
        elif math.isinf(self._next_tick):
            # the swap introduced a periodic pass mid-run: base its tick
            # train at the live clock (the fork path does the same)
            self._next_tick = st.now + e.params.period
            self._tick_armed = True
        self._exhausted = False         # the new policy may act again

    def attach_autotuner(self, tuner) -> None:
        """Attach an :class:`repro.tune.AutoTuner`: it fires lazily from
        the stepping loop like the narrator — fork, race, maybe promote —
        and its full state (RNG, schedule, decision log) rides along in
        snapshots bit-exactly."""
        self._require_open("attach an autotuner to")
        if self.engine.policy_ref is None:
            raise ValueError(
                "session policy has no rebuildable reference (ad-hoc "
                "Policy instance); the tuner could not race or restore it")
        self._tuner = tuner
        self._exhausted = False         # tuner peeks re-arm the loop

    @property
    def autotuner(self):
        return self._tuner

    # -- projected state (pending injections applied) -----------------------
    def _projected_alive(self, t: Optional[float] = None) -> np.ndarray:
        """Node liveness once the pending event suffix up to ``t`` (engine
        clock order; ``None`` = all pending) has been applied."""
        alive = self.engine.state.alive.copy()
        for ev in self._cev[self._ci:]:
            if t is not None and ev.time > t + _EPS:
                break
            if ev.kind == "fail":
                alive[list(ev.nodes)] = False
            elif ev.kind == "join":
                alive[list(ev.nodes)] = True
        return alive

    def _pending_cancels(self, t: Optional[float] = None) -> set:
        """Job ids with a cancellation pending in the event suffix."""
        out: set = set()
        for ev in self._cev[self._ci:]:
            if t is not None and ev.time > t + _EPS:
                break
            if ev.kind == "cancel":
                out.update(int(j) for j in ev.jids)
        return out

    # -- stepping -----------------------------------------------------------
    def _loop(self, until: float = math.inf,
              max_steps: Optional[int] = None,
              exclusive: bool = False) -> int:
        """The one event loop behind every stepping entry point.

        Processes event timestamps while they are ``<= until`` (boundary
        peeks are side-effect-free: they do not count as engine events) and
        while fewer than ``max_steps`` timestamps have been handled.  The
        committed iteration — event counting, cap checking, fluid advance,
        hook order — replicates the historical ``Engine.run()`` loop
        exactly.

        ``exclusive`` processes timestamps strictly ``< until`` — the
        stream() driver's bound: the timestamp at a chunk's first release
        must be handled in ONE iteration *after* that chunk is submitted,
        exactly as it would be with the whole trace submitted upfront.  An
        ``inf`` horizon is then also a boundary peek (more chunks are
        coming), never exhaustion.
        """
        e = self.engine
        p = e.params
        st = e.state
        pol = e.policy
        cev = self._cev
        periodic = self._periodic
        compact_every = p.compact_interval
        steps = 0
        t0 = time.perf_counter()
        try:
            while not self._exhausted:
                if max_steps is not None and steps >= max_steps:
                    break
                heap = self._arrivals       # compaction rebuilds the list
                t_arr = heap[0][0] if heap else math.inf
                t_cev = cev[self._ci].time if self._ci < len(cev) else math.inf
                t_done = st.next_completion_time()
                live = st.any_in_system()
                armed = live or heap or self._stream_pending
                t_tick = (self._next_tick
                          if (periodic and armed) else math.inf)
                t_next = min(t_arr, t_done, t_tick, t_cev)
                # narrator streams fire lazily, never past the next engine
                # event or the step bound (a fire injects into the pending
                # suffix, so the injected timestamps process right below);
                # gated on (live or heap) like the tick so a drained
                # session still exhausts
                nar = self._narrator
                if nar is not None and armed:
                    while True:
                        t_nar = nar.peek(self)
                        if not (t_nar <= t_next
                                and (t_nar < until if exclusive
                                     else t_nar <= until)):
                            break
                        nar.fire(self)
                        t_cev = (cev[self._ci].time
                                 if self._ci < len(cev) else math.inf)
                        t_next = min(t_next, t_cev)
                    if math.isinf(t_next) and math.isfinite(nar.peek(self)):
                        break           # chaos pending beyond the step
                                        # bound — a peek, not an event
                # the autotuner fires at the same lazy boundary the
                # narrator does: when its scheduled time is due before the
                # next engine event AND inside the step bound — so the
                # fire point (and therefore the race snapshot and the
                # decision log) is identical no matter how the run is
                # partitioned into step()/step_until() calls.  A fire is
                # not an engine event; a promotion invalidates the cached
                # loop locals, so restart the iteration.
                tun = self._tuner
                if tun is not None and armed and not math.isinf(t_next):
                    swapped = False
                    while True:
                        t_tun = tun.peek(self)
                        if not (t_tun <= t_next
                                and (t_tun < until if exclusive
                                     else t_tun <= until)):
                            break
                        if tun.fire(self):
                            swapped = True
                            break
                    if swapped:
                        pol = e.policy
                        p = e.params
                        periodic = self._periodic
                        cev = self._cev
                        compact_every = p.compact_interval
                        continue
                if exclusive and (math.isinf(t_next) or t_next >= until):
                    break               # stream-window boundary peek — the
                                        # next chunk arrives before t_next
                if t_next > until and not math.isinf(t_next):
                    break               # boundary peek — not an engine event
                e._events += 1
                if e._events > p.max_events:
                    e._events = p.max_events
                    if p.on_max_events == "truncate":
                        self._hit_cap = True
                        self._exhausted = True
                        break
                    n_done = (int((st.status == S_COMPLETED).sum())
                              + st.retired.n_completed)
                    raise RuntimeError(
                        f"event budget exceeded: max_events={p.max_events} at "
                        f"t={st.now:.6g}s with {n_done}/{st.n_total} jobs "
                        f"completed (policy {pol.__class__.__name__}); raise "
                        f"SimParams.max_events or set on_max_events='truncate' "
                        f"for a partial SimResult")
                if math.isinf(t_next):
                    self._exhausted = True
                    break
                st.advance(t_next)
                steps += 1

                acted = False
                # 1) completions
                while True:
                    fin = st.finished_running_indices()
                    if fin.size == 0:
                        break
                    for i in fin:
                        js = st.views[i]
                        pol.on_job_completed(js)   # mapping still set here
                        e.complete(js)
                    pol.on_complete()
                    acted = True
                # 2) cluster events
                while self._ci < len(cev) and cev[self._ci].time <= st.now + _EPS:
                    e._apply_cluster_event(cev[self._ci])
                    self._ci += 1
                    acted = True
                # 3) arrivals
                while heap and heap[0][0] <= st.now + _EPS:
                    _, _, i = heapq.heappop(heap)
                    if int(st.status[i]) != S_NOT_ARRIVED:
                        continue        # cancelled before it ever arrived
                    st.set_status(i, S_PENDING)
                    pol.on_submit(st.views[i])
                    acted = True
                # 4) periodic tick
                if periodic and st.now + _EPS >= self._next_tick:
                    pol.on_tick()
                    self._next_tick += p.period
                    acted = True
                pol.finalize(acted)
                if compact_every and st.n_retired_rows >= compact_every:
                    self._compact()
        finally:
            self._wall += time.perf_counter() - t0
        return steps

    def step_until(self, t: float) -> float:
        """Process every event timestamp ``<= t`` (inclusive); the session
        clock then reads ``t``.  Returns the new session clock."""
        self._require_open("step")
        t = float(t)
        self._loop(until=t)
        self._horizon = max(self._horizon, t, self.engine.state.now)
        return self.now

    def step(self, n_events: int = 1, *, until: float = math.inf) -> int:
        """Process up to ``n_events`` event timestamps; returns how many
        were actually processed (0 when the run is exhausted).  ``until``
        additionally bounds the processed timestamps (inclusive, like
        :meth:`step_until`) — fewer than ``n_events`` processed with a
        finite ``until`` means the bound was reached (or the run
        exhausted), which is what budgeted-horizon branch runs chunk on.
        """
        self._require_open("step")
        if n_events < 1:
            raise ValueError("n_events must be >= 1")
        steps = self._loop(until=float(until), max_steps=int(n_events))
        self._horizon = max(self._horizon, self.engine.state.now)
        return steps

    def run_to_exhaustion(self) -> "SimSession":
        """Step until no future event exists.

        With ``SimParams.compact_interval`` set, a trailing compaction
        evicts the tail of finished rows that accumulated since the last
        periodic trigger, so an exhausted compacting session always ends
        with the engine state holding active rows only (none, if the trace
        ran to completion).
        """
        self._require_open("step")
        self._loop()
        self._horizon = max(self._horizon, self.engine.state.now)
        if self.engine.params.compact_interval and self.engine.state.n_retired_rows:
            self._compact()
        return self

    def run(self) -> SimResult:
        """Step to exhaustion and finalize (the ``Engine.run()`` contract)."""
        self.run_to_exhaustion()
        return self.result()

    # -- streaming ingest ---------------------------------------------------
    def stream(self, chunks, *, run_to_exhaustion: bool = True
               ) -> "SimSession":
        """Feed an iterator of release-windowed :class:`Trace` chunks as
        true online arrivals, stepping the simulation between windows.

        At most one future window is materialized at any time (the chunk
        source — ``Trace.iter_chunks`` or a ``swf-stream`` workload — never
        holds the full log), and with ``SimParams.compact_interval`` set
        the engine state stays O(active) too.  Chunks must be
        release-disjoint and non-decreasing (every release in chunk k+1 is
        ``>=`` every release in chunk k), which any ``iter_chunks`` window
        partition satisfies.

        Bit-identity: between submits the loop runs with an *exclusive*
        bound at the next chunk's first release, so that timestamp is
        processed in one event iteration after its chunk is submitted —
        the run is indistinguishable from submitting the whole trace
        upfront, event count included.
        """
        self._require_open("stream into")
        it = iter(chunks)
        cur: Optional[Trace] = None
        try:
            for nxt in it:
                if not len(nxt):
                    continue
                if cur is None:
                    cur = nxt
                    continue
                self._stream_pending = True
                self.submit(cur)
                bound = float(nxt.release.min())
                self._loop(until=bound, exclusive=True)
                self._horizon = max(self._horizon, self.engine.state.now)
                cur = nxt
        finally:
            self._stream_pending = False
        if cur is not None:
            self.submit(cur)
        if run_to_exhaustion:
            self.run_to_exhaustion()
        return self

    # -- compaction ---------------------------------------------------------
    def compact(self) -> int:
        """Evict COMPLETED/CANCELLED rows from the engine state now (see
        ``EngineState.compact``); with ``SimParams.compact_interval`` set
        the loop does this automatically.  Returns rows evicted."""
        self._require_open("compact")
        return self._compact()

    def _compact(self) -> int:
        st = self.engine.state
        # rows with a pending arrival-heap entry must survive: a job
        # cancelled before it ever arrived still produces its (skipped)
        # arrival event, and dropping it would change the event count
        protect = [i for (_, _, i) in self._arrivals]
        n0 = len(st.retired)
        new_of_old = st.compact(protect=protect)
        if new_of_old is None:
            return 0
        # remap the arrival heap in place: (release, jid) keys are unique
        # per session, so the index never participates in heap ordering
        self._arrivals = [(r, j, int(new_of_old[i]))
                          for (r, j, i) in self._arrivals]
        evicted = st.retired.col("jid")[n0:]
        self._jids.difference_update(int(j) for j in evicted)
        return int(evicted.shape[0])

    # -- finalization -------------------------------------------------------
    def result(self, partial: Optional[bool] = None,
               light: bool = False) -> SimResult:
        """Finalize metrics.  Defaults to a *partial* result (covering the
        completed jobs only) while events remain, and to the strict
        closed-world result once exhausted.  ``light`` skips the O(jobs)
        per-job completion/stretch dicts (aggregates only, computed by the
        identical float ops) for bounded-RSS scale runs."""
        if partial is None:
            partial = not self._exhausted
        return self.engine._result(hit_cap=self._hit_cap, partial=partial,
                                   sim_wall_s=self._wall, light=light)

    # -- snapshot / restore / fork ------------------------------------------
    def snapshot(self) -> SessionState:
        """Capture the full session — SoA engine state (the CSR incidence
        is reconstructed exactly from the serialized mappings), node pool
        accumulators, policy-internal state, and the session's loop cursor
        — as a fingerprinted, JSON-serializable :class:`SessionState`."""
        self._require_open("snapshot")
        e = self.engine
        st = e.state
        cols = {
            "jid": [s.jid for s in st.specs],
            "release": [s.release for s in st.specs],
            "proc_time": [s.proc_time for s in st.specs],
            "n_tasks": [s.n_tasks for s in st.specs],
            "cpu_need": [s.cpu_need for s in st.specs],
            "mem_req": [s.mem_req for s in st.specs],
        }
        payload: Dict[str, Any] = {
            "schema": SCHEMA,
            "version": SNAPSHOT_VERSION,
            "params": dataclasses.asdict(e.params),
            "policy": e.policy_ref,
            "jobs": cols,
            "proc_truth": st.proc_truth.tolist(),
            "vt": st.vt.tolist(),
            "yld": st.yld.tolist(),
            "penalty_until": st.penalty_until.tolist(),
            "completed_at": st.completed_at.tolist(),
            "status": st.status.tolist(),
            "job_pmtn": st.n_pmtn.tolist(),
            "job_mig": st.n_mig.tolist(),
            "mappings": [None if m is None else list(m)
                         for m in st.mappings],
            "pool_load": st.pool.load.tolist(),
            "pool_mem_free": st.pool.mem_free.tolist(),
            "alive": st.alive.tolist(),
            "now": st.now,
            "util_integral": st.util_integral,
            "demand_integral": st.demand_integral,
            "bytes_moved_gb": e.bytes_moved_gb,
            "n_pmtn": e.n_pmtn,
            "n_mig": e.n_mig,
            "events": e._events,
            "arrivals": [list(a) for a in self._arrivals],
            "cluster_events": [[ev.time, ev.kind, list(ev.nodes),
                                list(ev.jids), ev.value]
                               for ev in self._cev[self._ci:]],
            "next_tick": self._next_tick,
            "tick_armed": self._tick_armed,
            "horizon": self._horizon,
            "exhausted": self._exhausted,
            "hit_cap": self._hit_cap,
            "wall_s": self._wall,
            "policy_state": _snapshot_policy_state(e.policy),
            # v3: compaction state — global arrival indices of the live
            # rows, lifetime counters, and the retired-row accumulators
            "gidx": st.gidx.tolist(),
            "n_total": st.n_total,
            "first_release": st.first_release,
            "retired": st.retired.payload(),
        }
        if self._narrator is not None:
            # optional key: narrator-free snapshots keep the legacy shape
            payload["narrator"] = self._narrator.state()
        if self._tuner is not None:
            # optional key: tuner RNG + schedule + decision log ride along
            payload["autotune"] = self._tuner.state()
        return SessionState(payload)

    @classmethod
    def restore(cls, snap: Union[SessionState, Dict[str, Any], str],
                policy=None) -> "SimSession":
        """Resume a session from a snapshot (same or a fresh process).

        Without ``policy`` the snapshot's own policy reference is rebuilt
        and its internal state restored verbatim — the continuation is
        bit-identical to never having snapshotted.  With ``policy`` the
        restored engine state is handed to a *different* policy (the
        what-if fork path): the new policy starts from the identical live
        cluster but rebuilds its private state from it.
        """
        if isinstance(snap, str):
            snap = SessionState.load(snap)
        elif isinstance(snap, dict):
            snap = SessionState.from_json_dict(snap)
        pl = snap.payload
        version = int(pl.get("version", 1))
        if version not in _SUPPORTED_VERSIONS:
            raise ValueError(
                f"session snapshot version {version} is not supported by "
                f"this build (supported: {list(_SUPPORTED_VERSIONS)}); the "
                f"snapshot was written by an incompatible repro version — "
                f"re-create it or restore with the version that wrote it")
        missing = _REQUIRED_KEYS - pl.keys()
        if missing:
            raise ValueError(
                f"session snapshot is missing required keys "
                f"{sorted(missing)} (stale, truncated, or foreign "
                f"snapshot?); cannot restore")
        params = SimParams(**pl["params"])
        switched = policy is not None
        if policy is None:
            policy = pl["policy"]
            if policy is None:
                raise ValueError(
                    "snapshot carries no rebuildable policy reference (the "
                    "session ran an ad-hoc Policy instance); pass policy=")
        cols = pl["jobs"]
        specs = [
            JobSpec(jid=int(j), release=float(r), proc_time=float(p),
                    n_tasks=int(t), cpu_need=float(c), mem_req=float(m))
            for j, r, p, t, c, m in zip(*(cols[k] for k in _JOB_COLS))
        ]
        e = Engine.__new__(Engine)
        e.params = params
        e.policy_spec, e.policy, e.policy_ref = resolve_policy_arg(policy)
        # allocator backends are process-local objects, not snapshot state:
        # restored engines always resume on the default numpy hot path
        e.alloc_backend = None
        from ..core.state import EngineState
        e.state = EngineState(specs, params.n_nodes)
        e.cluster_events = [
            ClusterEvent(
                float(row[0]), row[1], tuple(int(n) for n in row[2]),
                jids=tuple(int(j) for j in row[3]) if len(row) > 3 else (),
                value=(float(row[4]) if len(row) > 4 and row[4] is not None
                       else None))
            for row in pl["cluster_events"]]
        e.bytes_moved_gb = float(pl["bytes_moved_gb"])
        e.n_pmtn = int(pl["n_pmtn"])
        e.n_mig = int(pl["n_mig"])
        e._events = int(pl["events"])
        st = e.state
        if "proc_truth" in pl:          # pre-truth-split snapshots lack it
            st.proc_truth[:] = pl["proc_truth"]
        st.vt[:] = pl["vt"]
        st.yld[:] = pl["yld"]
        st.penalty_until[:] = pl["penalty_until"]
        st.completed_at[:] = pl["completed_at"]
        st.status[:] = pl["status"]
        st.n_pmtn[:] = pl["job_pmtn"]
        st.n_mig[:] = pl["job_mig"]
        if version >= 3:
            st.gidx[:] = pl["gidx"]
            st.n_total = int(pl["n_total"])
            st.first_release = float(pl["first_release"])
            from ..core.state import RetiredLog
            st.retired = RetiredLog.from_payload(pl["retired"])
        # (v1/v2: the fresh EngineState already has gidx = arange(n),
        # n_total = n, first_release = min(releases), empty retired log —
        # those snapshots predate compaction.)
        st.rebuild_index_sets()         # status was written wholesale
        st.mappings = [None if m is None else [int(x) for x in m]
                       for m in pl["mappings"]]
        st.pool.load[:] = pl["pool_load"]
        st.pool.mem_free[:] = pl["pool_mem_free"]
        st.alive[:] = pl["alive"]
        st.now = float(pl["now"])
        st.util_integral = float(pl["util_integral"])
        st.demand_integral = float(pl["demand_integral"])
        for i in st.running_indices():
            st.inc.place(int(i), st.mappings[int(i)])
        e.policy.validate(st.specs, params)
        e.policy.bind(e)

        ses = cls.__new__(cls)
        ses.engine = e
        ses._arrivals = [(float(r), int(j), int(i))
                         for r, j, i in pl["arrivals"]]
        ses._jids = {s.jid for s in specs}
        ses._cev = e.cluster_events if e.policy.handles_cluster_events else []
        ses._ci = 0
        ses._periodic = e.policy.periodic_kind is not None
        ses._next_tick = float(pl["next_tick"])
        ses._tick_armed = bool(pl["tick_armed"])
        ses._horizon = float(pl["horizon"])
        ses._exhausted = bool(pl["exhausted"])
        ses._hit_cap = bool(pl["hit_cap"])
        ses._wall = float(pl["wall_s"])
        # a stream() driver is a live Python iterator, not snapshot state:
        # restored sessions resume with whatever was already submitted
        ses._stream_pending = False
        nar_pl = pl.get("narrator")
        ses._narrator = Narrator.from_state(nar_pl) if nar_pl else None
        if (ses._narrator is not None and switched
                and ses._narrator.needs_cluster_events()
                and not e.policy.handles_cluster_events):
            # fork onto a batch baseline: the cluster script is dropped, so
            # the chaos streams that feed it go too (noise-only survives)
            ses._narrator = None
        tun_pl = pl.get("autotune")
        if tun_pl and not switched:
            from ..tune.controller import AutoTuner
            ses._tuner = AutoTuner.from_state(tun_pl)
        else:
            # policy-switching forks are what-if branches: they race under
            # the tuner, they never recursively run one
            ses._tuner = None
        ses._closed = False
        ses._close_hooks = []
        ses.scratch = {}
        if switched:
            if not e.policy.handles_cluster_events:
                # batch baselines do not model failures: the fork drops the
                # pending cluster script (as sweeps do), so dead nodes must
                # come back too or a wide job could never start again.
                # Failed nodes host nothing (failure force-preempts), so
                # revival is exactly the "join" transition.
                dead = np.nonzero(~st.alive)[0]
                st.alive[dead] = True
                st.pool.mem_free[dead] = 1.0
                st.pool.load[dead] = 0.0
            _adopt_policy_state(e.policy, e)
            if ses._periodic and math.isinf(ses._next_tick):
                # the fork introduced a periodic pass mid-run: base its
                # tick train at the live clock
                ses._next_tick = st.now + params.period
                ses._tick_armed = True
            ses._exhausted = False      # the new policy may act again
        else:
            _restore_policy_state(e.policy, pl["policy_state"], e)
        return ses

    def fork(self, policy=None) -> "SimSession":
        """Clone the live session (optionally under a different policy):
        what-if branching from an identical mid-run state."""
        return SimSession.restore(self.snapshot(), policy=policy)


def open_session(
    cluster: Union[int, SimParams],
    policy,
    params: Optional[SimParams] = None,
    *,
    cluster_events: Sequence[ClusterEvent] = (),
    **param_overrides: Any,
) -> SimSession:
    """Open a streaming simulation session on an (initially idle) cluster.

    ``cluster`` is a node count (combined with ``params``/keyword
    overrides) or a full :class:`SimParams`.  Submit jobs with
    :meth:`SimSession.submit`, advance with ``step_until``/``step``,
    perturb with ``inject``, checkpoint with ``snapshot``.
    """
    if isinstance(cluster, SimParams):
        if params is not None:
            raise ValueError("pass either a SimParams cluster or params=, "
                             "not both")
        params = dataclasses.replace(cluster, **param_overrides)
    else:
        base = params if params is not None else SimParams()
        params = dataclasses.replace(base, n_nodes=int(cluster),
                                     **param_overrides)
    return SimSession(policy, params, cluster_events=cluster_events)
