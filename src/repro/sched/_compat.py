"""One-shot deprecation warnings for the legacy scheduling entry points.

``repro.api`` is the supported surface; the historical wrappers
(``simulate``, ``DFRSSimulator``, ``batch_schedule``) keep working but
announce themselves exactly once per process so long-running sweeps are
not flooded.
"""
from __future__ import annotations

import warnings

_WARNED: set = set()


def warn_once(name: str, replacement: str = "repro.api") -> None:
    """Emit one DeprecationWarning per ``name`` per process."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use {replacement} instead",
        DeprecationWarning,
        stacklevel=3,
    )


def reset() -> None:
    """Forget prior warnings (test hook)."""
    _WARNED.clear()
