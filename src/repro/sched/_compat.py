"""One-shot deprecation warnings for the legacy scheduling entry points.

``repro.api`` is the supported surface; the historical wrappers
(``simulate``, ``DFRSSimulator``, ``batch_schedule``) keep working but
announce themselves exactly once per process so long-running sweeps are
not flooded.  All of the legacy entry points are *closed-world* (full
trace in, one result out) — the migration pointer names both
``repro.api.simulate`` (the like-for-like replacement) and
``repro.api.open_session`` (the streaming session API) so callers who
wrapped these shims in their own stepping loops land on the right door.
"""
from __future__ import annotations

import warnings

_WARNED: set = set()

#: the migration pointer for closed-world simulate-style entry points
BATCH_REPLACEMENT = ("repro.api.simulate (or repro.api.open_session for "
                     "streaming/step-wise runs)")


def warn_once(name: str, replacement: str = "repro.api") -> None:
    """Emit one DeprecationWarning per ``name`` per process."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use {replacement} instead",
        DeprecationWarning,
        stacklevel=3,
    )


def reset() -> None:
    """Forget prior warnings (test hook)."""
    _WARNED.clear()
