"""Named cluster-scenario transforms for sweeps, benchmarks and examples.

A *scenario* perturbs one simulation cell deterministically (given a seed).
Since the Trace-IR refactor a builder is a **vectorized transform over the
columnar trace**: ``(Trace, n_nodes, rng) -> (Trace, [ClusterEvent])`` — it
may inject :class:`ClusterEvent` scripts (node failures, elastic capacity
changes) and/or rewrite whole trace columns (arrival bursts, memory
pressure) without any per-job Python loop.  Benchmarks and examples refer
to scenarios by name instead of hand-rolling ``ClusterEvent`` lists, and
sweep cells carry just the name.

Scenario names **compose with the ``+`` chain grammar**: the cell name
``"rack_failure+arrival_burst"`` applies ``rack_failure`` to the workload
trace, then ``arrival_burst`` to the result, concatenating the cluster
scripts.  Each link draws from its own name-salted RNG stream, so a link
produces the same perturbation whether it runs alone or inside a chain,
and every timing is relative to the span of the trace the link *receives*
(later links see earlier links' rewrites).

Built-ins (all timed relative to the trace's release span, so they scale
with any workload):

* ``baseline``          — unperturbed cell.
* ``rack_failure``      — a contiguous quarter of the nodes dies at the
                          median release and rejoins after 10 % of the span.
* ``rolling_failures``  — Poisson single-node failures (≈6 over the span)
                          with deterministic repair (§ fault-tolerance
                          adaptation: failures reuse the preemption path).
* ``elastic``           — elastic capacity: a third of the cluster is
                          reclaimed at 30 % of the span and returned at 70 %
                          (shrink uses the failure path: force-preempt).
* ``arrival_burst``     — the middle half of the arrivals is compressed
                          into a 10×-narrower window (flash crowd).
* ``mem_pressure``      — a random half of the jobs needs 1.5× memory
                          (capped at a full node), stressing the packer.
* ``ptime_noise``       — lognormal noise on the *executed* processing time
                          (``proc_truth``); policies keep seeing the clean
                          estimate (non-clairvoyant truth split).

Use :func:`apply_scenario_trace` (columnar) or :func:`apply_scenario`
(``JobSpec``-list compatibility wrapper) to materialize a cell, and
:func:`register_scenario` to add project-specific transforms.

**Reactive scenarios** are the second, session-native layer: where a Trace
transform perturbs a cell *before* the run, a reactive rule is a callback
over a live :class:`repro.sched.session.SimSession` — it observes the
actual queue/cluster state between steps and injects events or submits
jobs in response (closed-loop perturbations the Trace grammar cannot
express, e.g. a load spike triggered by the queue draining).  A rule has
signature ``(session, observation, rng) -> None`` and is driven by
:func:`run_reactive`, which steps the session one interval at a time and
calls the rule after each chunk.  Register project rules with
:func:`register_reactive`; built-ins:

* ``surge_submit``    — flash crowd on drain: each time the observed queue
                        empties mid-run, submit a burst of short jobs
                        (at most 3 bursts).
* ``elastic_reserve`` — hold a quarter of the nodes in reserve; join them
                        when the observed queue exceeds half the live
                        cluster, reclaim them once the queue drains and
                        the reserve is idle.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.job import JobSpec
from ..workloads.trace import Trace
from .cluster import ClusterEvent, failure_trace

__all__ = [
    "SCENARIOS",
    "REACTIVE",
    "apply_scenario",
    "apply_scenario_trace",
    "parse_scenario_chain",
    "register_scenario",
    "list_scenarios",
    "scenario_docs",
    "register_reactive",
    "list_reactive",
    "reactive_docs",
    "run_reactive",
]

# a scenario builder: (trace, n_nodes, rng) -> (trace, cluster_events)
Builder = Callable[
    [Trace, int, np.random.Generator],
    Tuple[Trace, List[ClusterEvent]],
]

SCENARIOS: Dict[str, Builder] = {}


def register_scenario(name: str):
    if "+" in name:
        raise ValueError(f"scenario names must not contain '+' (reserved "
                         f"for the chain grammar): {name!r}")

    def deco(fn: Builder) -> Builder:
        if name in SCENARIOS:
            raise ValueError(f"scenario {name!r} already registered")
        SCENARIOS[name] = fn
        return fn
    return deco


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def scenario_docs() -> Dict[str, str]:
    """name -> first docstring line of the registered builder."""
    return {name: (fn.__doc__ or "").strip().split("\n")[0]
            for name, fn in sorted(SCENARIOS.items())}


def parse_scenario_chain(name: str) -> List[str]:
    """Split a ``"a+b+c"`` chain and validate every link is registered."""
    links = [part.strip() for part in name.split("+")]
    if not links or any(not p for p in links):
        raise KeyError(f"malformed scenario chain {name!r}")
    for link in links:
        if link not in SCENARIOS:
            raise KeyError(
                f"unknown scenario {link!r}; known: {list_scenarios()}")
    return links


def apply_scenario_trace(
    name: str,
    trace: Trace,
    n_nodes: int,
    seed: int = 0,
) -> Tuple[Trace, List[ClusterEvent]]:
    """Materialize scenario chain ``name`` for one cell, deterministically.

    Each link of the ``+`` chain gets its own ``[seed, salt(link)]`` RNG
    stream (repeated links are further salted by occurrence), so a link's
    perturbation does not depend on its chain position; cluster scripts
    concatenate and are returned time-sorted.
    """
    links = parse_scenario_chain(name)
    events: List[ClusterEvent] = []
    seen: Dict[str, int] = {}
    for link in links:
        k = seen.get(link, 0)
        seen[link] = k + 1
        words = [seed, _code(link)] + ([k] if k else [])
        rng = np.random.default_rng(np.random.SeedSequence(words))
        trace, evs = SCENARIOS[link](trace, n_nodes, rng)
        events.extend(evs)
    if len(links) > 1:
        events.sort(key=lambda e: e.time)
    return trace, events


def apply_scenario(
    name: str,
    specs: Sequence[JobSpec],
    n_nodes: int,
    seed: int = 0,
) -> Tuple[List[JobSpec], List[ClusterEvent]]:
    """``JobSpec``-list wrapper around :func:`apply_scenario_trace`."""
    trace, events = apply_scenario_trace(
        name, Trace.from_specs(specs), n_nodes, seed=seed)
    return trace.to_specs(), events


def _code(name: str) -> int:
    # stable (non-PYTHONHASHSEED) scenario salt for the seed sequence
    return sum((i + 1) * ord(c) for i, c in enumerate(name)) % (2**31)


# --------------------------------------------------------------------------- #
# built-ins                                                                    #
# --------------------------------------------------------------------------- #
@register_scenario("baseline")
def _baseline(trace, n_nodes, rng):
    """Unperturbed cell: the workload trace as generated, no cluster script."""
    return trace, []


@register_scenario("rack_failure")
def _rack_failure(trace, n_nodes, rng):
    """A contiguous quarter of the nodes fails mid-span, rejoins after 10%."""
    lo, span = trace.span()
    k = max(1, n_nodes // 4)
    first = int(rng.integers(0, max(1, n_nodes - k + 1)))
    rack = tuple(range(first, first + k))
    t_fail = lo + 0.5 * span
    return trace, [
        ClusterEvent(time=t_fail, kind="fail", nodes=rack),
        ClusterEvent(time=t_fail + 0.1 * span, kind="join", nodes=rack),
    ]


@register_scenario("rolling_failures")
def _rolling_failures(trace, n_nodes, rng):
    """Poisson single-node failures (~6 over the span), deterministic repair."""
    lo, span = trace.span()
    events = failure_trace(
        n_nodes,
        horizon=span,
        mtbf=span / 6.0,
        repair=span / 30.0,
        seed=int(rng.integers(2**31)),
    )
    # failure_trace generates on [0, horizon); shift onto the release span
    shifted = [ClusterEvent(ev.time + lo, ev.kind, ev.nodes) for ev in events]
    return trace, shifted


@register_scenario("elastic")
def _elastic(trace, n_nodes, rng):
    """A third of the cluster is reclaimed at 30% of the span, back at 70%."""
    lo, span = trace.span()
    k = max(1, n_nodes // 3)
    block = tuple(range(n_nodes - k, n_nodes))
    return trace, [
        ClusterEvent(time=lo + 0.3 * span, kind="fail", nodes=block),
        ClusterEvent(time=lo + 0.7 * span, kind="join", nodes=block),
    ]


@register_scenario("arrival_burst")
def _arrival_burst(trace, n_nodes, rng):
    """The middle half of the arrivals compresses into a 10x-narrower window."""
    lo, span = trace.span()
    a, b = lo + 0.25 * span, lo + 0.75 * span
    rel = trace.release
    hit = (rel >= a) & (rel <= b)
    return trace.replace(
        release=np.where(hit, a + (rel - a) / 10.0, rel)), []


@register_scenario("mem_pressure")
def _mem_pressure(trace, n_nodes, rng):
    """A random half of the jobs needs 1.5x memory (capped at a full node)."""
    hit = rng.random(len(trace)) < 0.5
    return trace.replace(
        mem_req=np.where(hit, np.minimum(1.0, 1.5 * trace.mem_req),
                         trace.mem_req)), []


@register_scenario("ptime_noise")
def _ptime_noise(trace, n_nodes, rng):
    """Lognormal truth noise: the engine executes proc_time x LogN(sigma=0.35)
    while policies keep observing the unperturbed estimate (non-clairvoyant
    split).  Mean-preserving (mu = -sigma^2/2); composes with any chain link
    by multiplying whatever truth column the incoming trace already has."""
    sigma = 0.35
    base = trace.proc_truth if trace.proc_truth is not None else trace.proc_time
    noise = rng.lognormal(mean=-0.5 * sigma * sigma, sigma=sigma,
                          size=len(trace))
    return trace.replace(proc_truth=base * noise), []


# --------------------------------------------------------------------------- #
# reactive scenarios: callbacks over live session state                        #
# --------------------------------------------------------------------------- #
# a reactive rule: (session, observation, rng) -> None; it may call
# session.inject(...) / session.submit(...) based on what it observes
REACTIVE: Dict[str, Callable] = {}


def register_reactive(name: str):
    """Decorator: register a reactive rule ``(session, obs, rng) -> None``."""

    def deco(fn: Callable) -> Callable:
        if name in REACTIVE:
            raise ValueError(f"reactive scenario {name!r} already registered")
        REACTIVE[name] = fn
        return fn
    return deco


def list_reactive() -> List[str]:
    return sorted(REACTIVE)


def reactive_docs() -> Dict[str, str]:
    """name -> first docstring line of the registered rule."""
    return {name: (fn.__doc__ or "").strip().split("\n")[0]
            for name, fn in sorted(REACTIVE.items())}


def run_reactive(
    session,
    rule,
    seed: int = 0,
    interval: Optional[float] = None,
    max_rounds: int = 100_000,
):
    """Drive ``session`` to exhaustion under a reactive rule.

    Steps the session roughly one ``interval`` (default: the session's
    periodic-pass period) past its next event at a time; after every chunk
    the rule sees the fresh observation and may inject events or submit
    jobs — including re-arming an exhausted session (the loop then
    continues).  The rule's RNG stream is salted by its name, mirroring
    the Trace-transform chain semantics.  Returns the final
    :class:`~repro.sched.engine.SimResult`.
    """
    import math

    if isinstance(rule, str):
        name = rule
        try:
            rule = REACTIVE[rule]
        except KeyError:
            raise KeyError(f"unknown reactive scenario {name!r}; "
                           f"known: {list_reactive()}") from None
    else:
        # salt by the *registered* name when the callable is registered, so
        # run_reactive(ses, "x") and run_reactive(ses, REACTIVE["x"]) draw
        # the same stream; ad-hoc rules fall back to their __name__
        name = next((n for n, f in REACTIVE.items() if f is rule),
                    getattr(rule, "__name__", "reactive"))
    rng = np.random.default_rng(np.random.SeedSequence([seed, _code(name)]))
    if interval is None:
        interval = session.engine.params.period
    interval = float(interval)
    if interval <= 0:
        raise ValueError("interval must be > 0")
    for _ in range(max_rounds):
        nxt = session.next_event_time()
        if math.isinf(nxt):
            session.run_to_exhaustion()     # final probe marks exhaustion
        else:
            session.step_until(max(session.now, nxt) + interval)
        rule(session, session.observe(), rng)
        if session.exhausted:
            return session.result()
    raise RuntimeError(
        f"reactive scenario {name!r} did not converge within "
        f"{max_rounds} rounds (interval={interval:.6g}s)")


@register_reactive("surge_submit")
def _surge_submit(session, obs, rng):
    """Flash crowd on drain: when the observed queue empties mid-run, submit a burst of short single-task jobs (at most 3 bursts)."""
    st = session.scratch.setdefault("surge_submit", {"bursts": 0})
    in_flight = obs["n_running"] + obs["n_future"]
    if st["bursts"] >= 3 or obs["queue_depth"] > 0 or in_flight == 0:
        return
    st["bursts"] += 1
    k = 8
    base = max(session._jids, default=0) + 1
    now = session.now
    burst = [
        JobSpec(jid=base + i,
                release=now + float(rng.uniform(1.0, 30.0)),
                proc_time=float(rng.uniform(60.0, 600.0)),
                n_tasks=1,
                cpu_need=float(rng.uniform(0.2, 1.0)),
                mem_req=float(rng.uniform(0.1, 0.4)))
        for i in range(k)
    ]
    session.submit(burst)


@register_reactive("elastic_reserve")
def _elastic_reserve(session, obs, rng):
    """Elastic capacity: hold 1/4 of the nodes in reserve; join them when the queue exceeds half the live cluster, reclaim them once idle."""
    if not session.handles_cluster_events:
        raise ValueError("elastic_reserve needs a policy that handles "
                         "cluster events (batch baselines do not)")
    n = session.engine.params.n_nodes
    k = max(1, n // 4)
    reserve = tuple(range(n - k, n))
    st = session.scratch.setdefault("elastic_reserve",
                                    {"out": False, "init": False})
    state = session.engine.state
    if not st["init"]:
        st["init"] = True
        # reclaim the reserve up front (attach the rule from the start:
        # a fail force-preempts any resident jobs)
        session.inject(ClusterEvent(time=session.now, kind="fail",
                                    nodes=reserve))
        return
    if not st["out"] and obs["queue_depth"] > obs["alive_nodes"] // 2:
        session.inject(ClusterEvent(time=session.now, kind="join",
                                    nodes=reserve))
        st["out"] = True
        return
    reserve_idle = all(not state.inc.rows[node] for node in reserve)
    if st["out"] and obs["queue_depth"] == 0 and reserve_idle:
        session.inject(ClusterEvent(time=session.now, kind="fail",
                                    nodes=reserve))
        st["out"] = False
