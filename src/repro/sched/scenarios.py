"""Named cluster-scenario scripts for sweeps, benchmarks and examples.

A *scenario* perturbs one simulation cell deterministically (given a seed):
it may inject :class:`ClusterEvent` scripts (node failures, elastic capacity
changes) and/or transform the trace itself (arrival bursts, memory
pressure).  Benchmarks and examples refer to scenarios by name instead of
hand-rolling ``ClusterEvent`` lists, and sweep cells carry just the name.

Built-ins (all timed relative to the trace's release span, so they scale
with any workload):

* ``baseline``          — unperturbed cell.
* ``rack_failure``      — a contiguous quarter of the nodes dies at the
                          median release and rejoins after 10 % of the span.
* ``rolling_failures``  — Poisson single-node failures (≈6 over the span)
                          with deterministic repair (§ fault-tolerance
                          adaptation: failures reuse the preemption path).
* ``elastic``           — elastic capacity: a third of the cluster is
                          reclaimed at 30 % of the span and returned at 70 %
                          (shrink uses the failure path: force-preempt).
* ``arrival_burst``     — the middle half of the arrivals is compressed
                          into a 10×-narrower window (flash crowd).
* ``mem_pressure``      — a random half of the jobs needs 1.5× memory
                          (capped at a full node), stressing the packer.

Use :func:`apply_scenario` to materialize ``(specs, cluster_events)`` for a
cell, or :func:`register_scenario` to add project-specific scripts.
"""
from __future__ import annotations

from dataclasses import replace
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np

from ..core.job import JobSpec
from .cluster import ClusterEvent, failure_trace

__all__ = [
    "SCENARIOS",
    "apply_scenario",
    "register_scenario",
    "list_scenarios",
]

# a scenario builder: (specs, n_nodes, rng) -> (specs, cluster_events)
Builder = Callable[
    [List[JobSpec], int, np.random.Generator],
    Tuple[List[JobSpec], List[ClusterEvent]],
]

SCENARIOS: Dict[str, Builder] = {}


def register_scenario(name: str):
    def deco(fn: Builder) -> Builder:
        if name in SCENARIOS:
            raise ValueError(f"scenario {name!r} already registered")
        SCENARIOS[name] = fn
        return fn
    return deco


def list_scenarios() -> List[str]:
    return sorted(SCENARIOS)


def apply_scenario(
    name: str,
    specs: Sequence[JobSpec],
    n_nodes: int,
    seed: int = 0,
) -> Tuple[List[JobSpec], List[ClusterEvent]]:
    """Materialize scenario ``name`` for one cell, deterministically."""
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; known: {list_scenarios()}")
    rng = np.random.default_rng(np.random.SeedSequence([seed, _code(name)]))
    return SCENARIOS[name](list(specs), n_nodes, rng)


def _code(name: str) -> int:
    # stable (non-PYTHONHASHSEED) scenario salt for the seed sequence
    return sum((i + 1) * ord(c) for i, c in enumerate(name)) % (2**31)


def _span(specs: Sequence[JobSpec]) -> Tuple[float, float]:
    if not specs:
        return 0.0, 1.0
    lo = min(s.release for s in specs)
    hi = max(s.release for s in specs)
    return lo, max(hi - lo, 1.0)


# --------------------------------------------------------------------------- #
# built-ins                                                                    #
# --------------------------------------------------------------------------- #
@register_scenario("baseline")
def _baseline(specs, n_nodes, rng):
    return specs, []


@register_scenario("rack_failure")
def _rack_failure(specs, n_nodes, rng):
    lo, span = _span(specs)
    k = max(1, n_nodes // 4)
    first = int(rng.integers(0, max(1, n_nodes - k + 1)))
    rack = tuple(range(first, first + k))
    t_fail = lo + 0.5 * span
    return specs, [
        ClusterEvent(time=t_fail, kind="fail", nodes=rack),
        ClusterEvent(time=t_fail + 0.1 * span, kind="join", nodes=rack),
    ]


@register_scenario("rolling_failures")
def _rolling_failures(specs, n_nodes, rng):
    lo, span = _span(specs)
    events = failure_trace(
        n_nodes,
        horizon=span,
        mtbf=span / 6.0,
        repair=span / 30.0,
        seed=int(rng.integers(2**31)),
    )
    # failure_trace generates on [0, horizon); shift onto the release span
    shifted = [ClusterEvent(ev.time + lo, ev.kind, ev.nodes) for ev in events]
    return specs, shifted


@register_scenario("elastic")
def _elastic(specs, n_nodes, rng):
    lo, span = _span(specs)
    k = max(1, n_nodes // 3)
    block = tuple(range(n_nodes - k, n_nodes))
    return specs, [
        ClusterEvent(time=lo + 0.3 * span, kind="fail", nodes=block),
        ClusterEvent(time=lo + 0.7 * span, kind="join", nodes=block),
    ]


@register_scenario("arrival_burst")
def _arrival_burst(specs, n_nodes, rng):
    lo, span = _span(specs)
    a, b = lo + 0.25 * span, lo + 0.75 * span
    out = []
    for s in specs:
        if a <= s.release <= b:
            out.append(replace(s, release=a + (s.release - a) / 10.0))
        else:
            out.append(s)
    return out, []


@register_scenario("mem_pressure")
def _mem_pressure(specs, n_nodes, rng):
    hit = rng.random(len(specs)) < 0.5
    out = [
        replace(s, mem_req=min(1.0, 1.5 * s.mem_req)) if h else s
        for s, h in zip(specs, hit)
    ]
    return out, []
