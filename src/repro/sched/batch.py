"""Batch-scheduling baselines: FCFS and EASY backfilling (paper §5.2).

Nodes are allocated integrally and exclusively: job j occupies n_j nodes for
exactly p_j seconds.  EASY gives the queue head a reservation at the
earliest time it could start under FCFS and backfills any job that does not
interfere with that reservation; as in the paper, EASY is given *perfect*
processing-time estimates (a best case for the baseline).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.job import JobSpec
from .metrics import bounded_stretch

__all__ = ["batch_schedule"]


def batch_schedule(specs: Sequence[JobSpec], algo: str, params=None):
    from .simulator import SimParams, SimResult

    p = params or SimParams()
    algo = algo.upper()
    if algo not in ("FCFS", "EASY"):
        raise ValueError(algo)
    specs = sorted(specs, key=lambda s: (s.release, s.jid))
    for s in specs:
        if s.n_tasks > p.n_nodes:
            raise ValueError(f"job {s.jid} needs {s.n_tasks} > {p.n_nodes} nodes")

    free = p.n_nodes
    queue: List[JobSpec] = []
    running: List[Tuple[float, int, int]] = []   # (end, jid, n_nodes) heap
    start_at: Dict[int, float] = {}
    completions: Dict[int, float] = {}
    ai = 0
    now = 0.0
    util_int = 0.0
    demand_int = 0.0
    in_system: Dict[int, JobSpec] = {}

    def try_start(now: float) -> None:
        nonlocal free
        # FCFS part: start queue head(s) while they fit.
        while queue and queue[0].n_tasks <= free:
            s = queue.pop(0)
            free -= s.n_tasks
            start_at[s.jid] = now
            heapq.heappush(running, (now + s.proc_time, s.jid, s.n_tasks))
        if algo == "FCFS" or not queue:
            return
        # EASY backfilling against the head's reservation.
        changed = True
        while changed:
            changed = False
            head = queue[0]
            ends = sorted(running)
            avail = free
            shadow, extra = math.inf, 0
            for end, _, n in ends:
                avail += n
                if avail >= head.n_tasks:
                    shadow = end
                    extra = avail - head.n_tasks
                    break
            for i, s in enumerate(list(queue[1:]), start=1):
                if s.n_tasks <= free and (
                    now + s.proc_time <= shadow + 1e-9 or s.n_tasks <= min(free, extra)
                ):
                    queue.pop(i)
                    free -= s.n_tasks
                    start_at[s.jid] = now
                    heapq.heappush(running, (now + s.proc_time, s.jid, s.n_tasks))
                    changed = True
                    break   # recompute the reservation after each backfill

    while ai < len(specs) or running or queue:
        t_arr = specs[ai].release if ai < len(specs) else math.inf
        t_end = running[0][0] if running else math.inf
        t_next = min(t_arr, t_end)
        if math.isinf(t_next):
            raise RuntimeError("batch deadlock (job larger than cluster?)")
        # integrate utilization/demand over [now, t_next)
        u = sum(in_system[jid].n_tasks * in_system[jid].cpu_need
                for _, jid, _ in running)
        d = sum(s.n_tasks * s.cpu_need for s in in_system.values())
        util_int += u * (t_next - now)
        demand_int += min(float(p.n_nodes), d) * (t_next - now)
        now = t_next
        while running and running[0][0] <= now + 1e-9:
            end, jid, n = heapq.heappop(running)
            completions[jid] = end
            free += n
            del in_system[jid]
        while ai < len(specs) and specs[ai].release <= now + 1e-9:
            queue.append(specs[ai])
            in_system[specs[ai].jid] = specs[ai]
            ai += 1
        try_start(now)

    from .simulator import SimResult

    stretches = {
        s.jid: bounded_stretch(completions[s.jid] - s.release, s.proc_time, p.stretch_tau)
        for s in specs
    }
    first = min(s.release for s in specs) if specs else 0.0
    makespan = max(completions.values()) - first if completions else 0.0
    total_work = sum(s.total_work for s in specs) or 1.0
    svals = list(stretches.values())
    return SimResult(
        policy=algo,
        completions=completions,
        stretches=stretches,
        max_stretch=max(svals) if svals else 0.0,
        mean_stretch=float(np.mean(svals)) if svals else 0.0,
        n_pmtn=0, n_mig=0,
        pmtn_per_job=0.0, mig_per_job=0.0,
        pmtn_per_hour=0.0, mig_per_hour=0.0,
        bytes_moved_gb=0.0, bandwidth_gbps=0.0,
        underutilization=(demand_int - util_int) / total_work,
        makespan=makespan,
        events=len(specs),
    )
