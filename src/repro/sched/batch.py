"""Batch-scheduling baselines: FCFS and EASY backfilling (paper §5.2).

The actual scheduling logic lives in :class:`repro.sched.engine.BatchPolicy`
and runs on the same unified engine (and the same ``SimResult`` metrics
pipeline) as the DFRS policies; this module keeps the historical
``batch_schedule`` entry point.
"""
from __future__ import annotations

from typing import Optional, Sequence

from ..core.job import JobSpec
from ..core.policies import parse_policy
from ._compat import BATCH_REPLACEMENT, warn_once
from .engine import Engine, SimParams, SimResult

__all__ = ["batch_schedule"]


def batch_schedule(
    specs: Sequence[JobSpec],
    algo: str,
    params: Optional[SimParams] = None,
) -> SimResult:
    warn_once("repro.sched.batch.batch_schedule", BATCH_REPLACEMENT)
    spec = parse_policy(algo)
    if not spec.is_batch:
        raise ValueError(algo)
    return Engine(specs, spec, params).run()
