"""Parallel scenario sweeps: evaluate a grid of simulation cells at once.

The paper's headline numbers come from sweeping ~116 policy combinations
against FCFS/EASY over many traces; this module makes that a first-class
operation.  A :class:`Cell` is one (workload × policy × scenario) point —
the workload a declarative :class:`repro.workloads.registry.WorkloadSpec`,
the scenario a name from :mod:`repro.sched.scenarios` — and
:func:`run_grid` fans cells across worker processes with chunked
scheduling, aggregating per-cell metrics into a tidy list of flat record
dicts plus an optional JSON artifact.

Cells are cheap to pickle (no trace objects cross process boundaries);
workers regenerate and memoize traces / Theorem-1 bounds locally, so a
policy sweep over one trace pays for trace generation and bound computation
once per worker, not once per cell.

    ws = [WorkloadSpec("lublin", n_jobs=250, n_nodes=64, seed=s) for s in range(3)]
    res = run_grid(grid(ws, TABLE2_POLICIES, ["baseline", "rack_failure"]),
                   n_workers=8, compute_bound=True)
    res.save_json("experiments/results/sweep.json")
    res.summary(by="policy")
"""
from __future__ import annotations

import json
import math
import multiprocessing as mp
import os
import sys
import threading
import time
import dataclasses
from dataclasses import dataclass, replace
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from ..core.bound import max_stretch_lower_bound
from ..core.ioutil import atomic_write_json
from ..core.policies import parse_policy
from ..workloads.registry import WorkloadSpec, make_trace_ir
from .engine import Engine, SimParams
from .scenarios import apply_scenario_trace, parse_scenario_chain

__all__ = ["Cell", "SweepResult", "RecordCache", "grid", "run_grid",
           "run_batched", "run_branches", "record_matches"]


def record_matches(record: Dict[str, Any], kv: Dict[str, Any]) -> bool:
    """Shared record predicate: every kv pair equals the record's value."""
    return all(record.get(k) == v for k, v in kv.items())


@dataclass(frozen=True)
class Cell:
    """One simulation point of a sweep grid."""

    workload: WorkloadSpec
    policy: str
    scenario: str = "baseline"
    params: Optional[SimParams] = None   # template; n_nodes comes from workload

    @property
    def name(self) -> str:
        return f"{self.workload.name} × {self.policy} × {self.scenario}"


def grid(
    workloads: Iterable[WorkloadSpec],
    policies: Iterable[str],
    scenarios: Iterable[str] = ("baseline",),
    params: Optional[SimParams] = None,
) -> List[Cell]:
    """Cross product of workloads × policies × scenarios."""
    return [
        Cell(w, p, sc, params)
        for w in workloads
        for p in policies
        for sc in scenarios
    ]


@dataclass
class SweepResult:
    records: List[Dict[str, Any]]
    wall_s: float
    n_workers: int

    @property
    def n_cells(self) -> int:
        return len(self.records)

    @property
    def cells_per_sec(self) -> float:
        return self.n_cells / max(self.wall_s, 1e-9)

    @property
    def quarantined(self) -> List[Dict[str, Any]]:
        """Cells that exhausted their retries under a supervised run: the
        sweep completed without them, and each carries ``error``/``attempts``
        instead of metrics."""
        return [r for r in self.records if r.get("quarantined")]

    @property
    def n_quarantined(self) -> int:
        return len(self.quarantined)

    def filter(self, **kv) -> List[Dict[str, Any]]:
        return [r for r in self.records if record_matches(r, kv)]

    def values(self, key: str, **kv) -> np.ndarray:
        return np.array([r[key] for r in self.filter(**kv)])

    def summary(self, by: str = "policy",
                keys: Sequence[str] = ("mean_stretch", "max_stretch")) -> Dict:
        """Per-group mean/max aggregates of the chosen metric keys."""
        groups: Dict[str, List[Dict[str, Any]]] = {}
        for r in self.records:
            if r.get("quarantined"):
                continue            # no metrics to aggregate
            groups.setdefault(str(r[by]), []).append(r)
        out = {}
        for g, rs in sorted(groups.items()):
            out[g] = {"n_cells": len(rs)}
            for k in keys:
                vals = np.array([r[k] for r in rs], dtype=float)
                out[g][f"mean_{k}"] = float(vals.mean())
                out[g][f"max_{k}"] = float(vals.max())
        return out

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": "repro.sweep/v1",
            "n_cells": self.n_cells,
            "n_quarantined": self.n_quarantined,
            "wall_s": self.wall_s,
            "cells_per_sec": self.cells_per_sec,
            "n_workers": self.n_workers,
            "records": self.records,
        }

    def save_json(self, path: str) -> str:
        """Write the artifact atomically (tmp file + rename), creating
        parent directories — parallel benchmark runs never observe a torn
        or partially written file."""
        return _atomic_write_json(path, self.to_dict())


def _atomic_write_json(path: str, payload: Any) -> str:
    # unique-temp-name atomic replace (core.ioutil): concurrent writers —
    # the serve layer shares one snapshot/cache store across tenants, and
    # parallel benchmark runs share cache files — never collide on a temp
    # path or observe a torn file
    return atomic_write_json(path, payload, indent=1)


# --------------------------------------------------------------------------- #
# worker side                                                                  #
# --------------------------------------------------------------------------- #
# per-process memo:
# (workload, scenario) -> (trace, events, bound-or-None, workload fingerprint)
_CELL_CACHE: Dict[Tuple[WorkloadSpec, str, bool], Tuple] = {}


def _materialize(workload: WorkloadSpec, scenario: str, compute_bound: bool):
    """Columnar cell inputs: the workload trace (memoized per process by the
    registry), the scenario chain applied as vectorized Trace transforms,
    and the workload trace's content fingerprint for cache identity."""
    key = (workload, scenario, compute_bound)
    hit = _CELL_CACHE.get(key)
    if hit is not None:
        return hit
    base = make_trace_ir(workload)
    trace, events = apply_scenario_trace(scenario, base, workload.n_nodes,
                                         seed=workload.seed)
    bound = (max_stretch_lower_bound(trace.to_specs(), workload.n_nodes)
             if compute_bound else None)
    out = (trace, events, bound, base.fingerprint)
    if len(_CELL_CACHE) > 32:       # sweeps iterate policies per workload
        _CELL_CACHE.clear()
    _CELL_CACHE[key] = out
    return out


def _run_cell(task: Tuple[int, Cell, bool],
              alloc_backend: Optional[object] = None) -> Dict[str, Any]:
    idx, cell, compute_bound = task
    trace, events, bound, fingerprint = _materialize(
        cell.workload, cell.scenario, compute_bound)
    base = cell.params or SimParams()
    params = replace(base, n_nodes=cell.workload.n_nodes)
    t0 = time.perf_counter()
    engine = Engine(trace, cell.policy, params, cluster_events=events,
                    alloc_backend=alloc_backend)
    # batch baselines drop ClusterEvents (they don't model failures) — flag
    # the record so failure-scenario cells aren't read as simulated for them
    applied = engine.policy.handles_cluster_events or not events
    r = engine.run()
    wall = time.perf_counter() - t0
    rec: Dict[str, Any] = {
        "cell": idx,
        "workload": cell.workload.name,
        **cell.workload.to_dict(),
        "trace_fingerprint": fingerprint,
        "policy": cell.policy,
        "scenario": cell.scenario,
        "scenario_applied": applied,
        "period": params.period,
        "max_stretch": r.max_stretch,
        "mean_stretch": r.mean_stretch,
        "makespan": r.makespan,
        "underutilization": r.underutilization,
        "n_pmtn": r.n_pmtn,
        "n_mig": r.n_mig,
        "pmtn_per_job": r.pmtn_per_job,
        "mig_per_job": r.mig_per_job,
        "pmtn_per_hour": r.pmtn_per_hour,
        "mig_per_hour": r.mig_per_hour,
        "bytes_moved_gb": r.bytes_moved_gb,
        "bandwidth_gbps": r.bandwidth_gbps,
        "events": r.events,
        "hit_max_events": r.hit_max_events,
        "wall_s": wall,
        # observability: attribute cells/s variance to event counts and
        # split driver overhead (trace/bound prep) from engine-loop time
        "n_events": r.n_events,
        "sim_wall_s": r.sim_wall_s,
        "final_time": r.final_time,
    }
    if bound is not None:
        rec["bound"] = bound
        rec["degradation"] = r.max_stretch / bound if bound > 0 else np.inf
    return rec


# --------------------------------------------------------------------------- #
# supervised execution: timeouts, bounded retries, quarantine                  #
# --------------------------------------------------------------------------- #
def _quarantine_record(idx: int, cell: Any, error: str,
                       attempts: int) -> Dict[str, Any]:
    """A record standing in for a cell (or what-if branch) that could not
    be simulated: same identity fields as a real record,
    ``quarantined=True``, no metrics."""
    if isinstance(cell, _Branch):
        return {
            "cell": idx,
            "branch": idx,
            "policy": cell.policy,
            "period": cell.period,
            "branch_policy": cell.snap.policy,
            "branch_time": cell.snap.time,
            "branch_fingerprint": cell.snap.fingerprint,
            "horizon_s": cell.horizon_s,
            "branch_seed": cell.branch_seed,
            "quarantined": True,
            "error": error,
            "attempts": attempts,
        }
    return {
        "cell": idx,
        "workload": cell.workload.name,
        **cell.workload.to_dict(),
        "policy": cell.policy,
        "scenario": cell.scenario,
        "quarantined": True,
        "error": error,
        "attempts": attempts,
    }


def _supervised_worker(conn) -> None:
    """Worker loop for the supervised driver: receive one ``(idx, cell,
    compute_bound)`` task at a time, answer with ``("ok", record)`` or
    ``("err", message)``.  Exits when the driver sends ``None`` or drops
    the pipe."""
    try:
        while True:
            task = conn.recv()
            if task is None:
                break
            try:
                rec = _run_task(task)
            except BaseException as exc:  # noqa: BLE001 — reported; driver decides
                conn.send(("err", f"{type(exc).__name__}: {exc}"))
            else:
                conn.send(("ok", rec))
    except (EOFError, OSError, KeyboardInterrupt):
        pass
    finally:
        try:
            conn.close()
        except OSError:
            pass


class _Worker:
    """One supervised worker process plus its duplex pipe and current task."""

    __slots__ = ("proc", "conn", "task", "t0")

    def __init__(self, ctx):
        parent, child = ctx.Pipe(duplex=True)
        self.proc = ctx.Process(target=_supervised_worker, args=(child,),
                                daemon=True)
        self.proc.start()
        child.close()
        self.conn = parent
        self.task: Optional[Tuple] = None
        self.t0 = 0.0

    def kill(self) -> None:
        try:
            self.conn.close()
        except OSError:
            pass
        if self.proc.is_alive():
            self.proc.terminate()
        self.proc.join(timeout=2.0)
        if self.proc.is_alive():
            self.proc.kill()
            self.proc.join(timeout=2.0)

    def shutdown(self) -> None:
        try:
            self.conn.send(None)
        except (OSError, BrokenPipeError):
            pass
        self.kill()


def _run_supervised(
    tasks: Sequence[Tuple[int, Cell, bool]],
    n_workers: int,
    timeout_s: Optional[float],
    retries: int,
) -> List[Dict[str, Any]]:
    """Supervising driver: every cell gets a wall-clock budget and a bounded
    number of retries on fresh (reseeded) worker processes; cells that
    exhaust their budget become quarantine records instead of taking the
    sweep down.  A hung cell costs its own timeout, never the grid's."""
    ctx = _pool_context()
    n_workers = max(1, min(n_workers, len(tasks)))
    pending: List[Tuple] = list(reversed(tasks))    # pop() == grid order
    attempts: Dict[int, int] = {}
    records: Dict[int, Dict[str, Any]] = {}

    def retire(w: _Worker, error: str) -> None:
        idx, cell, _ = w.task
        tries = attempts[idx] = attempts.get(idx, 0) + 1
        if tries > retries:
            records[idx] = _quarantine_record(idx, cell, error, tries)
        else:
            pending.append(w.task)      # retried on a fresh worker
        w.task = None

    workers = [_Worker(ctx) for _ in range(n_workers)]
    try:
        while len(records) < len(tasks):
            for w in workers:
                if w.task is None and pending:
                    w.task = pending.pop()
                    w.t0 = time.perf_counter()
                    w.conn.send(w.task)
            busy = [w for w in workers if w.task is not None]
            if not busy:
                break
            wait_s = 0.25
            if timeout_s is not None:
                now = time.perf_counter()
                slack = min(timeout_s - (now - w.t0) for w in busy)
                wait_s = min(wait_s, max(slack, 0.01))
            ready = set(mp.connection.wait([w.conn for w in busy],
                                           timeout=wait_s))
            now = time.perf_counter()
            for i, w in enumerate(workers):
                if w.task is None:
                    continue
                if w.conn in ready:
                    try:
                        kind, payload = w.conn.recv()
                    except (EOFError, OSError):
                        # the process died mid-cell (segfault, OOM kill)
                        kind, payload = "err", "worker process died"
                    if kind == "ok":
                        records[w.task[0]] = payload
                        w.task = None
                        continue
                    retire(w, payload)
                elif timeout_s is not None and now - w.t0 > timeout_s:
                    retire(w, f"timeout after {timeout_s:g}s")
                else:
                    continue
                # failed attempt: the old process may be wedged or tainted —
                # replace it so the retry runs on a reseeded worker
                w.kill()
                workers[i] = _Worker(ctx)
    finally:
        for w in workers:
            w.shutdown()
    return [records[i] for i in sorted(records)]


# --------------------------------------------------------------------------- #
# what-if branching: policy comparison from an identical live state            #
# --------------------------------------------------------------------------- #
@dataclass(frozen=True)
class _Branch:
    """One what-if branch task: a snapshot forked under one policy/period
    variant, optionally horizon-bounded, early-stopped, and chaos-reseeded.
    Picklable (travels through the supervised worker pipes)."""

    snap: Any                       # SessionState
    policy: str
    same: bool                      # continue the snapshot's own policy
    period: Optional[float] = None
    horizon_s: Optional[float] = None
    early_stop: Optional[Dict[str, float]] = None
    branch_seed: Optional[int] = None


def _run_task(task: Tuple, alloc_backend: Optional[object] = None
              ) -> Dict[str, Any]:
    """Worker-side dispatch: grid cells and what-if branches share the
    supervised driver and the batched-backend lanes."""
    if isinstance(task[1], _Branch):
        return _run_branch(task, alloc_backend=alloc_backend)
    return _run_cell(task, alloc_backend=alloc_backend)


#: early-stop progress check cadence (events between partial-metric looks).
#: Fixed, never caller-partitioned: the check points — and therefore the
#: stopped-at state — are deterministic for a given branch.
_EARLY_STOP_CHUNK = 256


def _run_branch(task: Tuple[int, "_Branch", Any],
                alloc_backend: Optional[object] = None) -> Dict[str, Any]:
    idx, br, _ = task
    from .session import SimSession

    t1 = time.perf_counter()
    ses = SimSession.restore(br.snap, policy=None if br.same else br.policy)
    ses._tuner = None           # branches race under a tuner, never run one
    period_changed = False
    if br.period is not None and br.period != ses.engine.params.period:
        ses.set_period(br.period)
        period_changed = True
    if br.branch_seed is not None and ses.narrator is not None:
        ses.narrator.reseed(br.branch_seed)
    if alloc_backend is not None:
        ses.engine.alloc_backend = alloc_backend
    target = (math.inf if br.horizon_s is None
              else br.snap.time + float(br.horizon_s))
    stopped = False
    thresh = (br.early_stop or {}).get("max_stretch_above")
    if thresh is not None:
        # chunked stepping with deterministic look points: completed-job
        # max stretch is monotone in sim time, so crossing the threshold
        # is final — stop paying for a branch that already lost
        while True:
            n = ses.step(_EARLY_STOP_CHUNK, until=target)
            if ses.result(partial=True, light=True).max_stretch > thresh:
                stopped = True
                break
            if n < _EARLY_STOP_CHUNK:
                break
    elif math.isinf(target):
        ses.run_to_exhaustion()
    else:
        ses.step_until(target)
    r = ses.result()
    wall = time.perf_counter() - t1
    return {
        "cell": idx,
        "branch": idx,
        "policy": br.policy,
        "period": ses.engine.params.period,
        "branch_policy": br.snap.policy,
        "branch_time": br.snap.time,
        "branch_fingerprint": br.snap.fingerprint,
        "exact_continuation": (br.same and not period_changed
                               and br.branch_seed is None),
        "horizon_s": br.horizon_s,
        "branch_seed": br.branch_seed,
        "early_stopped": stopped,
        "partial": not ses.exhausted,
        "max_stretch": r.max_stretch,
        "mean_stretch": r.mean_stretch,
        "makespan": r.makespan,
        "underutilization": r.underutilization,
        "n_pmtn": r.n_pmtn,
        "n_mig": r.n_mig,
        "pmtn_per_job": r.pmtn_per_job,
        "mig_per_job": r.mig_per_job,
        "bytes_moved_gb": r.bytes_moved_gb,
        "bandwidth_gbps": r.bandwidth_gbps,
        "events": r.events,
        "n_events": r.n_events,
        "hit_max_events": r.hit_max_events,
        "final_time": r.final_time,
        "sim_wall_s": r.sim_wall_s,
        "wall_s": wall,
    }


def run_branches(
    snapshot,
    policies: Sequence[Any],
    json_path: Optional[str] = None,
    *,
    horizon_s: Optional[float] = None,
    early_stop: Optional[Dict[str, float]] = None,
    branch_seed: Optional[int] = None,
    timeout_s: Optional[float] = None,
    retries: int = 0,
    quarantine: bool = False,
    backend: Optional[str] = None,
    n_workers: int = 1,
) -> SweepResult:
    """Fork one mid-run session snapshot under several policy variants.

    ``snapshot`` is a :class:`repro.sched.session.SessionState` (or a path
    / JSON dict of one).  Every variant resumes from the *identical* live
    cluster state — same running set, same queue, same virtual times, same
    pending arrivals — the scenario axis no closed-world batch run can
    produce.  The snapshot's own policy continues bit-identically
    (``exact_continuation``); other policies adopt the live state (see
    ``SimSession.restore``).  An attached autotuner never follows into a
    branch (branches race under tuners, they don't run them).

    ``policies`` entries are policy strings, or ``{"policy": ...,
    "period": ...}`` dicts to race period variants of one policy.

    Tuner-race options (all default to the legacy full-run behavior):

    * ``horizon_s`` — budgeted horizon: each branch runs only to
      ``snapshot.time + horizon_s`` and reports *partial* metrics
      (``partial=True`` on unfinished branches).
    * ``early_stop`` — ``{"max_stretch_above": x}`` declaratively stops a
      branch at a deterministic check point once its completed-job max
      stretch exceeds ``x`` (monotone, so the branch has already lost);
      the record carries ``early_stopped=True``.
    * ``branch_seed`` — reseed every branch's chaos narrator with this
      common seed: branches race under *common random numbers* while being
      decorrelated from the live session's actual future (oracle-free).
    * ``timeout_s``/``retries`` — the supervised driver from
      :func:`run_grid`: each branch gets a wall-clock budget and bounded
      reseeded retries on fresh worker processes; exhausted branches come
      back as quarantine records.  Wall-clock supervision is inherently
      nondeterministic — leave it off where bit-identical replay matters.
    * ``quarantine`` — in the default serial in-process mode, turn a
      crashing branch into a quarantine record instead of propagating
      (the supervised and batched paths always isolate failures).
    * ``backend="jax"``/``"pallas"`` — race all branches through one
      lockstep batched allocation device (see :func:`run_batched`).

    Records gain ``horizon_s``, ``branch_seed``, ``early_stopped``,
    ``partial`` and ``period`` next to the PR-5 branch fields.
    """
    from .session import SessionState

    if isinstance(snapshot, str):
        snapshot = SessionState.load(snapshot)
    elif isinstance(snapshot, dict):
        snapshot = SessionState.from_json_dict(snapshot)
    origin = (_canonical_policy(snapshot.policy)
              if snapshot.policy is not None else None)
    branches: List[_Branch] = []
    for entry in policies:
        if isinstance(entry, dict):
            policy = entry["policy"]
            period = entry.get("period")
            period = None if period is None else float(period)
        else:
            policy, period = entry, None
        same = origin is not None and _canonical_policy(policy) == origin
        branches.append(_Branch(
            snap=snapshot, policy=policy, same=same, period=period,
            horizon_s=horizon_s, early_stop=early_stop,
            branch_seed=branch_seed))
    tasks = [(i, br, None) for i, br in enumerate(branches)]
    supervised = timeout_s is not None or retries > 0
    t0 = time.perf_counter()
    if backend not in (None, "numpy"):
        if backend not in ("jax", "pallas"):
            raise ValueError(f"unknown branch backend {backend!r}")
        records = _run_branches_batched(
            tasks, matvec="jnp" if backend == "jax" else "pallas",
            quarantine=quarantine or supervised)
    elif supervised:
        records = _run_supervised(tasks, n_workers, timeout_s, retries)
    else:
        records = []
        for t in tasks:
            try:
                records.append(_run_task(t))
            except Exception as exc:  # noqa: BLE001 — quarantined below
                if not quarantine:
                    raise
                records.append(_quarantine_record(
                    t[0], t[1], f"{type(exc).__name__}: {exc}", attempts=1))
    records.sort(key=lambda r: r["cell"])
    res = SweepResult(records=records, wall_s=time.perf_counter() - t0,
                      n_workers=1)
    if json_path is not None:
        res.save_json(json_path)
    return res


def _run_branches_batched(tasks: Sequence[Tuple], matvec: str,
                          quarantine: bool) -> List[Dict[str, Any]]:
    """Race every branch through one lockstep batched allocation device
    (same lane structure as :func:`run_batched`; restore pins branches to
    the numpy backend, so each lane re-attaches its dispatcher lane)."""
    from ..core import alloc_jax

    n = len(tasks)
    if n == 0:
        return []
    dispatcher = alloc_jax.LockstepDispatcher(
        n, alloc_jax.BatchedAllocator(matvec=matvec))
    records: List[Optional[Dict[str, Any]]] = [None] * n
    errors: List[Optional[BaseException]] = [None] * n

    def _lane_main(i: int) -> None:
        try:
            records[i] = _run_task(tasks[i],
                                   alloc_backend=dispatcher.lane(i))
        except BaseException as exc:  # noqa: BLE001 — re-raised by driver
            errors[i] = exc
        finally:
            dispatcher.finish_lane(i)

    threads = [threading.Thread(target=_lane_main, args=(i,), daemon=True)
               for i in range(n)]
    for t in threads:
        t.start()
    dispatcher.serve()
    for t in threads:
        t.join()
    first = next((e for e in errors if e is not None), None)
    if first is not None and not quarantine:
        raise first
    out: List[Dict[str, Any]] = []
    for i, (rec, err) in enumerate(zip(records, errors)):
        if rec is None:
            msg = (f"{type(err).__name__}: {err}" if err is not None
                   else "lane produced no record")
            out.append(_quarantine_record(i, tasks[i][1], msg, attempts=1))
        else:
            rec["backend"] = "jax"
            out.append(rec)
    return out


# --------------------------------------------------------------------------- #
# driver side                                                                  #
# --------------------------------------------------------------------------- #
def _pool_context() -> mp.context.BaseContext:
    """Pick a start method: fork is fastest, but forking a process with an
    initialized (multithreaded) JAX runtime can deadlock the children, so
    prefer forkserver/spawn once jax is loaded.  Those methods re-import
    ``__main__`` in the worker, which breaks for stdin/REPL parents — in
    that corner fall back to fork anyway."""
    methods = mp.get_all_start_methods()
    if "fork" in methods and "jax" not in sys.modules:
        return mp.get_context("fork")
    main = sys.modules.get("__main__")
    main_file = getattr(main, "__file__", None)
    main_importable = (
        main_file is None
        or os.path.exists(main_file)
        or getattr(main, "__spec__", None) is not None
    )
    if main_importable:
        for method in ("forkserver", "spawn"):
            if method in methods:
                return mp.get_context(method)
    return mp.get_context("fork" if "fork" in methods else "spawn")


def run_batched(
    cells: Sequence[Cell],
    compute_bound: bool = False,
    json_path: Optional[str] = None,
    matvec: str = "auto",
    quarantine: bool = False,
) -> SweepResult:
    """Evaluate every cell through the batched JAX allocation backend.

    One device, one lockstep schedule: each cell's engine runs in its own
    thread with a :class:`repro.core.alloc_jax.LockstepDispatcher` lane as
    its allocation backend; the driver thread collects every live lane's
    §4.6 request per scheduling round, pads them into one dense batch, and
    answers the round with a single jitted water-filling dispatch (OPT=AVG
    floors batched on device, LPs on host).  Per-lane results are bit-equal
    to the numpy kernels, so the records match a ``run_grid`` sweep of the
    same cells exactly on every simulation outcome (records carry
    ``backend="jax"`` and their own wall times).

    ``matvec`` picks the inner-matvec kernel: ``"jnp"`` (pure jnp, the
    CPU default), ``"pallas"`` (the Pallas kernel, ``interpret=True``
    off-TPU), or ``"auto"`` (pallas only under the process-wide pallas
    kernel backend, at kernel-worthy shapes).

    A lane that raises re-raises on the driver thread by default (the other
    lanes are still released); with ``quarantine=True`` the failed lane
    becomes a quarantine record instead and the sweep completes.  Lanes run
    as threads, so per-cell wall-clock timeouts are not enforceable here —
    use the process-pool path for that.
    """
    from ..core import alloc_jax

    t0 = time.perf_counter()
    n = len(cells)
    if n == 0:
        return SweepResult(records=[], wall_s=time.perf_counter() - t0,
                           n_workers=1)
    dispatcher = alloc_jax.LockstepDispatcher(
        n, alloc_jax.BatchedAllocator(matvec=matvec))
    records: List[Optional[Dict[str, Any]]] = [None] * n
    errors: List[Optional[BaseException]] = [None] * n

    def _lane_main(i: int) -> None:
        try:
            records[i] = _run_cell((i, cells[i], compute_bound),
                                   alloc_backend=dispatcher.lane(i))
        except BaseException as exc:  # noqa: BLE001 — re-raised by driver
            errors[i] = exc
        finally:
            dispatcher.finish_lane(i)

    threads = [threading.Thread(target=_lane_main, args=(i,), daemon=True)
               for i in range(n)]
    for t in threads:
        t.start()
    dispatcher.serve()                  # the device loop (this thread)
    for t in threads:
        t.join()
    first = next((e for e in errors if e is not None), None)
    if first is not None and not quarantine:
        raise first
    out: List[Dict[str, Any]] = []
    for i, (rec, err) in enumerate(zip(records, errors)):
        if rec is None:
            msg = (f"{type(err).__name__}: {err}" if err is not None
                   else "lane produced no record")
            out.append(_quarantine_record(i, cells[i], msg, attempts=1))
        else:
            rec["backend"] = "jax"
            out.append(rec)
    res = SweepResult(records=out,
                      wall_s=time.perf_counter() - t0, n_workers=1)
    if json_path is not None:
        res.save_json(json_path)
    return res


def run_grid(
    cells: Sequence[Cell],
    n_workers: int = 1,
    chunksize: Optional[int] = None,
    compute_bound: bool = False,
    json_path: Optional[str] = None,
    backend: Optional[str] = None,
    timeout_s: Optional[float] = None,
    retries: int = 0,
) -> SweepResult:
    """Evaluate every cell, fanning across ``n_workers`` processes.

    ``n_workers <= 1`` runs serially in-process (deterministic, easiest to
    debug); otherwise a process pool consumes the cell list in chunks of
    ``chunksize`` (default: spread cells ~4 chunks per worker so stragglers
    rebalance).  Records come back in grid order regardless of scheduling.
    With ``compute_bound``, each record also carries the Theorem-1 lower
    bound of its (scenario-transformed) trace and the achieved
    ``degradation`` from it.  ``json_path`` additionally writes the artifact.

    ``backend="jax"`` (or ``"pallas"``) routes the whole grid through
    :func:`run_batched` instead — one device, allocation phases stepped in
    lockstep, bit-identical records; ``n_workers``/``chunksize`` don't
    apply there.  ``None``/``"numpy"`` is the process-pool path.

    ``timeout_s``/``retries`` turn the driver into a supervisor: each cell
    gets a wall-clock budget (``timeout_s``, ``None`` = unlimited) and up to
    ``retries`` re-runs on fresh worker processes; cells that exhaust their
    budget come back as quarantine records (``quarantined=True``, with
    ``error`` and ``attempts``) and the rest of the sweep completes.  With
    both left at their defaults the legacy fast path (serial or chunked
    ``Pool``) runs unchanged; supervision always uses worker processes,
    even at ``n_workers=1``, so a hung cell can be terminated.

    Note: when jax is loaded the pool uses the forkserver start method (see
    ``_pool_context``), which re-imports ``__main__`` — scripts calling this
    with ``n_workers > 1`` need the usual ``if __name__ == "__main__"`` guard.
    """
    supervised = timeout_s is not None or retries > 0
    if backend not in (None, "numpy"):
        if backend not in ("jax", "pallas"):
            raise ValueError(f"unknown sweep backend {backend!r}")
        # lanes are threads: no per-cell timeout there, but supervision
        # intent still means "complete the sweep" — quarantine failed lanes
        return run_batched(cells, compute_bound=compute_bound,
                           json_path=json_path,
                           matvec="jnp" if backend == "jax" else "pallas",
                           quarantine=supervised)
    tasks = [(i, c, compute_bound) for i, c in enumerate(cells)]
    t0 = time.perf_counter()
    if supervised:
        records = _run_supervised(tasks, n_workers, timeout_s, retries)
        n_workers = max(1, min(n_workers, len(tasks))) if tasks else 1
    elif n_workers <= 1 or len(tasks) <= 1:
        records = [_run_cell(t) for t in tasks]
        n_workers = 1
    else:
        if chunksize is None:
            chunksize = max(1, len(tasks) // (4 * n_workers))
        with _pool_context().Pool(processes=n_workers) as pool:
            records = list(pool.imap_unordered(_run_cell, tasks,
                                               chunksize=chunksize))
    records.sort(key=lambda r: r["cell"])
    res = SweepResult(records=records, wall_s=time.perf_counter() - t0,
                      n_workers=n_workers)
    if json_path is not None:
        res.save_json(json_path)
    return res


# --------------------------------------------------------------------------- #
# resumable record cache                                                       #
# --------------------------------------------------------------------------- #
CACHE_SCHEMA = "repro.sweep-cache/v1"


def _canonical_policy(policy: str) -> str:
    """Cache identity of a policy string: the canonical grammar spelling
    (so ``"greedy *"`` and ``"Greedy */OPT=MIN"`` share a cache slot) or
    the verbatim name for registered compositions."""
    try:
        return parse_policy(policy).name
    except ValueError:
        return policy


def _canonical_scenario(scenario: str) -> str:
    """Cache identity of a scenario chain: whitespace-insensitive link
    spelling (``"a + b"`` and ``"a+b"`` share a record); unknown names pass
    through verbatim so stale cached records never crash a load."""
    try:
        return "+".join(parse_scenario_chain(scenario))
    except KeyError:
        return scenario


def _params_key(params: SimParams) -> Dict[str, Any]:
    """The SimParams fields that are part of a cell's cache identity:
    everything except ``n_nodes`` (always taken from the workload) and
    ``period`` (already a key dimension of its own)."""
    d = dataclasses.asdict(params)
    d.pop("n_nodes")
    d.pop("period")
    return d


def _params_tuple(params: Dict[str, Any]) -> Tuple:
    return tuple(sorted(params.items()))


def _record_key(rec: Dict[str, Any]) -> Tuple:
    return (rec["kind"], rec["n_jobs"], rec["n_nodes"], rec["seed"],
            rec["load"], _params_tuple(rec["params"]),
            rec["trace_fingerprint"],
            _canonical_policy(rec["policy"]),
            _canonical_scenario(rec["scenario"]),
            float(rec["period"]),
            tuple(sorted(rec["sim_params"].items())))


class RecordCache:
    """Memoized sweep records, optionally persisted to one JSON file.

    Each (workload × policy × period × scenario × SimParams template) cell
    is simulated at most once per cache; :meth:`sweep` fans only the misses
    through :func:`run_grid` and — when constructed with a ``path`` —
    writes the cache back atomically after every miss batch, so an
    interrupted benchmark run resumes where it stopped and parallel runs
    never observe torn artifacts.  Policy strings are canonicalized for
    cache identity, so equivalent grammar spellings share one record; keys
    also carry the workload trace's content fingerprint, so records cached
    before a generator refactor (same spec, different jobs) are re-simulated
    instead of silently reused.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._records: Dict[Tuple, Dict[str, Any]] = {}
        if path is None or not os.path.exists(path):
            return
        try:
            with open(path) as f:
                payload = json.load(f)
        except (json.JSONDecodeError, UnicodeDecodeError, OSError) as exc:
            # a truncated or corrupted cache (killed mid-write on a
            # non-atomic filesystem, disk hiccup) is a cache *miss*, not a
            # crash: warn once, start empty, and let the next checkpoint
            # rewrite the file atomically
            print(f"warning: record cache {path} is unreadable "
                  f"({type(exc).__name__}: {exc}); starting empty and "
                  f"re-simulating", file=sys.stderr)
            return
        schema = payload.get("schema") if isinstance(payload, dict) else None
        if schema != CACHE_SCHEMA:
            # valid JSON that is *not* ours is a different story: refusing
            # protects the foreign file from being overwritten by save()
            raise ValueError(
                f"{path} is not a {CACHE_SCHEMA} record cache (schema: "
                f"{schema!r}); refusing to overwrite it — pass a fresh "
                f"path (sweep artifacts from --out/json_path are a "
                f"different format)")
        required = {"sim_params", "params", "trace_fingerprint",
                    "n_events", "sim_wall_s", "final_time"}
        dropped = 0
        for rec in payload.get("records", []):
            if not isinstance(rec, dict) or not required <= set(rec):
                continue        # record from an older schema (pre-Trace-
                # IR identity fields or pre-session observability
                # fields) — re-simulate it rather than mixing schemas
            try:
                self._records[_record_key(rec)] = rec
            except (KeyError, TypeError, ValueError, AttributeError):
                dropped += 1    # individually malformed record -> miss
        if dropped:
            print(f"warning: record cache {path}: dropped {dropped} "
                  f"malformed record(s); they will be re-simulated",
                  file=sys.stderr)

    def __len__(self) -> int:
        return len(self._records)

    @property
    def records(self) -> List[Dict[str, Any]]:
        return list(self._records.values())

    def save(self) -> Optional[str]:
        if self.path is None:
            return None
        return _atomic_write_json(self.path, {
            "schema": CACHE_SCHEMA,
            "n_records": len(self._records),
            "records": self.records,
        })

    def sweep(
        self,
        workloads: Iterable[WorkloadSpec],
        policies: Iterable[str],
        periods: Iterable[float] = (600.0,),
        scenarios: Iterable[str] = ("baseline",),
        params: Optional[SimParams] = None,
        n_workers: int = 1,
        chunksize: Optional[int] = None,
        compute_bound: bool = True,
        timeout_s: Optional[float] = None,
        retries: int = 0,
    ) -> List[Dict[str, Any]]:
        """Records for the full cross product, simulating only cache misses.

        A cached record without a Theorem-1 ``bound`` counts as a miss when
        ``compute_bound`` is requested (it is re-simulated with the bound).

        ``timeout_s``/``retries`` run the misses under the supervised driver
        (see :func:`run_grid`): cells exhausting their budget come back as
        quarantine records.  Quarantined records are returned but **never
        cached** — a later sweep over the same grid retries them, so a
        transient failure heals on resume instead of poisoning the cache.
        """
        base = params or SimParams()
        pkey_dict = _params_key(base)
        pkey = tuple(sorted(pkey_dict.items()))
        # materialize up front: one-pass iterables would silently empty the
        # inner loops after the first period otherwise
        workloads, policies = list(workloads), list(policies)
        periods, scenarios = list(periods), list(scenarios)
        want: List[Tuple[WorkloadSpec, str, float, str]] = [
            (w, p, float(per), sc)
            for per in periods for w in workloads
            for p in policies for sc in scenarios
        ]

        for sc in scenarios:
            parse_scenario_chain(sc)    # fail fast, driver-side
        # one fingerprint per distinct workload, materialized driver-side
        # exactly once (the per-process trace memo is an LRU — recomputing
        # inside key_of would thrash it on paper-scale grids)
        fps = {w: make_trace_ir(w).fingerprint for w in set(workloads)}

        def key_of(w: WorkloadSpec, p: str, per: float, sc: str) -> Tuple:
            return (w.kind, w.n_jobs, w.n_nodes, w.seed, w.load, w.params,
                    fps[w], _canonical_policy(p), _canonical_scenario(sc),
                    per, pkey)

        def hit(k: Tuple) -> bool:
            rec = self._records.get(k)
            return rec is not None and (not compute_bound or "bound" in rec)

        # dedup misses by *canonical* key — equivalent spellings (and
        # verbatim duplicates) of one cell must be simulated once
        missing: List[Tuple[WorkloadSpec, str, float, str]] = []
        missing_keys: List[Tuple] = []
        seen: set = set()
        for t in want:
            k = key_of(*t)
            if k in seen or hit(k):
                continue
            seen.add(k)
            missing.append(t)
            missing_keys.append(k)
        # with a disk path, checkpoint the cache every few miss chunks so an
        # interrupted sweep resumes mid-batch, not only between sweep() calls
        step = len(missing) if self.path is None else max(4 * n_workers, 8)
        quarantined: Dict[Tuple, Dict[str, Any]] = {}
        for lo in range(0, len(missing), max(step, 1)):
            batch = missing[lo:lo + step]
            batch_keys = missing_keys[lo:lo + step]
            cells = [Cell(w, p, sc, params=replace(base, period=per))
                     for (w, p, per, sc) in batch]
            res = run_grid(cells, n_workers=n_workers, chunksize=chunksize,
                           compute_bound=compute_bound,
                           timeout_s=timeout_s, retries=retries)
            for k, rec in zip(batch_keys, res.records):
                if rec.get("quarantined"):
                    quarantined[k] = rec   # returned, never persisted —
                    continue               # the next sweep retries the cell
                rec["sim_params"] = dict(pkey_dict)   # disk-key round-trip
                self._records[k] = rec
            self.save()
        # returned records mirror run_grid semantics: "policy"/"scenario"
        # are the spellings the caller asked for (so filter/summary keys
        # match the request even when an equivalent spelling filled the
        # cache) and "cell" is the want-order index (stable, collision-free
        # artifacts across resumed sweeps)
        out: List[Dict[str, Any]] = []
        for i, t in enumerate(want):
            k = key_of(*t)
            src = self._records.get(k)
            if src is None:
                src = quarantined[k]
            rec = dict(src)
            rec["policy"] = t[1]
            rec["scenario"] = t[3]
            rec["cell"] = i
            out.append(rec)
        return out
