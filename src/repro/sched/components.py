"""Composable policy components: the open policy API behind the engine.

The paper's 116-policy space (§4.5) is a *closed* grammar; this module
turns it into an *open* registry of first-class policy components that a
generic :class:`ComposedPolicy` assembles and the :class:`~.engine.Engine`
drives through one narrow hook protocol (``on_submit`` /
``on_job_completed`` / ``on_complete`` / ``on_tick`` / ``finalize``):

* **SubmitAction** — reaction to a job arrival: ``greedy`` / ``greedyP`` /
  ``greedyPM`` / ``mcb8`` (§4.2) or ``fcfs-queue`` (batch FIFO admission).
* **CompletionAction** — reaction to job completions: ``greedy`` /
  ``mcb8`` opportunistic passes (§4.2), batch ``reclaim`` + ``fcfs-start``
  / ``easy-backfill`` restarts (§5.2).
* **PeriodicPass** — the period-``T`` tick: ``mcb8`` / ``mcb8-stretch``
  (§4.3/§4.7) or ``backfill`` (batch queue drained only on the tick).
* **OptPass** — the per-event resource-allocation post-pass (§4.6):
  ``MIN`` / ``AVG`` / ``MAX`` (``MAX`` delegates its per-event reallocation
  to ``MIN``, exactly as the stretch-periodic policies do).

Every component is registered under ``(kind, name)`` via
:func:`register_component`; :func:`compose_from_spec` assembles the
canonical composition for any :class:`~repro.core.policies.PolicySpec`, and
the engine's default policy path runs entirely through it.  The seed
classes ``DFRSPolicy`` / ``BatchPolicy`` live on in ``repro.sched.engine``
as the equivalence oracle: composed policies reproduce their ``SimResult``
bit for bit (``tests/test_components.py``).

Whole compositions that the string grammar cannot express are registered by
*name* via :func:`register_policy` and then work everywhere a policy string
does (``Engine``, ``repro.api.simulate``, sweep cells, benchmarks).  The
built-in existence proof is ``"EASY+OPT=MIN"`` — EASY backfilling whose
backfill step may *fractionally* co-locate a candidate onto occupied nodes
(never onto free nodes) with a fractional OPT=MIN yield post-pass
arbitrating the sharing; see :class:`BatchStartPass` for the semantics and
the head-delay trade-off.
"""
from __future__ import annotations

import heapq
import math
from collections import Counter, deque
from itertools import islice
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..core.greedy import greedy_p, greedy_place, greedy_pm
from ..core.job import PAUSED, PENDING, RUNNING, JobSpec
from ..core.mcb8 import mcb8
from ..core.policies import PolicySpec, parse_policy
from ..core.state import JobView
from ..core.stretch_opt import improve_avg_stretch, improve_max_stretch, mcb8_stretch
from .engine import Policy, SimParams, _node_multiset, _reallocate_yields

__all__ = [
    "Component",
    "ComposedPolicy",
    "COMPONENT_KINDS",
    "register_component",
    "get_component",
    "list_components",
    "compose",
    "compose_from_spec",
    "register_policy",
    "registered_policies",
    "resolve_policy",
]


# --------------------------------------------------------------------------- #
# component protocol + registry                                                #
# --------------------------------------------------------------------------- #
class Component:
    """One pluggable piece of scheduling behaviour.

    A component implements any subset of the engine's hook protocol; the
    :class:`ComposedPolicy` fans each hook only to the components that
    override it (chains are precomputed, so unimplemented hooks cost
    nothing on the event loop).  ``bind`` is the per-run reset — a
    component may be reused across Engine runs and must not carry state
    over (coordination state lives in ``self.p.shared``, which the policy
    clears on every bind).
    """

    #: registry coordinates, filled in by :func:`register_component`
    kind: str = ""
    component_name: str = ""
    #: does the component tolerate cluster (failure/elastic) events?  The
    #: composition handles them only if *every* component does.
    handles_cluster_events = True
    #: non-None enables the engine's periodic tick for the composition
    periodic_kind: Optional[str] = None

    def __init__(self, spec: Optional[PolicySpec] = None):
        self.spec = spec

    def bind(self, policy: "ComposedPolicy") -> None:
        self.p = policy

    def validate(self, specs: Sequence[JobSpec], params: SimParams) -> None:
        pass

    def on_submit(self, js: JobView) -> None:
        pass

    def on_job_completed(self, js: JobView) -> None:
        pass

    def on_job_cancelled(self, js: JobView) -> None:
        pass

    def on_complete(self) -> None:
        pass

    def on_tick(self) -> None:
        pass

    def finalize(self, acted: bool) -> None:
        pass

    def __repr__(self) -> str:
        tag = f"{self.kind}/{self.component_name}" if self.kind else "unregistered"
        return f"<{self.__class__.__name__} {tag}>"


COMPONENT_KINDS = ("submit", "complete", "periodic", "opt")

_COMPONENTS: Dict[Tuple[str, str], type] = {}


def register_component(kind: str, name: str) -> Callable[[type], type]:
    """Class decorator: register a :class:`Component` under ``(kind, name)``."""
    if kind not in COMPONENT_KINDS:
        raise ValueError(f"unknown component kind {kind!r}; "
                         f"expected one of {COMPONENT_KINDS}")

    def deco(cls: type) -> type:
        key = (kind, name)
        if key in _COMPONENTS:
            raise ValueError(f"component {kind}/{name} already registered")
        cls.kind, cls.component_name = kind, name
        _COMPONENTS[key] = cls
        return cls

    return deco


def get_component(kind: str, name: str) -> type:
    try:
        return _COMPONENTS[(kind, name)]
    except KeyError:
        known = sorted(n for k, n in _COMPONENTS if k == kind)
        raise KeyError(f"unknown {kind} component {name!r}; known: {known}")


def list_components(kind: Optional[str] = None) -> Dict[str, List[str]]:
    """``{kind: [names...]}`` for one kind or all of them."""
    kinds = (kind,) if kind else COMPONENT_KINDS
    return {k: sorted(n for kk, n in _COMPONENTS if kk == k) for k in kinds}


# --------------------------------------------------------------------------- #
# the generic composed policy                                                  #
# --------------------------------------------------------------------------- #
class ComposedPolicy(Policy):
    """A :class:`~.engine.Policy` assembled from registry components.

    Hooks fan out to components in composition order; ``shared`` is a
    per-run scratch namespace for cross-component coordination (the batch
    queue state, the stretch-pass yield flag) cleared on every bind.
    """

    def __init__(
        self,
        components: Sequence[Component],
        name: str = "composed",
        spec: Optional[PolicySpec] = None,
    ):
        self.components = list(components)
        self.name = name
        self.spec = spec
        self.shared: Dict[str, object] = {}
        self.handles_cluster_events = all(
            c.handles_cluster_events for c in self.components)
        ticks = [c.periodic_kind for c in self.components if c.periodic_kind]
        if len(ticks) > 1:
            raise ValueError(
                f"at most one periodic component per composition, got {ticks}")
        self.periodic_kind = ticks[0] if ticks else None
        base = Component
        by_hook = lambda h: [c for c in self.components
                             if getattr(type(c), h) is not getattr(base, h)]
        self._submit_chain = by_hook("on_submit")
        self._job_completed_chain = by_hook("on_job_completed")
        self._cancel_chain = by_hook("on_job_cancelled")
        self._complete_chain = by_hook("on_complete")
        self._tick_chain = by_hook("on_tick")
        self._finalize_chain = by_hook("finalize")

    # ---- hook fan-out ---------------------------------------------------
    def bind(self, engine) -> None:
        super().bind(engine)
        self.shared = {}
        for c in self.components:
            c.bind(self)

    def validate(self, specs: Sequence[JobSpec], params: SimParams) -> None:
        for c in self.components:
            c.validate(specs, params)

    def on_submit(self, js: JobView) -> None:
        for c in self._submit_chain:
            c.on_submit(js)

    def on_job_completed(self, js: JobView) -> None:
        for c in self._job_completed_chain:
            c.on_job_completed(js)

    def on_job_cancelled(self, js: JobView) -> None:
        for c in self._cancel_chain:
            c.on_job_cancelled(js)

    def on_complete(self) -> None:
        for c in self._complete_chain:
            c.on_complete()

    def on_tick(self) -> None:
        for c in self._tick_chain:
            c.on_tick()

    def finalize(self, acted: bool) -> None:
        for c in self._finalize_chain:
            c.finalize(acted)

    def __repr__(self) -> str:
        return f"<ComposedPolicy {self.name!r} {self.components}>"


def compose(name: str, *components: Component,
            spec: Optional[PolicySpec] = None) -> ComposedPolicy:
    """Sugar: ``compose("my-policy", SubmitGreedy(), OptMin())``."""
    return ComposedPolicy(components, name=name, spec=spec)


def compose_from_spec(spec: PolicySpec | str) -> ComposedPolicy:
    """The canonical composition for a (parsed) policy-grammar spec."""
    if isinstance(spec, str):
        spec = parse_policy(spec)
    if spec.is_batch:
        start = "fcfs-start" if spec.name == "FCFS" else "easy-backfill"
        comps = [
            get_component("submit", "fcfs-queue")(spec),
            get_component("complete", "reclaim")(spec),
            get_component("complete", start)(spec),
        ]
    else:
        comps = []
        if spec.on_submit is not None:
            comps.append(get_component("submit", spec.on_submit)(spec))
        if spec.on_complete is not None:
            comps.append(get_component("complete", spec.on_complete)(spec))
        if spec.periodic is not None:
            comps.append(get_component("periodic", spec.periodic)(spec))
        comps.append(get_component("opt", spec.opt)(spec))
    return ComposedPolicy(comps, name=spec.name, spec=spec)


# --------------------------------------------------------------------------- #
# named whole-policy registry (compositions beyond the grammar)                #
# --------------------------------------------------------------------------- #
_POLICIES: Dict[str, Tuple[Callable[[], Policy], str]] = {}


def register_policy(name: str, factory: Optional[Callable[[], Policy]] = None,
                    *, description: str = ""):
    """Register a named policy composition the string grammar cannot spell.

    ``factory`` must build a *fresh* policy instance per call (policies are
    stateful).  The name then works everywhere a policy string does:
    ``Engine(specs, name)``, ``repro.api.simulate``, sweep ``Cell``s, the
    CLI.  Names that parse under the classic grammar are rejected — the
    grammar already canonicalizes those spellings.

    Sweep caveat: ``run_grid`` workers resolve names in their own process.
    Registrations done at import time of any module the workers load (like
    the built-ins here) are always visible; registrations done at runtime
    are visible under the default ``fork`` start method but not under
    ``spawn``/``forkserver`` (used once jax is loaded) — register from an
    imported module, or sweep with ``n_workers=1``, in that case.
    """
    def _register(fac: Callable[[], Policy]):
        try:
            parse_policy(name)
        except ValueError:
            pass
        else:
            raise ValueError(
                f"{name!r} is a policy-grammar spelling; registered names "
                f"must not shadow the grammar")
        if name in _POLICIES:
            raise ValueError(f"policy {name!r} already registered")
        _POLICIES[name] = (fac, description or (fac.__doc__ or "").strip())
        return fac

    if factory is None:
        return _register           # decorator form
    return _register(factory)


def registered_policies() -> Dict[str, str]:
    """``{name: description}`` of every registered composition."""
    return {name: desc for name, (_, desc) in sorted(_POLICIES.items())}


def resolve_policy(name: str) -> Optional[Policy]:
    """A fresh policy instance for a registered name, else None."""
    entry = _POLICIES.get(name)
    return entry[0]() if entry is not None else None


# --------------------------------------------------------------------------- #
# DFRS helpers (shared by the §4 components; bit-identical to DFRSPolicy)      #
#                                                                              #
# These deliberately *duplicate* DFRSPolicy's private orchestration (the seed  #
# classes in engine.py are the frozen equivalence oracle and must not share    #
# it, same pattern as core/alloc_reference.py) — any divergence between the    #
# two is exactly what the golden tests in tests/test_components.py exist to    #
# catch.                                                                       #
# --------------------------------------------------------------------------- #
def _pinned(e, spec: Optional[PolicySpec]) -> Dict[int, List[int]]:
    """Jobs protected from remapping by MINVT/MINFT (§4.3)."""
    pins: Dict[int, List[int]] = {}
    if spec is None or (spec.minvt is None and spec.minft is None):
        return pins
    now = e.state.now
    for js in e.state.running():
        if spec.minvt is not None and js.vt < spec.minvt:
            pins[js.spec.jid] = list(js.mapping)
        elif spec.minft is not None and js.flow_time(now) < spec.minft:
            pins[js.spec.jid] = list(js.mapping)
    return pins


def _apply_global_mapping(e, mappings: Dict[int, List[int]],
                          cands: Sequence[JobView]) -> None:
    """Apply a from-scratch MCB8 mapping transactionally: the mapping is
    feasible as a whole, so all removals happen before any placement."""
    migrations: List[Tuple[JobView, List[int]]] = []
    starts: List[Tuple[JobView, List[int]]] = []
    for js in cands:
        new_map = mappings.get(js.spec.jid)
        if js.status == RUNNING:
            if new_map is None:
                e.pause(js)
            elif _node_multiset(js.mapping) != _node_multiset(new_map):
                migrations.append((js, new_map))
        elif new_map is not None:
            starts.append((js, new_map))
    e.migrate_many(migrations)
    for js, new_map in starts:
        e.start(js, new_map)


def _apply_mcb8(e, spec: Optional[PolicySpec]) -> None:
    cands = e.state.uncompleted()
    if not cands:
        return
    res = mcb8(
        cands, e.params.n_nodes, e.state.now,
        pinned=_pinned(e, spec), alive=e.state.alive,
    )
    _apply_global_mapping(e, res.mappings, cands)


# --------------------------------------------------------------------------- #
# §4 DFRS components                                                           #
# --------------------------------------------------------------------------- #
@register_component("submit", "greedy")
class SubmitGreedy(Component):
    """Place the arriving job on the least-loaded feasible nodes (§4.2)."""

    def on_submit(self, js: JobView) -> None:
        e = self.p.e
        mapping = greedy_place(e.state.pool.copy(), js.spec)
        if mapping is not None:
            e.start(js, mapping)


class _SubmitPreempting(Component):
    """GreedyP/GreedyPM admission: pause (and move) lower-priority work."""

    _fn = None                      # greedy_p | greedy_pm

    def on_submit(self, js: JobView) -> None:
        e = self.p.e
        running = e.state.running()
        adm = type(self)._fn(e.state.pool.copy(), js.spec, running,
                             e.state.now)
        if adm.mapping is None:
            return
        by_jid = {j.spec.jid: j for j in running}
        for jid in adm.paused:
            e.pause(by_jid[jid])
        e.migrate_many(
            [(by_jid[jid], new_map) for jid, new_map in adm.moved.items()])
        e.start(js, adm.mapping)


@register_component("submit", "greedyP")
class SubmitGreedyP(_SubmitPreempting):
    _fn = staticmethod(greedy_p)


@register_component("submit", "greedyPM")
class SubmitGreedyPM(_SubmitPreempting):
    _fn = staticmethod(greedy_pm)


@register_component("submit", "mcb8")
class SubmitMCB8(Component):
    """Re-pack the whole cluster with MCB8 on every arrival (§4.2)."""

    def on_submit(self, js: JobView) -> None:
        _apply_mcb8(self.p.e, self.spec)


@register_component("complete", "greedy")
class CompleteGreedy(Component):
    """Opportunistically greedy-start waiting jobs by §4.1 priority."""

    def on_complete(self) -> None:
        e = self.p.e
        waiting = sorted(
            (j for j in e.state.uncompleted() if j.status in (PENDING, PAUSED)),
            key=lambda j: j.priority_key(e.state.now),
            reverse=True,
        )
        for js in waiting:
            mapping = greedy_place(e.state.pool.copy(), js.spec)
            if mapping is not None:
                e.start(js, mapping)


@register_component("complete", "mcb8")
class CompleteMCB8(Component):
    """Re-pack the whole cluster with MCB8 on completions."""

    def on_complete(self) -> None:
        _apply_mcb8(self.p.e, self.spec)


@register_component("periodic", "mcb8")
class PeriodicMCB8(Component):
    """The /per pass: MCB8 from scratch every period (§4.3)."""

    periodic_kind = "mcb8"

    def on_tick(self) -> None:
        _apply_mcb8(self.p.e, self.spec)


@register_component("periodic", "mcb8-stretch")
class PeriodicStretch(Component):
    """The /stretch-per pass (§4.7): MCB8-stretch mapping plus an explicit
    max- or average-stretch yield optimization, which preempts the per-event
    OPT pass for this timestamp (via the shared ``stretch_yields_set`` flag).
    """

    periodic_kind = "mcb8-stretch"

    def on_tick(self) -> None:
        e = self.p.e
        cands = e.state.uncompleted()
        if not cands:
            return
        res = mcb8_stretch(
            cands, e.params.n_nodes, e.state.now, e.params.period,
            pinned=_pinned(e, self.spec), alive=e.state.alive,
        )
        _apply_global_mapping(e, res.mappings, cands)
        running = e.state.running()
        mappings = {js.spec.jid: js.mapping for js in running}
        ylds = {js.spec.jid: res.yields.get(js.spec.jid, 0.0) for js in running}
        if self.spec is not None and self.spec.opt == "MAX":
            ylds = improve_max_stretch(
                running, mappings, ylds, e.params.n_nodes, e.state.now,
                e.params.period,
            )
        else:
            ylds = improve_avg_stretch(
                running, mappings, ylds, e.params.n_nodes, e.state.now,
                e.params.period,
            )
        for js in running:
            js.yld = float(min(1.0, ylds.get(js.spec.jid, 0.0)))
        self.p.shared["stretch_yields_set"] = True


class _OptPass(Component):
    """Per-event §4.6 yield reallocation for all running jobs."""

    _opt = "MIN"

    def finalize(self, acted: bool) -> None:
        if not acted:
            return
        if self.p.shared.pop("stretch_yields_set", False):
            return                 # /stretch-per just set yields explicitly
        _reallocate_yields(self.p.e, type(self)._opt)


@register_component("opt", "MIN")
class OptMin(_OptPass):
    _opt = "MIN"


@register_component("opt", "AVG")
class OptAvg(_OptPass):
    _opt = "AVG"


@register_component("opt", "MAX")
class OptMax(_OptPass):
    # OPT=MAX is the stretch-periodic target; its per-event pass is MIN,
    # exactly as in DFRSPolicy._reallocate
    _opt = "MIN"


# --------------------------------------------------------------------------- #
# §5.2 batch components (queue state shared via policy.shared["batch"])        #
# --------------------------------------------------------------------------- #
class _BatchState:
    """FIFO queue + free-node heap + running list, shared by the batch
    components of one composition.  The ``excl_owner`` / ``frac_*`` maps
    only fill up under fractional backfilling (:class:`BatchStartPass` with
    ``frac=True``); canonical FCFS/EASY never touch them."""

    def __init__(self, n_nodes: int):
        self.queue: deque = deque()                     # FIFO: O(1) head pops
        self.free: List[int] = list(range(n_nodes))     # free node ids (heap)
        heapq.heapify(self.free)
        self.running: List[Tuple[float, int, int]] = [] # (end, jid, n_tasks)
        self.dirty = False
        self.excl_owner: Dict[int, int] = {}            # node -> exclusive jid
        self.frac_jobs: Dict[int, List[int]] = {}       # jid -> mapping
        self.frac_count: Counter = Counter()            # node -> frac tasks


def _batch_state(p: ComposedPolicy) -> _BatchState:
    st = p.shared.get("batch")
    if st is None:
        st = p.shared["batch"] = _BatchState(p.e.params.n_nodes)
    return st


def batch_state_payload(bs: _BatchState) -> Dict[str, object]:
    """JSON-able form of a :class:`_BatchState` for session snapshots.

    Queued jobs are referenced by their dense engine index; ``free`` is
    stored in its live heap layout verbatim, so a restored state pops nodes
    in the identical order (heap pop order is layout-independent anyway for
    distinct ints, but verbatim storage keeps the round trip exact).
    """
    return {
        "queue": [js.i for js in bs.queue],
        "free": list(bs.free),
        "running": [list(r) for r in bs.running],
        "dirty": bs.dirty,
        "excl_owner": sorted(bs.excl_owner.items()),
        "frac_jobs": sorted((jid, list(m)) for jid, m in bs.frac_jobs.items()),
        "frac_count": sorted((n, c) for n, c in bs.frac_count.items() if c),
    }


def batch_state_from_payload(payload: Dict[str, object], views,
                             n_nodes: int) -> _BatchState:
    """Inverse of :func:`batch_state_payload` against a restored engine's
    ``state.views``."""
    bs = _BatchState(n_nodes)
    bs.queue = deque(views[int(i)] for i in payload["queue"])
    bs.free = [int(n) for n in payload["free"]]
    bs.running = [(float(e), int(j), int(n)) for e, j, n in payload["running"]]
    bs.dirty = bool(payload["dirty"])
    bs.excl_owner = {int(n): int(j) for n, j in payload["excl_owner"]}
    bs.frac_jobs = {int(j): [int(x) for x in m]
                    for j, m in payload["frac_jobs"]}
    bs.frac_count = Counter({int(n): int(c)
                             for n, c in payload["frac_count"]})
    return bs


@register_component("submit", "fcfs-queue")
class QueueSubmit(Component):
    """Batch admission: enqueue arrivals FIFO; a start pass drains the
    queue (``fcfs-start`` / ``easy-backfill`` on events, ``backfill`` on
    the periodic tick)."""

    handles_cluster_events = False  # batch does not model failures

    def validate(self, specs: Sequence[JobSpec], params: SimParams) -> None:
        for s in specs:
            if s.n_tasks > params.n_nodes:
                raise ValueError(
                    f"job {s.jid} needs {s.n_tasks} > {params.n_nodes} nodes")

    def on_submit(self, js: JobView) -> None:
        st = _batch_state(self.p)
        st.queue.append(js)
        st.dirty = True


@register_component("complete", "reclaim")
class ReclaimNodes(Component):
    """Return a finished job's nodes to the free heap (called before the
    engine clears the mapping).  Under fractional backfilling a node goes
    back only once its last occupant — exclusive owner *and* co-located
    fractional tasks — has left."""

    handles_cluster_events = False

    def on_job_completed(self, js: JobView) -> None:
        st = _batch_state(self.p)
        jid = js.spec.jid
        if jid in st.frac_jobs:                 # fractionally placed job
            del st.frac_jobs[jid]
            for node in js.mapping:
                st.frac_count[node] -= 1
                if st.frac_count[node] == 0 and node not in st.excl_owner:
                    heapq.heappush(st.free, node)
            st.dirty = True
            return
        st.running = [r for r in st.running if r[1] != jid]
        for node in js.mapping:
            st.excl_owner.pop(node, None)
            if st.frac_count[node] == 0:
                heapq.heappush(st.free, node)
        st.dirty = True


class BatchStartPass(Component):
    """FCFS head starts + optional EASY backfilling (§5.2) over the shared
    batch queue state.

    Nodes are allocated integrally and exclusively: job j occupies n_j whole
    nodes at yield 1 for exactly p_j seconds.  EASY gives the queue head a
    reservation at the earliest time it could start under FCFS and backfills
    any job that does not interfere with it; as in the paper, EASY is given
    *perfect* processing-time estimates (a best case for the baseline).

    With ``frac=True`` (the hybrid compositions) a backfill candidate that
    does not fit on whole free nodes may instead be placed *fractionally*
    with greedy least-loaded placement restricted to already-occupied nodes
    (free nodes stay untouched), provided its optimistic yield-1 completion
    fits before the head's shadow time.  Fractional placements share CPU
    with their hosts; an ``opt`` component (e.g. ``OPT=MIN`` water-filling)
    must be composed after this pass to arbitrate the sharing, otherwise
    co-located jobs would starve.

    Trade-off: unlike strict EASY, fractional co-location *can* delay the
    queue head — sharing slows the host jobs past their reservation-time
    estimates, and a node whose exclusive owner finished is withheld from
    the free heap until its last fractional occupant leaves.  The delay is
    bounded (every co-located job keeps a positive max-min yield, so nodes
    always drain), and the stretch the sharing saves the backfilled jobs
    typically dominates — but the EASY no-delay guarantee is deliberately
    given up.
    """

    handles_cluster_events = False
    _algo = "FCFS"                  # FCFS | EASY
    _frac = False                   # fractional backfill extension
    _on_tick = False                # drain the queue on the periodic tick

    def finalize(self, acted: bool) -> None:
        if self._on_tick:
            return
        st = _batch_state(self.p)
        if st.dirty:
            self._try_start(st)
            st.dirty = False

    def on_tick(self) -> None:
        if not self._on_tick:
            return
        st = _batch_state(self.p)
        self._try_start(st)
        st.dirty = False

    # ---- allocation -----------------------------------------------------
    def _start_job(self, st: _BatchState, js: JobView) -> None:
        nodes = [heapq.heappop(st.free) for _ in range(js.spec.n_tasks)]
        now = self.p.e.state.now
        st.running.append((now + js.spec.proc_time, js.spec.jid,
                           js.spec.n_tasks))
        for node in nodes:
            st.excl_owner[node] = js.spec.jid
        self.p.e.start(js, nodes)
        js.yld = 1.0            # dedicated nodes, full speed

    def _start_frac(self, st: _BatchState, js: JobView) -> bool:
        """Fractionally co-locate ``js`` on occupied nodes, if it fits."""
        e = self.p.e
        pool = e.state.pool.copy()
        for node in st.free:
            pool.mem_free[node] = 0.0       # free nodes are off limits
        mapping = greedy_place(pool, js.spec)
        if mapping is None:
            return False
        st.frac_jobs[js.spec.jid] = list(mapping)
        for node in mapping:
            st.frac_count[node] += 1
        e.start(js, mapping)
        js.yld = 1.0            # provisional; the opt pass arbitrates
        return True

    def _try_start(self, st: _BatchState) -> None:
        now = self.p.e.state.now
        q = st.queue
        # FCFS part: start queue head(s) while they fit.
        while q and q[0].spec.n_tasks <= len(st.free):
            self._start_job(st, q.popleft())
        if self._algo == "FCFS" or not q:
            return
        # EASY backfilling against the head's reservation.
        changed = True
        while changed:
            changed = False
            head = q[0]
            ends = sorted(st.running)
            avail = len(st.free)
            shadow, extra = math.inf, 0
            for end, _, n in ends:
                avail += n
                if avail >= head.spec.n_tasks:
                    shadow = end
                    extra = avail - head.spec.n_tasks
                    break
            if math.isinf(shadow):
                # the head's reservation is uncomputable — under fractional
                # backfilling, nodes withheld for frac occupants can leave
                # free + exclusive-running short of the head's need.  A
                # vacuous `t <= inf` check would disable EASY's reservation
                # protection entirely, so allow no backfill until the
                # withheld nodes drain.  (Strict EASY never gets here:
                # every node is then free or exclusively running.)
                break
            for i, js in enumerate(islice(q, 1, None), start=1):
                free = len(st.free)
                fits_before_shadow = now + js.spec.proc_time <= shadow + 1e-9
                if js.spec.n_tasks <= free and (
                    fits_before_shadow
                    or js.spec.n_tasks <= min(free, extra)
                ):
                    del q[i]
                    self._start_job(st, js)
                    changed = True
                    break   # recompute the reservation after each backfill
                if (self._frac and fits_before_shadow
                        and self._start_frac(st, js)):
                    del q[i]
                    changed = True
                    break
        return


@register_component("complete", "fcfs-start")
class FCFSStart(BatchStartPass):
    _algo = "FCFS"


@register_component("complete", "easy-backfill")
class EasyBackfill(BatchStartPass):
    _algo = "EASY"


@register_component("complete", "easy-frac-backfill")
class EasyFracBackfill(BatchStartPass):
    _algo = "EASY"
    _frac = True


@register_component("periodic", "backfill")
class PeriodicBackfill(BatchStartPass):
    """Drain the batch queue only on the periodic tick (delayed batch
    scheduling — a composition the paper's grammar cannot express)."""

    _algo = "EASY"
    _on_tick = True
    periodic_kind = "backfill"


# --------------------------------------------------------------------------- #
# built-in named compositions (the open-API existence proofs)                  #
# --------------------------------------------------------------------------- #
@register_policy("EASY+OPT=MIN", description=(
    "EASY backfilling whose backfill step may fractionally co-locate jobs "
    "on occupied nodes, with an OPT=MIN water-filling post-pass arbitrating "
    "the sharing (hybrid batch+DFRS; not expressible in the §4.5 grammar)"))
def _easy_opt_min() -> ComposedPolicy:
    return compose(
        "EASY+OPT=MIN",
        QueueSubmit(),
        ReclaimNodes(),
        EasyFracBackfill(),
        OptMin(),
    )
