"""Evaluation metrics (paper §2.2, §6.1, §6.4).

* bounded stretch — turnaround replaced by a threshold (10 s) when smaller;
* degradation from bound — max bounded stretch / Theorem-1 lower bound;
* normalized underutilization — ∫ (min(|P|, demand) − useful allocation) dt
  divided by the total work of the trace.
"""
from __future__ import annotations

from typing import Dict, Sequence

import numpy as np

from ..core.bound import max_stretch_lower_bound
from ..core.job import JobSpec

__all__ = [
    "bounded_stretch",
    "max_bounded_stretch",
    "degradation_from_bound",
    "normalized_underutilization",
]


def bounded_stretch(turnaround: float, proc_time: float, tau: float = 10.0) -> float:
    """max(T, tau) / p  (paper §2.2: 'bounded slowdown' variant)."""
    return max(turnaround, tau) / proc_time


def max_bounded_stretch(
    specs: Sequence[JobSpec], completions: Dict[int, float], tau: float = 10.0
) -> float:
    return max(
        bounded_stretch(completions[s.jid] - s.release, s.proc_time, tau)
        for s in specs
    )


def degradation_from_bound(
    specs: Sequence[JobSpec],
    achieved_max_stretch: float,
    n_nodes: int,
    tau: float = 10.0,
    bound: float | None = None,
) -> float:
    """Ratio to the Theorem-1 offline clairvoyant lower bound (§6.1)."""
    if bound is None:
        bound = max_stretch_lower_bound(specs, n_nodes, tau)
    return achieved_max_stretch / bound


def normalized_underutilization(
    underutil_integral: float, specs: Sequence[JobSpec]
) -> float:
    total = sum(s.total_work for s in specs)
    return underutil_integral / max(total, 1e-12)
