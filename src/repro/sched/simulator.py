"""Back-compat front-end for the unified engine (see ``repro.sched.engine``).

Historically this module held the DFRS discrete-event simulator; the event
loop, fluid-progress model and metrics now live in :class:`Engine`, which
runs DFRS policies and the FCFS/EASY batch baselines through one code path.
``DFRSSimulator`` and ``simulate`` are kept as thin wrappers so existing
callers and tests keep working unchanged — new code should use
``repro.api`` (both wrappers emit one DeprecationWarning per process).
"""
from __future__ import annotations

from typing import Optional, Sequence

from ..core.job import JobSpec
from ..core.policies import PolicySpec, parse_policy
from ._compat import BATCH_REPLACEMENT, warn_once
from .cluster import ClusterEvent
from .engine import Engine, SimParams, SimResult

__all__ = ["SimParams", "SimResult", "DFRSSimulator", "simulate"]


class DFRSSimulator(Engine):
    """DFRS-only front-end: rejects batch policies like the original class."""

    def __init__(
        self,
        specs: Sequence[JobSpec],
        policy: PolicySpec | str,
        params: Optional[SimParams] = None,
        cluster_events: Sequence[ClusterEvent] = (),
    ):
        warn_once("repro.sched.simulator.DFRSSimulator", BATCH_REPLACEMENT)
        spec = parse_policy(policy) if isinstance(policy, str) else policy
        if spec.is_batch:
            raise ValueError("use repro.sched.batch for FCFS/EASY")
        super().__init__(specs, spec, params, cluster_events)


def simulate(
    specs: Sequence[JobSpec],
    policy: str,
    params: Optional[SimParams] = None,
    cluster_events: Sequence[ClusterEvent] = (),
) -> SimResult:
    """Run one policy (DFRS or FCFS/EASY) on a trace via the unified engine.

    Cluster events are ignored for the batch baselines (they do not model
    failures), matching the historical behaviour of this entry point.
    """
    warn_once("repro.sched.simulator.simulate", BATCH_REPLACEMENT)
    return Engine(specs, policy, params, cluster_events).run()
