"""Discrete-event simulator for DFRS policies (paper §5.1).

Fluid model: between scheduling events every running job j progresses at its
yield y_j (virtual time vt += y_j * dt); job j completes when vt reaches its
processing time p_j.  Every preemption-resume and every migration costs a
*rescheduling penalty* (default 5 min) of zero progress — policies are
unaware of the penalty (§5.1).  Bandwidth accounting follows the paper's
pause/resume pessimism: a pause writes the job's memory image to storage,
a resume reads it back, a migration does both for the tasks that moved.

Node failures / elastic capacity changes are injected as ClusterEvents: a
failure force-preempts resident jobs (their progress is preserved — the
checkpoint/restart analogue on the TPU adaptation) and shrinks the pool.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.job import (
    COMPLETED,
    PAUSED,
    PENDING,
    RUNNING,
    JobSpec,
    JobState,
    NodePool,
)
from ..core.greedy import greedy_place, greedy_p, greedy_pm
from ..core.mcb8 import mcb8
from ..core.policies import PolicySpec, parse_policy
from ..core.stretch_opt import improve_avg_stretch, improve_max_stretch, mcb8_stretch
from ..core.yield_alloc import allocate
from .cluster import ClusterEvent

__all__ = ["SimParams", "SimResult", "DFRSSimulator", "simulate"]

_EPS = 1e-9


@dataclass
class SimParams:
    n_nodes: int = 128
    penalty: float = 300.0          # rescheduling penalty (s), §5.1
    period: float = 600.0           # periodic MCB8 period (default 2x penalty)
    node_mem_gb: float = 8.0        # bandwidth accounting only
    stretch_tau: float = 10.0       # bounded-stretch threshold (s)
    max_events: int = 20_000_000


@dataclass
class SimResult:
    policy: str
    completions: Dict[int, float]
    stretches: Dict[int, float]
    max_stretch: float
    mean_stretch: float
    n_pmtn: int
    n_mig: int
    pmtn_per_job: float
    mig_per_job: float
    pmtn_per_hour: float
    mig_per_hour: float
    bytes_moved_gb: float
    bandwidth_gbps: float
    underutilization: float         # normalized (§6.4)
    makespan: float
    events: int


class DFRSSimulator:
    def __init__(
        self,
        specs: Sequence[JobSpec],
        policy: PolicySpec | str,
        params: Optional[SimParams] = None,
        cluster_events: Sequence[ClusterEvent] = (),
    ):
        self.params = params or SimParams()
        self.policy = parse_policy(policy) if isinstance(policy, str) else policy
        if self.policy.is_batch:
            raise ValueError("use repro.sched.batch for FCFS/EASY")
        self.specs = sorted(specs, key=lambda s: (s.release, s.jid))
        self.cluster_events = sorted(cluster_events, key=lambda e: e.time)
        self.jobs: Dict[int, JobState] = {}
        self.pool = NodePool(self.params.n_nodes)
        self.alive = np.ones(self.params.n_nodes, dtype=bool)
        self.now = 0.0
        self.bytes_moved_gb = 0.0
        self.n_pmtn = 0
        self.n_mig = 0
        self._util_integral = 0.0      # ∫ u dt
        self._demand_integral = 0.0    # ∫ min(P, D) dt
        self._events = 0

    # ------------------------------------------------------------------ #
    # accounting helpers                                                  #
    # ------------------------------------------------------------------ #
    def _job_mem_gb(self, spec: JobSpec, n_tasks: Optional[int] = None) -> float:
        k = spec.n_tasks if n_tasks is None else n_tasks
        return k * spec.mem_req * self.params.node_mem_gb

    def _pause(self, js: JobState) -> None:
        assert js.status == RUNNING
        self.pool.remove(js.spec, js.mapping)
        js.status = PAUSED
        js.mapping = None
        js.yld = 0.0
        js.n_pmtn += 1
        self.n_pmtn += 1
        self.bytes_moved_gb += self._job_mem_gb(js.spec)  # save image

    def _start(self, js: JobState, mapping: List[int]) -> None:
        assert js.status in (PENDING, PAUSED)
        resume = js.status == PAUSED
        self.pool.place(js.spec, mapping)
        js.status = RUNNING
        js.mapping = list(mapping)
        js.started_once = True
        if resume:
            js.penalty_until = self.now + self.params.penalty
            self.bytes_moved_gb += self._job_mem_gb(js.spec)  # restore image

    def _migrate_many(self, pairs: Sequence[Tuple[JobState, List[int]]]) -> None:
        """Transactionally migrate several running jobs: the new mappings are
        feasible *as a set* (computed against a pool copy), so all removals
        must happen before any placement."""
        moves = []
        for js, new_mapping in pairs:
            assert js.status == RUNNING
            old = _node_multiset(js.mapping)
            new = _node_multiset(new_mapping)
            moved = js.spec.n_tasks - sum(
                min(old.get(n, 0), new.get(n, 0)) for n in old)
            moves.append((js, new_mapping, moved))
        for js, _, _ in moves:
            self.pool.remove(js.spec, js.mapping)
        for js, new_mapping, moved in moves:
            self.pool.place(js.spec, new_mapping)
            js.mapping = list(new_mapping)
            if moved == 0:
                continue
            js.n_mig += 1
            self.n_mig += 1
            js.penalty_until = self.now + self.params.penalty
            self.bytes_moved_gb += 2.0 * self._job_mem_gb(js.spec, moved)

    def _complete(self, js: JobState) -> None:
        self.pool.remove(js.spec, js.mapping)
        js.status = COMPLETED
        js.mapping = None
        js.yld = 0.0
        js.completed_at = self.now

    # ------------------------------------------------------------------ #
    # policy actions                                                      #
    # ------------------------------------------------------------------ #
    def _running(self) -> List[JobState]:
        return [j for j in self.jobs.values() if j.status == RUNNING]

    def _uncompleted(self) -> List[JobState]:
        return [j for j in self.jobs.values() if j.status != COMPLETED]

    def _pinned(self) -> Dict[int, List[int]]:
        """Jobs protected from remapping by MINVT/MINFT (§4.3)."""
        spec = self.policy
        pins: Dict[int, List[int]] = {}
        if spec.minvt is None and spec.minft is None:
            return pins
        for js in self._running():
            if spec.minvt is not None and js.vt < spec.minvt:
                pins[js.spec.jid] = list(js.mapping)
            elif spec.minft is not None and js.flow_time(self.now) < spec.minft:
                pins[js.spec.jid] = list(js.mapping)
        return pins

    def _apply_mcb8(self) -> None:
        cands = self._uncompleted()
        if not cands:
            return
        res = mcb8(
            cands, self.params.n_nodes, self.now,
            pinned=self._pinned(), alive=self.alive,
        )
        self._apply_global_mapping(res.mappings, cands)

    def _apply_global_mapping(
        self, mappings: Dict[int, List[int]], cands: Sequence[JobState]
    ) -> None:
        """Apply a from-scratch MCB8 mapping transactionally: the mapping is
        feasible as a whole, so all removals happen before any placement."""
        migrations: List[Tuple[JobState, List[int]]] = []
        starts: List[Tuple[JobState, List[int]]] = []
        for js in cands:
            new_map = mappings.get(js.spec.jid)
            if js.status == RUNNING:
                if new_map is None:
                    self._pause(js)
                elif _node_multiset(js.mapping) != _node_multiset(new_map):
                    migrations.append((js, new_map))
            elif new_map is not None:
                starts.append((js, new_map))
        self._migrate_many(migrations)
        for js, new_map in starts:
            self._start(js, new_map)

    def _apply_stretch_per(self) -> None:
        cands = self._uncompleted()
        if not cands:
            return
        res = mcb8_stretch(
            cands, self.params.n_nodes, self.now, self.params.period,
            pinned=self._pinned(), alive=self.alive,
        )
        self._apply_global_mapping(res.mappings, cands)
        running = self._running()
        mappings = {js.spec.jid: js.mapping for js in running}
        ylds = {js.spec.jid: res.yields.get(js.spec.jid, 0.0) for js in running}
        if self.policy.opt == "MAX":
            ylds = improve_max_stretch(
                running, mappings, ylds, self.params.n_nodes, self.now, self.params.period
            )
        else:
            ylds = improve_avg_stretch(
                running, mappings, ylds, self.params.n_nodes, self.now, self.params.period
            )
        for js in running:
            js.yld = float(min(1.0, ylds.get(js.spec.jid, 0.0)))
        self._stretch_yields_set = True

    def _on_submit(self, js: JobState) -> None:
        kind = self.policy.on_submit
        if kind is None:
            return
        if kind == "greedy":
            mapping = greedy_place(self.pool.copy(), js.spec)
            if mapping is not None:
                self._start(js, mapping)
            return
        if kind in ("greedyP", "greedyPM"):
            fn = greedy_p if kind == "greedyP" else greedy_pm
            adm = fn(self.pool.copy(), js.spec, self._running(), self.now)
            if adm.mapping is None:
                return
            by_jid = {j.spec.jid: j for j in self._running()}
            for jid in adm.paused:
                self._pause(by_jid[jid])
            self._migrate_many(
                [(by_jid[jid], new_map) for jid, new_map in adm.moved.items()])
            self._start(js, adm.mapping)
            return
        if kind == "mcb8":
            self._apply_mcb8()
            return
        raise ValueError(kind)

    def _on_complete(self) -> None:
        kind = self.policy.on_complete
        if kind is None:
            return
        if kind == "greedy":
            waiting = sorted(
                (j for j in self.jobs.values() if j.status in (PENDING, PAUSED)),
                key=lambda j: j.priority_key(self.now),
                reverse=True,
            )
            for js in waiting:
                mapping = greedy_place(self.pool.copy(), js.spec)
                if mapping is not None:
                    self._start(js, mapping)
            return
        if kind == "mcb8":
            self._apply_mcb8()
            return
        raise ValueError(kind)

    def _reallocate(self) -> None:
        """Recompute yields for running jobs (§4.6) unless /stretch-per just
        set them explicitly."""
        if getattr(self, "_stretch_yields_set", False):
            self._stretch_yields_set = False
            return
        running = self._running()
        specs = [js.spec for js in running]
        maps = [js.mapping for js in running]
        opt = self.policy.opt if self.policy.opt in ("MIN", "AVG") else "MIN"
        ylds = allocate(specs, maps, self.params.n_nodes, opt=opt)
        for js, y in zip(running, ylds):
            js.yld = float(y)

    # ------------------------------------------------------------------ #
    # cluster (failure / elastic) events                                  #
    # ------------------------------------------------------------------ #
    def _apply_cluster_event(self, ev: ClusterEvent) -> None:
        if ev.kind == "fail":
            for node in ev.nodes:
                if not self.alive[node]:
                    continue
                self.alive[node] = False
                # force-preempt every job with a task on the node
                for js in list(self._running()):
                    if node in (js.mapping or ()):
                        self._pause(js)
                # node can no longer host anything (0.0, not a negative
                # sentinel: NodePool.place validates global non-negativity)
                self.pool.mem_free[node] = 0.0
                self.pool.load[node] = np.inf
        elif ev.kind == "join":
            for node in ev.nodes:
                if self.alive[node]:
                    continue
                self.alive[node] = True
                self.pool.mem_free[node] = 1.0
                self.pool.load[node] = 0.0
        else:
            raise ValueError(ev.kind)

    # ------------------------------------------------------------------ #
    # main loop                                                           #
    # ------------------------------------------------------------------ #
    def _next_completion(self) -> Tuple[float, Optional[JobState]]:
        best_t, best = math.inf, None
        for js in self._running():
            if js.yld <= _EPS:
                continue
            t0 = max(self.now, js.penalty_until)
            t = t0 + js.remaining_vt() / js.yld
            if t < best_t:
                best_t, best = t, js
        return best_t, best

    def _advance(self, t_next: float) -> None:
        """Advance virtual times + utilization integrals to t_next."""
        if t_next <= self.now:
            return
        demand = sum(
            j.spec.n_tasks * j.spec.cpu_need for j in self._uncompleted()
        )
        cap = float(self.alive.sum())
        # u(t) is piecewise-constant except at penalty expiries inside the
        # window; integrate exactly by splitting at those points.
        cuts = sorted(
            {self.now, t_next}
            | {
                js.penalty_until
                for js in self._running()
                if self.now < js.penalty_until < t_next
            }
        )
        for a, b in zip(cuts[:-1], cuts[1:]):
            u = sum(
                js.yld * js.spec.cpu_need * js.spec.n_tasks
                for js in self._running()
                if js.penalty_until <= a + _EPS
            )
            self._util_integral += u * (b - a)
            self._demand_integral += min(cap, demand) * (b - a)
        for js in self._running():
            eff = max(0.0, t_next - max(self.now, js.penalty_until))
            js.vt = min(js.spec.proc_time, js.vt + js.yld * eff)
        self.now = t_next

    def run(self) -> SimResult:
        p = self.params
        arrivals = list(self.specs)
        ai = 0
        cev = list(self.cluster_events)
        ci = 0
        periodic = self.policy.periodic is not None
        next_tick = math.inf
        if periodic and arrivals:
            next_tick = arrivals[0].release + p.period

        while True:
            self._events += 1
            if self._events > p.max_events:
                raise RuntimeError("simulator event budget exceeded")
            t_arr = arrivals[ai].release if ai < len(arrivals) else math.inf
            t_cev = cev[ci].time if ci < len(cev) else math.inf
            t_done, _ = self._next_completion()
            live = any(js.status != COMPLETED for js in self.jobs.values())
            t_tick = next_tick if (periodic and (live or ai < len(arrivals))) else math.inf
            t_next = min(t_arr, t_done, t_tick, t_cev)
            if math.isinf(t_next):
                break
            self._advance(t_next)

            acted = False
            # 1) completions
            while True:
                finished = [
                    js for js in self._running()
                    if js.remaining_vt() <= _EPS and js.yld > _EPS
                ]
                if not finished:
                    break
                for js in finished:
                    self._complete(js)
                self._on_complete()
                acted = True
            # 2) cluster events
            while ci < len(cev) and cev[ci].time <= self.now + _EPS:
                self._apply_cluster_event(cev[ci])
                ci += 1
                acted = True
            # 3) arrivals
            while ai < len(arrivals) and arrivals[ai].release <= self.now + _EPS:
                spec = arrivals[ai]
                ai += 1
                js = JobState(spec=spec)
                self.jobs[spec.jid] = js
                self._on_submit(js)
                acted = True
            # 4) periodic tick
            if periodic and self.now + _EPS >= next_tick:
                if self.policy.periodic == "mcb8":
                    self._apply_mcb8()
                else:
                    self._apply_stretch_per()
                next_tick += p.period
                acted = True
            if acted:
                self._reallocate()

        return self._result()

    # ------------------------------------------------------------------ #
    def _result(self) -> SimResult:
        from .metrics import bounded_stretch

        p = self.params
        completions = {}
        stretches = {}
        for jid, js in self.jobs.items():
            if js.completed_at is None:
                raise RuntimeError(f"job {jid} never completed (deadlock?)")
            completions[jid] = js.completed_at
            t = js.completed_at - js.spec.release
            stretches[jid] = bounded_stretch(t, js.spec.proc_time, p.stretch_tau)
        first = min(s.release for s in self.specs) if self.specs else 0.0
        last = max(completions.values()) if completions else 0.0
        makespan = max(0.0, last - first)
        hours = max(makespan / 3600.0, 1e-9)
        total_work = sum(s.total_work for s in self.specs) or 1.0
        svals = list(stretches.values())
        return SimResult(
            policy=self.policy.name,
            completions=completions,
            stretches=stretches,
            max_stretch=max(svals) if svals else 0.0,
            mean_stretch=float(np.mean(svals)) if svals else 0.0,
            n_pmtn=self.n_pmtn,
            n_mig=self.n_mig,
            pmtn_per_job=self.n_pmtn / max(1, len(self.specs)),
            mig_per_job=self.n_mig / max(1, len(self.specs)),
            pmtn_per_hour=self.n_pmtn / hours,
            mig_per_hour=self.n_mig / hours,
            bytes_moved_gb=self.bytes_moved_gb,
            bandwidth_gbps=self.bytes_moved_gb / max(makespan, 1e-9),
            underutilization=(self._demand_integral - self._util_integral) / total_work,
            makespan=makespan,
            events=self._events,
        )


def _node_multiset(mapping: Sequence[int]) -> Dict[int, int]:
    out: Dict[int, int] = {}
    for n in mapping:
        out[n] = out.get(n, 0) + 1
    return out


def simulate(
    specs: Sequence[JobSpec],
    policy: str,
    params: Optional[SimParams] = None,
    cluster_events: Sequence[ClusterEvent] = (),
) -> SimResult:
    """Run one DFRS policy (or FCFS/EASY via repro.sched.batch) on a trace."""
    spec = parse_policy(policy)
    if spec.is_batch:
        from .batch import batch_schedule

        return batch_schedule(specs, spec.name, params)
    return DFRSSimulator(specs, spec, params, cluster_events).run()
