"""Cluster model: node failures and elastic capacity events.

On the TPU adaptation a "fail" is a chip/host loss — every resident job is
force-preempted (checkpoint image already on network storage; the DFRS
rescheduling penalty models restore + recompile) and the scheduler's node
pool shrinks; a "join" restores capacity.  DFRS needs no special-case logic:
failures reuse the pause path and the next scheduling event re-places work,
which is exactly how the paper's preemption/migration machinery doubles as
fault tolerance.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["ClusterEvent", "failure_trace"]

_KINDS = ("fail", "join", "cancel", "resize")


@dataclass(frozen=True)
class ClusterEvent:
    time: float
    kind: str                        # "fail" | "join" | "cancel" | "resize"
    nodes: Tuple[int, ...] = ()      # fail/join targets
    jids: Tuple[int, ...] = ()       # cancel/resize targets (job ids)
    value: Optional[float] = None    # resize: new n_tasks

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(self.kind)
        if self.kind in ("fail", "join") and not self.nodes:
            raise ValueError(f"{self.kind} event needs nodes")
        if self.kind in ("cancel", "resize") and not self.jids:
            raise ValueError(f"{self.kind} event needs jids")
        if self.kind == "resize" and self.value is None:
            raise ValueError("resize event needs value (new n_tasks)")


def failure_trace(
    n_nodes: int,
    horizon: float,
    mtbf: float,
    repair: float,
    seed: int = 0,
) -> List[ClusterEvent]:
    """Poisson node failures with deterministic repair time.

    ``mtbf`` is the per-cluster mean time between failures (s); each failure
    hits one uniformly random node and is repaired after ``repair`` seconds.
    """
    rng = np.random.default_rng(seed)
    events: List[ClusterEvent] = []
    t = 0.0
    while True:
        t += float(rng.exponential(mtbf))
        if t >= horizon:
            break
        node = int(rng.integers(n_nodes))
        events.append(ClusterEvent(t, "fail", (node,)))
        events.append(ClusterEvent(t + repair, "join", (node,)))
    events.sort(key=lambda e: e.time)
    return events
