"""End-to-end LM training: a smollm-family model for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses the reduced smollm config on this CPU container (pass --full-arch on a
real TPU slice to train the published config under the production mesh via
repro.launch.train).  Demonstrates the full substrate: deterministic data,
AdamW + schedule, grad accumulation, async checkpointing, loss curve.
"""
import argparse
import sys
import time

import jax

from repro.configs import get_reduced
from repro.train import checkpoint as ckpt
from repro.train.data import data_for
from repro.train.optimizer import OptConfig
from repro.train.trainer import init_train_state, make_train_step


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = get_reduced("smollm-360m")
    print(f"arch: {cfg.name} (reduced: {cfg.n_layers}L d={cfg.d_model}, "
          f"{cfg.param_count()/1e6:.1f}M params)")
    opt = OptConfig(lr=1e-3, warmup_steps=args.steps // 10,
                    total_steps=args.steps)
    data = data_for(cfg, args.batch, args.seq, seed=0)
    step_fn = jax.jit(make_train_step(cfg, opt, microbatches=args.microbatches))
    state = init_train_state(cfg, jax.random.PRNGKey(0))

    t0, first = time.time(), None
    for i in range(args.steps):
        state, m = step_fn(state, data.batch_for_step(i))
        loss = float(m["loss"])
        first = first if first is not None else loss
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {loss:.4f}  lr {float(m['lr']):.2e}  "
                  f"gnorm {float(m['grad_norm']):.2f}")
        if (i + 1) % 100 == 0:
            ckpt.save_async(args.ckpt_dir, i + 1, state)
    ckpt.wait_pending()
    dt = time.time() - t0
    print(f"\n{args.steps} steps in {dt:.0f}s "
          f"({dt/args.steps*1e3:.0f} ms/step); "
          f"loss {first:.3f} -> {loss:.3f}; "
          f"checkpoint at step {ckpt.latest_step(args.ckpt_dir)}")
    assert loss < first, "training must reduce the loss"
    return 0


if __name__ == "__main__":
    sys.exit(main())
