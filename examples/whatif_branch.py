"""What-if branching: compare policies from an identical *mid-run* state.

    PYTHONPATH=src python examples/whatif_branch.py

A closed-world sweep can only compare policies from t=0.  A streaming
session can do something no batch run can: run the cluster under one
policy, stop at a live mid-run moment — queue built up, jobs running at
fractional yields, a rack freshly failed — snapshot it, and fork the
*identical* state under several candidate policies to see which one digs
out of that exact situation best.

The script opens a session under GreedyP, lets load build, injects a rack
failure conditioned on the observed queue, snapshots at the worst of it,
then branches the snapshot across four policies with
``api.run_branches``.  The snapshot's own policy continues bit-identically
(``exact_continuation``); the others adopt the live state.
"""
import sys

from repro import api


def main() -> int:
    n_nodes = 32
    ses = api.open_session(n_nodes, "GreedyP */OPT=MIN")
    ses.submit(api.WorkloadSpec("lublin", n_jobs=150, n_nodes=n_nodes,
                                seed=7, load=1.1))

    # let the cluster warm up to a genuinely busy moment (observed, not
    # scheduled: step until a third of the jobs are done and work remains)
    while not ses.exhausted:
        ses.step(25)
        obs = ses.observe()
        if obs["n_completed"] >= 30 and obs["n_running"] > 0:
            break
    print(f"t={obs['t']:.0f}s  running={obs['n_running']} "
          f"queued={obs['queue_depth']} completed={obs['n_completed']}")
    rack = list(range(n_nodes // 4))
    ses.inject({"kind": "fail", "t": ses.now + 60.0, "nodes": rack})
    ses.inject({"kind": "join", "t": ses.now + 1800.0, "nodes": rack})
    ses.step_until(ses.now + 600.0)          # 10 min into the outage
    obs = ses.observe()
    print(f"t={obs['t']:.0f}s  rack down: alive={obs['alive_nodes']} "
          f"queued={obs['queue_depth']} preemptions={obs['n_pmtn']}\n")

    snap = ses.snapshot()
    print(f"forking snapshot {snap.fingerprint[:12]}… at t={snap.time:.0f}s")
    res = api.run_branches(snap, [
        "GreedyP */OPT=MIN",                 # the incumbent, continued
        "GreedyPM */OPT=MIN",                # + migration
        "GreedyPM */per/OPT=MIN/MINVT=600",  # + periodic repacking
        "EASY",                              # hand the mess to the baseline
    ])
    print(f"\n{'policy':36s} {'cont.':>5s} {'max stretch':>12s} "
          f"{'mean':>7s} {'mig/job':>8s}")
    for rec in res.records:
        cont = "yes" if rec["exact_continuation"] else "fork"
        print(f"{rec['policy']:36s} {cont:>5s} {rec['max_stretch']:12.2f} "
              f"{rec['mean_stretch']:7.2f} {rec['mig_per_job']:8.2f}")
    print("\nEvery branch resumed from the same live queue, the same "
          "fractional yields,\nthe same dead rack — only the policy "
          "differs from here on out.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
