"""Fault tolerance, end to end: chip failures at both layers of the stack.

    PYTHONPATH=src python examples/elastic_failover.py

1. Job level: a training run is killed twice mid-flight and restarts from
   the newest checkpoint; deterministic data makes the recovered loss curve
   bit-identical to an uninterrupted run.
2. Cluster level: DFRS absorbs node failures/rejoins — a failure is just a
   forced preemption, so the same GreedyP/MCB8 machinery re-places the
   affected jobs (elastic scaling uses the same path).
"""
import sys
import tempfile

import jax

from repro.api import (SimParams, apply_scenario, max_stretch_lower_bound,
                       simulate)
from repro.configs import get_reduced
from repro.train.data import data_for
from repro.train.ft import FailureInjector, run_restartable
from repro.train.optimizer import OptConfig
from repro.train.trainer import init_train_state, make_train_step
from repro.workloads.lublin import lublin_trace, scale_to_load


def job_level() -> None:
    print("=== 1. job-level failover (checkpoint/restart) ===")
    cfg = get_reduced("smollm-360m")
    opt = OptConfig(lr=1e-3, warmup_steps=5, total_steps=60)
    step = jax.jit(make_train_step(cfg, opt))
    data = data_for(cfg, 4, 64)
    mk = lambda: init_train_state(cfg, jax.random.PRNGKey(0))

    with tempfile.TemporaryDirectory() as d:
        clean = run_restartable(step, mk, data.batch_for_step, 40, d,
                                ckpt_every=10)
    with tempfile.TemporaryDirectory() as d:
        faulty = run_restartable(step, mk, data.batch_for_step, 40, d,
                                 ckpt_every=10,
                                 injector=FailureInjector(at_steps=(13, 27)))
    print(f"clean : final loss {clean.losses[-1]:.5f}, 0 restarts")
    print(f"faulty: final loss {faulty.losses[-1]:.5f}, "
          f"{faulty.n_restarts} restarts, resumed from {faulty.restored_from}")
    match = abs(clean.losses[-1] - faulty.losses[-1]) < 1e-5
    print(f"recovered trajectory identical: {match}\n")


def cluster_level() -> None:
    print("=== 2. cluster-level failover (DFRS absorbs node failures) ===")
    n = 32
    specs = scale_to_load(lublin_trace(200, n, seed=3), n, 0.6)
    bound = max_stretch_lower_bound(specs, n)
    # named scenario scripts replace hand-rolled ClusterEvent lists:
    # "rack_failure" kills a quarter of the nodes mid-trace and rejoins them
    for scenario in ("baseline", "rack_failure", "rolling_failures"):
        sspecs, events = apply_scenario(scenario, specs, n, seed=3)
        r = simulate(sspecs, "GreedyPM */per/OPT=MIN/MINVT=600",
                     SimParams(n_nodes=n), cluster_events=events)
        print(f"{scenario:24s} max-stretch {r.max_stretch:8.1f} "
              f"(x{r.max_stretch/bound:5.1f} bound) "
              f"pmtn {r.n_pmtn:4d} mig {r.n_mig:4d}")
    print("all jobs completed in every run — failures cost stretch, "
          "never work lost.")


def main() -> int:
    job_level()
    cluster_level()
    return 0


if __name__ == "__main__":
    sys.exit(main())
