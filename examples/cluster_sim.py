"""DFRS scheduling the framework's own TPU workloads.

    PYTHONPATH=src python examples/cluster_sim.py

Job types come from the multi-pod dry-run artifacts: each (arch x shape)
cell's roofline terms give its chip-fraction "CPU need" (a bandwidth-bound
decode cannot saturate the MXU) and HBM footprint.  DFRS then packs trainers
and decoders onto the same pod slices — the paper's fractional-sharing idea,
applied to this repo's own models.
"""
import sys

from repro.api import SimParams, max_stretch_lower_bound, simulate
from repro.workloads.jobgen import tpu_job_types, tpu_trace

sys.path.insert(0, ".")
from benchmarks.roofline import jobgen_records  # noqa: E402


def main() -> int:
    recs = jobgen_records("single")
    if not recs:
        print("no dry-run artifacts found; run "
              "`PYTHONPATH=src python -m repro.launch.dryrun --all` first")
        return 1
    types = tpu_job_types(recs, chips_per_task=16)
    print(f"{len(types)} TPU job types from {len(recs)} dry-run cells; e.g.:")
    for t in types[:6]:
        print(f"  {t.name:38s} chip-frac {t.cpu_need:.2f} "
              f"hbm {t.mem_req:.2f} slices {t.n_tasks}")

    specs = tpu_trace(types, n_jobs=150, n_nodes=64, seed=7, target_load=0.6)
    bound = max_stretch_lower_bound(specs, 64)
    print(f"\n150 jobs on 64 pod-slices (load 0.6); bound {bound:.2f}")
    for pol in ("FCFS", "EASY", "GreedyPM */per/OPT=MIN/MINVT=600"):
        r = simulate(specs, pol, SimParams(n_nodes=64))
        print(f"{pol:40s} max-stretch {r.max_stretch:9.1f} "
              f"(x{r.max_stretch/bound:6.1f} bound)  underut "
              f"{r.underutilization:.3f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
