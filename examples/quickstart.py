"""Quickstart: schedule one synthetic workload with DFRS vs batch scheduling.

    PYTHONPATH=src python examples/quickstart.py

Everything through the ``repro.api`` facade: a declarative workload
(Lublin-Feitelson trace at load 0.7), the Theorem-1 lower bound, the batch
baselines, the paper's best DFRS policy, and one policy the paper's grammar
cannot spell — the registered hybrid composition ``EASY+OPT=MIN``
(fractional backfilling arbitrated by OPT=MIN water-filling).  The
max-bounded-stretch comparison is the paper's headline result in one screen.
"""
import sys

from repro import api


def main() -> int:
    workload = api.WorkloadSpec("lublin", n_jobs=300, n_nodes=64, seed=42,
                                load=0.7)
    print(f"cluster: {workload.n_nodes} nodes; workload: {workload.name}")
    specs = api.make_trace(workload)
    bound = api.max_stretch_lower_bound(specs, workload.n_nodes)
    print(f"Theorem-1 lower bound on optimal max stretch: {bound:.2f}\n")

    policies = [
        "FCFS",
        "EASY",
        "EASY+OPT=MIN",                         # registered hybrid composition
        "GreedyP */OPT=MIN",
        "GreedyPM */per/OPT=MIN/MINVT=600",
    ]
    print(f"{'policy':40s} {'max stretch':>12s} {'vs bound':>9s} "
          f"{'pmtn/job':>9s} {'mig/job':>8s} {'underut':>8s}")
    for pol in policies:
        r = api.simulate(workload, pol)
        print(f"{pol:40s} {r.max_stretch:12.1f} {r.max_stretch/bound:9.1f} "
              f"{r.pmtn_per_job:9.2f} {r.mig_per_job:8.2f} "
              f"{r.underutilization:8.3f}")
    print("\nDFRS (fractional, migratable allocations driven by max-min yield)"
          "\nbeats batch scheduling on stretch by orders of magnitude.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
