"""Quickstart: schedule one synthetic workload with DFRS vs batch scheduling.

    PYTHONPATH=src python examples/quickstart.py

Generates a Lublin-Feitelson trace at load 0.7, computes the Theorem-1 lower
bound, runs FCFS / EASY / the paper's best DFRS policy, and prints the
max-bounded-stretch comparison — the paper's headline result in one screen.
"""
import sys

from repro.core.bound import max_stretch_lower_bound
from repro.sched.simulator import SimParams, simulate
from repro.workloads.lublin import lublin_trace, scale_to_load


def main() -> int:
    n_nodes, n_jobs, load = 64, 300, 0.7
    print(f"cluster: {n_nodes} nodes; workload: {n_jobs} jobs at load {load}")
    specs = scale_to_load(lublin_trace(n_jobs, n_nodes, seed=42), n_nodes, load)
    bound = max_stretch_lower_bound(specs, n_nodes)
    print(f"Theorem-1 lower bound on optimal max stretch: {bound:.2f}\n")

    policies = [
        "FCFS",
        "EASY",
        "GreedyP */OPT=MIN",
        "GreedyPM */per/OPT=MIN/MINVT=600",
    ]
    print(f"{'policy':40s} {'max stretch':>12s} {'vs bound':>9s} "
          f"{'pmtn/job':>9s} {'mig/job':>8s} {'underut':>8s}")
    for pol in policies:
        r = simulate(specs, pol, SimParams(n_nodes=n_nodes))
        print(f"{pol:40s} {r.max_stretch:12.1f} {r.max_stretch/bound:9.1f} "
              f"{r.pmtn_per_job:9.2f} {r.mig_per_job:8.2f} "
              f"{r.underutilization:8.3f}")
    print("\nDFRS (fractional, migratable allocations driven by max-min yield)"
          "\nbeats batch scheduling on stretch by orders of magnitude.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
