"""Scenario sweep in one screen: a (workload × policy × scenario) grid
through the parallel sweep subsystem.

    PYTHONPATH=src python examples/sweep_grid.py

Builds 20 cells — two workloads (synthetic Lublin, HPC2N-like), five
policies (both batch baselines, two DFRS policies, and the registered
hybrid composition ``EASY+OPT=MIN``), two cluster scenarios (baseline,
rack failure) — fans them over 4 worker processes via ``repro.api.sweep``,
and prints the per-policy aggregates.  The on-disk record cache makes
re-runs incremental: interrupt the sweep, run again, and only the missing
cells are simulated.
"""
import sys

from repro import api

CACHE = "experiments/results/sweep_grid_cache.json"
ARTIFACT = "experiments/results/sweep_grid.json"


def main() -> int:
    workloads = [
        api.WorkloadSpec("lublin", n_jobs=150, n_nodes=32, seed=0, load=0.7),
        api.WorkloadSpec("hpc2n", n_jobs=150, n_nodes=128, seed=0),
    ]
    policies = [
        "FCFS",
        "EASY",
        "EASY+OPT=MIN",
        "GreedyP */OPT=MIN",
        "GreedyPM */per/OPT=MIN/MINVT=600",
    ]
    scenarios = ["baseline", "rack_failure"]
    n_cells = len(workloads) * len(policies) * len(scenarios)
    print(f"sweeping {n_cells} cells "
          f"({len(workloads)} workloads x {len(policies)} policies x "
          f"{len(scenarios)} scenarios) on 4 workers ...")
    res = api.sweep(workloads, policies, scenarios, n_workers=4,
                    compute_bound=True, cache_path=CACHE, json_path=ARTIFACT)
    print(f"done: {res.wall_s:.1f}s, {res.cells_per_sec:.2f} cells/s "
          f"(cache: {CACHE})\n")

    print(f"{'policy':36s} {'scenario':14s} {'mean deg':>9s} {'max deg':>9s}")
    for policy in policies:
        for sc in scenarios:
            recs = res.filter(policy=policy, scenario=sc)
            d = res.values("degradation", policy=policy, scenario=sc)
            note = "" if all(r["scenario_applied"] for r in recs) \
                else "  (events ignored: batch)"
            print(f"{policy:36s} {sc:14s} {d.mean():9.1f} {d.max():9.1f}{note}")
    print(f"\nfull records: {ARTIFACT}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
