"""Scenario sweep in one screen: a (workload × policy × scenario) grid
through the parallel sweep subsystem.

    PYTHONPATH=src python examples/sweep_grid.py

Builds 16 cells — two workloads (synthetic Lublin, HPC2N-like), four
policies (both batch baselines + two DFRS policies), two cluster scenarios
(baseline, rack failure) — fans them over 4 worker processes, writes the
JSON artifact, and prints the per-policy aggregates.  This is the paper's
§6 evaluation methodology as a single API call.
"""
import sys

from repro.sched.sweep import grid, run_grid
from repro.workloads.registry import WorkloadSpec


def main() -> int:
    workloads = [
        WorkloadSpec("lublin", n_jobs=150, n_nodes=32, seed=0, load=0.7),
        WorkloadSpec("hpc2n", n_jobs=150, n_nodes=128, seed=0),
    ]
    policies = [
        "FCFS",
        "EASY",
        "GreedyP */OPT=MIN",
        "GreedyPM */per/OPT=MIN/MINVT=600",
    ]
    scenarios = ["baseline", "rack_failure"]
    cells = grid(workloads, policies, scenarios)
    print(f"sweeping {len(cells)} cells "
          f"({len(workloads)} workloads x {len(policies)} policies x "
          f"{len(scenarios)} scenarios) on 4 workers ...")
    res = run_grid(cells, n_workers=4, compute_bound=True,
                   json_path="experiments/results/sweep_grid.json")
    print(f"done: {res.wall_s:.1f}s, {res.cells_per_sec:.2f} cells/s\n")

    print(f"{'policy':36s} {'scenario':14s} {'mean deg':>9s} {'max deg':>9s}")
    for policy in policies:
        for sc in scenarios:
            recs = res.filter(policy=policy, scenario=sc)
            d = res.values("degradation", policy=policy, scenario=sc)
            note = "" if all(r["scenario_applied"] for r in recs) \
                else "  (events ignored: batch)"
            print(f"{policy:36s} {sc:14s} {d.mean():9.1f} {d.max():9.1f}{note}")
    print("\nfull records: experiments/results/sweep_grid.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
