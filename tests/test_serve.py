"""Service-layer tests: the multi-tenant SimSession server.

* registry durability: seq dedup / gap detection, write-ahead journal
  replay, snapshot-backed eviction → rehydration bit-identity, torn
  journal tails, snap-schema guards, checkpoint truncation;
* admission control: the credit formula and its decay, queue-full and
  over-budget refusals, weighted-DRF tenant ordering, the min-credit
  starvation floor;
* the live server (in-process ``ServerThread``): concurrent multi-tenant
  traffic parity against serial ``SimSession`` runs, eviction under
  ``max_live`` transparency, misbehaving-tenant credit collapse, seq
  dedup over the wire, close semantics, name validation;
* crash recovery: a real ``python -m repro serve`` subprocess killed with
  SIGKILL mid-workload, restarted, and re-driven — the recovered result
  must be bit-identical to an uninterrupted serial run;
* the two-writer atomic-write stress (concurrent writers, live reader,
  no torn reads, no leaked tmp files).
"""
import json
import os
import signal
import subprocess
import sys
import threading
import time

import pytest

from conftest import result_dict
from repro import api
from repro.core.ioutil import atomic_write_json
from repro.serve.admission import CreditParams, FairQueue, TenantState
from repro.serve.client import Client, ServeError
from repro.serve.protocol import (E_ADMISSION, E_BAD_REQUEST, E_OP_ERROR,
                                  E_OVER_BUDGET, E_SEQ_GAP, E_SESSION_CLOSED,
                                  E_UNKNOWN_SESSION, ProtocolError)
from repro.serve.registry import SessionRegistry, SessionStore
from repro.serve.server import ServeConfig, ServerThread

NODES = 16
POLICY = "GreedyP */OPT=MIN"


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def serial_result(policy=POLICY, jobs=30, seed=0, nodes=NODES,
                  until=None, inject=None):
    """The uninterrupted single-process reference run."""
    ses = api.open_session(nodes, policy)
    ses.submit(api.parse_workload("lublin", n_jobs=jobs, n_nodes=nodes,
                                  seed=seed))
    if until is not None:
        ses.step_until(until)
    if inject is not None:
        ses.inject(inject)
    ses.run_to_exhaustion()
    return result_dict(ses.result())


def norm_result(resp):
    """A server ``result`` payload, normalized for comparison against
    :func:`conftest.result_dict` (JSON round-trips dict keys to str)."""
    d = {k: v for k, v in resp.items()
         if k not in ("id", "ok", "partial", "sim_wall_s", "kind")}
    for k in ("completions", "stretches"):
        d[k] = {int(a): b for a, b in d[k].items()}
    return d


def registry_on(tmp_path, **kw):
    store = SessionStore(str(tmp_path / "store"))
    return SessionRegistry(store, **kw), store


OPEN = {"policy": POLICY, "nodes": NODES}
SUBMIT = {"workload": "lublin", "jobs": 30, "seed": 0, "nodes": NODES}


# --------------------------------------------------------------------------- #
# registry: seq discipline                                                     #
# --------------------------------------------------------------------------- #
def test_registry_seq_dedup_gap_and_close(tmp_path):
    reg, _ = registry_on(tmp_path)
    reg.apply_mutating("t", "s0", "open", OPEN, seq=0)
    reg.apply_mutating("t", "s0", "submit", SUBMIT, seq=1)

    # resending an applied seq is acknowledged without re-applying
    dup = reg.apply_mutating("t", "s0", "submit", SUBMIT, seq=1)
    assert dup == {"dup": True, "seq": 1, "applied_seq": 2}
    assert len(reg.entries[("t", "s0")].session.engine.state.specs) == 30

    # a seq from the future means an earlier op was lost
    with pytest.raises(ProtocolError) as ei:
        reg.apply_mutating("t", "s0", "step", {"n": 1}, seq=7)
    assert ei.value.code == E_SEQ_GAP

    # ops against a session never opened
    with pytest.raises(ProtocolError) as ei:
        reg.apply_mutating("t", "nope", "step", {"n": 1}, seq=0)
    assert ei.value.code == E_UNKNOWN_SESSION

    # re-opening an existing session is refused (unless it's a dup resend)
    with pytest.raises(ProtocolError) as ei:
        reg.apply_mutating("t", "s0", "open", OPEN, seq=2)
    assert ei.value.code == E_BAD_REQUEST
    assert reg.apply_mutating("t", "s0", "open", OPEN, seq=0)["dup"]

    # close consumes a seq; later ops are refused, resends still dedupe
    reg.apply_mutating("t", "s0", "close", {}, seq=2)
    with pytest.raises(ProtocolError) as ei:
        reg.apply_mutating("t", "s0", "step", {"n": 1}, seq=3)
    assert ei.value.code == E_SESSION_CLOSED
    assert reg.apply_mutating("t", "s0", "submit", SUBMIT, seq=1)["dup"]


def test_refused_ops_consume_no_seq(tmp_path):
    reg, _ = registry_on(tmp_path)
    reg.apply_mutating("t", "s0", "open", OPEN, seq=0)
    for _ in range(2):
        with pytest.raises(ProtocolError):
            reg.apply_mutating("t", "s0", "step", {"n": 1}, seq=9)
    assert reg.entries[("t", "s0")].seq == 1


# --------------------------------------------------------------------------- #
# registry: eviction → rehydration bit-identity                                #
# --------------------------------------------------------------------------- #
def test_evict_rehydrate_bit_identical(tmp_path):
    reg, _ = registry_on(tmp_path)
    reg.apply_mutating("t", "s0", "open", OPEN, seq=0)
    reg.apply_mutating("t", "s0", "submit", SUBMIT, seq=1)
    reg.apply_mutating("t", "s0", "step_until", {"t": 4000.0}, seq=2)

    reg.evict("t", "s0")
    ent = reg.entries[("t", "s0")]
    assert not ent.live and ent.snap_seq == 3 and not ent.dirty
    assert reg.n_evictions == 1

    # the next mutating op transparently rehydrates
    reg.apply_mutating("t", "s0", "run", {}, seq=3)
    assert reg.n_rehydrations == 1
    got = result_dict(reg.live_session("t", "s0").result())
    assert got == serial_result(until=4000.0)


def test_evict_over_cap_is_lru(tmp_path):
    clock = FakeClock()
    reg, _ = registry_on(tmp_path, max_live=2, clock=clock)
    for i, name in enumerate(["a", "b", "c"]):
        clock.advance(1.0)
        reg.apply_mutating("t", name, "open", OPEN, seq=0)
    assert reg.n_live == 3
    reg.evict_over_cap()
    assert reg.n_live == 2
    assert not reg.entries[("t", "a")].live      # oldest touch went first
    assert reg.entries[("t", "c")].live


def test_evict_idle(tmp_path):
    clock = FakeClock()
    reg, _ = registry_on(tmp_path, idle_evict_s=10.0, clock=clock)
    reg.apply_mutating("t", "s0", "open", OPEN, seq=0)
    assert reg.evict_idle() == 0                 # just touched
    clock.advance(11.0)
    assert reg.evict_idle() == 1
    assert not reg.entries[("t", "s0")].live


# --------------------------------------------------------------------------- #
# registry: crash recovery                                                     #
# --------------------------------------------------------------------------- #
def test_crash_recovery_replays_journal(tmp_path):
    store = SessionStore(str(tmp_path / "store"))
    reg = SessionRegistry(store)
    reg.apply_mutating("t", "s0", "open", OPEN, seq=0)
    reg.apply_mutating("t", "s0", "submit", SUBMIT, seq=1)
    reg.apply_mutating("t", "s0", "step_until", {"t": 4000.0}, seq=2)
    # crash: no close_all, no persist — only the fsynced journal survives
    del reg

    reg2 = SessionRegistry(SessionStore(str(tmp_path / "store")))
    assert reg2.recover() == 1
    ent = reg2.entries[("t", "s0")]
    assert ent.seq == 3 and not ent.live
    # resend of the in-flight op dedupes; the continuation applies fresh
    assert reg2.apply_mutating("t", "s0", "step_until",
                               {"t": 4000.0}, seq=2)["dup"]
    reg2.apply_mutating("t", "s0", "run", {}, seq=3)
    got = result_dict(reg2.live_session("t", "s0").result())
    assert got == serial_result(until=4000.0)


def test_recovery_from_snapshot_plus_journal_suffix(tmp_path):
    """Snapshot at seq 2, two more journaled ops, crash: replay starts
    from the snapshot and applies only the suffix."""
    store = SessionStore(str(tmp_path / "store"))
    reg = SessionRegistry(store)
    reg.apply_mutating("t", "s0", "open", OPEN, seq=0)
    reg.apply_mutating("t", "s0", "submit", SUBMIT, seq=1)
    reg.checkpoint("t", "s0")
    reg.apply_mutating("t", "s0", "step_until", {"t": 4000.0}, seq=2)
    reg.apply_mutating(
        "t", "s0", "inject",
        {"kind": "fail", "t": 4100.0, "nodes": [0, 1]}, seq=3)
    del reg

    reg2 = SessionRegistry(SessionStore(str(tmp_path / "store")))
    assert reg2.recover() == 1
    reg2.apply_mutating("t", "s0", "run", {}, seq=4)
    got = result_dict(reg2.live_session("t", "s0").result())
    assert got == serial_result(
        until=4000.0, inject={"kind": "fail", "t": 4100.0,
                              "nodes": [0, 1]})


def test_torn_journal_tail_is_dropped(tmp_path, capsys):
    store = SessionStore(str(tmp_path / "store"))
    reg = SessionRegistry(store)
    reg.apply_mutating("t", "s0", "open", OPEN, seq=0)
    reg.apply_mutating("t", "s0", "submit", SUBMIT, seq=1)
    del reg
    with open(SessionStore(str(tmp_path / "store")).journal_path(
            "t", "s0"), "a") as f:
        f.write('{"seq": 2, "op": "step_unt')     # crash mid-append

    reg2 = SessionRegistry(SessionStore(str(tmp_path / "store")))
    assert reg2.recover() == 1
    # the torn entry was never applied pre-crash: it does not count
    assert reg2.entries[("t", "s0")].seq == 2
    reg2.apply_mutating("t", "s0", "run", {}, seq=2)
    got = result_dict(reg2.live_session("t", "s0").result())
    assert got == serial_result()


def test_snap_schema_guard(tmp_path):
    store = SessionStore(str(tmp_path / "store"))
    reg = SessionRegistry(store)
    reg.apply_mutating("t", "s0", "open", OPEN, seq=0)
    reg.checkpoint("t", "s0")
    path = store.snap_path("t", "s0")
    payload = json.load(open(path))
    payload["schema"] = "something/else"
    json.dump(payload, open(path, "w"))
    with pytest.raises(ValueError, match="schema"):
        store.read_snapshot("t", "s0")


def test_checkpoint_truncates_journal(tmp_path):
    store = SessionStore(str(tmp_path / "store"))
    reg = SessionRegistry(store)
    reg.apply_mutating("t", "s0", "open", OPEN, seq=0)
    reg.apply_mutating("t", "s0", "submit", SUBMIT, seq=1)
    assert len(store.read_journal("t", "s0")) == 2
    out = reg.checkpoint("t", "s0")
    assert out["seq"] == 2 and out["fingerprint"]
    assert store.read_journal("t", "s0") == []
    assert json.load(open(out["path"]))["seq"] == 2


# --------------------------------------------------------------------------- #
# admission: credit model                                                      #
# --------------------------------------------------------------------------- #
def test_credit_formula_terms_and_decay():
    clock = FakeClock()
    p = CreditParams(budget=100.0, window_s=10.0)
    t = TenantState("acme", p, clock)
    assert t.credit() == 1.0

    # saturate the budget term: credit = 1 − α·1
    t.charge(ops=200.0)
    assert t.budget_used() == 1.0
    assert t.credit() == pytest.approx(1.0 - p.alpha)

    # violations bite with weight β
    t.violation(10.0)
    assert t.violations_norm() == 1.0
    assert t.credit() == pytest.approx(
        max(p.min_credit, 1.0 - p.alpha - p.beta))

    # both pressures decay exponentially: forgiveness over window_s
    clock.advance(5 * p.window_s)
    assert t.budget_used() < 0.02 and t.violations_norm() < 0.02
    assert t.credit() > 0.98


def test_tail_latency_pressure():
    clock = FakeClock()
    # huge budget so only the latency term moves the credit
    p = CreditParams(target_latency_s=0.05, budget=1e9)
    t = TenantState("slow", p, clock)
    for _ in range(20):
        t.charge(ops=0.0, wall=0.5)              # 10× the p99 target
    assert t.tail_latency_norm() == 1.0
    assert t.credit() == pytest.approx(1.0 - p.gamma)


def test_min_credit_floor():
    clock = FakeClock()
    p = CreditParams(budget=1.0, min_credit=0.05)
    t = TenantState("worst", p, clock)
    t.charge(ops=100.0)
    t.violation(100.0)
    for _ in range(10):
        t.charge(ops=0.0, wall=10.0)
    assert t.credit() == p.min_credit


def test_admission_queue_full_refuses_and_counts_violation():
    q = FairQueue(CreditParams(max_pending=2), clock=FakeClock())
    q.admit("t", "op1")
    q.admit("t", "op2")
    with pytest.raises(ProtocolError) as ei:
        q.admit("t", "op3")
    assert ei.value.code == E_ADMISSION
    t = q.tenant("t")
    assert t.n_rejected == 1 and t.violations > 0
    assert len(t.pending) == 2                   # refusals take no space


def test_admission_over_budget_refuses_without_violation():
    clock = FakeClock()
    q = FairQueue(CreditParams(budget=10.0), clock=clock)
    t = q.tenant("t")
    t.charge(ops=20.0)
    with pytest.raises(ProtocolError) as ei:
        q.admit("t", "op")
    assert ei.value.code == E_OVER_BUDGET
    assert t.n_rejected == 1
    assert t.violations == 0.0                   # throttled, not punished
    # the budget decays: the tenant is admitted again later
    clock.advance(100.0)
    q.admit("t", "op")


def test_fair_queue_prefers_light_and_credited_tenants():
    clock = FakeClock()
    q = FairQueue(CreditParams(), clock=clock)
    heavy, fresh = q.tenant("heavy"), q.tenant("fresh")
    heavy.charge(ops=50.0, events=5000.0, wall=1.0)
    heavy.pending.append("H")
    fresh.pending.append("F")
    picked, item = q.pick()
    assert picked is fresh and item == "F"

    # equal usage: the tenant with more credit (fewer violations) wins
    q2 = FairQueue(CreditParams(), clock=clock)
    a, b = q2.tenant("a"), q2.tenant("b")
    for t in (a, b):
        t.charge(ops=10.0)
        t.pending.append(t.name)
    b.violation(10.0)
    picked, _ = q2.pick()
    assert picked is a


# --------------------------------------------------------------------------- #
# live server: parity, eviction, fairness                                      #
# --------------------------------------------------------------------------- #
def _drive(port, tenant, plan, out, errs):
    """One tenant thread: interleaved stepping across its sessions, then
    run-to-exhaustion and result collection."""
    try:
        with Client("127.0.0.1", port, tenant=tenant) as c:
            for name, seed in plan:
                c.open(name, POLICY, nodes=NODES)
                c.submit(name, workload="lublin", jobs=30, seed=seed,
                         nodes=NODES)
            for frac in (2000.0, 6000.0):
                for name, _ in plan:
                    c.step_until(name, frac)
            for name, seed in plan:
                c.run(name)
                out[(tenant, name)] = norm_result(c.result(name))
    except BaseException as exc:  # noqa: BLE001 — surfaced in the test
        errs.append(exc)


def test_concurrent_multi_tenant_parity_vs_serial(tmp_path):
    out, errs, threads = {}, [], []
    plans = {"acme": [("s0", 0), ("s1", 1)],
             "umbrella": [("u0", 2), ("u1", 3)]}
    with ServerThread(store=str(tmp_path / "store"), max_live=2) as srv:
        for tenant, plan in plans.items():
            th = threading.Thread(target=_drive,
                                  args=(srv.port, tenant, plan, out, errs))
            th.start()
            threads.append(th)
        for th in threads:
            th.join(timeout=120)
        assert not errs
        with Client("127.0.0.1", srv.port) as c:
            stats = c.stats()
    # interleaved multi-tenant service == serial single-session runs
    for tenant, plan in plans.items():
        for name, seed in plan:
            assert out[(tenant, name)] == serial_result(seed=seed), \
                f"{tenant}/{name} diverged from the serial run"
    # 4 sessions over max_live=2 forces the evict/rehydrate path
    assert stats["registry"]["evictions"] > 0
    assert stats["registry"]["rehydrations"] > 0
    assert stats["registry"]["sessions"] == 4


def test_eviction_is_transparent_to_the_client(tmp_path):
    with ServerThread(store=str(tmp_path / "store"), max_live=1) as srv:
        with Client("127.0.0.1", srv.port, tenant="t") as c:
            for name, seed in [("a", 0), ("b", 1)]:
                c.open(name, POLICY, nodes=NODES)
                c.submit(name, workload="lublin", jobs=30, seed=seed,
                         nodes=NODES)
            # ping-pong between the two sessions: every switch evicts one
            for t in (2000.0, 4000.0, 6000.0):
                c.step_until("a", t)
                c.step_until("b", t)
            results = {n: norm_result(c.result(n))
                       for n in ("a", "b") if c.run(n)}
            stats = c.stats()
    assert results["a"] == serial_result(seed=0)
    assert results["b"] == serial_result(seed=1)
    assert stats["registry"]["evictions"] >= 4


def test_misbehaving_tenant_loses_credit(tmp_path):
    with ServerThread(store=None) as srv:
        with Client("127.0.0.1", srv.port, tenant="good") as good, \
                Client("127.0.0.1", srv.port, tenant="evil") as evil:
            good.open("g0", "EASY", nodes=NODES)
            # the misbehaving tenant spams ops that error out
            for i in range(25):
                with pytest.raises(ServeError) as ei:
                    evil.call("step", "ghost", n=1, seq=i)
                assert ei.value.code == E_UNKNOWN_SESSION
            stats = good.stats()["tenants"]
    assert stats["evil"]["n_errors"] >= 25
    assert stats["evil"]["violations"] > 0.5
    assert stats["evil"]["credit"] < stats["good"]["credit"]
    assert stats["good"]["credit"] > 0.9


def test_wire_seq_dedup_and_close_semantics(tmp_path):
    with ServerThread(store=str(tmp_path / "store")) as srv:
        with Client("127.0.0.1", srv.port, tenant="t") as c:
            c.open("s0", "EASY", nodes=NODES)
            c.submit("s0", workload="lublin", jobs=10, nodes=NODES)
            # explicit resend of an applied seq: acknowledged as dup
            resp = c.call("submit", "s0", workload="lublin", jobs=10,
                          nodes=NODES, seq=1)
            assert resp["dup"] is True
            # a seq gap is a typed refusal
            with pytest.raises(ServeError) as ei:
                c.call("step", "s0", n=1, seq=9)
            assert ei.value.code == E_SEQ_GAP

            c.run("s0")
            closed = c.close_session("s0")
            assert closed["closed"] is True
            with pytest.raises(ServeError) as ei:
                c.step("s0")
            assert ei.value.code == E_SESSION_CLOSED
            # reads still work: the closed session rehydrates from disk
            assert norm_result(c.result("s0")) == serial_result(
                policy="EASY", jobs=10)
            assert c.sessions() == ["s0"]


def test_name_validation_and_unknown_ops(tmp_path):
    with ServerThread(store=None) as srv:
        with Client("127.0.0.1", srv.port, tenant="t") as c:
            for bad in ("../evil", "a/b", "", "x" * 65, ".hidden"):
                with pytest.raises(ServeError) as ei:
                    c.open(bad, "EASY")
                assert ei.value.code == E_BAD_REQUEST
            with pytest.raises(ServeError) as ei:
                c.call("frobnicate", "s0")
            assert ei.value.code == E_BAD_REQUEST
            # tenant names are checked too
            bad = Client("127.0.0.1", srv.port, tenant="../../etc")
            with pytest.raises(ServeError) as ei:
                bad.ping()
            bad.close()
            assert ei.value.code == E_BAD_REQUEST


def test_hello_stats_and_snapshot_op(tmp_path):
    with ServerThread(store=str(tmp_path / "store"),
                      credit=CreditParams(budget=123.0)) as srv:
        with Client("127.0.0.1", srv.port, tenant="t") as c:
            hello = c.hello()
            assert hello["limits"]["budget"] == 123.0
            assert 0 < hello["credit"] <= 1.0
            c.open("s0", "EASY", nodes=NODES)
            snap = c.snapshot("s0")
            assert snap["fingerprint"] and os.path.exists(snap["path"])
            stats = c.stats()
            assert stats["registry"]["sessions"] == 1
            assert stats["backlog"] == 0


def test_snapshot_without_store_is_refused():
    with ServerThread(store=None) as srv:
        with Client("127.0.0.1", srv.port, tenant="t") as c:
            c.open("s0", "EASY", nodes=NODES)
            with pytest.raises(ServeError) as ei:
                c.snapshot("s0")
            assert ei.value.code == E_BAD_REQUEST


def test_checkpoint_every_bounds_replay(tmp_path):
    store = str(tmp_path / "store")
    with ServerThread(store=store, checkpoint_every=2) as srv:
        with Client("127.0.0.1", srv.port, tenant="t") as c:
            c.open("s0", "EASY", nodes=NODES)
            c.submit("s0", workload="lublin", jobs=10, nodes=NODES)
            c.step_until("s0", 2000.0)
            c.step_until("s0", 3000.0)
    # auto-checkpoints kept the journal short (≤ checkpoint_every entries)
    entries = SessionStore(store).read_journal("t", "s0")
    assert len(entries) < 4


def test_client_resyncs_seq_after_engine_rejected_op(tmp_path):
    """An op the engine rejects (op-error) was journaled, so it consumed
    its seq.  The client must resync from the response's ``next_seq`` —
    otherwise every later op re-sends a stale seq and is swallowed as a
    dup: silent op loss reported as success."""
    with ServerThread(store=str(tmp_path / "store")) as srv:
        with Client("127.0.0.1", srv.port, tenant="t") as c:
            c.open("s0", "EASY", nodes=NODES)
            c.submit("s0", workload="lublin", jobs=10, nodes=NODES)
            with pytest.raises(ServeError) as ei:
                c.step("s0", n=0)       # engine rejects: n_events must be >= 1
            assert ei.value.code == E_OP_ERROR
            # the failed op consumed a seq; the next ops must APPLY, not dup
            resp = c.step("s0", n=1)
            assert "dup" not in resp and resp["steps"] == 1
            resp = c.run("s0")
            assert "dup" not in resp
            assert norm_result(c.result("s0")) == serial_result(
                policy="EASY", jobs=10)


def test_closed_name_delete_and_reuse(tmp_path):
    store = str(tmp_path / "store")
    with ServerThread(store=store) as srv:
        with Client("127.0.0.1", srv.port, tenant="t") as c:
            c.open("s0", "EASY", nodes=NODES)
            c.submit("s0", workload="lublin", jobs=5, nodes=NODES)
            c.run("s0")
            # deleting a still-open session is refused
            with pytest.raises(ServeError) as ei:
                c.delete_session("s0")
            assert ei.value.code == E_BAD_REQUEST
            c.close_session("s0")
            # the event-accounting baseline is dropped with the session
            assert ("t", "s0") not in srv.server._events_seen
            # re-opening a closed name gets the accurate refusal
            with pytest.raises(ServeError) as ei:
                c.open("s0", "EASY", nodes=NODES)
            assert ei.value.code == E_SESSION_CLOSED
            assert "delete" in str(ei.value)
            paths = SessionStore(store)
            assert os.path.exists(paths.snap_path("t", "s0"))
            assert c.delete_session("s0")["deleted"] is True
            assert not os.path.exists(paths.snap_path("t", "s0"))
            assert not os.path.exists(paths.journal_path("t", "s0"))
            with pytest.raises(ServeError) as ei:
                c.delete_session("s0")
            assert ei.value.code == E_UNKNOWN_SESSION
            # the name is free again: a fresh session starting at seq 0
            c.open("s0", "EASY", nodes=NODES)
            c.submit("s0", workload="lublin", jobs=10, nodes=NODES)
            c.run("s0")
            assert norm_result(c.result("s0")) == serial_result(
                policy="EASY", jobs=10)


def test_failed_open_does_not_poison_the_name(tmp_path):
    """An ``open`` the engine rejects (bad policy) must not leave a
    journaled entry behind — it could never rehydrate, so the name would
    be stuck forever.  The entry is erased and a corrected open applies
    fresh at seq 0."""
    with ServerThread(store=str(tmp_path / "store")) as srv:
        with Client("127.0.0.1", srv.port, tenant="t") as c:
            with pytest.raises(ServeError) as ei:
                c.open("s0", "NOSUCH-POLICY", nodes=NODES)
            assert ei.value.code == E_OP_ERROR
            c.open("s0", "EASY", nodes=NODES)
            c.submit("s0", workload="lublin", jobs=10, nodes=NODES)
            c.run("s0")
            assert norm_result(c.result("s0")) == serial_result(
                policy="EASY", jobs=10)


def test_session_cap_prunes_on_close_and_survives_restart(tmp_path):
    store = str(tmp_path / "store")
    with ServerThread(store=store,
                      credit=CreditParams(max_sessions=1)) as srv:
        with Client("127.0.0.1", srv.port, tenant="t") as c:
            c.open("s0", "EASY", nodes=NODES)
            with pytest.raises(ServeError) as ei:
                c.open("s1", "EASY", nodes=NODES)
            assert "session cap" in str(ei.value)
            # the cap counts OPEN sessions: closing s0 frees the slot
            c.close_session("s0")
            c.open("s1", "EASY", nodes=NODES)
    # restart: recovered still-open sessions count against the cap again
    with ServerThread(store=store,
                      credit=CreditParams(max_sessions=1)) as srv:
        with Client("127.0.0.1", srv.port, tenant="t") as c:
            assert c.stats()["tenants"]["t"]["sessions"] == 1  # s1 only
            with pytest.raises(ServeError) as ei:
                c.open("s2", "EASY", nodes=NODES)
            assert "session cap" in str(ei.value)


def test_events_charge_baselines_on_first_sighting():
    """A session first seen with a big lifetime event count (recovery
    after restart) establishes a baseline — it is not charged as a fresh
    delta that would spuriously exhaust the tenant's budget."""
    from repro.serve.server import SchedServer
    srv = SchedServer(ServeConfig())
    req = {"session": "s0"}
    assert srv._events_delta("t", req, {"events": 5000}) == 0.0
    assert srv._events_delta("t", req, {"events": 5600}) == 600.0
    assert srv._events_delta("t", req, {"events": 5500}) == 0.0


# --------------------------------------------------------------------------- #
# crash recovery: a real server process, SIGKILL mid-workload                  #
# --------------------------------------------------------------------------- #
def _spawn_server(store, port_file):
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro", "serve", "--store", store,
         "--port-file", port_file],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        env={**os.environ, "PYTHONPATH": os.pathsep.join(sys.path)})
    deadline = time.monotonic() + 60
    while time.monotonic() < deadline:
        if os.path.exists(port_file):
            return proc, int(open(port_file).read())
        if proc.poll() is not None:
            raise RuntimeError("server died at startup:\n"
                               + proc.stdout.read().decode())
        time.sleep(0.05)
    proc.kill()
    raise RuntimeError("server did not announce a port within 60s")


def test_kill9_recovery_is_bit_identical(tmp_path):
    store, port_file = str(tmp_path / "store"), str(tmp_path / "port")
    proc, port = _spawn_server(store, port_file)
    try:
        with Client("127.0.0.1", port, tenant="t") as c:
            c.open("s0", POLICY, nodes=NODES)
            c.submit("s0", workload="lublin", jobs=30, seed=0, nodes=NODES)
            c.step_until("s0", 4000.0)
        os.kill(proc.pid, signal.SIGKILL)        # no cleanup, no persist
        proc.wait(timeout=30)
        os.unlink(port_file)

        proc, port = _spawn_server(store, port_file)
        c = Client("127.0.0.1", port, tenant="t", retry_for=10.0)
        # re-drive the full script: the applied prefix dedupes, the rest
        # applies fresh — exactly-once end to end
        assert c.call("open", "s0", seq=0, **OPEN)["dup"]
        assert c.call("submit", "s0", seq=1, **SUBMIT)["dup"]
        assert c.call("step_until", "s0", seq=2, t=4000.0)["dup"]
        c.call("run", "s0", seq=3)
        got = norm_result(c.result("s0"))
        c.close()
    finally:
        proc.kill()
        proc.wait(timeout=30)
    assert got == serial_result(until=4000.0)


# --------------------------------------------------------------------------- #
# CLI: the client script driver                                                #
# --------------------------------------------------------------------------- #
def test_cli_client_script(tmp_path):
    from repro.__main__ import main as cli_main
    script = tmp_path / "script.jsonl"
    script.write_text("\n".join([
        '# comment lines and blanks are skipped',
        '',
        json.dumps({"op": "open", "session": "s0", "policy": "EASY",
                    "nodes": NODES}),
        json.dumps({"op": "submit", "session": "s0", "workload": "lublin",
                    "jobs": 10, "nodes": NODES}),
        json.dumps({"op": "run", "session": "s0"}),
        json.dumps({"op": "result", "session": "s0"}),
    ]) + "\n")
    out = tmp_path / "out.jsonl"
    with ServerThread(store=None) as srv:
        rc = cli_main(["client", "--port", str(srv.port), "--tenant", "t",
                       "--script", str(script), "--metrics", str(out)])
    assert rc == 0
    lines = [json.loads(l) for l in out.read_text().splitlines()]
    assert [l["kind"] for l in lines] == ["open", "submit", "run", "result"]
    assert all(l["ok"] for l in lines)
    assert norm_result(lines[-1]) == serial_result(policy="EASY", jobs=10)


def test_cli_client_error_paths(tmp_path, capsys):
    from repro.__main__ import main as cli_main
    script = tmp_path / "script.jsonl"
    script.write_text(json.dumps({"op": "step", "session": "nope"}) + "\n")
    with ServerThread(store=None) as srv:
        rc = cli_main(["client", "--port", str(srv.port),
                       "--script", str(script)])
        assert rc == 2
        assert "unknown session" in capsys.readouterr().err
        # --keep-going turns refusals into error lines, rc 0
        out = tmp_path / "out.jsonl"
        rc = cli_main(["client", "--port", str(srv.port),
                       "--script", str(script), "--keep-going",
                       "--metrics", str(out)])
        assert rc == 0
        line = json.loads(out.read_text())
        assert line["kind"] == "error"
        assert line["code"] == E_UNKNOWN_SESSION


# --------------------------------------------------------------------------- #
# atomic writes under concurrent writers                                       #
# --------------------------------------------------------------------------- #
def test_atomic_write_two_writer_stress(tmp_path):
    """Concurrent writers to one path + a live reader: every read parses,
    every read is one writer's complete payload, no tmp files leak."""
    path = str(tmp_path / "shared.json")
    atomic_write_json(path, {"writer": -1, "n": -1})
    errs, stop = [], threading.Event()

    def writer(wid):
        try:
            for n in range(200):
                atomic_write_json(path, {"writer": wid, "n": n})
        except BaseException as exc:  # noqa: BLE001
            errs.append(exc)

    def reader():
        try:
            while not stop.is_set():
                payload = json.load(open(path))
                assert set(payload) == {"writer", "n"}
        except BaseException as exc:  # noqa: BLE001
            errs.append(exc)

    threads = [threading.Thread(target=writer, args=(w,)) for w in range(4)]
    threads.append(threading.Thread(target=reader))
    for th in threads:
        th.start()
    for th in threads[:-1]:
        th.join(timeout=60)
    stop.set()
    threads[-1].join(timeout=60)
    assert not errs
    final = json.load(open(path))
    assert final["n"] == 199                     # some writer's last write
    leftovers = [f for f in os.listdir(tmp_path) if ".tmp." in f]
    assert leftovers == []
