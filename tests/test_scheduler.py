"""Core scheduler unit + property tests (greedy, MCB8, yields, policies)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.greedy import greedy_p, greedy_place, greedy_pm
from repro.core.job import JobSpec, JobState, NodePool, RUNNING
from repro.core.mcb8 import mcb8, mcb8_pack
from repro.core.policies import (TABLE1_POLICIES, all_paper_policies,
                                 parse_policy)
from repro.core.yield_alloc import allocate, maxmin_yields, min_yield

# --------------------------------------------------------------------------- #
# strategies                                                                   #
# --------------------------------------------------------------------------- #
job_st = st.builds(
    JobSpec,
    jid=st.integers(0, 10_000),
    release=st.floats(0, 1e5),
    proc_time=st.floats(1.0, 1e5),
    n_tasks=st.integers(1, 16),
    cpu_need=st.sampled_from([0.25, 0.5, 1.0]),
    mem_req=st.sampled_from([0.1, 0.2, 0.3, 0.5, 0.8, 1.0]),
)


def _states(specs, vt_seed=0):
    rng = np.random.default_rng(vt_seed)
    out = []
    for i, s in enumerate(specs):
        js = JobState(spec=JobSpec(
            jid=i, release=0.0, proc_time=s.proc_time, n_tasks=s.n_tasks,
            cpu_need=s.cpu_need, mem_req=s.mem_req))
        js.vt = float(rng.uniform(0.1, 100.0))
        out.append(js)
    return out


# --------------------------------------------------------------------------- #
# greedy placement                                                             #
# --------------------------------------------------------------------------- #
@settings(max_examples=50, deadline=None)
@given(st.lists(job_st, min_size=1, max_size=20), st.integers(2, 16))
def test_greedy_place_never_oversubscribes_memory(specs, n_nodes):
    pool = NodePool(n_nodes)
    for s in specs:
        mapping = greedy_place(pool, s)
        if mapping is not None:
            assert len(mapping) == s.n_tasks
    assert (pool.mem_free >= -1e-9).all()


def test_greedy_place_picks_lowest_load():
    pool = NodePool(3)
    pool.load[:] = [0.5, 0.1, 0.9]
    s = JobSpec(jid=0, release=0, proc_time=10, n_tasks=1,
                cpu_need=0.25, mem_req=0.1)
    assert greedy_place(pool, s) == [1]


def test_greedy_place_rolls_back_on_failure():
    pool = NodePool(2)
    pool.mem_free[:] = [0.25, 0.15]
    s = JobSpec(jid=0, release=0, proc_time=10, n_tasks=3,
                cpu_need=1.0, mem_req=0.2)
    before = pool.mem_free.copy()
    assert greedy_place(pool, s) is None
    np.testing.assert_allclose(pool.mem_free, before)


def test_greedy_p_pauses_lowest_priority_first():
    pool = NodePool(1)
    # two running jobs fill memory; the lower-priority one must be paused
    specs = [JobSpec(jid=i, release=0, proc_time=100, n_tasks=1,
                     cpu_need=1.0, mem_req=0.5) for i in range(2)]
    running = []
    for i, s in enumerate(specs):
        js = JobState(spec=s, status=RUNNING, mapping=[0])
        js.vt = 10.0 if i == 0 else 100.0    # jid 1: bigger vt -> lower prio
        pool.place(s, [0])
        running.append(js)
    new = JobSpec(jid=2, release=50, proc_time=10, n_tasks=1,
                  cpu_need=1.0, mem_req=0.5)
    adm = greedy_p(pool.copy(), new, running, now=50.0)
    assert adm.mapping is not None
    assert adm.paused == [1]


@settings(max_examples=30, deadline=None)
@given(st.lists(job_st, min_size=1, max_size=12), st.integers(2, 8),
       st.integers(0, 5))
def test_greedy_pm_admission_is_feasible(specs, n_nodes, seed):
    """Applying a GreedyPM admission plan transactionally never violates
    memory capacity."""
    rng = np.random.default_rng(seed)
    pool = NodePool(n_nodes)
    running = []
    for i, s in enumerate(specs[:-1]):
        spec = JobSpec(jid=i, release=0, proc_time=10, n_tasks=s.n_tasks,
                       cpu_need=s.cpu_need, mem_req=s.mem_req)
        m = greedy_place(pool, spec)
        if m is None:
            continue
        js = JobState(spec=spec, status=RUNNING, mapping=m)
        js.vt = float(rng.uniform(1, 100))
        running.append(js)
    s = specs[-1]
    new = JobSpec(jid=999, release=1, proc_time=10, n_tasks=s.n_tasks,
                  cpu_need=s.cpu_need, mem_req=s.mem_req)
    adm = greedy_pm(pool.copy(), new, running, now=1.0)
    if adm.mapping is None:
        return
    # rebuild: survivors (possibly moved) + the new job
    check = NodePool(n_nodes)
    for js in running:
        if js.spec.jid in adm.paused:
            continue
        check.place(js.spec, adm.moved.get(js.spec.jid, js.mapping))
    check.place(new, adm.mapping)      # raises if memory oversubscribed


# --------------------------------------------------------------------------- #
# MCB8                                                                         #
# --------------------------------------------------------------------------- #
@settings(max_examples=40, deadline=None)
@given(st.lists(job_st, min_size=1, max_size=20), st.integers(2, 16))
def test_mcb8_pack_respects_capacities(specs, n_nodes):
    items = [(i, s.cpu_need * 0.5, s.mem_req, s.n_tasks)
             for i, s in enumerate(specs)]
    res = mcb8_pack(n_nodes, items)
    if res is None:
        return
    cpu = np.zeros(n_nodes)
    mem = np.zeros(n_nodes)
    for (jid, c, m, n) in items:
        assert len(res[jid]) == n
        for node in res[jid]:
            cpu[node] += c
            mem[node] += m
    assert (cpu <= 1 + 1e-9).all() and (mem <= 1 + 1e-9).all()


@settings(max_examples=25, deadline=None)
@given(st.lists(job_st, min_size=1, max_size=15), st.integers(2, 8))
def test_mcb8_full_allocation_valid(specs, n_nodes):
    states = _states(specs)
    res = mcb8(states, n_nodes, now=200.0)
    cpu = np.zeros(n_nodes)
    mem = np.zeros(n_nodes)
    by = {js.spec.jid: js for js in states}
    for jid, mapping in res.mappings.items():
        s = by[jid].spec
        assert len(mapping) == s.n_tasks
        for node in mapping:
            cpu[node] += min(1.0, s.cpu_need * res.yld)
            mem[node] += s.mem_req
    assert (mem <= 1 + 1e-9).all()
    assert (cpu <= 1 + 1e-6).all()
    # every candidate is either mapped or explicitly removed
    assert set(res.mappings) | set(res.removed) == set(by)


def test_mcb8_removes_lowest_priority_when_infeasible():
    # 1 node, three jobs of mem 0.5 -> at most 2 fit; lowest prio removed
    specs = [JobSpec(jid=i, release=0, proc_time=100, n_tasks=1,
                     cpu_need=1.0, mem_req=0.5) for i in range(3)]
    states = [JobState(spec=s) for s in specs]
    states[0].vt = 100.0      # lowest priority (largest vt)
    states[1].vt = 10.0
    states[2].vt = 1.0
    res = mcb8(states, 1, now=200.0)
    assert res.removed == [0]
    assert set(res.mappings) == {1, 2}


def test_mcb8_pinned_jobs_keep_mapping():
    specs = [JobSpec(jid=i, release=0, proc_time=100, n_tasks=1,
                     cpu_need=1.0, mem_req=0.3) for i in range(3)]
    states = _states(specs)
    res = mcb8(states, 4, now=200.0, pinned={1: [3]})
    assert res.mappings[1] == [3]


def test_mcb8_deterministic_across_priority_shuffle():
    """Mapping stability (paper SS4.4 footnote): permuting the candidate
    order (priorities change over time) must not change the packing."""
    specs = [JobSpec(jid=i, release=0, proc_time=100, n_tasks=2,
                     cpu_need=1.0, mem_req=0.2) for i in range(8)]
    a = _states(specs, vt_seed=1)
    b = _states(specs, vt_seed=2)     # different priorities
    ra = mcb8(a, 8, now=200.0)
    rb = mcb8(b, 8, now=200.0)
    assert ra.mappings == rb.mappings


# --------------------------------------------------------------------------- #
# yield allocation (SS4.6)                                                     #
# --------------------------------------------------------------------------- #
@settings(max_examples=40, deadline=None)
@given(st.lists(job_st, min_size=1, max_size=10), st.integers(1, 8),
       st.integers(0, 3))
def test_maxmin_yields_feasible_and_floor(specs, n_nodes, seed):
    rng = np.random.default_rng(seed)
    pool = NodePool(n_nodes)
    placed, maps = [], []
    for i, s in enumerate(specs):
        spec = JobSpec(jid=i, release=0, proc_time=10, n_tasks=s.n_tasks,
                       cpu_need=s.cpu_need, mem_req=s.mem_req)
        m = greedy_place(pool, spec)
        if m is not None:
            placed.append(spec)
            maps.append(m)
    if not placed:
        return
    y = maxmin_yields(placed, maps, n_nodes)
    assert ((0 <= y) & (y <= 1.0 + 1e-12)).all()
    # feasibility: per-node allocated CPU <= 1
    load = np.zeros(n_nodes)
    for spec, m, yi in zip(placed, maps, y):
        for node in m:
            load[node] += yi * spec.cpu_need
    assert (load <= 1 + 1e-6).all()
    # floor: no one below the equal-share min yield
    assert (y >= min_yield(pool.load.max()) - 1e-9).all()
    # OPT=AVG dominates OPT=MIN on the sum, never below the floor
    y_avg = allocate(placed, maps, n_nodes, opt="AVG")
    assert y_avg.sum() >= y.sum() - 1e-6


def test_priority_function():
    s = JobSpec(jid=1, release=100.0, proc_time=10, n_tasks=1,
                cpu_need=1.0, mem_req=0.1)
    js = JobState(spec=s)
    assert js.priority(150.0) == np.inf          # never ran -> infinite
    js.vt = 5.0
    assert js.priority(150.0) == pytest.approx(50.0 / 25.0)


# --------------------------------------------------------------------------- #
# policy naming (SS4.5)                                                        #
# --------------------------------------------------------------------------- #
def test_parse_policy_roundtrip():
    p = parse_policy("GreedyPM */per/OPT=MIN/MINVT=600")
    assert p.on_submit == "greedyPM" and p.opportunistic
    assert p.periodic == "mcb8" and p.opt == "MIN" and p.minvt == 600.0
    assert p.on_complete == "greedy"
    p2 = parse_policy("MCB8 */OPT=AVG/MINFT=300")
    assert p2.on_submit == "mcb8" and p2.on_complete == "mcb8"
    assert p2.minft == 300.0 and p2.periodic is None
    p3 = parse_policy("/stretch-per/OPT=MAX")
    assert p3.on_submit is None and p3.periodic == "mcb8-stretch"


def test_table1_and_full_policy_space():
    for name in TABLE1_POLICIES:
        parse_policy(name)
    space = all_paper_policies()
    assert len(space) == len(set(space))
    for name in space:
        parse_policy(name)
    # the paper counts 116 combinations (SS6.1)
    assert len(space) == 116


def test_parse_policy_rejects_unknown():
    with pytest.raises(ValueError):
        parse_policy("Greedy */per/OPT=WAT")
    with pytest.raises(ValueError):
        parse_policy("Foo */per")
