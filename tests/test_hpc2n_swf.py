"""Golden-file tests for the real-log path: ``parse_swf`` +
``hpc2n_preprocess`` against the checked-in ``tests/data/mini.swf``
fixture (field mapping, the multi-threaded detection rule, the 10 %
memory floor), plus the ``swf`` workload kind and the registry's
kind-specific knob validation.
"""
import os

import pytest

from repro.workloads.hpc2n import hpc2n_preprocess, parse_swf
from repro.workloads.registry import (WorkloadSpec, list_workloads,
                                      make_trace, make_trace_ir,
                                      parse_workload, workload_kind)

MINI_SWF = os.path.join(os.path.dirname(__file__), "data", "mini.swf")


# --------------------------------------------------------------------------- #
# parse_swf: field mapping + row filtering                                     #
# --------------------------------------------------------------------------- #
def test_parse_swf_fixture_field_mapping():
    jobs = parse_swf(MINI_SWF)
    # 13 data lines: job 5 (run=0), job 6 (procs=-1) and the short line 12
    # are dropped
    assert [j.jid for j in jobs] == [1, 2, 3, 4, 7, 8, 9, 10, 11, 13]
    by = {j.jid: j for j in jobs}
    j1 = by[1]
    assert (j1.submit, j1.run, j1.procs) == (10.0, 3600.0, 4)
    assert (j1.used_mem_kb, j1.req_mem_kb) == (262144.0, -1.0)
    j11 = by[11]                       # decimal KB fields parse as floats
    assert (j11.used_mem_kb, j11.req_mem_kb) == (419430.4, 838860.8)


def test_parse_swf_accepts_text_blob():
    text = "; comment\n1 0 0 50 2 -1 0 2 60 -1 1 1 1 -1 1 -1 -1 -1\n"
    jobs = parse_swf(text)
    assert len(jobs) == 1 and jobs[0].run == 50.0


# --------------------------------------------------------------------------- #
# hpc2n_preprocess: the §5.3.1 transformation, golden values                   #
# --------------------------------------------------------------------------- #
def test_preprocess_fixture_golden():
    specs = hpc2n_preprocess(parse_swf(MINI_SWF))
    assert len(specs) == 10
    # sorted by submit; jids renumbered densely in that order
    assert [s.jid for s in specs] == list(range(10))
    assert [s.release for s in specs] == [5.0, 10.0, 15.0, 20.0, 30.0,
                                          60.0, 70.0, 80.0, 90.0, 100.0]
    rows = {s.release: s for s in specs}

    # swf 2 (odd procs): tasks = procs, one core each, memory unchanged
    s = rows[5.0]
    assert (s.n_tasks, s.cpu_need, s.mem_req) == (3, 0.5, 0.25)
    # swf 1 (even procs, 12.5% < 50%): multi-threaded — tasks halved,
    # CPU need 1.0 (both cores), memory doubled
    s = rows[10.0]
    assert (s.n_tasks, s.cpu_need, s.mem_req) == (2, 1.0, 0.25)
    assert s.proc_time == 3600.0
    # swf 13 (-1 memory sentinels): 10% floor
    assert rows[15.0].mem_req == 0.10
    # swf 3 (zero memory): 10% floor
    s = rows[20.0]
    assert (s.n_tasks, s.cpu_need, s.mem_req) == (1, 0.5, 0.10)
    # swf 4 (even procs but exactly 50% memory): NOT multi-threaded
    s = rows[30.0]
    assert (s.n_tasks, s.cpu_need, s.mem_req) == (2, 0.5, 0.5)
    # swf 7 (used=0 but requested 25%): max(used, req) rule, then doubled
    s = rows[60.0]
    assert (s.n_tasks, s.cpu_need, s.mem_req) == (4, 1.0, 0.5)
    # swf 8 (128 procs, 12.5%): the wide job keeps 64 two-core tasks
    s = rows[70.0]
    assert (s.n_tasks, s.cpu_need, s.mem_req) == (64, 1.0, 0.25)
    # swf 9 (150% of node memory): capped at a full node, not multi-threaded
    s = rows[80.0]
    assert (s.n_tasks, s.cpu_need, s.mem_req) == (2, 0.5, 1.0)
    # swf 10 (9.77% memory): floored to 10% *before* the rule, so the even
    # job is multi-threaded and lands at exactly 2x the floor
    s = rows[90.0]
    assert (s.n_tasks, s.cpu_need) == (3, 1.0)
    assert s.mem_req == pytest.approx(0.2)
    # swf 11: max(used, req) on decimal KB (40%), doubled to 80%
    s = rows[100.0]
    assert (s.n_tasks, s.cpu_need) == (2, 1.0)
    assert s.mem_req == pytest.approx(0.8)


# --------------------------------------------------------------------------- #
# the swf workload kind                                                        #
# --------------------------------------------------------------------------- #
def test_swf_kind_materializes_fixture():
    w = parse_workload(f"swf:{MINI_SWF}", n_jobs=0, n_nodes=128)
    specs = make_trace(w)
    assert specs == hpc2n_preprocess(parse_swf(MINI_SWF))
    # same spec -> same memoized trace object, stable fingerprint
    assert make_trace_ir(w) is make_trace_ir(w)


def test_swf_kind_caps_prefix_and_drops_wide_jobs():
    capped = make_trace(parse_workload(f"swf:{MINI_SWF}", n_jobs=3,
                                       n_nodes=128))
    assert len(capped) == 3
    assert [s.release for s in capped] == [5.0, 10.0, 15.0]
    narrow = make_trace(parse_workload(f"swf:{MINI_SWF}", n_jobs=0,
                                       n_nodes=16))
    assert all(s.n_tasks <= 16 for s in narrow)
    assert len(narrow) == 9            # the 64-task job is dropped


def test_swf_spec_requires_path():
    with pytest.raises(ValueError, match="requires params"):
        WorkloadSpec("swf")
    wk = workload_kind("swf")
    assert wk.required == ("path",) and wk.path_param == "path"


def test_swf_cell_simulates_end_to_end():
    from repro import api
    w = parse_workload(f"swf:{MINI_SWF}", n_jobs=0, n_nodes=128)
    r = api.simulate(w, "GreedyP */OPT=MIN")
    assert len(r.completions) == 10 and not r.hit_max_events


# --------------------------------------------------------------------------- #
# registry knob validation                                                     #
# --------------------------------------------------------------------------- #
def test_registered_kinds_present():
    assert {"lublin", "hpc2n", "swf", "tpu"} <= set(list_workloads())


def test_load_rejected_for_kinds_that_ignore_it():
    for kind, params in [("hpc2n", ()), ("swf", {"path": MINI_SWF})]:
        with pytest.raises(ValueError, match="ignores load="):
            WorkloadSpec(kind, load=0.5, params=params)
    # load-aware kinds accept it
    WorkloadSpec("lublin", load=0.5)
    WorkloadSpec("tpu", load=0.5)


def test_unknown_and_missing_params_rejected():
    with pytest.raises(ValueError, match="does not accept params"):
        WorkloadSpec("lublin", params={"path": "/x"})
    with pytest.raises(ValueError, match="JSON scalar"):
        WorkloadSpec("swf", params={"path": ["not", "a", "scalar"]})
    with pytest.raises(ValueError, match="unknown workload kind"):
        WorkloadSpec("marsaglia")


def test_parse_workload_grammar():
    w = parse_workload(f"swf:{MINI_SWF}", n_jobs=50, n_nodes=128, seed=2)
    assert w.kind == "swf" and w.param("path") == MINI_SWF
    assert w.n_jobs == 50 and w.seed == 2
    assert "swf" in w.name and "path=" in w.name
    with pytest.raises(ValueError, match="takes no"):
        parse_workload("lublin:whatever")
    assert parse_workload("lublin", load=0.3).load == 0.3


def test_workload_spec_params_hashable_and_json_round_trip():
    w = parse_workload(f"swf:{MINI_SWF}", n_nodes=128)
    assert hash(w) == hash(parse_workload(f"swf:{MINI_SWF}", n_nodes=128))
    d = w.to_dict()
    assert d["params"] == {"path": MINI_SWF}
    import json
    assert json.loads(json.dumps(d)) == d


def test_tpu_kind_default_mix_deterministic():
    w = WorkloadSpec("tpu", n_jobs=40, n_nodes=64, seed=5)
    a, b = make_trace_ir(w), make_trace_ir(w)
    assert a.fingerprint == b.fingerprint and len(a) == 40
    # load knob maps to the target offered load
    hot = WorkloadSpec("tpu", n_jobs=40, n_nodes=64, seed=5, load=0.9)
    assert make_trace_ir(hot).fingerprint != a.fingerprint
