"""Composable policy-component API tests.

Three layers:

* spec model: the 116-combination enumeration, canonicalization of
  equivalent spellings, render/parse round-trips;
* golden equivalence: every Table-1 policy plus the 17-cell acceptance
  grid (failure scenarios included) run once through the registry-backed
  ``ComposedPolicy`` and once through the pre-redesign monolithic classes
  (``DFRSPolicy``/``BatchPolicy``), requiring *bit-identical*
  ``SimResult``s;
* open API: a composition the grammar cannot express (the hybrid
  ``EASY+OPT=MIN``) registers via the public API, runs through
  ``run_grid``, and lands in a sweep artifact.
"""
import dataclasses

from conftest import result_dict as _result_dict
import itertools
import json

import pytest

from repro.core.policies import (PolicySpec, TABLE1_POLICIES,
                                 all_paper_policies, parse_policy,
                                 render_policy)
from repro.sched.components import (ComposedPolicy, Component, compose,
                                    compose_from_spec, get_component,
                                    list_components, register_component,
                                    register_policy, registered_policies,
                                    resolve_policy)
from repro.sched.engine import Engine, SimParams, make_seed_policy
from repro.sched.scenarios import apply_scenario
from repro.sched.sweep import grid, run_grid
from repro.workloads.registry import WorkloadSpec, make_trace


def mini_trace(n=30, nodes=16, seed=0):
    return make_trace(WorkloadSpec("lublin", n_jobs=n, n_nodes=nodes,
                                   seed=seed))


# --------------------------------------------------------------------------- #
# spec model: enumeration + canonicalization + round-trip                      #
# --------------------------------------------------------------------------- #
def test_paper_space_is_116_unique_parseable():
    names = all_paper_policies()
    assert len(names) == 116
    canon = [parse_policy(n).name for n in names]     # all parseable
    assert len(set(canon)) == 116                     # no duplicates


@pytest.mark.parametrize("a,b", [
    ("Greedy *", "greedy */OPT=MIN"),
    ("GreedyP */per/OPT=MIN/MINVT=600", "greedyp */MINVT=600/per/opt=min"),
    ("  GreedyPM  */per", "GREEDYPM*/PER/OPT=MIN"),
    ("/per", "/per/OPT=MIN"),
    ("/stretch-per/OPT=MAX", "/OPT=MAX/stretch-per"),
    ("MCB8 *", "mcb8*/OPT=MIN"),
    ("fcfs", "FCFS"),
])
def test_equivalent_spellings_parse_to_equal_specs(a, b):
    sa, sb = parse_policy(a), parse_policy(b)
    assert sa == sb
    assert sa.name == sb.name                         # one canonical name


def test_all_spellings_round_trip():
    """parse(render(spec)) == spec across the full combination space."""
    for name in all_paper_policies() + TABLE1_POLICIES + ["FCFS", "EASY"]:
        spec = parse_policy(name)
        assert render_policy(spec) == spec.name
        assert parse_policy(render_policy(spec)) == spec


def test_make_round_trips_over_component_product():
    limits = [(None, None), (300.0, None), (None, 600.0)]
    for on_submit, opp, periodic, (minvt, minft) in itertools.product(
            [None, "greedy", "greedyP", "greedyPM", "mcb8"],
            [False, True],
            [None, "mcb8", "mcb8-stretch"],
            limits):
        opts = ("MIN", "AVG", "MAX") if periodic == "mcb8-stretch" \
            else ("MIN", "AVG")
        for opt in opts:
            spec = PolicySpec.make(on_submit, opp, periodic, opt, minvt, minft)
            assert parse_policy(render_policy(spec)) == spec


def test_opt_max_requires_stretch_per():
    with pytest.raises(ValueError):
        parse_policy("GreedyP */OPT=MAX")


# --------------------------------------------------------------------------- #
# registry                                                                     #
# --------------------------------------------------------------------------- #
def test_component_registry_contents():
    comps = list_components()
    assert set(comps) == {"submit", "complete", "periodic", "opt"}
    assert {"greedy", "greedyP", "greedyPM", "mcb8",
            "fcfs-queue"} <= set(comps["submit"])
    assert {"greedy", "mcb8", "reclaim", "fcfs-start",
            "easy-backfill"} <= set(comps["complete"])
    assert {"mcb8", "mcb8-stretch", "backfill"} <= set(comps["periodic"])
    assert set(comps["opt"]) == {"MIN", "AVG", "MAX"}


def test_registry_rejects_duplicates_and_unknowns():
    with pytest.raises(ValueError):
        register_component("submit", "greedy")(type("Dup", (Component,), {}))
    with pytest.raises(ValueError):
        register_component("not-a-kind", "x")
    with pytest.raises(KeyError, match="unknown submit"):
        get_component("submit", "nope")


def test_compose_from_spec_shapes():
    p = compose_from_spec(parse_policy("GreedyPM */per/OPT=MIN/MINVT=600"))
    assert isinstance(p, ComposedPolicy)
    kinds = [(c.kind, c.component_name) for c in p.components]
    assert kinds == [("submit", "greedyPM"), ("complete", "greedy"),
                     ("periodic", "mcb8"), ("opt", "MIN")]
    assert p.periodic_kind == "mcb8" and p.handles_cluster_events

    b = compose_from_spec(parse_policy("EASY"))
    kinds = [(c.kind, c.component_name) for c in b.components]
    assert kinds == [("submit", "fcfs-queue"), ("complete", "reclaim"),
                     ("complete", "easy-backfill")]
    assert b.periodic_kind is None and not b.handles_cluster_events


def test_composition_rejects_two_periodic_components():
    with pytest.raises(ValueError, match="periodic"):
        compose("broken",
                get_component("periodic", "mcb8")(),
                get_component("periodic", "mcb8-stretch")())


def test_register_policy_rejects_grammar_spellings_and_duplicates():
    with pytest.raises(ValueError, match="grammar"):
        register_policy("GreedyP */OPT=MIN", lambda: None)
    with pytest.raises(ValueError, match="already registered"):
        register_policy("EASY+OPT=MIN", lambda: None)
    assert "EASY+OPT=MIN" in registered_policies()
    assert resolve_policy("no-such-policy") is None
    # factories build fresh (stateful) instances per resolution
    assert resolve_policy("EASY+OPT=MIN") is not resolve_policy("EASY+OPT=MIN")


# --------------------------------------------------------------------------- #
# golden equivalence: composed == seed classes, bit for bit                    #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("policy", TABLE1_POLICIES + ["FCFS", "EASY"])
def test_every_table1_policy_composed_equals_seed(policy):
    specs = mini_trace()
    spec = parse_policy(policy)
    params = SimParams(n_nodes=16)
    composed = Engine(specs, policy, params).run()
    seed = Engine(specs, make_seed_policy(spec), params).run()
    assert _result_dict(composed) == _result_dict(seed)


# the 17-cell acceptance harness of tests/test_alloc_kernels.py
GOLDEN_POLICIES = ["FCFS", "EASY", "GreedyP */OPT=MIN",
                   "GreedyPM */per/OPT=MIN/MINVT=600"]
GOLDEN_WORKLOADS = [WorkloadSpec("lublin", n_jobs=40, n_nodes=16, seed=0),
                    WorkloadSpec("hpc2n", n_jobs=40, n_nodes=128, seed=1)]
GOLDEN_CASES = [(w, p, sc)
                for w in GOLDEN_WORKLOADS
                for p in GOLDEN_POLICIES
                for sc in ("baseline", "rack_failure")]
GOLDEN_CASES.append((GOLDEN_WORKLOADS[0], "/stretch-per/OPT=MAX", "baseline"))


@pytest.mark.parametrize(
    "workload,policy,scenario", GOLDEN_CASES,
    ids=[f"{w.name}-{p}-{sc}" for w, p, sc in GOLDEN_CASES])
def test_golden_composed_vs_seed_simresult(workload, policy, scenario):
    specs = make_trace(workload)
    specs, events = apply_scenario(scenario, specs, workload.n_nodes,
                                   seed=workload.seed)
    params = SimParams(n_nodes=workload.n_nodes)
    composed = Engine(specs, policy, params, cluster_events=events).run()
    seed = Engine(specs, make_seed_policy(parse_policy(policy)), params,
                  cluster_events=events).run()
    assert _result_dict(composed) == _result_dict(seed)


def test_default_engine_policy_is_composed():
    eng = Engine(mini_trace(n=5), "GreedyP */OPT=MIN", SimParams(n_nodes=16))
    assert isinstance(eng.policy, ComposedPolicy)


# --------------------------------------------------------------------------- #
# the open API: compositions beyond the grammar                                #
# --------------------------------------------------------------------------- #
def test_hybrid_runs_end_to_end_and_fractionally_backfills(monkeypatch):
    from repro.sched import components as C

    frac_starts = []
    orig = C.BatchStartPass._start_frac

    def counting(self, st, js):
        ok = orig(self, st, js)
        if ok:
            frac_starts.append(js.spec.jid)
        return ok

    monkeypatch.setattr(C.BatchStartPass, "_start_frac", counting)
    specs = make_trace(WorkloadSpec("lublin", n_jobs=60, n_nodes=16, seed=0,
                                    load=0.9))
    r = Engine(specs, "EASY+OPT=MIN", SimParams(n_nodes=16)).run()
    assert set(r.completions) == {s.jid for s in specs}
    assert r.policy == "EASY+OPT=MIN"
    assert frac_starts, "fractional backfill never fired on this trace"
    # fractional sharing is arbitrated by OPT=MIN: co-located jobs finish,
    # and the hybrid is still a batch policy from the engine's perspective
    assert not Engine(specs, "EASY+OPT=MIN",
                      SimParams(n_nodes=16)).policy.handles_cluster_events


def test_hybrid_improves_mean_stretch_on_contended_trace():
    specs = mini_trace(n=80, seed=1)
    hybrid = Engine(specs, "EASY+OPT=MIN", SimParams(n_nodes=16)).run()
    easy = Engine(specs, "EASY", SimParams(n_nodes=16)).run()
    assert hybrid.mean_stretch <= easy.mean_stretch + 1e-9


def test_hybrid_through_run_grid_lands_in_artifact(tmp_path):
    w = WorkloadSpec("lublin", n_jobs=30, n_nodes=16, seed=3)
    path = str(tmp_path / "hybrid_sweep.json")
    res = run_grid(grid([w], ["EASY", "EASY+OPT=MIN"]), n_workers=1,
                   json_path=path)
    assert res.n_cells == 2
    art = json.loads(open(path).read())
    assert {r["policy"] for r in art["records"]} == {"EASY", "EASY+OPT=MIN"}
    for rec in art["records"]:
        assert not rec["hit_max_events"] and rec["makespan"] > 0


def test_hybrid_blocks_backfill_when_reservation_uncomputable():
    """When withheld frac-occupied nodes make the head's shadow time
    uncomputable (free + exclusive-running < head need), no job may
    backfill — a vacuous `t <= inf` check would disable EASY's reservation
    protection entirely."""
    from repro.core.job import JobSpec
    from repro.core.state import S_PENDING
    from repro.sched.components import BatchStartPass, _batch_state

    specs = [JobSpec(jid=0, release=0.0, proc_time=100.0, n_tasks=2,
                     cpu_need=1.0, mem_req=0.5),      # head: needs both nodes
            JobSpec(jid=1, release=0.0, proc_time=10.0, n_tasks=1,
                    cpu_need=1.0, mem_req=0.2)]       # would fit node 1
    e = Engine(specs, "EASY+OPT=MIN", SimParams(n_nodes=2))
    pol = e.policy
    st = _batch_state(pol)
    st.free = [1]                 # node 0 withheld: frac occupant remains
    st.frac_count[0] = 1
    e.state.status[:] = S_PENDING
    st.queue.append(e.state.views[0])
    st.queue.append(e.state.views[1])
    start = next(c for c in pol.components if isinstance(c, BatchStartPass))
    start._try_start(st)
    # head cannot start (1 free < 2) and the candidate must NOT jump it
    assert e.state.views[0].status == "pending"
    assert e.state.views[1].status == "pending"


def test_custom_composition_registers_and_sweeps():
    """A user-defined composition (periodic-only batch backfill — the queue
    drains on the tick, not on events) goes through the whole public path."""
    name = "test-periodic-backfill"
    if name not in registered_policies():
        register_policy(name, lambda: compose(
            name,
            get_component("submit", "fcfs-queue")(),
            get_component("complete", "reclaim")(),
            get_component("periodic", "backfill")(),
        ), description="batch queue drained only on the periodic tick")
    pol = resolve_policy(name)
    assert pol.periodic_kind == "backfill"
    w = WorkloadSpec("lublin", n_jobs=20, n_nodes=16, seed=0)
    res = run_grid(grid([w], [name]), n_workers=1)
    assert res.records[0]["policy"] == name
    assert not res.records[0]["hit_max_events"]
    # delaying every start to the tick can only push completions later
    direct = run_grid(grid([w], ["EASY"]), n_workers=1)
    assert res.records[0]["makespan"] >= direct.records[0]["makespan"] - 1e-9
