"""Sharding-plan tests: rules, divisibility fallbacks, spec coverage.

Uses AbstractMesh — no 512-device requirement; only the dry-run itself
needs real (virtual) devices.
"""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import dp_axes, dp_size
from repro.launch.shardings import make_plan
from repro.models import backbone


def _abstract_mesh(sizes, names):
    try:                                   # jax >= 0.5: (axis_sizes, axis_names)
        return AbstractMesh(sizes, names)
    except TypeError:                      # jax 0.4.x: ((name, size), ...) pairs
        return AbstractMesh(tuple(zip(names, sizes)))


def amesh(multi=False):
    if multi:
        return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return _abstract_mesh((16, 16), ("data", "model"))


def test_mesh_axes_helpers():
    m = amesh(multi=True)
    assert dp_axes(m) == ("pod", "data")
    assert dp_size(m) == 32
    assert dp_size(amesh()) == 16


def test_rules_llama():
    plan = make_plan(get_config("llama3-8b"), amesh())
    r = plan.rules
    assert r["vocab"] == "model"       # 128256 % 16 == 0
    assert r["heads"] == "model"
    assert r["kv_heads"] is None       # 8 kv heads < 16
    assert r["d_ff"] == "model"
    assert not plan.fsdp               # 8B: no ZeRO-3 needed
    assert not plan.ep


def test_rules_divisibility_fallbacks():
    plan = make_plan(get_config("smollm-360m"), amesh())
    assert plan.rules["heads"] is None       # 15 heads
    assert plan.rules["d_ff"] == "model"     # 2560
    wh = make_plan(get_config("whisper-large-v3"), amesh())
    assert wh.rules["vocab"] is None         # 51866 % 16 != 0
    assert wh.rules["heads"] is None         # 20 heads


def test_rules_moe_and_fsdp():
    ds = make_plan(get_config("deepseek-v3-671b"), amesh())
    assert ds.fsdp and ds.ep
    assert ds.rules["experts"] == "model"    # 256 % 16
    assert ds.rules["d_expert"] is None      # EP replaces expert-TP
    assert ds.rules["d_model"] == "data"     # ZeRO-3 weight sharding
    qw = make_plan(get_config("qwen2-moe-a2.7b"), amesh())
    assert not qw.ep                         # 60 % 16 != 0 -> TP fallback
    assert qw.rules["d_expert"] == "model"
    assert qw.ep_spec() == P("data", None, None, None)
    assert ds.ep_spec() == P("data", "model", None, None)


def test_param_specs_cover_every_leaf():
    for arch in ("llama3-8b", "deepseek-v3-671b", "rwkv6-7b",
                 "recurrentgemma-2b", "whisper-large-v3"):
        cfg = get_config(arch)
        plan = make_plan(cfg, amesh(multi=True))
        shapes = backbone.param_shapes(cfg, dtype=jnp.bfloat16)
        specs = plan.param_specs()
        flat_shapes, t1 = jax.tree.flatten(shapes)
        flat_specs, t2 = jax.tree.flatten(specs,
                                          is_leaf=lambda x: isinstance(x, P))
        assert len(flat_shapes) == len(flat_specs)
        for s, spec in zip(flat_shapes, flat_specs):
            assert isinstance(spec, P)
            assert len(spec) <= s.ndim
            # every sharded dim must divide evenly
            for dim, ax in zip(s.shape, tuple(spec) + (None,) * s.ndim):
                if ax == "model":
                    assert dim % 16 == 0, (arch, s.shape, spec)


def test_zero1_moment_sharding():
    cfg = get_config("llama3-8b")
    plan = make_plan(cfg, amesh())
    shapes = backbone.param_shapes(cfg, dtype=jnp.bfloat16)
    pspecs = plan.param_specs()
    mspecs = plan.opt_moment_specs(shapes, pspecs)
    flat_s = jax.tree.leaves(shapes)
    flat_m = jax.tree.flatten(mspecs, is_leaf=lambda x: isinstance(x, P))[0]
    n_extra = 0
    for s, spec in zip(flat_s, flat_m):
        dims = tuple(spec) + (None,) * (s.ndim - len(spec))
        if "data" in [d for d in dims if isinstance(d, str)]:
            n_extra += 1
        for dim, ax in zip(s.shape, dims):
            if ax == "data":
                assert dim % 16 == 0
    assert n_extra > 0       # ZeRO-1 actually engaged


def test_cache_specs_shard_long_axes():
    cfg = get_config("llama3-8b")
    plan = make_plan(cfg, amesh())
    caches = jax.eval_shape(lambda: backbone.init_cache(cfg, 128, 32768))
    specs = plan.cache_specs(caches)
    flat_c = jax.tree.leaves(caches)
    flat_s = jax.tree.flatten(specs, is_leaf=lambda x: isinstance(x, P))[0]
    for c, spec in zip(flat_c, flat_s):
        if c.ndim == 5:      # (L, B, S, Hkv, hd)
            assert spec[1] == "data" and spec[2] == "model"


def test_batch_specs_batch1_replicated():
    plan = make_plan(get_config("rwkv6-7b"), amesh())
    sds = {"tokens": jax.ShapeDtypeStruct((1, 524288), jnp.int32)}
    assert plan.batch_specs(sds)["tokens"] == P(None, None)
    sds = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    assert plan.batch_specs(sds)["tokens"] == P("data", None)


def test_act_spec_sequence_parallel():
    plan = make_plan(get_config("llama3-8b"), amesh(multi=True))
    assert plan.act_spec() == P(("pod", "data"), "model", None)
    plan_off = make_plan(get_config("llama3-8b"), amesh(), sp=False)
    assert plan_off.act_spec() is None
