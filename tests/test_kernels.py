"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention, flash_decode
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import wkv6


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# --------------------------------------------------------------------------- #
# flash attention                                                              #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("B,Tq,Tk,H,Hkv,hd", [
    (1, 128, 128, 4, 4, 64),
    (2, 256, 256, 8, 2, 64),      # GQA
    (1, 64, 512, 4, 1, 128),      # MQA, cross-length
    (2, 384, 384, 6, 3, 32),      # non-pow2 blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_causal(B, Tq, Tk, H, Hkv, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, Tq, H, hd), dtype)
    k = rand(ks[1], (B, Tk, Hkv, hd), dtype)
    v = rand(ks[2], (B, Tk, Hkv, hd), dtype)
    off = Tk - Tq
    got = flash_attention(q, k, v, causal=True, q_offset=off,
                          block_q=128, block_k=128, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    B, T, H, hd = 1, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (B, T, H, hd), jnp.float32)
    k = rand(ks[1], (B, T, H, hd), jnp.float32)
    v = rand(ks[2], (B, T, H, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=window,
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    B, Tq, Tk, H, hd = 1, 128, 192, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = rand(ks[0], (B, Tq, H, hd), jnp.float32)
    k = rand(ks[1], (B, Tk, H, hd), jnp.float32)
    v = rand(ks[2], (B, Tk, H, hd), jnp.float32)
    got = flash_attention(q, k, v, causal=False, block_q=64, block_k=64,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,H,Hkv,hd", [
    (2, 256, 4, 4, 64),
    (4, 512, 8, 2, 64),
    (1, 128, 4, 1, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(B, S, H, Hkv, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = rand(ks[0], (B, H, hd), dtype)
    k = rand(ks[1], (B, S, Hkv, hd), dtype)
    v = rand(ks[2], (B, S, Hkv, hd), dtype)
    cur = jnp.int32(S // 2)
    got = flash_decode(q, k, v, cur, block_k=128, interpret=True)
    want = ref.flash_decode_ref(q, k, v, cur)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_flash_decode_per_request_lengths():
    """Continuous batching: each request has its own context length."""
    B, S, H, hd = 3, 256, 4, 64
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    q = rand(ks[0], (B, H, hd), jnp.float32)
    k = rand(ks[1], (B, S, H, hd), jnp.float32)
    v = rand(ks[2], (B, S, H, hd), jnp.float32)
    lens = jnp.array([10, 100, 255], jnp.int32)
    got = flash_decode(q, k, v, lens, block_k=64, interpret=True)
    want = ref.flash_decode_ref(q, k, v, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------- #
# RWKV6 WKV                                                                    #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("B,T,H,dk,dv", [
    (1, 64, 2, 32, 32),
    (2, 128, 4, 64, 64),
    (1, 96, 2, 64, 64),      # non-pow2 T
])
def test_wkv6(B, T, H, dk, dv):
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    r = rand(ks[0], (B, T, H, dk), jnp.float32) * 0.5
    k = rand(ks[1], (B, T, H, dk), jnp.float32) * 0.5
    v = rand(ks[2], (B, T, H, dv), jnp.float32) * 0.5
    w = jax.nn.sigmoid(rand(ks[3], (B, T, H, dk), jnp.float32)) * 0.5 + 0.45
    u = rand(ks[4], (H, dk), jnp.float32) * 0.5
    s0 = rand(ks[5], (B, H, dk, dv), jnp.float32) * 0.1
    y_got, s_got = wkv6(r, k, v, w, u, s0, block_t=32, interpret=True)
    y_want, s_want = ref.wkv6_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want),
                               atol=1e-4, rtol=1e-4)


def test_wkv6_state_chaining():
    """Running two half-sequences with carried state == one full run."""
    B, T, H, dk = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(6), 5)
    r = rand(ks[0], (B, T, H, dk), jnp.float32) * 0.5
    k = rand(ks[1], (B, T, H, dk), jnp.float32) * 0.5
    v = rand(ks[2], (B, T, H, dk), jnp.float32) * 0.5
    w = jax.nn.sigmoid(rand(ks[3], (B, T, H, dk), jnp.float32)) * 0.5 + 0.45
    u = rand(ks[4], (H, dk), jnp.float32) * 0.5
    s0 = jnp.zeros((B, H, dk, dk), jnp.float32)
    y_full, s_full = wkv6(r, k, v, w, u, s0, interpret=True)
    h = T // 2
    y1, s1 = wkv6(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u, s0, interpret=True)
    y2, s2 = wkv6(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u, s1, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------- #
# RG-LRU linear recurrence                                                     #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("B,T,W", [
    (1, 128, 256),
    (2, 256, 512),
    (1, 192, 160),           # non-pow2 both
])
def test_rglru(B, T, W):
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    a = jax.nn.sigmoid(rand(ks[0], (B, T, W), jnp.float32)) * 0.9
    b = rand(ks[1], (B, T, W), jnp.float32)
    h0 = rand(ks[2], (B, W), jnp.float32)
    h_got, hT_got = rglru_scan(a, b, h0, block_t=64, block_w=128, interpret=True)
    h_want, hT_want = ref.linear_recurrence_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                               atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(hT_got), np.asarray(hT_want),
                               atol=1e-5, rtol=1e-4)


def test_rglru_state_chaining():
    B, T, W = 1, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    a = jax.nn.sigmoid(rand(ks[0], (B, T, W), jnp.float32)) * 0.9
    b = rand(ks[1], (B, T, W), jnp.float32)
    h0 = rand(ks[2], (B, W), jnp.float32)
    h_full, hT_full = rglru_scan(a, b, h0, interpret=True)
    h1, s1 = rglru_scan(a[:, :64], b[:, :64], h0, interpret=True)
    h2, s2 = rglru_scan(a[:, 64:], b[:, 64:], s1, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([h1, h2], 1)),
                               np.asarray(h_full), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(hT_full),
                               atol=1e-5, rtol=1e-4)


# --------------------------------------------------------------------------- #
# ops dispatch: pallas backend end-to-end inside a model block                 #
# --------------------------------------------------------------------------- #
def test_ops_backend_switch():
    from repro.kernels import ops
    B, T, H, hd = 1, 64, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = rand(ks[0], (B, T, H, hd), jnp.float32)
    k = rand(ks[1], (B, T, H, hd), jnp.float32)
    v = rand(ks[2], (B, T, H, hd), jnp.float32)
    ref_out = ops.flash_attention(q, k, v, causal=True)
    try:
        ops.set_backend("pallas")
        pal_out = ops.flash_attention(q, k, v, causal=True)
        # gradient flows through the custom_vjp oracle backward
        g = jax.grad(lambda q: ops.flash_attention(q, k, v).sum())(q)
        assert g.shape == q.shape and not np.isnan(np.asarray(g)).any()
    finally:
        ops.set_backend("ref")
    np.testing.assert_allclose(np.asarray(pal_out), np.asarray(ref_out),
                               atol=2e-5, rtol=2e-5)
