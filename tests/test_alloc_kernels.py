"""Vectorized allocation-kernel tests: bit-identity against the
pre-vectorization reference implementations (``repro.core.alloc_reference``)
and golden end-to-end engine equivalence.

Two layers:

* property tests drive randomized specs/mappings through the vectorized
  kernels and the reference oracle and require *bitwise* equal outputs
  (the kernels are engineered to perform the identical IEEE operation
  sequence, so exact equality — not allclose — is the contract);
* golden tests run full simulation cells (the 16-cell acceptance grid plus
  a stretch-per cell, failure scenarios included) once on the vectorized
  hot path and once under ``reference_kernels()`` and require identical
  ``SimResult``s.
"""
import dataclasses

from conftest import result_dict as _result_dict

import numpy as np
import pytest

from repro.core import alloc_reference as ref
from repro.core.alloc_kernels import (NodeIncidence, build_csr,
                                      reference_kernels)
from repro.core.greedy import greedy_place
from repro.core.job import JobSpec, JobState, NodePool
from repro.core.mcb8 import mcb8, mcb8_pack
from repro.core.stretch_opt import (improve_avg_stretch, improve_max_stretch,
                                    mcb8_stretch)
from repro.core.yield_alloc import avg_yields, maxmin_yields
from repro.sched.engine import Engine, SimParams
from repro.sched.scenarios import apply_scenario
from repro.workloads.registry import WorkloadSpec, make_trace

# --------------------------------------------------------------------------- #
# randomized fixtures (deterministic per seed)                                 #
# --------------------------------------------------------------------------- #
CPU_CHOICES = [0.25, 0.37, 0.5, 1.0]
MEM_CHOICES = [0.1, 0.2, 0.3, 0.5, 0.8, 1.0]


def random_jobs(rng, n_max=14, wide=False):
    out = []
    for i in range(int(rng.integers(1, n_max + 1))):
        out.append(JobSpec(
            jid=i, release=0.0, proc_time=float(rng.uniform(10.0, 1e4)),
            n_tasks=int(rng.integers(1, 17 if wide else 5)),
            cpu_need=float(rng.choice(CPU_CHOICES)),
            mem_req=float(rng.choice(MEM_CHOICES)),
        ))
    return out


def placed_fixture(seed):
    """Specs + feasible mappings via (reference) greedy placement."""
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(1, 10))
    pool = NodePool(n_nodes)
    specs, maps = [], []
    for s in random_jobs(rng):
        m = ref.greedy_place(pool, s)
        if m is not None:
            specs.append(s)
            maps.append(m)
    return specs, maps, n_nodes


def states_fixture(seed, n_max=14, wide=False):
    rng = np.random.default_rng(seed)
    states = []
    for s in random_jobs(rng, n_max=n_max, wide=wide):
        js = JobState(spec=s)
        js.vt = float(rng.uniform(0.1, 500.0))
        states.append(js)
    n_nodes = int(rng.integers(2, 20))
    return states, n_nodes


# --------------------------------------------------------------------------- #
# §4.6 yield kernels vs reference — bitwise                                    #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(60))
def test_maxmin_yields_bitwise_equals_reference(seed):
    specs, maps, n_nodes = placed_fixture(seed)
    if not specs:
        return
    a = maxmin_yields(specs, maps, n_nodes)
    b = ref.maxmin_yields(specs, maps, n_nodes)
    assert np.array_equal(a, b)


@pytest.mark.parametrize("seed", range(25))
def test_avg_yields_bitwise_equals_reference(seed):
    specs, maps, n_nodes = placed_fixture(seed)
    if not specs:
        return
    a = avg_yields(specs, maps, n_nodes)
    b = ref.avg_yields(specs, maps, n_nodes)
    assert np.array_equal(a, b)


@pytest.mark.parametrize("seed", range(60))
def test_greedy_place_bitwise_equals_reference(seed):
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(1, 10))
    pa, pb = NodePool(n_nodes), NodePool(n_nodes)
    for s in random_jobs(rng):
        ma = greedy_place(pa, s)
        mb = ref.greedy_place(pb, s)
        assert ma == mb
        assert np.array_equal(pa.load, pb.load)
        assert np.array_equal(pa.mem_free, pb.mem_free)


# --------------------------------------------------------------------------- #
# MCB8 fast pack vs reference pack                                             #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(40))
def test_mcb8_pack_equals_reference(seed):
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(2, 20))
    y = float(rng.uniform(0.01, 1.0))
    jobs = [(i, min(1.0, s.cpu_need * y), s.mem_req, s.n_tasks)
            for i, s in enumerate(random_jobs(rng, n_max=20, wide=True))]
    fast = mcb8_pack(n_nodes, jobs)
    with reference_kernels():
        slow = mcb8_pack(n_nodes, jobs)
    assert fast == slow


@pytest.mark.parametrize("seed", range(25))
def test_mcb8_full_equals_reference(seed):
    states, n_nodes = states_fixture(seed, n_max=16, wide=True)
    pinned = {}
    if len(states) >= 2 and states[0].spec.n_tasks <= n_nodes:
        pinned[states[0].spec.jid] = list(range(states[0].spec.n_tasks))
    fast = mcb8(states, n_nodes, now=1000.0, pinned=pinned)
    with reference_kernels():
        slow = mcb8(states, n_nodes, now=1000.0, pinned=pinned)
    assert fast.mappings == slow.mappings
    assert fast.yld == slow.yld
    assert fast.removed == slow.removed


@pytest.mark.parametrize("seed", range(25))
def test_mcb8_stretch_equals_reference(seed):
    states, n_nodes = states_fixture(seed, n_max=16, wide=True)
    fast = mcb8_stretch(states, n_nodes, now=1000.0, period=600.0)
    with reference_kernels():
        slow = mcb8_stretch(states, n_nodes, now=1000.0, period=600.0)
    assert fast.mappings == slow.mappings
    assert fast.yields == slow.yields
    assert fast.target == slow.target
    assert fast.removed == slow.removed


# --------------------------------------------------------------------------- #
# §4.7 post-passes vs reference                                                #
# --------------------------------------------------------------------------- #
def _stretch_fixture(seed):
    rng = np.random.default_rng(seed)
    n_nodes = int(rng.integers(2, 12))
    pool = NodePool(n_nodes)
    jobs, mappings, yields = [], {}, {}
    for s in random_jobs(rng, n_max=10):
        m = ref.greedy_place(pool, s)
        if m is None:
            continue
        js = JobState(spec=s)
        js.vt = float(rng.uniform(0.1, 500.0))
        jobs.append(js)
        mappings[s.jid] = m
        yields[s.jid] = float(rng.uniform(0.0, 0.6))
    return jobs, mappings, yields, n_nodes


@pytest.mark.parametrize("seed", range(25))
def test_improve_max_stretch_bitwise_equals_reference(seed):
    jobs, mappings, yields, n_nodes = _stretch_fixture(seed)
    a = improve_max_stretch(jobs, mappings, dict(yields), n_nodes,
                            now=700.0, period=600.0)
    b = ref.improve_max_stretch(jobs, mappings, dict(yields), n_nodes,
                                now=700.0, period=600.0)
    assert a == b


@pytest.mark.parametrize("seed", range(15))
def test_improve_avg_stretch_bitwise_equals_reference(seed):
    jobs, mappings, yields, n_nodes = _stretch_fixture(seed)
    a = improve_avg_stretch(jobs, mappings, dict(yields), n_nodes,
                            now=700.0, period=600.0)
    b = ref.improve_avg_stretch(jobs, mappings, dict(yields), n_nodes,
                                now=700.0, period=600.0)
    assert a == b


# --------------------------------------------------------------------------- #
# incremental incidence == from-scratch CSR                                    #
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(20))
def test_node_incidence_matches_from_scratch_build(seed):
    rng = np.random.default_rng(seed)
    n_nodes, n_jobs = int(rng.integers(2, 10)), int(rng.integers(1, 12))
    cpu = rng.choice(CPU_CHOICES, size=n_jobs)
    inc = NodeIncidence(n_nodes, cpu)
    current = {}
    for _ in range(40):
        if current and rng.random() < 0.4:           # remove one
            j = int(rng.choice(list(current)))
            inc.remove(j, current.pop(j))
        else:                                        # place one
            j = int(rng.integers(0, n_jobs))
            if j in current:
                continue
            mapping = rng.integers(0, n_nodes,
                                   size=int(rng.integers(1, 6))).tolist()
            current[j] = mapping
            inc.place(j, mapping)
        snap = inc.csr()
        mappings = [current.get(j, []) for j in range(n_jobs)]
        scratch = build_csr(cpu, mappings, n_nodes)
        assert np.array_equal(snap.indptr, scratch.indptr)
        assert np.array_equal(snap.indices, scratch.indices)
        assert np.array_equal(snap.data, scratch.data)


def test_engine_incidence_consistent_after_run():
    """After a full simulation every job is complete — the incrementally
    maintained incidence must be empty again (no leaked entries)."""
    specs = make_trace(WorkloadSpec("lublin", n_jobs=30, n_nodes=16, seed=0))
    eng = Engine(specs, "GreedyPM */per/OPT=MIN/MINVT=600",
                 SimParams(n_nodes=16))
    eng.run()
    snap = eng.state.inc.csr()
    assert snap.indices.size == 0
    assert all(not r for r in eng.state.inc.rows)


# --------------------------------------------------------------------------- #
# golden end-to-end equivalence: 17 cells, vectorized vs reference engine      #
# --------------------------------------------------------------------------- #
GOLDEN_POLICIES = ["FCFS", "EASY", "GreedyP */OPT=MIN",
                   "GreedyPM */per/OPT=MIN/MINVT=600"]
GOLDEN_WORKLOADS = [WorkloadSpec("lublin", n_jobs=40, n_nodes=16, seed=0),
                    WorkloadSpec("hpc2n", n_jobs=40, n_nodes=128, seed=1)]
GOLDEN_CASES = [(w, p, sc)
                for w in GOLDEN_WORKLOADS
                for p in GOLDEN_POLICIES
                for sc in ("baseline", "rack_failure")]
GOLDEN_CASES.append((GOLDEN_WORKLOADS[0], "/stretch-per/OPT=MAX", "baseline"))


def test_golden_case_count():
    assert len(GOLDEN_CASES) == 17


@pytest.mark.parametrize(
    "workload,policy,scenario", GOLDEN_CASES,
    ids=[f"{w.name}-{p}-{sc}" for w, p, sc in GOLDEN_CASES])
def test_golden_simresult_bitwise_equivalence(workload, policy, scenario):
    specs = make_trace(workload)
    specs, events = apply_scenario(scenario, specs, workload.n_nodes,
                                   seed=workload.seed)
    params = SimParams(n_nodes=workload.n_nodes)
    fast = Engine(specs, policy, params, cluster_events=events).run()
    with reference_kernels():
        slow = Engine(specs, policy, params, cluster_events=events).run()
    assert _result_dict(fast) == _result_dict(slow)
