"""Columnar Trace IR tests.

Three layers:

* the IR itself: construction + vectorized validation, spec round-trips,
  npz/json serialization, fingerprint semantics, transforms;
* scenario transforms over the IR: chain grammar, per-link determinism,
  equivalence with the JobSpec-list wrapper;
* the golden contract: the array-native engine path (``Engine(Trace)``)
  produces *bit-identical* ``SimResult``s to the JobSpec-list path on all
  14 Table-1 policies and the 17-cell acceptance grid.
"""
import dataclasses

from conftest import result_dict as _result_dict

import numpy as np
import pytest

from repro.core.policies import TABLE1_POLICIES
from repro.sched.engine import Engine, SimParams
from repro.sched.scenarios import (apply_scenario, apply_scenario_trace,
                                   parse_scenario_chain, list_scenarios,
                                   scenario_docs)
from repro.workloads.registry import WorkloadSpec, make_trace, make_trace_ir
from repro.workloads.trace import Trace


def mini_trace_ir(n=40, nodes=16, seed=0) -> Trace:
    return make_trace_ir(WorkloadSpec("lublin", n_jobs=n, n_nodes=nodes,
                                      seed=seed))


# --------------------------------------------------------------------------- #
# the IR                                                                       #
# --------------------------------------------------------------------------- #
def test_from_specs_to_specs_round_trip_exact():
    specs = make_trace(WorkloadSpec("lublin", n_jobs=30, n_nodes=16, seed=2))
    tr = Trace.from_specs(specs)
    assert len(tr) == 30
    assert tr.to_specs() == specs          # exact values, same order


def test_columns_are_read_only_and_trace_frozen():
    tr = mini_trace_ir()
    with pytest.raises(ValueError):
        tr.release[0] = 99.0
    with pytest.raises(AttributeError):
        tr.release = np.zeros(len(tr))


def test_vectorized_validation_matches_jobspec_invariants():
    ok = dict(jid=[0], release=[0.0], proc_time=[10.0], n_tasks=[1],
              cpu_need=[0.5], mem_req=[0.5])
    Trace(**{k: np.asarray(v) for k, v in ok.items()})    # sanity
    for field, bad in [("cpu_need", 0.0), ("cpu_need", 1.5),
                       ("mem_req", 0.0), ("mem_req", 2.0),
                       ("n_tasks", 0), ("proc_time", 0.0),
                       ("release", np.inf)]:
        cols = {k: np.asarray(v) for k, v in ok.items()}
        cols[field] = np.asarray([bad], dtype=cols[field].dtype)
        with pytest.raises(ValueError):
            Trace(**cols)


def test_fingerprint_content_identity():
    a, b = mini_trace_ir(seed=0), mini_trace_ir(seed=0)
    assert a.fingerprint == b.fingerprint and a == b
    c = mini_trace_ir(seed=1)
    assert a.fingerprint != c.fingerprint and a != c
    # any column change moves the fingerprint
    d = a.replace(mem_req=np.minimum(1.0, a.mem_req * 1.5))
    assert d.fingerprint != a.fingerprint
    # hashable: usable directly as a cache key
    assert len({a, b, c, d}) == 3


def test_npz_and_json_round_trips(tmp_path):
    tr = mini_trace_ir(n=25)
    npz = str(tmp_path / "t.npz")
    tr.save_npz(npz)
    back = Trace.load_npz(npz)
    assert back == tr and back.to_specs() == tr.to_specs()

    js = str(tmp_path / "t.json")
    tr.save_json(js)
    back = Trace.load_json(js)
    assert back == tr and back.to_specs() == tr.to_specs()


def test_load_npz_rejects_foreign_file(tmp_path):
    path = str(tmp_path / "x.npz")
    np.savez(path, a=np.zeros(3))
    with pytest.raises(ValueError, match="repro.trace"):
        Trace.load_npz(path)


def test_select_replace_and_sort():
    tr = mini_trace_ir(n=30)
    wide = tr.select(tr.n_tasks >= 2)
    assert len(wide) < len(tr) and (wide.n_tasks >= 2).all()
    with pytest.raises(ValueError, match="unknown Trace columns"):
        tr.replace(nope=tr.release)
    # sorted_by_release matches the engine's (release, jid) tuple sort
    shuffled = tr.select(np.random.default_rng(0).permutation(len(tr)))
    specs = sorted(shuffled.to_specs(), key=lambda s: (s.release, s.jid))
    assert shuffled.sorted_by_release().to_specs() == specs


def test_span_and_total_work():
    tr = mini_trace_ir(n=20)
    specs = tr.to_specs()
    lo, span = tr.span()
    assert lo == min(s.release for s in specs)
    assert span == max(max(s.release for s in specs) - lo, 1.0)
    assert tr.total_work == pytest.approx(sum(s.total_work for s in specs))


# --------------------------------------------------------------------------- #
# scenario transforms over the IR                                              #
# --------------------------------------------------------------------------- #
def test_scenario_trace_matches_spec_wrapper():
    tr = mini_trace_ir(n=30)
    specs = tr.to_specs()
    for name in list_scenarios():
        t_tr, e_tr = apply_scenario_trace(name, tr, 16, seed=4)
        s_ls, e_ls = apply_scenario(name, specs, 16, seed=4)
        assert t_tr.to_specs() == s_ls
        assert e_tr == e_ls


def test_chain_grammar_composes_left_to_right():
    tr = mini_trace_ir(n=40)
    chained, events = apply_scenario_trace(
        "mem_pressure+arrival_burst", tr, 16, seed=7)
    step1, e1 = apply_scenario_trace("mem_pressure", tr, 16, seed=7)
    step2, e2 = apply_scenario_trace("arrival_burst", step1, 16, seed=7)
    assert chained == step2
    assert events == e1 + e2


def test_chain_links_are_position_independent():
    """A link draws from its own name-salted stream: same perturbation
    alone or inside a chain (baseline+x == x)."""
    tr = mini_trace_ir(n=30)
    a, ea = apply_scenario_trace("mem_pressure", tr, 16, seed=3)
    b, eb = apply_scenario_trace("baseline+mem_pressure", tr, 16, seed=3)
    assert a == b and ea == eb


def test_chain_events_are_time_sorted():
    tr = mini_trace_ir(n=40)
    _, events = apply_scenario_trace(
        "elastic+rolling_failures+rack_failure", tr, 16, seed=1)
    times = [e.time for e in events]
    assert times == sorted(times) and len(events) > 4


def test_parse_scenario_chain_validation():
    assert parse_scenario_chain("rack_failure+arrival_burst") == [
        "rack_failure", "arrival_burst"]
    with pytest.raises(KeyError):
        parse_scenario_chain("rack_failure+meteor_strike")
    with pytest.raises(KeyError):
        parse_scenario_chain("rack_failure+")


def test_scenario_docs_one_liners():
    docs = scenario_docs()
    assert set(docs) == set(list_scenarios())
    for name, doc in docs.items():
        assert doc and "\n" not in doc, name


def test_chained_cell_simulates_end_to_end():
    w = WorkloadSpec("lublin", n_jobs=30, n_nodes=16, seed=3)
    from repro import api
    r = api.simulate(w, "GreedyPM */per/OPT=MIN/MINVT=600",
                     scenario="rack_failure+arrival_burst")
    assert set(r.completions) == {s.jid for s in make_trace(w)}
    assert not r.hit_max_events


# --------------------------------------------------------------------------- #
# golden contract: the Trace-native engine path is bit-identical               #
# --------------------------------------------------------------------------- #
GOLDEN_POLICIES = ["FCFS", "EASY", "GreedyP */OPT=MIN",
                   "GreedyPM */per/OPT=MIN/MINVT=600"]
GOLDEN_WORKLOADS = [WorkloadSpec("lublin", n_jobs=40, n_nodes=16, seed=0),
                    WorkloadSpec("hpc2n", n_jobs=40, n_nodes=128, seed=1)]
GOLDEN_CASES = [(w, p, sc)
                for w in GOLDEN_WORKLOADS
                for p in GOLDEN_POLICIES
                for sc in ("baseline", "rack_failure")]
GOLDEN_CASES.append((GOLDEN_WORKLOADS[0], "/stretch-per/OPT=MAX", "baseline"))


@pytest.mark.parametrize(
    "workload,policy,scenario", GOLDEN_CASES,
    ids=[f"{w.name}-{p}-{sc}" for w, p, sc in GOLDEN_CASES])
def test_golden_trace_native_vs_spec_list_simresult(workload, policy, scenario):
    trace, events = apply_scenario_trace(
        scenario, make_trace_ir(workload), workload.n_nodes,
        seed=workload.seed)
    params = SimParams(n_nodes=workload.n_nodes)
    native = Engine(trace, policy, params, cluster_events=events).run()
    via_specs = Engine(trace.to_specs(), policy, params,
                       cluster_events=events).run()
    assert _result_dict(native) == _result_dict(via_specs)


@pytest.mark.parametrize("policy", TABLE1_POLICIES)
def test_every_table1_policy_trace_native_equals_spec_list(policy):
    w = WorkloadSpec("lublin", n_jobs=30, n_nodes=16, seed=0)
    trace = make_trace_ir(w)
    params = SimParams(n_nodes=16)
    native = Engine(trace, policy, params).run()
    via_specs = Engine(trace.to_specs(), policy, params).run()
    assert _result_dict(native) == _result_dict(via_specs)
